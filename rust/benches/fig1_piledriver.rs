//! Figure 1 — performance normalized to OpenBLAS GEMM on AMD Piledriver.
//!
//! AlexNet conv layers, 4 threads. Series normalized to the SGEMM-only
//! dashed line (packing assumed free):
//!   * `sgemm+im2col` (the blue bars: real packing cost included),
//!   * `direct` (the yellow bars).
//! Expected shape (paper): sgemm+im2col < 0.8, direct > 1.0 on every
//! layer.

use dconv::arch::piledriver;
use dconv::bench_harness::emit;
use dconv::metrics::Table;
use dconv::nets;
use dconv::sim::{estimate, Algo};

fn main() {
    let m = piledriver();
    let threads = 4;
    let mut t = Table::new(&[
        "layer",
        "sgemm-only GFLOPS",
        "sgemm+im2col (rel)",
        "direct (rel)",
        "direct GFLOPS",
        "im2col extra MiB",
    ]);
    for l in nets::alexnet() {
        let gemm = estimate(&m, &l.shape, Algo::GemmOnly, threads);
        let low = estimate(&m, &l.shape, Algo::Im2colGemm, threads);
        let dir = estimate(&m, &l.shape, Algo::Direct, threads);
        t.row(vec![
            l.name.clone(),
            format!("{:.1}", gemm.gflops),
            format!("{:.2}", gemm.secs / low.secs),
            format!("{:.2}", gemm.secs / dir.secs),
            format!("{:.1}", dir.gflops),
            format!("{:.1}", low.extra_bytes as f64 / (1 << 20) as f64),
        ]);
    }
    emit(
        "fig1_piledriver",
        &format!(
            "Figure 1 — {} / {} threads / AlexNet (normalized to SGEMM-only)",
            m.name, threads
        ),
        &t,
    );
}

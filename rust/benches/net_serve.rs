//! Whole-network batched serving, two sections:
//!
//! 1. `NetEngine` throughput on a conv chain, one worker vs a full
//!    worker pool. Batch items are independent images fanned out across
//!    scoped threads with per-worker activation arenas, so on any
//!    multi-core host the threaded batch beats the single-thread path —
//!    the serving-side payoff of the zero-allocation forward (no
//!    allocator contention, no cross-worker state).
//! 2. The production server (`dconv::serve`) with an f32 and an i8
//!    compile of the same net resident at once, driven by the *same
//!    seeded arrival schedule* (loadgen): completed throughput, server
//!    latency split (queue wait / e2e p50/p99) and the ~4x activation
//!    arena delta, emitted as `net_serve_i8` plus a loadgen JSON
//!    artifact under `bench_results/`.

use std::time::Duration;

use dconv::arch::host;
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::ConvShape;
use dconv::engine::{NetEngine, NetRunner};
use dconv::metrics::{gflops, Table};
use dconv::nets::builder::resnet_micro;
use dconv::nets::NetPlans;
use dconv::quant::DType;
use dconv::runtime::ModelExecutor;
use dconv::serve::{loadgen, LoadSpec, ModelLoad, ServeConfig, ServerBuilder};
use dconv::sim::ArrivalPattern;
use dconv::tensor::Tensor;

const BATCH: usize = 8;

/// A VGG-flavoured three-layer chain: enough work per image for the
/// fan-out to pay, small enough for smoke runs.
fn chain() -> Vec<ConvShape> {
    vec![
        ConvShape::new(32, 56, 56, 64, 3, 3, 1, 1),
        ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1),
        ConvShape::new(64, 14, 14, 128, 3, 3, 1, 1),
    ]
}

fn build_runner() -> NetRunner {
    let plans = NetPlans::from_shapes("bench-chain", &chain(), "direct", &host(), 7).unwrap();
    NetRunner::new(plans).unwrap()
}

fn main() {
    let opts = opts_from_env();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let model = format!("net_b{BATCH}");
    let flops_batch: u64 = chain().iter().map(|s| s.flops()).sum::<u64>() * BATCH as u64;

    let serial = NetEngine::new(build_runner(), 1, &[BATCH], "net").unwrap();
    let pooled = NetEngine::new(build_runner(), cores, &[BATCH], "net").unwrap();
    assert_eq!(serial.runner().overhead_bytes(), 0, "direct chain must be zero-overhead");

    let image_in = serial.runner().input_len();
    let mut batch = Vec::with_capacity(BATCH * image_in);
    for i in 0..BATCH as u64 {
        batch.extend_from_slice(Tensor::random(&[image_in], 100 + i).data());
    }

    // Correctness gate before timing: the pool is bitwise-serial.
    let a = serial.run(&model, batch.clone()).unwrap();
    let b = pooled.run(&model, batch.clone()).unwrap();
    assert_eq!(a, b, "worker pool must match the single-thread path");

    let t1 = bench("1-worker", opts, || {
        sink(serial.run(&model, batch.clone()).unwrap());
    });
    let tp = bench("pool", opts, || {
        sink(pooled.run(&model, batch.clone()).unwrap());
    });

    let mut t = Table::new(&["config", "batch", "GFLOPS", "img/s", "speedup"]);
    for (name, workers, meas) in [("1 worker", 1, &t1), ("worker pool", cores, &tp)] {
        t.row(vec![
            format!("{name} ({workers})"),
            BATCH.to_string(),
            format!("{:.2}", gflops(flops_batch, meas.median_secs)),
            format!("{:.1}", BATCH as f64 / meas.median_secs),
            format!("{:.2}x", t1.median_secs / meas.median_secs),
        ]);
    }
    emit(
        "net_serve",
        &format!("Whole-network batched serving — NetEngine, {cores}-core host"),
        &t,
    );
    if cores > 1 && tp.median_secs >= t1.median_secs {
        println!("note: pool did not beat serial on this host/run (cores={cores})");
    }

    serve_i8_vs_f32();
}

/// Section 2: i8 vs f32 under the same offered load, through the full
/// production serving path (admission, continuous batching, telemetry).
fn serve_i8_vs_f32() {
    let fast = std::env::var("DCONV_BENCH_FAST").is_ok();
    let (requests, rate) = if fast { (40, 400.0) } else { (240, 800.0) };

    let f32_model = resnet_micro();
    let mut i8_model = resnet_micro();
    i8_model.dtype = DType::I8;
    let cfg = ServeConfig {
        queue_depth: 128,
        batch_wait: Duration::from_millis(1),
        workers: 2,
        batch_sizes: vec![1, 2, 4, 8],
        ..Default::default()
    };
    let mut b = ServerBuilder::new(&host(), cfg).backend("direct");
    b.add_model("rm_f32", &f32_model).unwrap();
    b.add_model("rm_i8", &i8_model).unwrap();
    let server = b.start().unwrap();

    // The same seeded schedule offered to both models concurrently.
    let seed = 0xBE9C;
    let spec = LoadSpec::default()
        .push(ModelLoad::new("rm_f32", ArrivalPattern::Burst, rate, requests).seed(seed))
        .push(ModelLoad::new("rm_i8", ArrivalPattern::Burst, rate, requests).seed(seed));
    let report = loadgen::run(&server, &spec).unwrap();

    let mut t = Table::new(&[
        "model", "arena B/worker", "offered", "done", "shed", "req/s",
        "wait p50 ms", "e2e p50 ms", "e2e p99 ms",
    ]);
    for r in &report.results {
        let h = server.model(&r.model).unwrap();
        t.row(vec![
            r.model.clone(),
            h.runner().arena_bytes().to_string(),
            r.requests.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            format!("{:.1}", r.throughput()),
            format!("{:.2}", r.server.queue_wait.p50() * 1e3),
            format!("{:.2}", r.server.e2e.p50() * 1e3),
            format!("{:.2}", r.server.e2e.p99() * 1e3),
        ]);
    }
    emit(
        "net_serve_i8",
        &format!(
            "i8 vs f32 serving — same net, same seeded {} schedule ({rate:.0} req/s), \
             fingerprint {:016x}",
            ArrivalPattern::Burst.name(),
            report.results[0].fingerprint
        ),
        &t,
    );
    let hf = server.model("rm_f32").unwrap();
    let hq = server.model("rm_i8").unwrap();
    println!(
        "arena delta: {} B f32 -> {} B i8 ({:.2}x smaller per worker); both zero-overhead \
         (f32 {} B, i8 {} B)",
        hf.runner().arena_bytes(),
        hq.runner().arena_bytes(),
        hf.runner().arena_bytes() as f64 / hq.runner().arena_bytes() as f64,
        hf.runner().overhead_bytes(),
        hq.runner().overhead_bytes()
    );
    if let Err(e) = report.write_artifact("bench_results/net_serve_loadgen.json") {
        println!("note: could not write loadgen artifact: {e}");
    }
    server.shutdown().unwrap();
}

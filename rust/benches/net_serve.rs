//! Whole-network batched serving: `NetEngine` throughput on a conv
//! chain, one worker vs a full worker pool. Batch items are independent
//! images fanned out across scoped threads with per-worker activation
//! arenas, so on any multi-core host the threaded batch beats the
//! single-thread path — the serving-side payoff of the zero-allocation
//! forward (no allocator contention, no cross-worker state).

use dconv::arch::host;
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::ConvShape;
use dconv::engine::{NetEngine, NetRunner};
use dconv::metrics::{gflops, Table};
use dconv::nets::NetPlans;
use dconv::runtime::ModelExecutor;
use dconv::tensor::Tensor;

const BATCH: usize = 8;

/// A VGG-flavoured three-layer chain: enough work per image for the
/// fan-out to pay, small enough for smoke runs.
fn chain() -> Vec<ConvShape> {
    vec![
        ConvShape::new(32, 56, 56, 64, 3, 3, 1, 1),
        ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1),
        ConvShape::new(64, 14, 14, 128, 3, 3, 1, 1),
    ]
}

fn build_runner() -> NetRunner {
    let plans = NetPlans::from_shapes("bench-chain", &chain(), "direct", &host(), 7).unwrap();
    NetRunner::new(plans).unwrap()
}

fn main() {
    let opts = opts_from_env();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let model = format!("net_b{BATCH}");
    let flops_batch: u64 = chain().iter().map(|s| s.flops()).sum::<u64>() * BATCH as u64;

    let serial = NetEngine::new(build_runner(), 1, &[BATCH], "net").unwrap();
    let pooled = NetEngine::new(build_runner(), cores, &[BATCH], "net").unwrap();
    assert_eq!(serial.runner().overhead_bytes(), 0, "direct chain must be zero-overhead");

    let image_in = serial.runner().input_len();
    let mut batch = Vec::with_capacity(BATCH * image_in);
    for i in 0..BATCH as u64 {
        batch.extend_from_slice(Tensor::random(&[image_in], 100 + i).data());
    }

    // Correctness gate before timing: the pool is bitwise-serial.
    let a = serial.run(&model, batch.clone()).unwrap();
    let b = pooled.run(&model, batch.clone()).unwrap();
    assert_eq!(a, b, "worker pool must match the single-thread path");

    let t1 = bench("1-worker", opts, || {
        sink(serial.run(&model, batch.clone()).unwrap());
    });
    let tp = bench("pool", opts, || {
        sink(pooled.run(&model, batch.clone()).unwrap());
    });

    let mut t = Table::new(&["config", "batch", "GFLOPS", "img/s", "speedup"]);
    for (name, workers, meas) in [("1 worker", 1, &t1), ("worker pool", cores, &tp)] {
        t.row(vec![
            format!("{name} ({workers})"),
            BATCH.to_string(),
            format!("{:.2}", gflops(flops_batch, meas.median_secs)),
            format!("{:.1}", BATCH as f64 / meas.median_secs),
            format!("{:.2}x", t1.median_secs / meas.median_secs),
        ]);
    }
    emit(
        "net_serve",
        &format!("Whole-network batched serving — NetEngine, {cores}-core host"),
        &t,
    );
    if cores > 1 && tp.median_secs >= t1.median_secs {
        println!("note: pool did not beat serial on this host/run (cores={cores})");
    }
}

//! Ablation (ours, host-measured): blocking-parameter sensitivity of
//! Algorithm 3 — sweep `C_o,b x W_o,b` register tiles and `C_i,b` cache
//! blocks around the analytically selected point, confirming the Low et
//! al. model picks a near-optimal configuration (§6's auto-tuning remark).

use dconv::arch::host;
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::{conv_direct_blocked, select_params, BlockParams, ConvShape};
use dconv::layout::{to_blocked_io, to_blocked_kernel};
use dconv::metrics::{gflops, Table};
use dconv::tensor::Tensor;

fn main() {
    let opts = opts_from_env();
    let m = host();
    let s = ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1);
    let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);
    let selected = select_params(&m, &s);

    let mut t = Table::new(&["c_ob", "w_ob", "c_ib", "GFLOPS", "selected"]);
    for c_ob in [8usize, 16, 32] {
        for w_ob in [2usize, 4, 5, 6, 8] {
            for c_ib in [8usize, 32, 64] {
                let bp = BlockParams::new(c_ob, w_ob, c_ib);
                if bp.validate_for(&s).is_err() {
                    continue;
                }
                let bi = to_blocked_io(&input, bp.c_ib).unwrap();
                let bk = to_blocked_kernel(&kernel, bp.c_ob, bp.c_ib).unwrap();
                let meas = bench("cfg", opts, || {
                    sink(conv_direct_blocked(&bi, &bk, &s, bp, 1).unwrap());
                });
                t.row(vec![
                    c_ob.to_string(),
                    w_ob.to_string(),
                    c_ib.to_string(),
                    format!("{:.2}", gflops(s.flops(), meas.median_secs)),
                    if bp == selected { "<== analytical".into() } else { String::new() },
                ]);
            }
        }
    }
    emit(
        "ablation_blocking",
        &format!(
            "Ablation — blocking parameters on {} (analytical pick: {:?})",
            m.name, selected
        ),
        &t,
    );
}

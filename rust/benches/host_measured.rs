//! Host-measured Figure-4 analogue: every convolution implementation in
//! this crate, wall-clock on this machine, on shape-faithful layers from
//! the three benchmark networks. This is the real-hardware counterpart
//! of the simulator figures (single machine, single thread — the
//! multi-arch / multi-thread shapes come from `fig4_all_archs` and
//! `fig5_scaling`).
//!
//! Also prints the memory-overhead table (the paper's core claim).

use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::{conv_direct, conv_naive, select_params, ConvShape};
use dconv::fftconv::FftConvPlan;
use dconv::lowering::{conv_im2col, conv_mec, im2col_extra_bytes, mec_extra_bytes};
use dconv::metrics::{gflops, Table};
use dconv::tensor::Tensor;
use dconv::winograd::{conv_winograd, winograd_applicable, winograd_extra_bytes};

fn main() {
    let opts = opts_from_env();
    let m = dconv::arch::host();
    // Shape-faithful (channel counts + kernel geometry preserved,
    // spatial extent reduced where the original would take minutes).
    let layers = [
        ("alexnet/conv1-ish", ConvShape::new(3, 115, 115, 96, 11, 11, 4, 0)),
        ("alexnet/conv3-ish", ConvShape::new(128, 13, 13, 192, 3, 3, 1, 1)),
        ("googlenet/3x3-ish", ConvShape::new(96, 28, 28, 128, 3, 3, 1, 1)),
        ("googlenet/5x5-ish", ConvShape::new(16, 14, 14, 32, 5, 5, 1, 2)),
        ("vgg/conv3-ish", ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1)),
    ];
    let mut t = Table::new(&["layer", "algorithm", "GFLOPS", "rel to im2col", "extra MiB"]);
    for (name, s) in &layers {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);
        let bp = select_params(&m, s);

        // Correctness gate before timing anything.
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = conv_direct(&input, &kernel, s, bp, 1).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "{name}: direct kernel wrong");

        let t_im2col = bench("im2col", opts, || { sink(conv_im2col(&input, &kernel, s).unwrap()); });
        let base = t_im2col.median_secs;
        let mib = |b: u64| format!("{:.1}", b as f64 / (1 << 20) as f64);
        t.row(vec![
            name.to_string(),
            "im2col+sgemm".into(),
            format!("{:.2}", gflops(s.flops(), base)),
            "1.00".into(),
            mib(im2col_extra_bytes(s)),
        ]);

        let t_direct =
            bench("direct", opts, || { sink(conv_direct(&input, &kernel, s, bp, 1).unwrap()); });
        t.row(vec![
            name.to_string(),
            "direct (ours)".into(),
            format!("{:.2}", gflops(s.flops(), t_direct.median_secs)),
            format!("{:.2}", base / t_direct.median_secs),
            "0.0".into(),
        ]);

        let t_mec = bench("mec", opts, || { sink(conv_mec(&input, &kernel, s).unwrap()); });
        t.row(vec![
            name.to_string(),
            "mec".into(),
            format!("{:.2}", gflops(s.flops(), t_mec.median_secs)),
            format!("{:.2}", base / t_mec.median_secs),
            mib(mec_extra_bytes(s)),
        ]);

        if winograd_applicable(s) {
            let t_wino =
                bench("winograd", opts, || { sink(conv_winograd(&input, &kernel, s).unwrap()); });
            t.row(vec![
                name.to_string(),
                "winograd".into(),
                format!("{:.2}", gflops(s.flops(), t_wino.median_secs)),
                format!("{:.2}", base / t_wino.median_secs),
                mib(winograd_extra_bytes(s)),
            ]);
        }

        // FFT with precomputed kernel spectra (NNPACK inference mode);
        // skip the largest layer where spectra would not fit in time.
        if s.c_i * s.c_o <= 128 * 192 {
            let plan = FftConvPlan::new(&kernel, s).unwrap();
            let t_fft = bench("fft", opts, || { sink(plan.run(&input).unwrap()); });
            t.row(vec![
                name.to_string(),
                "fft (precomp)".into(),
                format!("{:.2}", gflops(s.flops(), t_fft.median_secs)),
                format!("{:.2}", base / t_fft.median_secs),
                mib(plan.retained_bytes()),
            ]);
        }
    }
    emit(
        "host_measured",
        &format!("Host-measured convolution comparison ({} / 1 thread)", m.name),
        &t,
    );
}

//! Host-measured Figure-4 analogue: every convolution backend in the
//! registry, wall-clock on this machine, on shape-faithful layers from
//! the three benchmark networks. This is the real-hardware counterpart
//! of the simulator figures (single machine, single thread — the
//! multi-arch / multi-thread shapes come from `fig4_all_archs` and
//! `fig5_scaling`).
//!
//! Backends are planned once per layer and timed on `execute_into` with
//! pre-packed operands and caller-owned buffers — the deployment hot
//! path, which is also what the paper measures (packing is a one-time
//! cost, §4.3). The memory column is the engine's uniform
//! `retained_bytes + workspace_bytes` accounting; MEC keeps its raw
//! entry point as the one non-registry comparator.

use dconv::bench_harness::{bench, emit_with_roofline, opts_from_env, sink};
use dconv::conv::{conv_naive, ConvShape};
use dconv::engine::{BackendRegistry, ConvAlgo, ConvPlan};
use dconv::lowering::{conv_mec, mec_extra_bytes};
use dconv::metrics::{gflops, Table};
use dconv::nets::{Layer, NetPlans, PlannedLayer};
use dconv::tensor::Tensor;
use dconv::trace::roofline::RooflineReport;
use dconv::trace::{Span, SpanKind};

fn main() {
    let opts = opts_from_env();
    let m = dconv::arch::host();
    let registry = BackendRegistry::default();
    // Shape-faithful (channel counts + kernel geometry preserved,
    // spatial extent reduced where the original would take minutes).
    let layers = [
        ("alexnet/conv1-ish", ConvShape::new(3, 115, 115, 96, 11, 11, 4, 0)),
        ("alexnet/conv3-ish", ConvShape::new(128, 13, 13, 192, 3, 3, 1, 1)),
        ("googlenet/3x3-ish", ConvShape::new(96, 28, 28, 128, 3, 3, 1, 1)),
        ("googlenet/5x5-ish", ConvShape::new(16, 14, 14, 32, 5, 5, 1, 2)),
        ("vgg/conv3-ish", ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1)),
    ];
    let mib = |b: u64| format!("{:.1}", b as f64 / (1 << 20) as f64);
    let mut t = Table::new(&["layer", "backend", "GFLOPS", "rel to im2col", "extra MiB"]);
    // Direct-backend plans + their measured medians feed the per-layer
    // roofline breakdown stored in the JSON artifact.
    let mut direct_plans: Vec<PlannedLayer> = Vec::new();
    let mut direct_secs: Vec<f64> = Vec::new();
    for (name, s) in &layers {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);

        // Correctness gate before timing anything.
        let want = conv_naive(&input, &kernel, s).unwrap();
        let direct_plan = registry.plan("direct", s, &kernel, &m, 1).unwrap();
        let got = direct_plan.execute(&input).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "{name}: direct kernel wrong");

        // im2col first: it is the normalization baseline.
        let mut base = f64::NAN;
        for backend in ["im2col", "direct", "reorder", "winograd", "fft"] {
            let Some(algo) = registry.get(backend) else { continue };
            if !algo.applicable(s) {
                continue;
            }
            // FFT spectra for the widest layer take too long to plan in a
            // bench sweep; same skip the seed applied.
            if backend == "fft" && s.c_i * s.c_o > 128 * 192 {
                continue;
            }
            let plan = algo.plan(s, &kernel, &m, 1).unwrap();
            let packed = plan.pack_input(&input).unwrap();
            let mut out = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
            let mut ws = vec![0.0f32; plan.workspace_len()];
            let meas = bench(backend, opts, || {
                plan.execute_into(packed.data(), &mut out, &mut ws).unwrap();
                sink(out[0]);
            });
            if backend == "im2col" {
                base = meas.median_secs;
            }
            let label = if backend == "direct" { "direct (ours)" } else { backend };
            t.row(vec![
                name.to_string(),
                label.into(),
                format!("{:.2}", gflops(s.flops(), meas.median_secs)),
                format!("{:.2}", base / meas.median_secs),
                mib(plan.retained_bytes() + plan.workspace_bytes()),
            ]);
            if backend == "direct" {
                direct_plans.push(PlannedLayer {
                    layer: Layer {
                        net: "host_measured".into(),
                        name: name.to_string(),
                        shape: s.clone(),
                    },
                    backend: "direct",
                    threads: 1,
                    plan,
                });
                direct_secs.push(meas.median_secs);
            }
        }

        let t_mec = bench("mec", opts, || {
            sink(conv_mec(&input, &kernel, s).unwrap());
        });
        t.row(vec![
            name.to_string(),
            "mec".into(),
            format!("{:.2}", gflops(s.flops(), t_mec.median_secs)),
            format!("{:.2}", base / t_mec.median_secs),
            mib(mec_extra_bytes(s)),
        ]);
    }
    // Roofline over the direct rows: one synthetic conv span per layer
    // carrying its measured median, judged against this host's ceilings.
    let plans = NetPlans { net: "host_measured".into(), layers: direct_plans };
    let spans: Vec<Span> = direct_secs
        .iter()
        .enumerate()
        .map(|(i, secs)| Span {
            id: i as u32,
            kind: SpanKind::Conv,
            meta: i as u64,
            t_start: 0,
            t_end: (secs * 1e9) as u64,
            ..Span::default()
        })
        .collect();
    let wall: f64 = direct_secs.iter().sum();
    let roofline = RooflineReport::from_spans(&plans, &m, &spans, wall, 4);
    print!("\n{}", roofline.render());
    emit_with_roofline(
        "host_measured",
        &format!("Host-measured convolution comparison ({} / 1 thread)", m.name),
        &t,
        Some(&roofline.to_json()),
    );
}

//! Figure 5 — scaling with thread count: GFLOPS *per core*, threads from
//! 1 to 2x the physical cores, direct vs SGEMM-based convolution.
//!
//! Expected shape: direct stays flat up to the core count then drops
//! sharply under oversubscription; SGEMM per-core decays from 2 threads
//! on (partition skew + packing serialization).
//!
//! A host-measured correctness column runs the real threaded direct
//! convolution at each thread count (single-core machine: this validates
//! the code path, the curve itself comes from the model — DESIGN.md §4).

use dconv::arch::{cortex_a57, haswell, piledriver};
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::ConvShape;
use dconv::engine::{BackendRegistry, ConvPlan};
use dconv::metrics::{gflops, Table};
use dconv::nets;
use dconv::sim::{scaling_curve, Algo};
use dconv::tensor::Tensor;

fn main() {
    for m in [haswell(), piledriver(), cortex_a57()] {
        let threads: Vec<usize> = (0..)
            .map(|i| 1usize << i)
            .take_while(|&p| p <= 2 * m.cores)
            .collect();
        let mut t = Table::new(&["layer", "algo", "threads", "GFLOPS", "GFLOPS/core"]);
        for l in &nets::alexnet()[1..3] {
            for (algo, label) in [(Algo::Direct, "direct"), (Algo::Im2colGemm, "sgemm+im2col")] {
                for pt in scaling_curve(&m, &l.shape, algo, &threads) {
                    t.row(vec![
                        l.name.clone(),
                        label.into(),
                        pt.threads.to_string(),
                        format!("{:.1}", pt.gflops),
                        format!("{:.1}", pt.gflops_per_core),
                    ]);
                }
            }
        }
        emit(
            &format!("fig5_{}", m.name.split_whitespace().next().unwrap().to_lowercase()),
            &format!("Figure 5 — thread scaling on {} (model)", m.name),
            &t,
        );
    }

    // Host-measured: the real threaded kernel at increasing thread counts,
    // planned once per thread count and timed on the execute_into hot path.
    let opts = opts_from_env();
    let host = dconv::arch::host();
    let registry = BackendRegistry::default();
    let s = ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1);
    let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 5);
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 6);
    let mut t = Table::new(&["threads", "measured GFLOPS", "note"]);
    for p in [1usize, 2, 4] {
        let plan = registry.plan("direct", &s, &kernel, &host, p).unwrap();
        let packed = plan.pack_input(&input).unwrap();
        let mut out = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
        let mut ws = vec![0.0f32; plan.workspace_len()];
        let meas = bench(&format!("direct-{p}t"), opts, || {
            plan.execute_into(packed.data(), &mut out, &mut ws).unwrap();
            sink(out[0]);
        });
        let note = if host.cores == 1 {
            "single-core host: expect flat/worse".to_string()
        } else {
            String::new()
        };
        t.row(vec![
            p.to_string(),
            format!("{:.2}", gflops(s.flops(), meas.median_secs)),
            note,
        ]);
    }
    emit("fig5_host", "Figure 5 (host-measured threaded direct conv)", &t);
}

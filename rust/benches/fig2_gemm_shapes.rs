//! Figure 2 — why im2col matrices are bad for BLAS.
//!
//! The paper's Figure 2 illustrates the lowering and notes that the
//! GEMM's inner dimension `H_f*W_f*C_i` usually dwarfs `C_o` and the
//! spatial extent. This bench regenerates the quantitative version: for
//! every AlexNet/VGG layer, the im2col matrix shape and the modeled
//! SGEMM efficiency on it against the square-HPC reference — plus a
//! host-measured confirmation.

use dconv::arch::{haswell, host};
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::gemm::sgemm;
use dconv::metrics::{gflops, Table};
use dconv::nets;
use dconv::sim::gemm_time;
use dconv::tensor::Tensor;

fn main() {
    let m = haswell();
    let mut t = Table::new(&[
        "layer",
        "m=C_o",
        "n=HoWo",
        "k=HfWfCi",
        "model frac-of-peak (1t)",
        "model frac-of-peak (4t)",
    ]);
    let frac = |mm: usize, nn: usize, kk: usize, p: usize| {
        let fl = 2.0 * (mm as f64) * (nn as f64) * (kk as f64);
        fl / gemm_time(&m, mm, nn, kk, p) / 1e9 / m.peak_gflops(p)
    };
    t.row(vec![
        "HPC square (2000^3)".into(),
        "2000".into(),
        "2000".into(),
        "2000".into(),
        format!("{:.3}", frac(2000, 2000, 2000, 1)),
        format!("{:.3}", frac(2000, 2000, 2000, 4)),
    ]);
    for l in nets::alexnet().into_iter().chain(nets::vgg16()) {
        let s = &l.shape;
        let (mm, nn, kk) = (s.c_o, s.h_o() * s.w_o(), s.c_i * s.h_f * s.w_f);
        t.row(vec![
            format!("{}/{}", l.net, l.name),
            mm.to_string(),
            nn.to_string(),
            kk.to_string(),
            format!("{:.3}", frac(mm, nn, kk, 1)),
            format!("{:.3}", frac(mm, nn, kk, 4)),
        ]);
    }
    emit("fig2_gemm_shapes", "Figure 2 — SGEMM efficiency on im2col shapes (model)", &t);

    // Host-measured confirmation: conv-shaped vs square GEMM.
    let opts = opts_from_env();
    let hostm = host();
    let mut t2 = Table::new(&["shape", "m", "n", "k", "measured GFLOPS"]);
    let cases = [
        ("square", 256usize, 256usize, 256usize),
        ("conv-ish deep-k", 96, 729, 2400),
        ("conv-ish wide-n", 64, 12544, 27),
    ];
    for (name, mm, nn, kk) in cases {
        let a = Tensor::random(&[mm, kk], 1);
        let b = Tensor::random(&[kk, nn], 2);
        let mut c = vec![0.0f32; mm * nn];
        let meas = bench(name, opts, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            sgemm(mm, nn, kk, a.data(), kk, b.data(), nn, &mut c, nn);
            sink(c[0]);
        });
        t2.row(vec![
            name.into(),
            mm.to_string(),
            nn.to_string(),
            kk.to_string(),
            format!("{:.2}", gflops(2 * (mm * nn * kk) as u64, meas.median_secs)),
        ]);
    }
    emit(
        "fig2_gemm_shapes_host",
        &format!("Figure 2 (host-measured on {})", hostm.name),
        &t2,
    );
}

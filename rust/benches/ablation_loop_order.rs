//! Ablation (ours, host-measured): the three algorithm stages of the
//! paper — Algorithm 1 (naive NCHW loops), Algorithm 2 (reordered
//! `(l,n,m,i,k,j)` loops over channel-last data), Algorithm 3 (register
//! + cache blocked over the §4 layouts) — on identical layers.
//!
//! This isolates how much of the paper's win comes from loop order alone
//! vs blocking + layout.

// This ablation deliberately times the raw per-call algorithm stages
// (including their packing), not the engine's plan/execute path.

use dconv::arch::host;
use dconv::bench_harness::{bench, emit, opts_from_env, sink};
use dconv::conv::reorder::kernel_to_hwio;
use dconv::conv::{
    conv_direct_blocked, conv_naive, conv_reorder_into, select_params, BlockParams, ConvShape,
};
use dconv::layout::{from_blocked_io, nchw_to_nhwc, to_blocked_io, to_blocked_kernel};
use dconv::metrics::{gflops, Table};
use dconv::tensor::Tensor;

/// Per-call Algorithm 3 including its §4 packing (what the removed
/// `conv_direct` wrapper measured).
fn direct_oneshot(input: &Tensor, kernel: &Tensor, s: &ConvShape, bp: BlockParams) -> Tensor {
    let bi = to_blocked_io(input, bp.c_ib).unwrap();
    let bk = to_blocked_kernel(kernel, bp.c_ob, bp.c_ib).unwrap();
    let bo = conv_direct_blocked(&bi, &bk, s, bp, 1).unwrap();
    from_blocked_io(&bo).unwrap()
}

/// Per-call Algorithm 2 over pre-permuted channel-last operands.
fn reorder_oneshot(nhwc: &Tensor, hwio: &Tensor, s: &ConvShape) -> Tensor {
    let mut out = Tensor::zeros(&[s.h_o(), s.w_o(), s.c_o]);
    conv_reorder_into(nhwc.data(), hwio.data(), s, out.data_mut()).unwrap();
    out
}

fn main() {
    let opts = opts_from_env();
    let m = host();
    // Down-scaled but shape-faithful layers (naive is very slow).
    let layers = [
        ("alexnet-conv3-ish", ConvShape::new(64, 13, 13, 96, 3, 3, 1, 1)),
        ("vgg-ish", ConvShape::new(32, 28, 28, 32, 3, 3, 1, 1)),
        ("googlenet-5x5-ish", ConvShape::new(16, 14, 14, 32, 5, 5, 1, 2)),
    ];
    let mut t = Table::new(&["layer", "algorithm", "GFLOPS", "speedup vs naive"]);
    for (name, s) in layers {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);
        let nhwc = nchw_to_nhwc(&input).unwrap();
        let hwio = kernel_to_hwio(&kernel).unwrap();
        let bp = select_params(&m, &s);

        let t_naive = bench("alg1", opts, || { sink(conv_naive(&input, &kernel, &s).unwrap()); });
        let t_reord = bench("alg2", opts, || { sink(reorder_oneshot(&nhwc, &hwio, &s)); });
        let t_direct =
            bench("alg3", opts, || { sink(direct_oneshot(&input, &kernel, &s, bp)); });

        for (alg, meas) in [
            ("alg1 naive", &t_naive),
            ("alg2 reordered", &t_reord),
            ("alg3 blocked direct", &t_direct),
        ] {
            t.row(vec![
                name.into(),
                alg.into(),
                format!("{:.2}", gflops(s.flops(), meas.median_secs)),
                format!("{:.1}x", t_naive.median_secs / meas.median_secs),
            ]);
        }
    }
    emit("ablation_loop_order", "Ablation — Algorithm 1 vs 2 vs 3 (host-measured)", &t);
}

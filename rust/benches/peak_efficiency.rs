//! §6 peak-efficiency numbers: the paper reports direct convolution at
//! 87.5% / 58.2% / 88.9% of theoretical peak on Intel / AMD / ARM, vs
//! SGEMM on HPC matrices at 89% / 54% / 92%. Regenerates both columns
//! from the simulator (FLOP-weighted over AlexNet conv2-5, matching the
//! paper's measurement layers).

use dconv::arch::{cortex_a57, haswell, piledriver, render_table1};
use dconv::bench_harness::emit;
use dconv::metrics::Table;
use dconv::nets;
use dconv::sim::{estimate, gemm_time, Algo};

fn main() {
    println!("\n## Table 1 — machines\n\n{}", render_table1());

    let paper = [
        ("Intel", 0.875, 0.89),
        ("AMD", 0.582, 0.54),
        ("ARM", 0.889, 0.92),
    ];
    let mut t = Table::new(&[
        "machine",
        "direct frac-of-peak (model)",
        "paper",
        "HPC sgemm frac-of-peak (model)",
        "paper",
    ]);
    for (m, (tag, p_dir, p_gemm)) in
        [haswell(), piledriver(), cortex_a57()].into_iter().zip(paper)
    {
        let (mut num, mut den) = (0.0, 0.0);
        for l in &nets::alexnet()[1..] {
            let e = estimate(&m, &l.shape, Algo::Direct, 1);
            num += e.frac_peak * l.shape.flops() as f64;
            den += l.shape.flops() as f64;
        }
        let direct = num / den;
        let n = 2000;
        let fl = 2.0 * (n as f64).powi(3);
        let gemm = fl / gemm_time(&m, n, n, n, 1) / 1e9 / m.peak_gflops(1);
        t.row(vec![
            format!("{tag} ({})", m.name),
            format!("{direct:.3}"),
            format!("{p_dir:.3}"),
            format!("{gemm:.3}"),
            format!("{p_gemm:.2}"),
        ]);
    }
    emit("peak_efficiency", "§6 — fraction of theoretical peak (paper vs model)", &t);
}

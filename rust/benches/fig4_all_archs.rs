//! Figure 4 — direct vs FFT-based (NNPACK) vs SGEMM-based convolution on
//! all conv layers of AlexNet, GoogLeNet and VGG, on the three Table-1
//! machines. All series normalized to SGEMM+im2col = 1.0 (the paper's
//! normalization).
//!
//! Expected shape: direct 1.1x–4x everywhere; NNPACK beats SGEMM only on
//! large-image stride-1 layers on Intel, never on ARM; AMD has no NNPACK
//! port (the paper reports none), marked n/a.

use dconv::arch::{cortex_a57, haswell, piledriver, Machine};
use dconv::bench_harness::emit;
use dconv::metrics::Table;
use dconv::nets;
use dconv::sim::{estimate, Algo};

fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

fn run_machine(m: &Machine, nnpack_supported: bool) {
    let p = m.cores;
    let mut t = Table::new(&["layer", "GFLOPs", "direct (rel)", "nnpack-best (rel)"]);
    let mut per_net: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
    for net in ["alexnet", "googlenet", "vgg16"] {
        let mut dirs = Vec::new();
        let mut ffts = Vec::new();
        for l in nets::by_name(net).unwrap() {
            let base = estimate(m, &l.shape, Algo::Im2colGemm, p);
            let dir = estimate(m, &l.shape, Algo::Direct, p);
            let rel_dir = base.secs / dir.secs;
            dirs.push(rel_dir);
            let rel_fft = if nnpack_supported {
                let fft = estimate(m, &l.shape, Algo::FftNnpack, p);
                let r = base.secs / fft.secs;
                ffts.push(r);
                format!("{r:.2}")
            } else {
                "n/a".to_string()
            };
            t.row(vec![
                format!("{}/{}", l.net, l.name),
                format!("{:.2}", l.gflops()),
                format!("{rel_dir:.2}"),
                rel_fft,
            ]);
        }
        per_net.push((net.to_string(), dirs, ffts));
    }
    emit(
        &format!("fig4_{}", m.name.split_whitespace().next().unwrap().to_lowercase()),
        &format!("Figure 4 — {} ({p} threads, rel to sgemm+im2col)", m.name),
        &t,
    );
    let mut s = Table::new(&["net", "direct geomean", "direct min..max", "nnpack geomean"]);
    for (net, dirs, ffts) in per_net {
        let min = dirs.iter().cloned().fold(f64::MAX, f64::min);
        let max = dirs.iter().cloned().fold(0.0, f64::max);
        s.row(vec![
            net,
            format!("{:.2}", geomean(&dirs)),
            format!("{min:.2}..{max:.2}"),
            if ffts.is_empty() { "n/a".into() } else { format!("{:.2}", geomean(&ffts)) },
        ]);
    }
    emit(
        &format!("fig4_{}_summary", m.name.split_whitespace().next().unwrap().to_lowercase()),
        &format!("Figure 4 summary — {}", m.name),
        &s,
    );
}

fn main() {
    run_machine(&haswell(), true);
    run_machine(&piledriver(), false); // paper: NNPACK does not support AMD
    run_machine(&cortex_a57(), true);
}

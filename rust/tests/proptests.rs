//! Randomized property tests (the `proptest` crate is absent from the
//! offline registry; these use the crate's own deterministic xorshift to
//! generate hundreds of cases per property — same idea, reproducible
//! seeds printed on failure).

#![allow(deprecated)] // legacy wrappers stay property-tested until removed

use dconv::conv::{conv_direct, conv_naive, BlockParams, ConvShape};
use dconv::coordinator::{Batcher, BatcherConfig};
use dconv::gemm::{sgemm, sgemm_naive};
use dconv::json::Json;
use dconv::layout::{from_blocked_io, from_blocked_kernel, to_blocked_io, to_blocked_kernel};
use dconv::tensor::{Tensor, XorShiftRng};

fn random_shape(rng: &mut XorShiftRng) -> (ConvShape, BlockParams) {
    // channels constrained so block params can divide them
    let c_ib = [1usize, 2, 3, 4][rng.next_usize(4)];
    let c_i = c_ib * (1 + rng.next_usize(5));
    let c_ob = [1usize, 2, 4, 8, 16][rng.next_usize(5)];
    let c_o = c_ob * (1 + rng.next_usize(4));
    let h_f = 1 + rng.next_usize(5);
    let w_f = 1 + rng.next_usize(5);
    let stride = 1 + rng.next_usize(3);
    let pad = rng.next_usize(3).min(h_f - 1).min(w_f - 1);
    let h_i = (h_f + stride * rng.next_usize(6)).max(h_f.saturating_sub(2 * pad).max(1));
    let w_i = (w_f + stride * rng.next_usize(6)).max(w_f.saturating_sub(2 * pad).max(1));
    let w_ob = 1 + rng.next_usize(8);
    (
        ConvShape::new(c_i, h_i, w_i, c_o, h_f, w_f, stride, pad),
        BlockParams::new(c_ob, w_ob, c_ib),
    )
}

/// Property: Algorithm 3 == Algorithm 1 on random shapes and blockings.
#[test]
fn prop_direct_matches_naive() {
    let mut rng = XorShiftRng::new(0xD1EC7);
    let mut tested = 0;
    while tested < 120 {
        let (s, bp) = random_shape(&mut rng);
        if s.validate().is_err() {
            continue;
        }
        tested += 1;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], rng.next_u64());
        let want = conv_naive(&input, &kernel, &s).unwrap();
        let got = conv_direct(&input, &kernel, &s, bp, 1 + tested % 3).unwrap();
        assert!(
            got.allclose(&want, 1e-3, 1e-4),
            "case {tested}: {s:?} {bp:?} diff {}",
            got.max_abs_diff(&want)
        );
    }
}

/// Property: convolution is linear in the input (direct kernel).
#[test]
fn prop_direct_is_linear() {
    let mut rng = XorShiftRng::new(0x11EA2);
    for case in 0..30 {
        let (s, bp) = random_shape(&mut rng);
        if s.validate().is_err() {
            continue;
        }
        let x1 = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let x2 = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let k = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], rng.next_u64());
        let y1 = conv_direct(&x1, &k, &s, bp, 1).unwrap();
        let y2 = conv_direct(&x2, &k, &s, bp, 1).unwrap();
        let added: Vec<f32> = x1.data().iter().zip(x2.data()).map(|(a, b)| a + b).collect();
        let sum = Tensor::from_vec(x1.shape(), added).unwrap();
        let ysum = conv_direct(&sum, &k, &s, bp, 1).unwrap();
        let want = Tensor::from_vec(
            y1.shape(),
            y1.data().iter().zip(y2.data()).map(|(a, b)| a + b).collect(),
        )
        .unwrap();
        assert!(ysum.allclose(&want, 1e-3, 1e-4), "case {case}: additivity violated");
    }
}

/// Property: layout conversions are lossless permutations (round trip,
/// element conservation) for random block sizes.
#[test]
fn prop_layout_round_trips() {
    let mut rng = XorShiftRng::new(0x1A707);
    for _ in 0..200 {
        let c_b = [1usize, 2, 4, 8][rng.next_usize(4)];
        let c = c_b * (1 + rng.next_usize(8));
        let h = 1 + rng.next_usize(12);
        let w = 1 + rng.next_usize(12);
        let t = Tensor::random(&[c, h, w], rng.next_u64());
        let b = to_blocked_io(&t, c_b).unwrap();
        assert_eq!(b.len(), t.len(), "permutation must conserve elements");
        let mut sorted_a: Vec<u32> = t.data().iter().map(|v| v.to_bits()).collect();
        let mut sorted_b: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
        sorted_a.sort_unstable();
        sorted_b.sort_unstable();
        assert_eq!(sorted_a, sorted_b, "multiset of values must be preserved");
        assert_eq!(from_blocked_io(&b).unwrap(), t);

        let c_ob = [1usize, 2, 4][rng.next_usize(3)];
        let c_o = c_ob * (1 + rng.next_usize(6));
        let kshape = [c_o, c, 1 + rng.next_usize(4), 1 + rng.next_usize(4)];
        let k = Tensor::random(&kshape, rng.next_u64());
        let bk = to_blocked_kernel(&k, c_ob, c_b).unwrap();
        assert_eq!(from_blocked_kernel(&bk).unwrap(), k);
    }
}

/// Property: blocked GEMM == naive GEMM on random sizes/leading dims.
#[test]
fn prop_gemm_matches_naive() {
    let mut rng = XorShiftRng::new(0x6E44);
    for case in 0..60 {
        let m = 1 + rng.next_usize(80);
        let n = 1 + rng.next_usize(80);
        let k = 1 + rng.next_usize(80);
        let lda = k + rng.next_usize(5);
        let a = Tensor::random(&[m, lda], rng.next_u64());
        let b = Tensor::random(&[k, n], rng.next_u64());
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        sgemm(m, n, k, a.data(), lda, b.data(), n, &mut c1, n);
        sgemm_naive(m, n, k, a.data(), lda, b.data(), n, &mut c2, n);
        let md = c1.iter().zip(&c2).fold(0.0f32, |mx, (x, y)| mx.max((x - y).abs()));
        assert!(md < 1e-3, "case {case}: m={m} n={n} k={k} lda={lda} diff {md}");
    }
}

/// Coordinator invariants: for any request count and any compiled-size
/// set, the plan covers the requests, never exceeds the largest size,
/// and picks the padding-minimal compiled size.
#[test]
fn prop_batcher_invariants() {
    let mut rng = XorShiftRng::new(0xBA7C4);
    for _ in 0..300 {
        // random compiled-size set
        let mut sizes: Vec<usize> = (0..1 + rng.next_usize(5))
            .map(|_| 1 << rng.next_usize(6))
            .collect();
        sizes.push(1 + rng.next_usize(16));
        let b = Batcher::new(BatcherConfig {
            sizes: sizes.clone(),
            max_wait: std::time::Duration::from_millis(1),
        });
        let n = rng.next_usize(100);
        let plan = b.plan(n);
        // padded is one of the compiled sizes
        assert!(b.cfg().sizes.contains(&plan.padded));
        // occupancy never exceeds padded or n (when n >= 1)
        assert!(plan.occupancy <= plan.padded);
        assert!(plan.occupancy <= n.max(1));
        // padding-minimality: no smaller compiled size also fits
        for &s in &b.cfg().sizes {
            if s >= n.max(1) {
                assert!(plan.padded <= s, "picked {} but {} fits n={}", plan.padded, s, n);
            }
        }
        // covering: everything fits in ceil(n/max) batches of max size
        let max = b.max_size();
        if n > max {
            assert_eq!(plan.padded, max);
        }
        // split covers the whole queue with compiled sizes and never
        // wastes more than the single padded batch would.
        let split = b.split(n);
        let occupancy: usize = split.iter().map(|p| p.occupancy).sum();
        assert_eq!(occupancy, n, "split must cover every request exactly");
        let total_padded: usize = split.iter().map(|p| p.padded).sum();
        for p in &split {
            assert!(b.cfg().sizes.contains(&p.padded));
            assert!(p.occupancy >= 1 && p.occupancy <= p.padded);
        }
        if n == 0 {
            assert!(split.is_empty());
        } else if n <= max {
            assert!(total_padded - n <= Batcher::waste(&plan), "split beat by one batch");
        }
    }
}

/// JSON round-trip on randomly generated documents.
#[test]
fn prop_json_round_trip() {
    fn gen(rng: &mut XorShiftRng, depth: usize) -> Json {
        match if depth == 0 { rng.next_usize(4) } else { rng.next_usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_usize(2) == 0),
            2 => Json::Num((rng.next_usize(2_000_001) as f64 - 1e6) / 64.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n{}", rng.next_usize(100), rng.next_usize(10))),
            4 => Json::Arr((0..rng.next_usize(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_usize(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = XorShiftRng::new(0x150);
    for case in 0..200 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, doc, "case {case}");
    }
}

/// Property: stride-1 no-pad convolution of a shifted impulse shifts the
/// output (translation equivariance away from borders).
#[test]
fn prop_translation_equivariance() {
    let mut rng = XorShiftRng::new(0x7E5);
    for _ in 0..20 {
        let s = ConvShape::new(1, 12, 12, 4, 3, 3, 1, 0);
        let bp = BlockParams::new(4, 4, 1);
        let k = Tensor::random(&[4, 1, 3, 3], rng.next_u64());
        // impulse at (y, x) and at (y+1, x+1)
        let y = 1 + rng.next_usize(6);
        let x = 1 + rng.next_usize(6);
        let mut i1 = Tensor::zeros(&[1, 12, 12]);
        i1.set(&[0, y, x], 1.0);
        let mut i2 = Tensor::zeros(&[1, 12, 12]);
        i2.set(&[0, y + 1, x + 1], 1.0);
        let o1 = conv_direct(&i1, &k, &s, bp, 1).unwrap();
        let o2 = conv_direct(&i2, &k, &s, bp, 1).unwrap();
        // o2[c][l][m] == o1[c][l-1][m-1] in the interior
        for c in 0..4 {
            for l in 1..s.h_o() {
                for m in 1..s.w_o() {
                    let a = o2.at(&[c, l, m]);
                    let b = o1.at(&[c, l - 1, m - 1]);
                    assert!((a - b).abs() < 1e-6, "({c},{l},{m}): {a} vs {b}");
                }
            }
        }
    }
}

//! Randomized property tests (the `proptest` crate is absent from the
//! offline registry; these use the crate's own deterministic xorshift to
//! generate hundreds of cases per property — same idea, reproducible
//! seeds printed on failure).

use dconv::arch::haswell;
use dconv::conv::{conv_direct_blocked, conv_naive, BlockParams, ConvShape};
use dconv::coordinator::{Batcher, BatcherConfig};
use dconv::engine::{pool_nchw, NetRunner};
use dconv::gemm::{sgemm, sgemm_naive};
use dconv::json::Json;
use dconv::layout::{from_blocked_io, from_blocked_kernel, to_blocked_io, to_blocked_kernel};
use dconv::nets::{BranchTag, GraphNode, GraphOp, NetGraph, NetPlans, PoolKind};
use dconv::tensor::{Tensor, XorShiftRng};

/// One-shot §4 pack -> blocked direct conv -> unpack with explicit
/// `BlockParams` (the raw Algorithm-3 kernel under property test; the
/// engine's `direct` backend is the production entry point).
fn conv_direct(
    input: &Tensor,
    kernel: &Tensor,
    s: &ConvShape,
    bp: BlockParams,
    threads: usize,
) -> dconv::Result<Tensor> {
    let bi = to_blocked_io(input, bp.c_ib)?;
    let bk = to_blocked_kernel(kernel, bp.c_ob, bp.c_ib)?;
    let bo = conv_direct_blocked(&bi, &bk, s, bp, threads)?;
    from_blocked_io(&bo)
}

fn random_shape(rng: &mut XorShiftRng) -> (ConvShape, BlockParams) {
    // channels constrained so block params can divide them
    let c_ib = [1usize, 2, 3, 4][rng.next_usize(4)];
    let c_i = c_ib * (1 + rng.next_usize(5));
    let c_ob = [1usize, 2, 4, 8, 16][rng.next_usize(5)];
    let c_o = c_ob * (1 + rng.next_usize(4));
    let h_f = 1 + rng.next_usize(5);
    let w_f = 1 + rng.next_usize(5);
    let stride = 1 + rng.next_usize(3);
    let pad = rng.next_usize(3).min(h_f - 1).min(w_f - 1);
    let h_i = (h_f + stride * rng.next_usize(6)).max(h_f.saturating_sub(2 * pad).max(1));
    let w_i = (w_f + stride * rng.next_usize(6)).max(w_f.saturating_sub(2 * pad).max(1));
    let w_ob = 1 + rng.next_usize(8);
    (
        ConvShape::new(c_i, h_i, w_i, c_o, h_f, w_f, stride, pad),
        BlockParams::new(c_ob, w_ob, c_ib),
    )
}

/// Property: Algorithm 3 == Algorithm 1 on random shapes and blockings.
#[test]
fn prop_direct_matches_naive() {
    let mut rng = XorShiftRng::new(0xD1EC7);
    let mut tested = 0;
    while tested < 120 {
        let (s, bp) = random_shape(&mut rng);
        if s.validate().is_err() {
            continue;
        }
        tested += 1;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], rng.next_u64());
        let want = conv_naive(&input, &kernel, &s).unwrap();
        let got = conv_direct(&input, &kernel, &s, bp, 1 + tested % 3).unwrap();
        assert!(
            got.allclose(&want, 1e-3, 1e-4),
            "case {tested}: {s:?} {bp:?} diff {}",
            got.max_abs_diff(&want)
        );
    }
}

/// Property: convolution is linear in the input (direct kernel).
#[test]
fn prop_direct_is_linear() {
    let mut rng = XorShiftRng::new(0x11EA2);
    for case in 0..30 {
        let (s, bp) = random_shape(&mut rng);
        if s.validate().is_err() {
            continue;
        }
        let x1 = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let x2 = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
        let k = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], rng.next_u64());
        let y1 = conv_direct(&x1, &k, &s, bp, 1).unwrap();
        let y2 = conv_direct(&x2, &k, &s, bp, 1).unwrap();
        let added: Vec<f32> = x1.data().iter().zip(x2.data()).map(|(a, b)| a + b).collect();
        let sum = Tensor::from_vec(x1.shape(), added).unwrap();
        let ysum = conv_direct(&sum, &k, &s, bp, 1).unwrap();
        let want = Tensor::from_vec(
            y1.shape(),
            y1.data().iter().zip(y2.data()).map(|(a, b)| a + b).collect(),
        )
        .unwrap();
        assert!(ysum.allclose(&want, 1e-3, 1e-4), "case {case}: additivity violated");
    }
}

/// Property: layout conversions are lossless permutations (round trip,
/// element conservation) for random block sizes.
#[test]
fn prop_layout_round_trips() {
    let mut rng = XorShiftRng::new(0x1A707);
    for _ in 0..200 {
        let c_b = [1usize, 2, 4, 8][rng.next_usize(4)];
        let c = c_b * (1 + rng.next_usize(8));
        let h = 1 + rng.next_usize(12);
        let w = 1 + rng.next_usize(12);
        let t = Tensor::random(&[c, h, w], rng.next_u64());
        let b = to_blocked_io(&t, c_b).unwrap();
        assert_eq!(b.len(), t.len(), "permutation must conserve elements");
        let mut sorted_a: Vec<u32> = t.data().iter().map(|v| v.to_bits()).collect();
        let mut sorted_b: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
        sorted_a.sort_unstable();
        sorted_b.sort_unstable();
        assert_eq!(sorted_a, sorted_b, "multiset of values must be preserved");
        assert_eq!(from_blocked_io(&b).unwrap(), t);

        let c_ob = [1usize, 2, 4][rng.next_usize(3)];
        let c_o = c_ob * (1 + rng.next_usize(6));
        let kshape = [c_o, c, 1 + rng.next_usize(4), 1 + rng.next_usize(4)];
        let k = Tensor::random(&kshape, rng.next_u64());
        let bk = to_blocked_kernel(&k, c_ob, c_b).unwrap();
        assert_eq!(from_blocked_kernel(&bk).unwrap(), k);
    }
}

/// Property: blocked GEMM == naive GEMM on random sizes/leading dims.
#[test]
fn prop_gemm_matches_naive() {
    let mut rng = XorShiftRng::new(0x6E44);
    for case in 0..60 {
        let m = 1 + rng.next_usize(80);
        let n = 1 + rng.next_usize(80);
        let k = 1 + rng.next_usize(80);
        let lda = k + rng.next_usize(5);
        let a = Tensor::random(&[m, lda], rng.next_u64());
        let b = Tensor::random(&[k, n], rng.next_u64());
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        sgemm(m, n, k, a.data(), lda, b.data(), n, &mut c1, n);
        sgemm_naive(m, n, k, a.data(), lda, b.data(), n, &mut c2, n);
        let md = c1.iter().zip(&c2).fold(0.0f32, |mx, (x, y)| mx.max((x - y).abs()));
        assert!(md < 1e-3, "case {case}: m={m} n={n} k={k} lda={lda} diff {md}");
    }
}

/// Coordinator invariants: for any request count and any compiled-size
/// set, the plan covers the requests, never exceeds the largest size,
/// and picks the padding-minimal compiled size.
#[test]
fn prop_batcher_invariants() {
    let mut rng = XorShiftRng::new(0xBA7C4);
    for _ in 0..300 {
        // random compiled-size set
        let mut sizes: Vec<usize> = (0..1 + rng.next_usize(5))
            .map(|_| 1 << rng.next_usize(6))
            .collect();
        sizes.push(1 + rng.next_usize(16));
        let b = Batcher::new(BatcherConfig {
            sizes: sizes.clone(),
            max_wait: std::time::Duration::from_millis(1),
        });
        let n = rng.next_usize(100);
        let plan = b.plan(n);
        // padded is one of the compiled sizes
        assert!(b.cfg().sizes.contains(&plan.padded));
        // occupancy never exceeds padded or n (when n >= 1)
        assert!(plan.occupancy <= plan.padded);
        assert!(plan.occupancy <= n.max(1));
        // padding-minimality: no smaller compiled size also fits
        for &s in &b.cfg().sizes {
            if s >= n.max(1) {
                assert!(plan.padded <= s, "picked {} but {} fits n={}", plan.padded, s, n);
            }
        }
        // covering: everything fits in ceil(n/max) batches of max size
        let max = b.max_size();
        if n > max {
            assert_eq!(plan.padded, max);
        }
        // split covers the whole queue with compiled sizes and never
        // wastes more than the single padded batch would.
        let split = b.split(n);
        let occupancy: usize = split.iter().map(|p| p.occupancy).sum();
        assert_eq!(occupancy, n, "split must cover every request exactly");
        let total_padded: usize = split.iter().map(|p| p.padded).sum();
        for p in &split {
            assert!(b.cfg().sizes.contains(&p.padded));
            assert!(p.occupancy >= 1 && p.occupancy <= p.padded);
        }
        if n == 0 {
            assert!(split.is_empty());
        } else if n <= max {
            assert!(total_padded - n <= Batcher::waste(&plan), "split beat by one batch");
        }
    }
}

/// Random module-structured DAG (the family the graph builders emit):
/// a backbone of fan-out/concat modules with optional inter-module
/// pools, every conv a 1x1 so references stay cheap. Returns the conv
/// table and the tagged graph.
fn random_module_net(rng: &mut XorShiftRng) -> (Vec<ConvShape>, NetGraph) {
    let mut shapes: Vec<ConvShape> = Vec::new();
    let c0 = 1 + rng.next_usize(12);
    let mut h = 8usize;
    let mut nodes = vec![GraphNode {
        name: "input".into(),
        op: GraphOp::Input { c: c0, h, w: h },
        preds: Vec::new(),
        branch: None,
    }];
    let mut x = 0usize;
    let mut c = c0;
    let modules = 1 + rng.next_usize(3);
    for m in 0..modules {
        if h >= 4 && rng.next_usize(2) == 0 {
            nodes.push(GraphNode {
                name: format!("pool{m}"),
                op: GraphOp::Pool {
                    kind: PoolKind::Max,
                    kh: 2,
                    kw: 2,
                    sh: 2,
                    sw: 2,
                    ph: 0,
                    pw: 0,
                },
                preds: vec![x],
                branch: None,
            });
            x = nodes.len() - 1;
            h /= 2;
        }
        let branches = 1 + rng.next_usize(4);
        let mut ends = Vec::new();
        let mut out_c = 0usize;
        for lane in 0..branches {
            let tag = Some(BranchTag { group: m, lane });
            let depth = 1 + rng.next_usize(2);
            let mut pred = x;
            let mut c_in = c;
            for d in 0..depth {
                let c_out = 1 + rng.next_usize(20);
                shapes.push(ConvShape::new(c_in, h, h, c_out, 1, 1, 1, 0));
                nodes.push(GraphNode {
                    name: format!("m{m}b{lane}d{d}"),
                    op: GraphOp::Conv { layer: shapes.len() - 1 },
                    preds: vec![pred],
                    branch: tag,
                });
                pred = nodes.len() - 1;
                c_in = c_out;
            }
            ends.push(pred);
            out_c += c_in;
        }
        nodes.push(GraphNode {
            name: format!("concat{m}"),
            op: GraphOp::Concat,
            preds: ends,
            branch: None,
        });
        x = nodes.len() - 1;
        c = out_c;
    }
    (shapes, NetGraph { net: "prop".into(), nodes })
}

/// NCHW interpreter over an arbitrary graph — the executor-independent
/// oracle for the random-DAG forward cross-check.
fn graph_reference(
    graph: &NetGraph,
    shapes: &[ConvShape],
    kernels: &[Tensor],
    input: &Tensor,
) -> Tensor {
    let mut outs: Vec<Option<Tensor>> = (0..graph.len()).map(|_| None).collect();
    for (i, n) in graph.nodes.iter().enumerate() {
        let t = match &n.op {
            GraphOp::Input { .. } => input.clone(),
            GraphOp::Conv { layer } => {
                let x = outs[n.preds[0]].as_ref().unwrap();
                conv_naive(x, &kernels[*layer], &shapes[*layer]).unwrap()
            }
            GraphOp::Pool { kind: PoolKind::Max, kh, kw, sh, sw, ph, pw } => {
                let x = outs[n.preds[0]].as_ref().unwrap();
                pool_nchw(x, *kh, *kw, *sh, *sw, *ph, *pw).unwrap()
            }
            GraphOp::Pool { kind: PoolKind::Avg, .. } => {
                unreachable!("random module nets only emit max pools")
            }
            GraphOp::Concat => {
                let parts: Vec<&Tensor> =
                    n.preds.iter().map(|&p| outs[p].as_ref().unwrap()).collect();
                let (ch, cw) = (parts[0].shape()[1], parts[0].shape()[2]);
                let c: usize = parts.iter().map(|t| t.shape()[0]).sum();
                let mut data = Vec::with_capacity(c * ch * cw);
                for p in &parts {
                    data.extend_from_slice(p.data());
                }
                Tensor::from_vec(&[c, ch, cw], data).unwrap()
            }
        };
        outs[i] = Some(t);
    }
    outs[graph.output()].take().unwrap()
}

/// Property: for random module DAGs (serial and branch-parallel
/// liveness), the arena region allocator never lets two live
/// activations alias, and the placed arena stays within the max
/// live-set bounds: never below it (it is a hard lower bound) and
/// never more than 2x above it. Exact equality cannot be promised on
/// arbitrary DAGs — offline offset allocation has instances whose
/// optimum provably exceeds the max live-set (classic dynamic-storage
/// allocation fragmentation; 5-value chains suffice) — but the
/// allocator does place every paper net *exactly* at its max live-set,
/// which `net_forward`/`net_graph` assert separately.
#[test]
fn prop_arena_regions_never_alias_and_stay_near_max_live() {
    let mut rng = XorShiftRng::new(0xA3E4A);
    for case in 0..40 {
        let (shapes, graph) = random_module_net(&mut rng);
        let lanes = [1usize, 3][rng.next_usize(2)];
        let seed = rng.next_u64();
        let plans = NetPlans::from_shapes("prop", &shapes, "direct", &haswell(), seed).unwrap();
        let runner = NetRunner::from_graph(plans, graph.clone(), lanes).unwrap();

        let regions = runner.arena_regions();
        for (i, a) in regions.iter().enumerate() {
            for b in &regions[i + 1..] {
                let overlap_t = a.first_step <= b.last_step && b.first_step <= a.last_step;
                let overlap_s = a.offset < b.offset + b.floats && b.offset < a.offset + a.floats;
                assert!(
                    !(overlap_t && overlap_s),
                    "case {case}: live regions alias ({} vs {}, lanes {lanes})",
                    a.name,
                    b.name
                );
            }
        }
        assert!(
            runner.arena_floats() >= runner.max_live_floats(),
            "case {case}: arena below the max live-set is impossible"
        );
        assert!(
            runner.arena_floats() <= 2 * runner.max_live_floats(),
            "case {case}: fragmentation blew past 2x the max live-set \
             (lanes {lanes}, {} nodes, arena {} vs live {})",
            graph.len(),
            runner.arena_floats(),
            runner.max_live_floats()
        );

        // Cross-check the executor against the NCHW oracle on a subset
        // (1x1 convs keep this cheap).
        if case % 4 == 0 {
            let kernels: Vec<Tensor> = shapes
                .iter()
                .enumerate()
                .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + i as u64))
                .collect();
            let d = runner.input_dims();
            let input = Tensor::random(&[d.c, d.h, d.w], rng.next_u64());
            let got = runner.forward(&input).unwrap();
            let want = graph_reference(&graph, &shapes, &kernels, &input);
            assert_eq!(got.shape(), want.shape(), "case {case}");
            assert!(
                got.allclose(&want, 1e-3, 1e-3),
                "case {case}: random DAG forward diverged by {}",
                got.max_abs_diff(&want)
            );
        }
    }
}

/// Exhaustive reachability oracle: the minimum total padded slots any
/// multiset of compiled sizes covering `n` requests can achieve.
/// Deliberately different machinery from `Batcher::split`'s cost DP.
fn brute_force_min_padded(sizes: &[usize], n: usize) -> usize {
    let max = *sizes.iter().max().unwrap();
    // reachable[s] = some multiset of sizes sums to exactly s.
    let bound = n + max;
    let mut reachable = vec![false; bound + 1];
    reachable[0] = true;
    for s in 0..=bound {
        if !reachable[s] {
            continue;
        }
        for &k in sizes {
            if s + k <= bound {
                reachable[s + k] = true;
            }
        }
    }
    (n..=bound).find(|&s| reachable[s]).expect("padding by one extra batch always covers")
}

/// Property: `Batcher::split` is padding-minimal — its total padded
/// slots equal the brute-force optimum over all covers — while still
/// covering every request exactly once.
#[test]
fn prop_split_padding_minimality_vs_brute_force() {
    let mut rng = XorShiftRng::new(0x5B117);
    for case in 0..200 {
        let mut sizes: Vec<usize> =
            (0..1 + rng.next_usize(4)).map(|_| 1 + rng.next_usize(12)).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let b = Batcher::new(BatcherConfig {
            sizes: sizes.clone(),
            max_wait: std::time::Duration::from_millis(1),
        });
        let n = 1 + rng.next_usize(40);
        let plans = b.split(n);
        let occupancy: usize = plans.iter().map(|p| p.occupancy).sum();
        let padded: usize = plans.iter().map(|p| p.padded).sum();
        assert_eq!(occupancy, n, "case {case}: split must cover every request");
        let best = brute_force_min_padded(b.cfg().sizes.as_slice(), n);
        assert_eq!(
            padded, best,
            "case {case}: split padded {padded} but brute force found {best} (sizes {:?}, n={n})",
            b.cfg().sizes
        );
    }
}

/// JSON round-trip on randomly generated documents.
#[test]
fn prop_json_round_trip() {
    fn gen(rng: &mut XorShiftRng, depth: usize) -> Json {
        match if depth == 0 { rng.next_usize(4) } else { rng.next_usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_usize(2) == 0),
            2 => Json::Num((rng.next_usize(2_000_001) as f64 - 1e6) / 64.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n{}", rng.next_usize(100), rng.next_usize(10))),
            4 => Json::Arr((0..rng.next_usize(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_usize(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = XorShiftRng::new(0x150);
    for case in 0..200 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, doc, "case {case}");
    }
}

/// Property: stride-1 no-pad convolution of a shifted impulse shifts the
/// output (translation equivariance away from borders).
#[test]
fn prop_translation_equivariance() {
    let mut rng = XorShiftRng::new(0x7E5);
    for _ in 0..20 {
        let s = ConvShape::new(1, 12, 12, 4, 3, 3, 1, 0);
        let bp = BlockParams::new(4, 4, 1);
        let k = Tensor::random(&[4, 1, 3, 3], rng.next_u64());
        // impulse at (y, x) and at (y+1, x+1)
        let y = 1 + rng.next_usize(6);
        let x = 1 + rng.next_usize(6);
        let mut i1 = Tensor::zeros(&[1, 12, 12]);
        i1.set(&[0, y, x], 1.0);
        let mut i2 = Tensor::zeros(&[1, 12, 12]);
        i2.set(&[0, y + 1, x + 1], 1.0);
        let o1 = conv_direct(&i1, &k, &s, bp, 1).unwrap();
        let o2 = conv_direct(&i2, &k, &s, bp, 1).unwrap();
        // o2[c][l][m] == o1[c][l-1][m-1] in the interior
        for c in 0..4 {
            for l in 1..s.h_o() {
                for m in 1..s.w_o() {
                    let a = o2.at(&[c, l, m]);
                    let b = o1.at(&[c, l - 1, m - 1]);
                    assert!((a - b).abs() < 1e-6, "({c},{l},{m}): {a} vs {b}");
                }
            }
        }
    }
}

//! End-to-end runtime tests: load the real AOT artifacts, compile them on
//! the PJRT CPU client, execute, and verify against the manifest goldens
//! (which were computed by JAX at build time — this closes the
//! python-compiles / rust-executes loop).
//!
//! Requires `make artifacts`; tests panic with a clear message otherwise.

use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::runtime::{verify_golden, Engine};
use dconv::tensor::Tensor;

fn engine() -> Engine {
    Engine::start("artifacts").expect("run `make artifacts` before `cargo test`")
}

#[test]
fn all_artifact_goldens_verify() {
    let eng = engine();
    let h = eng.handle();
    for art in h.manifest().clone().all() {
        let (d_sum, d_sum2) = verify_golden(&h, art)
            .unwrap_or_else(|e| panic!("golden failed for {}: {e}", art.name));
        assert!(d_sum.is_finite() && d_sum2.is_finite());
    }
}

#[test]
fn layer_artifact_shapes_and_determinism() {
    let eng = engine();
    let h = eng.handle();
    let layer = h.manifest().layers[0].clone();
    let n_in: usize = layer.input_shape.iter().product();
    let n_out: usize = layer.output_shape.iter().product();
    let x = Tensor::random(&layer.input_shape, 42).into_vec();
    let y1 = h.run(&layer.name, x.clone()).unwrap();
    let y2 = h.run(&layer.name, x).unwrap();
    assert_eq!(y1.len(), n_out);
    assert_eq!(y1, y2, "executions must be deterministic");
    assert!(n_in > 0);
}

#[test]
fn wrong_input_size_is_rejected() {
    let eng = engine();
    let h = eng.handle();
    let err = h.run("cnn_b1", vec![0.0; 7]).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("elements"), "unexpected error: {msg}");
    assert!(h.run("no_such_model", vec![]).is_err());
}

#[test]
fn coordinator_serves_batches_and_matches_direct_execution() {
    let eng = engine();
    let h = eng.handle();
    let coord = Coordinator::start(h.clone(), CoordinatorConfig::default()).unwrap();

    // Direct execution of cnn_b1 as the reference for a single image.
    let img = Tensor::random(&[1, 32, 32, 3], 777).into_vec();
    let want = h.run("cnn_b1", img.clone()).unwrap();

    // Same image through the coordinator (batched path).
    let got = coord.submit(img.clone()).unwrap().wait().unwrap();
    assert_eq!(got.len(), coord.classes());
    for (a, b) in got.iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-4, "coordinator result differs: {a} vs {b}");
    }

    // A burst: all results must come back and batching must kick in.
    let pendings: Vec<_> = (0..12)
        .map(|i| {
            let x = Tensor::random(&[1, 32, 32, 3], 800 + i as u64).into_vec();
            coord.submit_blocking(x).unwrap()
        })
        .collect();
    for p in pendings {
        let logits = p.wait().unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let stats = coord.stats();
    assert_eq!(stats.requests, 13);
    assert!(stats.batches <= 13);
    assert_eq!(stats.latency.count(), 13);
}

#[test]
fn batch_padding_consistency() {
    // Running 2 images via cnn_b4 (padded) must give the same logits as
    // via cnn_b2 (exact) — padding slots must not leak into real ones.
    let eng = engine();
    let h = eng.handle();
    let imgs = Tensor::random(&[2, 32, 32, 3], 31).into_vec();
    let via_b2 = h.run("cnn_b2", imgs.clone()).unwrap();
    let mut padded = imgs.clone();
    padded.extend(vec![0.0; 2 * 32 * 32 * 3]);
    let via_b4 = h.run("cnn_b4", padded).unwrap();
    for i in 0..via_b2.len() {
        assert!(
            (via_b2[i] - via_b4[i]).abs() < 1e-4,
            "padding changed result at {i}: {} vs {}",
            via_b2[i],
            via_b4[i]
        );
    }
}

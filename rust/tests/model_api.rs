//! The public model-description API end to end: builder programs must
//! reproduce the legacy table-built graphs exactly (structure and bits),
//! JSON specs must round-trip and drive the whole serving stack, and
//! the residual `Add` node must execute with the same zero-allocation /
//! zero-overhead guarantees as the paper nets.
//!
//! * builder GoogLeNet == table GoogLeNet node-for-node (ops, preds,
//!   branch tags) and shape-for-shape; AlexNet / VGG-16 likewise;
//! * builder-built AlexNet/GoogLeNet forwards are *bitwise* identical
//!   to the table-built ones. NB: since the table constructors are now
//!   themselves `GraphBuilder` wrappers, these asserts pin the two
//!   construction paths against each other; equivalence with the
//!   *pre-redesign* executor is pinned independently by the committed
//!   `net_golden` fixtures (NumPy reference, unchanged this PR);
//! * `resnet_micro` — defined via `GraphBuilder` AND parsed from the
//!   committed `examples/models/resnet_micro.json` — matches an NCHW
//!   naive reference with explicit residual sums, allocates nothing on
//!   the hot path (counting allocator), reports `overhead_bytes()==0`,
//!   and serves through `NetEngine`/coordinator;
//! * every `GraphBuilder` validation error fires (negative battery).

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;
use std::path::PathBuf;

use dconv::arch::haswell;
use dconv::conv::{conv_naive, ConvShape};
use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::engine::{add_nchw, pool_nchw, NetEngine, NetRunner};
use dconv::nets::builder;
use dconv::nets::{fuse, net_bn_params, net_kernel, GraphBuilder, Model, NetGraph, NetPlans};
use dconv::runtime::ModelExecutor;
use dconv::tensor::Tensor;

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as conformance.rs).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Builder programs vs the legacy table constructors
// ---------------------------------------------------------------------

fn paper_shapes(net: &str) -> Vec<ConvShape> {
    dconv::nets::by_name(net).unwrap().into_iter().map(|l| l.shape).collect()
}

/// Node-for-node structural equality: same op, same predecessors, same
/// branch tag. (Names may differ — builder programs use the real layer
/// names, the table wrappers keep their legacy `l{i}`/`m{m}` scheme.)
fn assert_same_structure(a: &NetGraph, b: &NetGraph, net: &str) {
    assert_eq!(a.len(), b.len(), "{net}: node counts differ");
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(x.op, y.op, "{net}: node {i} op differs ({} vs {})", x.name, y.name);
        assert_eq!(x.preds, y.preds, "{net}: node {i} preds differ ({})", x.name);
        assert_eq!(x.branch, y.branch, "{net}: node {i} branch tag differs ({})", x.name);
    }
}

#[test]
fn builder_paper_nets_equal_table_graphs_node_for_node() {
    for (model, net) in [
        (builder::alexnet(), "alexnet"),
        (builder::vgg16(), "vgg16"),
        (builder::googlenet(), "googlenet"),
    ] {
        let shapes = paper_shapes(net);
        assert_eq!(model.shapes, shapes, "{net}: shape tables differ");
        let table = NetGraph::for_net(net, &shapes).unwrap();
        assert_same_structure(&model.graph, &table, net);
        // Both validate to identical per-node dims.
        assert_eq!(model.graph.validate(&shapes).unwrap(), table.validate(&shapes).unwrap());
    }
}

#[test]
fn builder_alexnet_forward_is_bitwise_table_alexnet() {
    let input = Tensor::random(&[3, 227, 227], 0xB17);
    let table = NetRunner::new(NetPlans::build("alexnet", "direct", &haswell(), 1).unwrap())
        .unwrap();
    let model = builder::alexnet();
    let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
    let built = NetRunner::from_graph(plans, model.graph, 1).unwrap();
    let a = table.forward(&input).unwrap();
    let b = built.forward(&input).unwrap();
    assert_eq!(a.data(), b.data(), "builder-built alexnet must match the table net bitwise");
}

#[test]
fn builder_googlenet_forward_is_bitwise_table_googlenet() {
    let input = Tensor::random(&[3, 224, 224], 0xB18);
    let table = NetRunner::new(NetPlans::build("googlenet", "direct", &haswell(), 1).unwrap())
        .unwrap();
    let model = builder::googlenet();
    let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
    let built = NetRunner::from_graph(plans, model.graph, 1).unwrap();
    let a = table.forward(&input).unwrap();
    let b = built.forward(&input).unwrap();
    assert_eq!(a.data(), b.data(), "builder-built googlenet must match the table DAG bitwise");
}

// ---------------------------------------------------------------------
// The residual micro-net: builder == JSON == naive reference
// ---------------------------------------------------------------------

fn spec_path() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/models/resnet_micro.json")
}

fn resnet_runner(model: &Model) -> NetRunner {
    let plans = NetPlans::build_model(model, "direct", &haswell(), 1).unwrap();
    NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap()
}

/// NCHW naive reference with explicit residual sums and per-conv
/// BatchNorm + ReLU interludes (BN ordinals follow node order, exactly
/// as the planner resolves them), weights from the same deterministic
/// `net_kernel` / `net_bn_params` streams the planner uses.
fn resnet_reference(model: &Model, input: &Tensor) -> Tensor {
    let ks: Vec<Tensor> =
        model.shapes.iter().enumerate().map(|(i, s)| net_kernel(i, s)).collect();
    let conv = |x: &Tensor, i: usize| conv_naive(x, &ks[i], &model.shapes[i]).unwrap();
    let bn = |x: &Tensor, ord: usize| {
        let (scale, shift) = net_bn_params(ord, x.shape()[0]);
        let hw = x.shape()[1] * x.shape()[2];
        let mut d = x.data().to_vec();
        for (ci, px) in d.chunks_mut(hw).enumerate() {
            for v in px.iter_mut() {
                *v *= scale[ci];
                *v += shift[ci];
            }
        }
        Tensor::from_vec(x.shape(), d).unwrap()
    };
    let relu = |x: &Tensor| {
        let d = x.data().iter().map(|v| v.max(0.0)).collect();
        Tensor::from_vec(x.shape(), d).unwrap()
    };
    let stem = relu(&bn(&conv(input, 0), 0));
    let b2 = bn(&conv(&relu(&bn(&conv(&stem, 1), 1)), 2), 2);
    let j1 = relu(&add_nchw(&stem, &b2).unwrap());
    let b4 = bn(&conv(&relu(&bn(&conv(&j1, 3), 3)), 4), 4);
    let j2 = relu(&add_nchw(&j1, &b4).unwrap());
    conv(&pool_nchw(&j2, 2, 2, 2, 2, 0, 0).unwrap(), 5)
}

#[test]
fn committed_spec_parses_to_the_builder_program() {
    let from_file = Model::from_file(spec_path()).unwrap();
    let programmatic = builder::resnet_micro();
    assert_eq!(
        from_file, programmatic,
        "examples/models/resnet_micro.json drifted from nets::builder::resnet_micro()"
    );
    // And the serialized form round-trips.
    let again = Model::from_json(&programmatic.to_json()).unwrap();
    assert_eq!(programmatic, again);
}

#[test]
fn residual_net_matches_naive_reference_via_builder_and_json() {
    let input = Tensor::random(&[3, 32, 32], 0x2E5);
    let want = resnet_reference(&builder::resnet_micro(), &input);
    for model in [builder::resnet_micro(), Model::from_file(spec_path()).unwrap()] {
        let runner = resnet_runner(&model);
        assert_eq!(runner.output_len(), 32 * 16 * 16);
        let got = runner.forward(&input).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert!(
            got.allclose(&want, 1e-3, 1e-3),
            "residual forward diverged: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn residual_net_is_zero_alloc_and_zero_overhead() {
    let model = Model::from_file(spec_path()).unwrap();
    let runner = resnet_runner(&model);
    assert_eq!(runner.retained_bytes(), 0);
    assert_eq!(runner.workspace_bytes(), 0);
    assert_eq!(runner.overhead_bytes(), 0, "direct residual net must be zero-overhead");

    let mut arena = runner.arena();
    let input = vec![0.1f32; runner.input_len()];
    let mut output = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let before = allocs_now();
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let after = allocs_now();
    assert_eq!(after - before, 0, "residual forward allocated on the hot path");
    assert!(output.iter().any(|v| *v != 0.0));
}

/// The FUSED f32 schedule keeps both halves of the contract at once:
/// zero overhead and zero hot-path allocations (epilogues ride the
/// conv cores' register tiles, buying no scratch), and — because the
/// f32 epilogue replays the standalone ops' scalar arithmetic — the
/// output is bitwise identical to the unfused schedule.
#[test]
fn fused_residual_net_is_zero_alloc_zero_overhead_and_bitwise_exact() {
    let model = Model::from_file(spec_path()).unwrap();
    let fused = fuse(&model).unwrap();
    let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
    let runner = NetRunner::from_graph_fused(plans, model.graph.clone(), 1, &fused).unwrap();
    assert_eq!(runner.overhead_bytes(), 0, "fused schedule must stay zero-overhead");

    let mut arena = runner.arena();
    let input = vec![0.1f32; runner.input_len()];
    let mut output = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let before = allocs_now();
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let after = allocs_now();
    assert_eq!(after - before, 0, "fused forward allocated on the hot path");

    let unfused = resnet_runner(&model);
    let x = Tensor::random(&[3, 32, 32], 0x2E6);
    let a = runner.forward(&x).unwrap();
    let b = unfused.forward(&x).unwrap();
    assert_eq!(a.data(), b.data(), "fused f32 must be bitwise the unfused schedule");
}

#[test]
fn net_engine_serves_a_spec_model_through_the_coordinator() {
    let model = Model::from_file(spec_path()).unwrap();
    let runner = resnet_runner(&model);
    let image_out = runner.output_len();
    let reference = builder::resnet_micro();

    let engine = NetEngine::new(runner, 2, &[1, 2], "net").unwrap();
    let art = engine.manifest().get("net_b1").unwrap();
    assert_eq!(art.output_shape, vec![1, 32, 16, 16]);

    let cfg = CoordinatorConfig { model_prefix: "net".into(), ..Default::default() };
    let coord = Coordinator::start(engine, cfg).unwrap();
    let inputs: Vec<Tensor> = (0..5).map(|i| Tensor::random(&[3, 32, 32], 900 + i)).collect();
    let pendings: Vec<_> =
        inputs.iter().map(|x| coord.submit_blocking(x.data().to_vec()).unwrap()).collect();
    for (x, p) in inputs.iter().zip(pendings) {
        let out = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(out.len(), image_out);
        let want = resnet_reference(&reference, x);
        let got = Tensor::from_vec(&[32, 16, 16], out).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "served residual output differs");
    }
    assert_eq!(coord.stats().requests, 5);
}

// ---------------------------------------------------------------------
// Negative battery: every GraphBuilder validation error
// ---------------------------------------------------------------------

#[test]
fn builder_negative_battery() {
    // No nodes at all.
    assert!(GraphBuilder::new("t").build(builder_output_stub()).is_err(), "empty model");

    // Input must be first / unique.
    let mut b = GraphBuilder::new("t");
    let x = b.input(3, 8, 8).unwrap();
    assert!(b.input(3, 8, 8).is_err(), "second input rejected");
    assert!(b.input_named("late", 3, 8, 8).is_err(), "named late input rejected");

    // Zero-dimension input.
    assert!(GraphBuilder::new("t").input(0, 8, 8).is_err(), "zero channel input");

    // Duplicate / empty node names.
    let c0 = b.conv("c0", x, 8, 3, 1, 1).unwrap();
    assert!(b.conv("c0", x, 8, 3, 1, 1).is_err(), "duplicate name");
    assert!(b.conv("", x, 8, 3, 1, 1).is_err(), "empty name");

    // Conv shape errors: kernel larger than padded input; zero c_o.
    assert!(b.conv("big", x, 8, 11, 1, 0).is_err(), "kernel > padded input");
    assert!(b.conv("none", x, 0, 3, 1, 1).is_err(), "zero output channels");

    // conv_with input-mismatch (declared input != pred output).
    let wrong = ConvShape::new(5, 8, 8, 8, 3, 3, 1, 1);
    assert!(b.conv_with("mism", x, wrong).is_err(), "conv_with channel mismatch");

    // Pool geometry: pad >= kernel, kernel > padded extent, zero stride.
    assert!(b.pool("p1", x, 2, 1, 2).is_err(), "pad >= kernel");
    assert!(b.pool("p2", x, 11, 1, 0).is_err(), "kernel > extent");
    assert!(b.pool("p3", x, 2, 0, 0).is_err(), "zero stride");

    // pool_to upsampling.
    assert!(b.pool_to("up", x, 16, 16).is_err(), "upsampling glue");

    // Join arity and operand mismatches.
    assert!(b.concat("cat1", &[c0]).is_err(), "concat arity");
    assert!(b.add("add1", &[c0]).is_err(), "add arity");
    let down = b.pool("down", c0, 2, 2, 0).unwrap();
    assert!(b.concat("cat2", &[c0, down]).is_err(), "concat extent mismatch");
    assert!(b.add("add2", &[c0, down]).is_err(), "add shape mismatch");

    // Output must be the last node: `down` is live, so naming an earlier
    // node the output (or leaving `down` dead) must fail the build.
    let ta = b.conv("tail_a", down, 8, 3, 1, 1).unwrap();
    let tb = b.conv("tail_b", down, 8, 3, 1, 1).unwrap();
    let j = b.add("join", &[ta, tb]).unwrap();
    let _tail = b.conv("tail", j, 8, 3, 1, 1).unwrap();
    assert!(b.build(j).is_err(), "output must be the last node");
}

/// A NodeId for the empty-build negative test: builders hand these out,
/// so fabricate one from a throwaway builder.
fn builder_output_stub() -> dconv::nets::NodeId {
    let mut b = GraphBuilder::new("stub");
    b.input(1, 1, 1).unwrap()
}

#[test]
fn cross_lane_dependency_is_rejected_at_build() {
    let mut b = GraphBuilder::new("t");
    let x = b.input(4, 4, 4).unwrap();
    b.lane(0, 0);
    let a = b.conv("a", x, 8, 1, 1, 0).unwrap();
    b.lane(0, 1);
    let c = b.conv("b", a, 8, 1, 1, 0).unwrap();
    b.backbone();
    assert!(b.build(c).is_err(), "lane 1 depending on lane 0 must be rejected");
}

#[test]
fn spec_layer_numbering_follows_node_order() {
    // The spec promises conv layers are numbered in node order — that is
    // what ties the JSON file to the deterministic net_kernel weights.
    let model = Model::from_file(spec_path()).unwrap();
    let names: Vec<&str> = model
        .graph
        .nodes
        .iter()
        .filter(|n| matches!(n.op, dconv::nets::GraphOp::Conv { .. }))
        .map(|n| n.name.as_str())
        .collect();
    assert_eq!(names, ["conv0", "conv1", "conv2", "conv3", "conv4", "conv5"]);
    let layers = model.layers();
    assert_eq!(layers[5].name, "conv5");
    assert_eq!(layers[5].shape, ConvShape::new(16, 16, 16, 32, 3, 3, 1, 1));
}

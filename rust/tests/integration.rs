//! Cross-module integration tests: every convolution implementation in
//! the crate must agree on the same layers, layers must chain in the §4
//! blocked layout without repacking, and the simulator must stay
//! consistent with the crate's structural ground truth.
//!
//! Each algorithm is exercised through its non-deprecated core
//! (`conv_direct_blocked`, the `*_into` slice kernels, `FftConvPlan`)
//! via small one-shot helpers below; the plan/execute API has its own
//! cross-backend suite in `conformance.rs`.

use dconv::arch::{haswell, host};
use dconv::conv::reorder::kernel_to_hwio;
use dconv::conv::{
    conv_direct_blocked, conv_naive, conv_reorder_into, select_params, BlockParams, ConvShape,
};
use dconv::fftconv::FftConvPlan;
use dconv::layout::{
    from_blocked_io, nchw_to_nhwc, nhwc_to_nchw, to_blocked_io, to_blocked_kernel,
};
use dconv::lowering::{conv_im2col_into, conv_mec};
use dconv::nets;
use dconv::sim::{estimate, Algo};
use dconv::tensor::Tensor;
use dconv::winograd::{
    conv_winograd_into, transform_kernels, winograd_applicable, winograd_workspace_len,
};

/// One-shot §4 pack -> blocked direct conv -> unpack with explicit
/// `BlockParams`.
fn conv_direct(
    input: &Tensor,
    kernel: &Tensor,
    s: &ConvShape,
    bp: BlockParams,
    threads: usize,
) -> Tensor {
    let bi = to_blocked_io(input, bp.c_ib).unwrap();
    let bk = to_blocked_kernel(kernel, bp.c_ob, bp.c_ib).unwrap();
    let bo = conv_direct_blocked(&bi, &bk, s, bp, threads).unwrap();
    from_blocked_io(&bo).unwrap()
}

/// Channel-last one-shot over the Algorithm-2 `_into` core.
fn conv_reorder(nhwc: &Tensor, hwio: &Tensor, s: &ConvShape) -> Tensor {
    let mut out = Tensor::zeros(&[s.h_o(), s.w_o(), s.c_o]);
    conv_reorder_into(nhwc.data(), hwio.data(), s, out.data_mut()).unwrap();
    out
}

/// One-shot im2col + SGEMM over a fresh lowering workspace.
fn conv_im2col(input: &Tensor, kernel: &Tensor, s: &ConvShape) -> Tensor {
    let (h_o, w_o) = (s.h_o(), s.w_o());
    let mut ws = vec![0.0f32; s.c_i * s.h_f * s.w_f * h_o * w_o];
    let mut out = Tensor::zeros(&[s.c_o, h_o, w_o]);
    conv_im2col_into(input.data(), kernel.data(), s, 1, out.data_mut(), &mut ws).unwrap();
    out
}

/// One-shot Winograd F(2x2,3x3) over freshly transformed weights.
fn conv_winograd(input: &Tensor, kernel: &Tensor, s: &ConvShape) -> Tensor {
    let u = transform_kernels(kernel, s).unwrap();
    let mut out = Tensor::zeros(&[s.c_o, s.h_o(), s.w_o()]);
    let mut v = vec![0.0f32; winograd_workspace_len(s)];
    conv_winograd_into(input.data(), &u, s, out.data_mut(), &mut v).unwrap();
    out
}

/// Every implementation on one battery of layers.
#[test]
fn all_algorithms_agree() {
    let shapes = [
        ConvShape::new(3, 11, 11, 8, 3, 3, 1, 0),
        ConvShape::new(4, 9, 9, 8, 3, 3, 1, 1),
        ConvShape::new(8, 13, 13, 16, 5, 5, 2, 2),
        ConvShape::new(16, 8, 8, 8, 1, 1, 1, 0),
        ConvShape::new(3, 23, 23, 16, 11, 11, 4, 0), // AlexNet conv1 geometry
    ];
    let m = host();
    for (i, s) in shapes.iter().enumerate() {
        let seed = 100 + i as u64;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();

        let bp = select_params(&m, s);
        let direct = conv_direct(&input, &kernel, s, bp, 2);
        assert!(direct.allclose(&want, 1e-3, 1e-4), "direct {s:?}");

        let reord = nhwc_to_nchw(&conv_reorder(
            &nchw_to_nhwc(&input).unwrap(),
            &kernel_to_hwio(&kernel).unwrap(),
            s,
        ))
        .unwrap();
        assert!(reord.allclose(&want, 1e-3, 1e-4), "reorder {s:?}");

        let im2col = conv_im2col(&input, &kernel, s);
        assert!(im2col.allclose(&want, 1e-3, 1e-4), "im2col {s:?}");

        let mec = conv_mec(&input, &kernel, s).unwrap();
        assert!(mec.allclose(&want, 1e-3, 1e-4), "mec {s:?}");

        let fft = FftConvPlan::new(&kernel, s).unwrap().run(&input).unwrap();
        assert!(fft.allclose(&want, 1e-2, 1e-2), "fft {s:?}");

        if winograd_applicable(s) {
            let wino = conv_winograd(&input, &kernel, s);
            assert!(wino.allclose(&want, 1e-2, 1e-2), "winograd {s:?}");
        }
    }
}

/// The §4 property the coordinator relies on: layer k's blocked output
/// feeds layer k+1 directly — no repacking between layers, and the final
/// result matches running each layer separately on conventional layouts.
#[test]
fn layers_chain_in_blocked_layout() {
    let s1 = ConvShape::new(8, 16, 16, 16, 3, 3, 1, 1);
    let s2 = ConvShape::new(16, 16, 16, 32, 3, 3, 1, 1);
    let bp1 = BlockParams::new(8, 4, 8); // c_ob of layer1 == c_ib of layer2
    let bp2 = BlockParams::new(8, 4, 16);

    let input = Tensor::random(&[s1.c_i, s1.h_i, s1.w_i], 7);
    let k1 = Tensor::random(&[s1.c_o, s1.c_i, s1.h_f, s1.w_f], 8);
    let k2 = Tensor::random(&[s2.c_o, s2.c_i, s2.h_f, s2.w_f], 9);

    // Conventional-path reference.
    let mid = conv_naive(&input, &k1, &s1).unwrap();
    let want = conv_naive(&mid, &k2, &s2).unwrap();

    // Blocked chain: pack once at the entry, never again. Layer 1's
    // output pencil (c_ob=8) is layer 2's input pencil... here layer 2
    // uses c_ib=16 = full channels, so reinterpret the [2][16][16][8]
    // blocked tensor: with c_ob=8 blocks and H_o=W_o=16 the chaining
    // needs matching pencils; use c_ib2 = bp1.c_ob instead.
    let bp2 = BlockParams::new(bp2.c_ob, bp2.w_ob, bp1.c_ob);
    let bin = to_blocked_io(&input, bp1.c_ib).unwrap();
    let bk1 = to_blocked_kernel(&k1, bp1.c_ob, bp1.c_ib).unwrap();
    let bk2 = to_blocked_kernel(&k2, bp2.c_ob, bp2.c_ib).unwrap();
    let bmid = conv_direct_blocked(&bin, &bk1, &s1, bp1, 1).unwrap();
    // bmid IS the layer-2 input — same tensor, zero repacking:
    let bout = conv_direct_blocked(&bmid, &bk2, &s2, bp2, 1).unwrap();
    let got = from_blocked_io(&bout).unwrap();
    assert!(got.allclose(&want, 1e-3, 1e-3), "chained: {}", got.max_abs_diff(&want));
}

/// Analytical parameters must be executable for every paper layer, and
/// the resulting kernel must be correct on a downscaled version.
#[test]
fn selected_params_run_on_downscaled_paper_layers() {
    let m = host();
    for l in nets::all_layers().into_iter().step_by(7) {
        let mut s = l.shape.clone();
        while s.h_i > 28 && s.h_o() > 4 {
            s.h_i /= 2;
            s.w_i /= 2;
        }
        while s.c_i * s.c_o > 64 * 64 {
            s.c_i = (s.c_i / 2).max(1);
            s.c_o = (s.c_o / 2).max(8);
        }
        if s.validate().is_err() || s.h_i + 2 * s.pad < s.h_f {
            continue;
        }
        let bp = select_params(&m, &s);
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 3);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 4);
        let want = conv_naive(&input, &kernel, &s).unwrap();
        let got = conv_direct(&input, &kernel, &s, bp, 1);
        assert!(got.allclose(&want, 1e-3, 1e-3), "{} ({s:?}, {bp:?})", l.name);
    }
}

/// The simulator's structural invariants against the real nets: direct
/// beats im2col+SGEMM on every layer of every net on every machine
/// (the paper's headline "10% to 400%"), with speedups within sane bounds.
#[test]
fn simulator_headline_claim_over_all_nets() {
    for m in dconv::arch::table1() {
        for l in nets::all_layers() {
            let d = estimate(&m, &l.shape, Algo::Direct, m.cores);
            let g = estimate(&m, &l.shape, Algo::Im2colGemm, m.cores);
            let rel = g.secs / d.secs;
            assert!(rel > 1.0, "{} on {}: direct should win (rel {rel:.2})", l.name, m.name);
            assert!(rel < 20.0, "{} on {}: speedup implausible (rel {rel:.2})", l.name, m.name);
        }
    }
}

/// Memory accounting: direct = 0 extra bytes, baselines ordered
/// im2col > mec > 0 on every standard layer.
#[test]
fn memory_overhead_ordering() {
    let m = haswell();
    for l in nets::all_layers() {
        let d = estimate(&m, &l.shape, Algo::Direct, 1);
        let g = estimate(&m, &l.shape, Algo::Im2colGemm, 1);
        let mec = estimate(&m, &l.shape, Algo::Mec, 1);
        assert_eq!(d.extra_bytes, 0, "{}", l.name);
        assert!(g.extra_bytes > 0, "{}", l.name);
        assert!(mec.extra_bytes > 0, "{}", l.name);
        // Cho & Brand's saving comes from eliminating kernel-row
        // duplication, so it only applies to spatial kernels — for 1x1
        // convs im2col already duplicates nothing.
        if l.shape.h_f * l.shape.w_f > 1 {
            assert!(g.extra_bytes > mec.extra_bytes, "{}: im2col must exceed MEC", l.name);
        }
    }
}

/// Threaded direct convolution is exact (not approximately equal) vs the
/// single-threaded result: thread partitioning touches disjoint blocks.
#[test]
fn threading_is_bitwise_deterministic() {
    let s = ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1);
    let bp = BlockParams::new(8, 4, 4);
    let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 21);
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 22);
    let t1 = conv_direct(&input, &kernel, &s, bp, 1);
    for p in [2, 3, 4, 8] {
        let tp = conv_direct(&input, &kernel, &s, bp, p);
        assert_eq!(t1, tp, "threads={p} must be bitwise identical");
    }
}

//! The measured cost-model planner (`dconv::tune`) end to end:
//!
//! * autotune-cache JSON round-trip is lossless (proptest-style over
//!   random heuristic records);
//! * stale-schema files and foreign-arch-fingerprint entries are
//!   ignored on lookup but foreign entries survive save/reload;
//! * `MeasureOnce` measures a layer exactly once — the second lookup
//!   is a cache hit with zero new measurements;
//! * the acceptance battery: a tuned `alexnet` plan mixing two
//!   distinct backends executes bitwise-equal to per-layer
//!   single-backend plans, its whole-net forward is bitwise identical
//!   across two fresh `CacheOnly` tuners sharing one cache file (the
//!   cross-process determinism guard; CI's `autotune-smoke` job covers
//!   the literal two-process case), and the mixed-backend forward
//!   passes the counting-allocator zero-alloc proof.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;
use std::collections::BTreeSet;
use std::path::PathBuf;

use dconv::arch::haswell;
use dconv::conv::ConvShape;
use dconv::engine::{BackendRegistry, NetRunner};
use dconv::nets::{self, net_kernel, NetPlans};
use dconv::tensor::{Tensor, XorShiftRng};
use dconv::tune::{
    shape_key, ArchFingerprint, BestHeuristic, CacheEntry, TuneCache, TunePolicy, Tuner,
    DTYPE_F32, SCHEMA_VERSION,
};

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as net_forward.rs: the
// parallel test harness's other threads cannot perturb the assertion).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

/// Unique-per-test temp cache path (tests run concurrently in one
/// process, so the tag keeps them from clobbering each other).
fn temp_cache(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dconv_tune_{tag}_{}.json", std::process::id()))
}

/// A random-but-valid heuristic record. `time_secs` is an integer
/// scaled by a power of two, so the value is an exact f64 and any
/// round-trip loss is detectable by `==`.
fn random_heuristic(rng: &mut XorShiftRng) -> BestHeuristic {
    let backends = ["direct", "reorder", "im2col", "fft", "winograd"];
    BestHeuristic {
        backend: backends[rng.next_usize(backends.len())].to_string(),
        time_secs: (rng.next_u64() % (1 << 53)) as f64 * (0.5f64).powi(70),
        workspace_bytes: rng.next_u64() % (1 << 50),
        retained_bytes: rng.next_u64() % (1 << 50),
        deterministic: rng.next_u64() % 2 == 0,
        simd: format!("simd-{}", rng.next_usize(4)),
    }
}

fn random_entry(rng: &mut XorShiftRng, arch: &str, shape: &str) -> CacheEntry {
    CacheEntry {
        arch: arch.to_string(),
        shape: shape.to_string(),
        dtype: DTYPE_F32.to_string(),
        best: random_heuristic(rng),
        candidates: (0..rng.next_usize(4)).map(|_| random_heuristic(rng)).collect(),
    }
}

/// An entry that forces `backend` as the winner (for seeding a
/// `CacheOnly` plan deterministically).
fn forced(backend: &str, simd: &str) -> BestHeuristic {
    BestHeuristic {
        backend: backend.to_string(),
        time_secs: 1e-6,
        workspace_bytes: 0,
        retained_bytes: 0,
        deterministic: true,
        simd: simd.to_string(),
    }
}

// ---------------------------------------------------------------------
// Cache persistence
// ---------------------------------------------------------------------

#[test]
fn cache_json_round_trip_is_lossless() {
    let mut rng = XorShiftRng::new(0x7E57_CACE);
    let path = temp_cache("roundtrip");
    std::fs::remove_file(&path).ok();
    let mut cache = TuneCache::load(&path).unwrap();
    assert!(cache.is_empty(), "fresh path must load empty");
    for case in 0..40 {
        let arch = format!("arch-{}", rng.next_usize(4));
        let shape = format!("shape-{case}");
        cache.insert(random_entry(&mut rng, &arch, &shape));
    }
    cache.save().unwrap();
    let reloaded = TuneCache::load(&path).unwrap();
    assert_eq!(cache.entries(), reloaded.entries(), "JSON round trip must be lossless");
    // Atomic-write hygiene: no temp file left behind.
    let dir_entries: Vec<_> = std::fs::read_dir(std::env::temp_dir())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains("dconv_tune_roundtrip") && n.contains(".tmp."))
        .collect();
    assert!(dir_entries.is_empty(), "temp files left behind: {dir_entries:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn stale_schema_version_discards_the_file() {
    let path = temp_cache("schema");
    let mut rng = XorShiftRng::new(0x5CE4A);
    // Write a file that is valid in every way except its schema tag.
    let mut cache = TuneCache::load(&path).unwrap();
    cache.insert(random_entry(&mut rng, "arch-x", "shape-x"));
    cache.save().unwrap();
    let good = std::fs::read_to_string(&path).unwrap();
    let stale = good.replacen(
        &format!("\"schema\": {SCHEMA_VERSION}"),
        &format!("\"schema\": {}", SCHEMA_VERSION + 1),
        1,
    );
    assert_ne!(good, stale, "schema tag must appear in the serialized file");
    std::fs::write(&path, stale).unwrap();
    assert!(TuneCache::load(&path).unwrap().is_empty(), "stale schema must be discarded");
    std::fs::remove_file(&path).ok();
}

#[test]
fn foreign_arch_entries_are_invisible_but_preserved() {
    let m = haswell();
    let host_arch = ArchFingerprint::current(&m).key();
    let path = temp_cache("foreign");
    std::fs::remove_file(&path).ok();
    let mut rng = XorShiftRng::new(0xF04E16);
    let mut cache = TuneCache::load(&path).unwrap();
    // Proptest-style: many random foreign records, none may answer a
    // host-fingerprint lookup.
    for i in 0..25 {
        let foreign_arch = format!("alien-isa-{}/l{}/c{}", i % 5, rng.next_usize(64), i);
        assert_ne!(foreign_arch, host_arch);
        cache.insert(random_entry(&mut rng, &foreign_arch, &format!("shape-{i}")));
    }
    for i in 0..25 {
        assert!(cache.lookup(&host_arch, &format!("shape-{i}"), DTYPE_F32).is_none());
    }
    // Insert one host entry, save, reload: the foreign records survive
    // alongside it (one cache file can serve a fleet).
    let mut host_entry = random_entry(&mut rng, &host_arch, "shape-0");
    host_entry.best = forced("direct", "any");
    cache.insert(host_entry);
    cache.save().unwrap();
    let reloaded = TuneCache::load(&path).unwrap();
    assert_eq!(reloaded.len(), 26);
    assert_eq!(
        reloaded.lookup(&host_arch, "shape-0", DTYPE_F32).unwrap().best.backend,
        "direct"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn tuner_treats_foreign_fingerprint_as_miss() {
    let m = haswell();
    let s = ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1);
    let path = temp_cache("tuner_foreign");
    std::fs::remove_file(&path).ok();
    let mut cache = TuneCache::load(&path).unwrap();
    cache.insert(CacheEntry {
        arch: "definitely-not-this-host/l128/c999".to_string(),
        shape: shape_key(&s),
        dtype: DTYPE_F32.to_string(),
        best: forced("fft", "alien"),
        candidates: vec![forced("fft", "alien")],
    });
    cache.save().unwrap();
    let mut tuner = Tuner::with_cache_file(TunePolicy::CacheOnly, &path).unwrap();
    let kernel = Tensor::random(&[16, 8, 3, 3], 2);
    let input = Tensor::random(&[8, 9, 9], 1);
    let choice = tuner.choose(&s, &kernel, &input, &m, 1).unwrap();
    assert!(!choice.cache_hit, "foreign fingerprint must not hit");
    assert_eq!(choice.backend, "direct", "CacheOnly miss falls back to the heuristic");
    assert_eq!(tuner.hits(), 0);
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// Measure-once behaviour
// ---------------------------------------------------------------------

#[test]
fn measure_once_measures_then_hits_the_cache() {
    let m = haswell();
    let s = ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1);
    let kernel = Tensor::random(&[16, 8, 3, 3], 2);
    let input = Tensor::random(&[8, 9, 9], 1);
    let mut tuner = Tuner::new(TunePolicy::MeasureOnce).budget_ms(2);
    let first = tuner.choose(&s, &kernel, &input, &m, 1).unwrap();
    assert!(!first.cache_hit && first.measured);
    assert!(first.candidates.len() >= 2, "dense 3x3/s1 admits several backends");
    assert!(first.candidates.windows(2).all(|w| w[0].time_secs <= w[1].time_secs));
    assert_eq!(first.backend, first.candidates[0].backend, "winner is the fastest candidate");
    let second = tuner.choose(&s, &kernel, &input, &m, 1).unwrap();
    assert!(second.cache_hit && !second.measured);
    assert_eq!(second.backend, first.backend);
    assert_eq!(second.candidates, first.candidates, "hit returns the recorded ranking");
    assert_eq!((tuner.lookups(), tuner.hits(), tuner.measurements()), (2, 1, 1));
}

// ---------------------------------------------------------------------
// Acceptance: mixed-backend alexnet plan — bitwise, zero-alloc,
// bit-reproducible across fresh tuners sharing one cache file
// ---------------------------------------------------------------------

/// Seed the cache so conv1 runs `reorder` (NHWC in/out) and the tail
/// runs `direct` (blocked in/out): two distinct backends with
/// *different* layouts, so the Adapt staging between them is genuinely
/// exercised, and both allocation-free in execute (the zero-alloc
/// proof stays meaningful). `CacheOnly` then resolves every layer from
/// the file, deterministically.
fn seed_mixed_alexnet_cache(path: &PathBuf) {
    let m = haswell();
    let arch = ArchFingerprint::current(&m).key();
    let mut cache = TuneCache::load(path).unwrap();
    for (i, layer) in nets::alexnet().iter().enumerate() {
        let backend = if i == 0 { "reorder" } else { "direct" };
        cache.insert(CacheEntry {
            arch: arch.clone(),
            shape: shape_key(&layer.shape),
            dtype: DTYPE_F32.to_string(),
            best: forced(backend, "any"),
            candidates: vec![forced(backend, "any")],
        });
    }
    cache.save().unwrap();
}

fn build_mixed(path: &PathBuf) -> NetPlans {
    let m = haswell();
    let mut tuner = Tuner::with_cache_file(TunePolicy::CacheOnly, path).unwrap();
    let (plans, report) = NetPlans::build_tuned("alexnet", &m, &mut tuner, 1).unwrap();
    assert!(report.iter().all(|r| r.cache_hit && !r.measured), "all layers from cache");
    assert_eq!(tuner.hits(), 5);
    assert_eq!(tuner.measurements(), 0, "CacheOnly never measures");
    plans
}

#[test]
fn tuned_alexnet_mixes_backends_bitwise_and_zero_alloc() {
    let m = haswell();
    let path = temp_cache("mixed");
    std::fs::remove_file(&path).ok();
    seed_mixed_alexnet_cache(&path);

    let plans_a = build_mixed(&path);
    let distinct: BTreeSet<&str> = plans_a.layers.iter().map(|l| l.backend).collect();
    assert!(distinct.len() >= 2, "plan must mix >= 2 backends, got {distinct:?}");
    assert_eq!(plans_a.layers[0].backend, "reorder");
    assert!(plans_a.layers[1..].iter().all(|l| l.backend == "direct"));
    // Both chosen backends are zero-overhead, network-wide.
    assert_eq!(plans_a.total_retained_bytes() + plans_a.total_workspace_bytes(), 0);

    // Per-layer: the tuned plan executes bitwise-equal to a fresh
    // single-backend plan of the same layer.
    let registry = BackendRegistry::shared();
    for (i, l) in plans_a.layers.iter().enumerate() {
        let s = &l.layer.shape;
        let kernel = net_kernel(i, s);
        let single = registry.plan(l.backend, s, &kernel, &m, 1).unwrap();
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 0x11A + i as u64);
        let got = l.plan.execute(&input).unwrap();
        let want = single.execute(&input).unwrap();
        assert_eq!(got.data(), want.data(), "layer {i} ({}) not bitwise", l.layer.name);
    }

    // Whole-net: two fresh tuners over the same cache file (fresh
    // loads, as two processes would do) produce bit-identical
    // forwards, and the mixed-backend forward allocates nothing.
    let plans_b = build_mixed(&path);
    let runner_a = NetRunner::new(plans_a).unwrap();
    let runner_b = NetRunner::new(plans_b).unwrap();
    let input = Tensor::random(&[3, 227, 227], 0xA1ED);

    let mut arena_a = runner_a.arena();
    let mut out_a = vec![0.0f32; runner_a.output_len()];
    runner_a.forward_with(&mut arena_a, input.data(), &mut out_a).unwrap();

    let mut arena_b = runner_b.arena();
    let mut out_b = vec![0.0f32; runner_b.output_len()];
    runner_b.forward_with(&mut arena_b, input.data(), &mut out_b).unwrap();
    assert_eq!(out_a, out_b, "CacheOnly planning must be bit-reproducible across fresh tuners");

    let before = allocs_now();
    runner_b.forward_with(&mut arena_b, input.data(), &mut out_b).unwrap();
    assert_eq!(allocs_now(), before, "mixed-backend forward must stay allocation-free");
    assert_eq!(out_a, out_b, "repeat forward stays bitwise identical");

    std::fs::remove_file(&path).ok();
}

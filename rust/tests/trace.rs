//! Observability layer (`dconv::trace`) end to end:
//!
//! * **zero allocation when on** — with tracing enabled, a
//!   whole-network forward performs no heap allocations after setup
//!   (counting allocator): spans land in the arena's preallocated
//!   rings;
//! * **zero interference when off** — with tracing disabled the
//!   forward records nothing and its output is **bitwise identical**
//!   to the traced run (recording never touches the data path);
//! * **span attribution** — a traced forward yields one conv span per
//!   op with the planned-layer index in `meta`, plus input/output
//!   staging and the whole-forward span;
//! * **Chrome export** — real spans serialize through the crate's own
//!   JSON module and parse back with the fields Perfetto needs;
//! * **roofline** — per-layer FLOPs match the naive analytical formula
//!   `2 · c_o · h_o · w_o · (c_i/g) · h_f · w_f` on all three paper
//!   nets, and a traced forward covers ≥95% of the measured wall time;
//! * **serving** — a traced server records the pipeline spans
//!   (assemble/execute/reply) and exposes Prometheus text with the
//!   request counters and span aggregates.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;
use std::sync::Mutex;
use std::time::Duration;

use dconv::arch::haswell;
use dconv::engine::NetRunner;
use dconv::json::Json;
use dconv::nets::{self, NetPlans};
use dconv::serve::{ServeConfig, ServerBuilder};
use dconv::tensor::Tensor;
use dconv::trace::{self, chrome, roofline::RooflineReport, SpanKind};

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as net_forward.rs: the
// parallel test harness's other threads cannot perturb the assertion).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// The trace gate is process-global; tests that toggle it serialize
// here, and a drop guard turns it back off even on assertion failure.
// ---------------------------------------------------------------------

static TRACE_GATE: Mutex<()> = Mutex::new(());

struct TracingOn(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl TracingOn {
    fn acquire() -> TracingOn {
        let g = TRACE_GATE.lock().unwrap_or_else(|p| p.into_inner());
        trace::set_enabled(true);
        TracingOn(g)
    }
}

impl Drop for TracingOn {
    fn drop(&mut self) {
        trace::set_enabled(false);
    }
}

fn alexnet_runner() -> NetRunner {
    let plans = NetPlans::build("alexnet", "direct", &haswell(), 1).unwrap();
    NetRunner::new(plans).unwrap()
}

// ---------------------------------------------------------------------
// Zero allocation when on, zero interference when off
// ---------------------------------------------------------------------

#[test]
fn traced_forward_allocates_nothing_after_setup_on_every_paper_net() {
    let _t = TracingOn::acquire();
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "auto", &haswell(), 1).unwrap();
        let runner = NetRunner::new(plans).unwrap();
        let mut arena = runner.arena();
        let input = vec![0.1f32; runner.input_len()];
        let mut output = vec![0.0f32; runner.output_len()];

        // Warm up once (first touch), then count a fully traced forward.
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let before = allocs_now();
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let after = allocs_now();
        assert_eq!(after - before, 0, "{net}: traced forward allocated on the hot path");
        assert!(!arena.spans().is_empty(), "{net}: traced forward recorded no spans");
    }
}

#[test]
fn disabled_tracing_records_nothing_and_output_is_bitwise_identical() {
    let g = TRACE_GATE.lock().unwrap_or_else(|p| p.into_inner());
    trace::set_enabled(false);

    let runner = alexnet_runner();
    let input = Tensor::random(&[runner.input_len()], 0x7ACE).into_vec();
    let mut arena = runner.arena();
    let mut off = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut off).unwrap();
    assert!(arena.spans().is_empty(), "spans recorded while tracing was off");
    assert_eq!(arena.spans_dropped(), 0);

    // Same runner, same arena, tracing on: the recorded run must be
    // bitwise identical — instrumentation never touches the data path.
    trace::set_enabled(true);
    let mut on = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut on).unwrap();
    trace::set_enabled(false);
    drop(g);
    assert!(!on.is_empty());
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "output[{i}] diverged under tracing");
    }
}

// ---------------------------------------------------------------------
// Span attribution + Chrome export
// ---------------------------------------------------------------------

#[test]
fn traced_forward_attributes_every_conv_and_round_trips_through_chrome_json() {
    let _t = TracingOn::acquire();
    let runner = alexnet_runner();
    let n_layers = runner.plans().layers.len();
    let mut arena = runner.arena();
    let input = vec![0.1f32; runner.input_len()];
    let mut output = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut output).unwrap();

    let spans = arena.spans();
    let convs: Vec<_> = spans.iter().filter(|s| s.kind == SpanKind::Conv).collect();
    assert_eq!(convs.len(), n_layers, "one conv span per planned layer");
    let mut seen: Vec<usize> = convs.iter().map(|s| s.meta as usize).collect();
    seen.sort_unstable();
    assert_eq!(seen, (0..n_layers).collect::<Vec<_>>(), "meta = planned-layer index");
    assert!(spans.iter().any(|s| s.kind == SpanKind::Input));
    assert!(spans.iter().any(|s| s.kind == SpanKind::Output));
    assert_eq!(spans.iter().filter(|s| s.kind == SpanKind::Forward).count(), 1);
    // The merged stream is sorted on the shared epoch timeline.
    assert!(spans.windows(2).all(|w| w[0].t_start <= w[1].t_start));

    let events: Vec<_> =
        spans.iter().map(|s| chrome::event(s, runner.span_name(s), 0)).collect();
    let text = chrome::chrome_json(&events).to_string_pretty();
    let parsed = Json::parse(&text).unwrap();
    let rows = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert_eq!(rows.len(), spans.len());
    for row in rows {
        assert_eq!(row.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(row.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(row.get("dur").and_then(|d| d.as_f64()).is_some());
    }
    // Conv names resolve to "layer [backend/kernel]" through the runner.
    assert!(
        events.iter().any(|e| e.cat == "conv" && e.name.contains("conv1")),
        "conv span names resolve through the plan table"
    );
}

// ---------------------------------------------------------------------
// Roofline: analytical FLOPs + span coverage
// ---------------------------------------------------------------------

#[test]
fn roofline_flops_match_the_naive_formula_on_every_paper_net() {
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "direct", &haswell(), 1).unwrap();
        let report = RooflineReport::from_spans(&plans, &haswell(), &[], 0.0, 4);
        assert_eq!(report.layers.len(), plans.layers.len());
        for (row, l) in report.layers.iter().zip(&plans.layers) {
            let s = &l.layer.shape;
            let want = 2
                * (s.c_o * s.h_o() * s.w_o() * (s.c_i / s.groups) * s.h_f * s.w_f) as u64;
            assert_eq!(row.flops, want, "{net}/{}: analytical FLOPs", row.name);
            let want_bytes = s.input_bytes() + s.kernel_bytes() + s.output_bytes();
            assert_eq!(row.min_bytes, want_bytes, "{net}/{}: f32 min bytes", row.name);
            assert!(row.intensity > 0.0 && row.roof_gflops > 0.0);
        }
    }
}

#[test]
fn traced_forward_covers_at_least_95_percent_of_wall_time() {
    let _t = TracingOn::acquire();
    let runner = alexnet_runner();
    let mut arena = runner.arena();
    let input = vec![0.1f32; runner.input_len()];
    let mut output = vec![0.0f32; runner.output_len()];
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    arena.clear_spans();
    let forwards = 3;
    let (_, wall) = dconv::metrics::time_it(|| {
        for _ in 0..forwards {
            runner.forward_with(&mut arena, &input, &mut output).unwrap();
        }
    });
    let spans = arena.spans();
    let report = RooflineReport::from_spans(runner.plans(), &haswell(), &spans, wall, 4);
    assert_eq!(report.forwards, forwards);
    assert!(report.conv_secs > 0.0);
    assert!(
        report.coverage() >= 0.95,
        "spans cover {:.1}% of wall time (want >= 95%)",
        report.coverage() * 100.0
    );
    let text = report.render();
    assert!(text.starts_with("roofline: alexnet"));
    assert!(text.contains("pct_peak") && text.contains("span coverage"));
}

// ---------------------------------------------------------------------
// Serving: pipeline spans + Prometheus exposition
// ---------------------------------------------------------------------

#[test]
fn traced_server_records_pipeline_spans_and_exposes_prometheus_text() {
    let _t = TracingOn::acquire();
    let cfg = ServeConfig {
        queue_depth: 32,
        batch_wait: Duration::from_millis(1),
        workers: 1,
        batch_sizes: vec![1, 2, 4],
        ..Default::default()
    };
    let mut b = ServerBuilder::new(&haswell(), cfg).backend("direct");
    b.add_model("rm", &nets::builder::resnet_micro()).unwrap();
    let server = b.start().unwrap();
    let h = server.model("rm").unwrap();

    let tickets: Vec<_> = (0..4)
        .map(|i| {
            let x = Tensor::random(&[h.image_in()], 3_000 + i as u64).into_vec();
            server.submit("rm", x).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(60)).unwrap();
    }

    let agg = h.trace_agg();
    assert!(agg.count(SpanKind::Execute) > 0, "execute spans recorded");
    assert!(agg.count(SpanKind::BatchAssemble) > 0, "batch-assembly spans recorded");
    assert!(agg.count(SpanKind::Reply) > 0, "reply spans recorded");
    assert!(agg.count(SpanKind::Conv) > 0, "per-op arena spans drained into the track");
    assert!(agg.secs(SpanKind::Execute) > 0.0);

    let events = server.trace_events();
    assert!(events.iter().any(|e| e.cat == "execute"));

    let text = server.prometheus();
    assert!(text.contains("# TYPE dconv_requests_completed_total counter"));
    assert!(text.contains("dconv_requests_completed_total{model=\"rm\"} 4"));
    assert!(text.contains("dconv_e2e_seconds_count{model=\"rm\"} 4"));
    assert!(text.contains("dconv_span_seconds_total{model=\"rm\",kind=\"execute\"}"));

    // Window reset: snapshot_and_reset hands back the old window and
    // opens a fresh one atomically.
    let w = h.snapshot_and_reset();
    assert_eq!(w.completed, 4);
    assert_eq!(h.stats().completed, 0, "counters reset for the next window");
    server.shutdown().unwrap();
}

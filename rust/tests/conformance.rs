//! Cross-backend conformance suite for the plan/execute engine:
//!
//! * every registry backend's `execute_into` output matches the
//!   `conv_naive` oracle on a grid of adversarial shapes (odd sizes,
//!   stride 2, channel counts that no block size divides);
//! * the direct backend's hot path performs **zero allocations** after
//!   planning (counted by a thread-local counting allocator) and
//!   reports `retained_bytes() + workspace_bytes() == 0` on every
//!   paper benchmark layer;
//! * the coordinator serves repeated requests through one cached
//!   `ConvPlan` (PlanEngine), with results identical to the oracle.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use dconv::arch::haswell;
use dconv::conv::{conv_naive, ConvShape};
use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::engine::{BackendRegistry, ConvAlgo, ConvPlan, PlanEngine, BACKEND_NAMES};
use dconv::nets;
use dconv::tensor::Tensor;

// ---------------------------------------------------------------------
// Thread-local allocation counter. Thread-local (not a global atomic)
// so the parallel test harness's other threads cannot perturb the
// zero-alloc assertion.
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        // try_with: TLS may be unavailable during thread teardown.
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Conformance grid
// ---------------------------------------------------------------------

/// Odd spatial sizes, stride 2, and `c_i`/`c_o` that defeat every
/// power-of-two block size — the shapes the zero-overhead layouts must
/// still handle exactly.
fn grid() -> Vec<ConvShape> {
    vec![
        ConvShape::new(3, 9, 9, 5, 3, 3, 1, 1),      // c_o=5: no vector block divides
        ConvShape::new(5, 11, 11, 7, 3, 3, 2, 1),    // stride 2, odd channels
        ConvShape::new(2, 8, 8, 6, 5, 5, 1, 2),      // 5x5, pad 2
        ConvShape::new(16, 7, 7, 8, 1, 1, 1, 0),     // pointwise, odd spatial
        ConvShape::new(3, 23, 23, 16, 11, 11, 4, 0), // AlexNet conv1 geometry
        ConvShape::new(7, 10, 12, 9, 3, 3, 1, 0),    // non-square, c_i=7, c_o=9
    ]
}

fn tolerance(backend: &str) -> (f32, f32) {
    match backend {
        // Transform-domain arithmetic accumulates more rounding.
        "fft" | "winograd" => (1e-2, 1e-2),
        // 8-bit quantization: per-element error is bounded by half the
        // input scale times the weight L1 norm plus half the output
        // scale (self-calibrated with 1.5x range headroom).
        "direct_i8" => (0.1, 0.1),
        _ => (1e-3, 1e-4),
    }
}

#[test]
fn every_backend_matches_naive_on_the_grid() {
    let registry = BackendRegistry::default();
    let machine = haswell();
    for (i, s) in grid().iter().enumerate() {
        let seed = 500 + i as u64;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        for name in BACKEND_NAMES {
            let algo = registry.get(name).unwrap();
            if !algo.applicable(s) {
                // Non-applicable backends must refuse to plan, not
                // silently compute something else.
                assert!(algo.plan(s, &kernel, &machine, 1).is_err(), "{name} {s:?}");
                continue;
            }
            let plan = algo.plan(s, &kernel, &machine, 1).unwrap();
            assert_eq!(plan.backend(), name);
            let got = plan.execute(&input).unwrap();
            let (rtol, atol) = tolerance(name);
            assert!(
                got.allclose(&want, rtol, atol),
                "{name} mismatch on {s:?}: {}",
                got.max_abs_diff(&want)
            );
            // Plans are reusable: a second execution is bit-identical.
            let again = plan.execute(&input).unwrap();
            assert_eq!(got, again, "{name} not deterministic across reuse on {s:?}");
        }
    }
}

#[test]
fn multithreaded_direct_plans_match_on_the_grid() {
    let registry = BackendRegistry::default();
    let machine = haswell();
    for (i, s) in grid().iter().enumerate() {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 700 + i as u64);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 800 + i as u64);
        let p1 = registry.plan("direct", s, &kernel, &machine, 1).unwrap();
        let p4 = registry.plan("direct", s, &kernel, &machine, 4).unwrap();
        assert_eq!(
            p1.execute(&input).unwrap(),
            p4.execute(&input).unwrap(),
            "thread partitioning must be bitwise deterministic on {s:?}"
        );
    }
}

// ---------------------------------------------------------------------
// Zero-allocation / zero-overhead claims
// ---------------------------------------------------------------------

#[test]
fn direct_execute_into_allocates_nothing_after_planning() {
    let s = ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1);
    let machine = haswell();
    let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);
    let registry = BackendRegistry::default();

    // Zero-overhead backends: direct plus the other permutation-layout
    // algorithms, all with workspace_len() == 0 — including the int8
    // backend, whose f32 boundary quantizes on the fly (nothing staged).
    for name in ["direct", "reorder", "naive", "direct_i8"] {
        let plan = registry.plan(name, &s, &kernel, &machine, 1).unwrap();
        assert_eq!(plan.workspace_len(), 0, "{name}");
        let packed = plan.pack_input(&input).unwrap();
        let mut out = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
        let mut ws = vec![0.0f32; 0];
        // Warm-up, then count.
        plan.execute_into(packed.data(), &mut out, &mut ws).unwrap();
        let before = allocs_now();
        plan.execute_into(packed.data(), &mut out, &mut ws).unwrap();
        let after = allocs_now();
        assert_eq!(after - before, 0, "{name}: execute_into allocated on the hot path");
    }

    // Workspace backends allocate nothing either once the caller owns
    // the workspace.
    for name in ["im2col", "fft", "winograd"] {
        let plan = registry.plan(name, &s, &kernel, &machine, 1).unwrap();
        let mut out = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
        let mut ws = vec![0.0f32; plan.workspace_len()];
        plan.execute_into(input.data(), &mut out, &mut ws).unwrap();
        let before = allocs_now();
        plan.execute_into(input.data(), &mut out, &mut ws).unwrap();
        let after = allocs_now();
        // The Goto SGEMM inside im2col grows two internal pack panels on
        // first use per call-site; allow its bounded packing, forbid
        // anything proportional to repetition for the rest.
        if name == "im2col" {
            assert!(after - before <= 4, "{name}: unexpected allocations ({})", after - before);
        } else {
            assert_eq!(after - before, 0, "{name}: execute_into allocated on the hot path");
        }
    }
}

#[test]
fn direct_backend_is_zero_overhead_on_every_paper_layer() {
    let registry = BackendRegistry::default();
    let machine = haswell();
    for l in nets::all_layers() {
        let s = &l.shape;
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 42);
        let plan = registry.plan("direct", s, &kernel, &machine, 1).unwrap();
        assert_eq!(
            plan.retained_bytes() + plan.workspace_bytes(),
            0,
            "{}/{} must satisfy the zero-memory-overhead claim",
            l.net,
            l.name
        );
    }
}

#[test]
fn workspace_accounting_matches_paper_formulas() {
    let registry = BackendRegistry::default();
    let machine = haswell();
    let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
    let kernel = Tensor::random(&[64, 64, 3, 3], 3);
    let im2col = registry.plan("im2col", &s, &kernel, &machine, 1).unwrap();
    assert_eq!(im2col.workspace_bytes(), s.im2col_bytes());
    let wino = registry.plan("winograd", &s, &kernel, &machine, 1).unwrap();
    // 16/9 transformed weights minus the weights they replace.
    assert_eq!(
        wino.retained_bytes(),
        dconv::winograd::winograd_extra_bytes(&s) - s.kernel_bytes()
    );
    // Wrong workspace size must be rejected, not UB.
    let mut out = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
    let mut tiny = vec![0.0f32; 1];
    let input = Tensor::random(&[64, 56, 56], 4);
    assert!(im2col.execute_into(input.data(), &mut out, &mut tiny).is_err());
}

// ---------------------------------------------------------------------
// SIMD-vs-scalar dispatch battery
// ---------------------------------------------------------------------

/// The dispatch toggle is process-global: serialize every test that
/// flips it so a concurrent comparison keeps its discriminating power.
static DISPATCH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// The adversarial grid plus the shapes that actually reach the SIMD
/// kernels (vector-width channel blocks) and every structural variant
/// they must cover: stride, dilation, groups, depthwise.
fn dispatch_grid() -> Vec<ConvShape> {
    let mut g = grid();
    g.push(ConvShape::new(16, 13, 13, 32, 3, 3, 1, 1)); // vector-width blocks
    g.push(ConvShape::new(32, 9, 9, 16, 3, 3, 2, 1)); // strided, c_ob 16
    g.push(ConvShape::new(8, 14, 14, 16, 3, 3, 1, 2).with_dilation(2)); // dilated
    g.push(ConvShape::new(16, 10, 10, 16, 3, 3, 1, 1).with_groups(2)); // grouped
    g.push(ConvShape::new(16, 12, 12, 16, 3, 3, 1, 1).with_groups(16)); // depthwise
    g
}

/// Every f32 backend that routes through the dispatched microkernels,
/// run with detection active and again with the scalar oracle pinned:
/// the two must agree **bitwise** (the SIMD kernels keep the scalar
/// reduction chain order — this is the force-scalar reproduction
/// guarantee, asserted per shape across the structural grid).
#[test]
fn dispatched_f32_kernels_match_scalar_oracle_bitwise() {
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let registry = BackendRegistry::default();
    let machine = haswell();
    for (i, s) in dispatch_grid().iter().enumerate() {
        let seed = 900 + i as u64;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i / s.groups, s.h_f, s.w_f], seed + 1);
        let plan = registry.plan("direct", s, &kernel, &machine, 1).unwrap();
        let dispatched = plan.execute(&input).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(true);
        let scalar = plan.execute(&input).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(false);
        assert_eq!(
            dispatched.data(),
            scalar.data(),
            "dispatched f32 kernel must be bitwise-equal to the scalar oracle on {s:?}"
        );
        // And both conform to the naive oracle (not just to each other).
        let want = conv_naive(&input, &kernel, s).unwrap();
        assert!(
            dispatched.allclose(&want, 1e-3, 1e-4),
            "direct mismatch vs naive on {s:?}: {}",
            dispatched.max_abs_diff(&want)
        );
    }
}

/// The fused-epilogue path must run on the dispatched vector tile too:
/// fused execute (scale/shift/residual/ReLU6 inside the register tile)
/// with dispatch active vs the scalar-pinned run — still bitwise.
#[test]
fn dispatched_fused_epilogue_matches_scalar_bitwise() {
    use dconv::conv::Epilogue;
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let registry = BackendRegistry::default();
    let machine = haswell();
    for (i, s) in dispatch_grid().iter().enumerate() {
        let seed = 1100 + i as u64;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i / s.groups, s.h_f, s.w_f], seed + 1);
        let ep = Epilogue::bn(
            (0..s.c_o).map(|c| 0.5 + c as f32 * 0.05).collect(),
            (0..s.c_o).map(|c| c as f32 * 0.01 - 0.2).collect(),
        )
        .with_relu(Some(6.0));
        let plan = registry.plan("direct", s, &kernel, &machine, 1).unwrap();
        let out_len = s.c_o * s.h_o() * s.w_o();
        let packed = plan.pack_input(&input).unwrap();
        let mut dispatched = vec![0.0f32; out_len];
        let mut scalar = vec![0.0f32; out_len];
        plan.execute_fused_into(packed.data(), &mut dispatched, &mut [], &ep, None).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(true);
        plan.execute_fused_into(packed.data(), &mut scalar, &mut [], &ep, None).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(false);
        assert_eq!(
            dispatched, scalar,
            "fused epilogue on the vector tile must match the scalar tile bitwise on {s:?}"
        );
    }
}

/// The i8 core is exact integer arithmetic: the AVX2 widening-multiply
/// kernel must reproduce the scalar oracle **bit-for-bit** (on the
/// dequantized f32 boundary, equality of every bit pattern).
#[test]
fn dispatched_i8_core_is_bit_exact_vs_scalar() {
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let registry = BackendRegistry::default();
    let machine = haswell();
    for (i, s) in dispatch_grid().iter().enumerate() {
        let seed = 1300 + i as u64;
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i / s.groups, s.h_f, s.w_f], seed + 1);
        let algo = registry.get("direct_i8").unwrap();
        if !algo.applicable(s) {
            continue;
        }
        let plan = algo.plan(s, &kernel, &machine, 1).unwrap();
        let dispatched = plan.execute(&input).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(true);
        let scalar = plan.execute(&input).unwrap();
        dconv::conv::dispatch::_force_scalar_for_tests(false);
        assert_eq!(
            dispatched, scalar,
            "i8 dispatch must be bit-exact vs the scalar oracle on {s:?}"
        );
    }
}

/// Under `CONV_FORCE_SCALAR=1 cargo test` (the CI force-scalar job)
/// the dispatcher must pin the scalar oracle for the whole process;
/// without the env var this still asserts the cached detection is
/// stable and the labels stay consistent with it.
#[test]
fn conv_force_scalar_env_pins_the_oracle() {
    use dconv::conv::dispatch::{self, SimdLevel};
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let forced = std::env::var("CONV_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0") == Ok(true);
    if forced {
        assert_eq!(dispatch::active(), SimdLevel::Scalar);
        assert_eq!(dispatch::kernel_label_f32(16), "scalar");
        assert_eq!(dispatch::kernel_label_i8(16), "scalar");
    }
    assert_eq!(dispatch::active(), dispatch::active(), "detection must be cached and stable");
}

// ---------------------------------------------------------------------
// Coordinator serves through a cached plan (native, no PJRT)
// ---------------------------------------------------------------------

#[test]
fn coordinator_serves_batches_through_one_cached_plan() {
    let s = ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1);
    let machine = haswell();
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 9);
    let engine = PlanEngine::new(&s, &kernel, "auto", &machine, 1, &[1, 2, 4], "conv").unwrap();
    assert_eq!(engine.plan().backend(), "direct");
    assert_eq!(
        engine.plan().retained_bytes() + engine.plan().workspace_bytes(),
        0,
        "the served plan is zero-overhead"
    );

    let image_in = s.c_i * s.h_i * s.w_i;
    let image_out = s.c_o * s.h_o() * s.w_o();
    let cfg = CoordinatorConfig { model_prefix: "conv".into(), ..Default::default() };
    let coord = Coordinator::start(engine, cfg).unwrap();

    // Single request matches the oracle exactly.
    let img = Tensor::random(&[s.c_i, s.h_i, s.w_i], 77);
    let want = conv_naive(&img, &kernel, &s).unwrap();
    let got = coord.submit(img.data().to_vec()).unwrap().wait().unwrap();
    assert_eq!(got.len(), image_out);
    let got = Tensor::from_vec(&[s.c_o, s.h_o(), s.w_o()], got).unwrap();
    assert!(got.allclose(&want, 1e-3, 1e-4), "served result differs from oracle");

    // A burst: batching kicks in, every response is correct for its own
    // input (padding slots must not leak), all through the same plan.
    let inputs: Vec<Tensor> =
        (0..12).map(|i| Tensor::random(&[s.c_i, s.h_i, s.w_i], 100 + i as u64)).collect();
    let pendings: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit_blocking(x.data().to_vec()).unwrap())
        .collect();
    for (x, p) in inputs.iter().zip(pendings) {
        let out = p.wait().unwrap();
        let want = conv_naive(x, &kernel, &s).unwrap();
        let got = Tensor::from_vec(&[s.c_o, s.h_o(), s.w_o()], out).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-4));
    }
    let stats = coord.stats();
    assert_eq!(stats.requests, 13);
    assert!(stats.batches <= 13);
    assert_eq!(stats.latency.count(), 13);

    // Wrong-sized submissions are rejected up front.
    assert!(coord.submit(vec![0.0; image_in + 1]).is_err());
}

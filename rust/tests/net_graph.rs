//! DAG conformance for the graph executor: GoogLeNet (and friends) run
//! as real branch/concat dataflow, proven against branch-by-branch
//! naive references with *explicit* channel concatenation — no
//! channel-cycling approximation anywhere.
//!
//! * GoogLeNet full forward through [`NetGraph`] matches the reference
//!   exactly (structure) and numerically (f32 reassociation tolerance);
//! * the counting allocator proves the graph executor's hot path
//!   allocates nothing after planning, on all three paper nets;
//! * `overhead_bytes() == 0` network-wide for the direct backend over
//!   the true dataflow, and the liveness arena equals the max live-set;
//! * branch-parallel lanes are bitwise identical to the serial
//!   schedule;
//! * `NetEngine` serves an inception DAG through the coordinator with
//!   the concat output shape (not the last conv layer) in its manifest.
//!
//! The full-size VGG-16 cross-check is `#[ignore]`d (minutes of naive
//! reference work) and runs in CI's `--include-ignored` job.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;

use dconv::arch::haswell;
use dconv::conv::{conv_naive, ConvShape};
use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::engine::{adapt_nchw, pool_nchw, NetEngine, NetRunner};
use dconv::nets::{self, net_kernel, NetGraph, NetPlans};
use dconv::runtime::ModelExecutor;
use dconv::tensor::Tensor;

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as conformance.rs).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Branch-by-branch naive reference for inception-structured tables
// (3 stem convs + 6 convs per module, the `NetGraph::inception` layout)
// ---------------------------------------------------------------------

/// Mirror of the graph builder in plain NCHW tensors: stem chain with
/// derived pooling, then per module four explicit branches —
/// `1x1 | 3x3_reduce->3x3 | 5x5_reduce->5x5 | pool3x3s1p1->pool_proj` —
/// concatenated channel-wise in that order. Entirely independent of the
/// arena/layout/scheduling machinery under test.
fn inception_reference(shapes: &[ConvShape], kernels: &[Tensor], input: &Tensor) -> Tensor {
    let conv = |x: &Tensor, i: usize| conv_naive(x, &kernels[i], &shapes[i]).unwrap();
    let fit = |x: &Tensor, s: &ConvShape| adapt_nchw(x, s.c_i, s.h_i, s.w_i).unwrap();
    let mut x = fit(input, &shapes[0]);
    for i in 0..3 {
        x = conv(&fit(&x, &shapes[i]), i);
    }
    let modules = (shapes.len() - 3) / 6;
    for m in 0..modules {
        let base = 3 + 6 * m;
        x = fit(&x, &shapes[base]);
        let b0 = conv(&x, base);
        let b1 = conv(&conv(&x, base + 1), base + 2);
        let b2 = conv(&conv(&x, base + 3), base + 4);
        let b3 = conv(&pool_nchw(&x, 3, 3, 1, 1, 1, 1).unwrap(), base + 5);
        let branches = [&b0, &b1, &b2, &b3];
        let mut data = Vec::new();
        for b in branches {
            data.extend_from_slice(b.data());
        }
        let c: usize = branches.iter().map(|t| t.shape()[0]).sum();
        x = Tensor::from_vec(&[c, b0.shape()[1], b0.shape()[2]], data).unwrap();
    }
    x
}

fn paper_shapes(net: &str) -> Vec<ConvShape> {
    nets::by_name(net).unwrap().into_iter().map(|l| l.shape).collect()
}

fn paper_kernels(shapes: &[ConvShape]) -> Vec<Tensor> {
    shapes.iter().enumerate().map(|(i, s)| net_kernel(i, s)).collect()
}

// ---------------------------------------------------------------------
// GoogLeNet: the DAG acceptance test
// ---------------------------------------------------------------------

#[test]
fn googlenet_forward_matches_branch_by_branch_reference() {
    let plans = NetPlans::build("googlenet", "auto", &haswell(), 1).unwrap();
    let runner = NetRunner::new(plans).unwrap();
    // The output is the final inception concat — 1024 channels — not
    // the 128-channel pool_proj that ends the flat layer table. This is
    // the structural point of the graph executor.
    assert_eq!(runner.output_len(), 1024 * 7 * 7);

    let shapes = paper_shapes("googlenet");
    let kernels = paper_kernels(&shapes);
    let input = Tensor::random(&[3, 224, 224], 0x6006);

    let got = runner.forward(&input).unwrap();
    let want = inception_reference(&shapes, &kernels, &input);
    assert_eq!(got.shape(), want.shape());
    assert_eq!(got.shape(), &[1024, 7, 7]);
    assert!(
        got.allclose(&want, 1e-2, 1e-2),
        "googlenet DAG forward diverged from the branch-by-branch reference: {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn googlenet_branch_lanes_are_bitwise_serial() {
    let input = Tensor::random(&[3, 224, 224], 0x6007);
    let build = |lanes| {
        let plans = NetPlans::build("googlenet", "direct", &haswell(), 1).unwrap();
        NetRunner::with_branch_lanes(plans, lanes).unwrap()
    };
    let serial = build(1);
    let laned = build(4);
    assert_eq!(laned.branch_lanes(), 4);
    let a = serial.forward(&input).unwrap();
    let b = laned.forward(&input).unwrap();
    assert_eq!(a.data(), b.data(), "branch scheduling must not change a single bit");
}

// ---------------------------------------------------------------------
// Zero allocations + zero overhead over the graph executor
// ---------------------------------------------------------------------

#[test]
fn graph_executor_allocates_nothing_after_planning_on_every_net() {
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "auto", &haswell(), 1).unwrap();
        let runner = NetRunner::new(plans).unwrap();
        let mut arena = runner.arena();
        let input = vec![0.1f32; runner.input_len()];
        let mut output = vec![0.0f32; runner.output_len()];

        // Warm up once (first touch), then count a full forward.
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let before = allocs_now();
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let after = allocs_now();
        assert_eq!(after - before, 0, "{net}: graph forward allocated on the hot path");
        assert!(output.iter().any(|v| *v != 0.0), "{net}: forward produced no output");
    }
}

#[test]
fn overhead_is_zero_and_arena_is_max_live_on_every_net() {
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "direct", &haswell(), 1).unwrap();
        let runner = NetRunner::new(plans).unwrap();
        assert_eq!(runner.retained_bytes(), 0, "{net}");
        assert_eq!(runner.workspace_bytes(), 0, "{net}");
        assert_eq!(runner.overhead_bytes(), 0, "{net}: zero overhead over the true dataflow");
        assert_eq!(
            runner.arena_floats(),
            runner.max_live_floats(),
            "{net}: liveness placement fragmented"
        );
        // No live pair of arena regions may alias.
        let regions = runner.arena_regions();
        for (i, a) in regions.iter().enumerate() {
            for b in &regions[i + 1..] {
                let overlap_t = a.first_step <= b.last_step && b.first_step <= a.last_step;
                let overlap_s = a.offset < b.offset + b.floats && b.offset < a.offset + a.floats;
                assert!(!(overlap_t && overlap_s), "{net}: {} aliases {}", a.name, b.name);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Serving an inception DAG through the coordinator
// ---------------------------------------------------------------------

/// Small inception-style table: stem (3 convs) + 2 modules; cheap
/// enough for a naive reference and a serving burst.
fn mini_inception_shapes() -> Vec<ConvShape> {
    let mut v = vec![
        ConvShape::new(3, 32, 32, 16, 7, 7, 2, 3),
        ConvShape::new(16, 8, 8, 16, 1, 1, 1, 0),
        ConvShape::new(16, 8, 8, 32, 3, 3, 1, 1),
    ];
    let ma =
        [(32, 16, 1, 0), (32, 8, 1, 0), (8, 16, 3, 1), (32, 4, 1, 0), (4, 8, 5, 2), (32, 8, 1, 0)];
    for (ci, co, f, p) in ma {
        v.push(ConvShape::new(ci, 8, 8, co, f, f, 1, p));
    }
    let mb = [
        (48, 32, 1, 0),
        (48, 16, 1, 0),
        (16, 32, 3, 1),
        (48, 8, 1, 0),
        (8, 16, 5, 2),
        (48, 16, 1, 0),
    ];
    for (ci, co, f, p) in mb {
        v.push(ConvShape::new(ci, 4, 4, co, f, f, 1, p));
    }
    v
}

#[test]
fn coordinator_serves_an_inception_dag_through_net_engine() {
    let shapes = mini_inception_shapes();
    let seed = 0xD0;
    let plans = NetPlans::from_shapes("mini", &shapes, "direct", &haswell(), seed).unwrap();
    let kernels: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + i as u64))
        .collect();
    let graph = NetGraph::inception("mini", &shapes).unwrap();
    let runner = NetRunner::from_graph(plans, graph, 1).unwrap();
    let image_out = runner.output_len();
    assert_eq!(image_out, 96 * 4 * 4);

    let engine = NetEngine::new(runner, 2, &[1, 2, 4], "net").unwrap();
    // The manifest must advertise the concat output, not the last conv.
    let art = engine.manifest().get("net_b1").unwrap();
    assert_eq!(art.output_shape, vec![1, 96, 4, 4]);

    let cfg = CoordinatorConfig { model_prefix: "net".into(), ..Default::default() };
    let coord = Coordinator::start(engine, cfg).unwrap();
    let inputs: Vec<Tensor> = (0..9).map(|i| Tensor::random(&[3, 32, 32], 500 + i)).collect();
    let pendings: Vec<_> =
        inputs.iter().map(|x| coord.submit_blocking(x.data().to_vec()).unwrap()).collect();
    for (x, p) in inputs.iter().zip(pendings) {
        let out = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(out.len(), image_out);
        let want = inception_reference(&shapes, &kernels, x);
        let got = Tensor::from_vec(&[96, 4, 4], out).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "served DAG output differs from reference");
    }
    assert_eq!(coord.stats().requests, 9);
}

// ---------------------------------------------------------------------
// Slow full-size cross-checks (CI --include-ignored job)
// ---------------------------------------------------------------------

#[test]
#[ignore = "full-size VGG-16 naive reference takes minutes; run with --include-ignored"]
fn full_vgg16_forward_matches_layerwise_naive_reference() {
    let plans = NetPlans::build("vgg16", "auto", &haswell(), 1).unwrap();
    let runner = NetRunner::new(plans).unwrap();
    let shapes = paper_shapes("vgg16");
    let kernels = paper_kernels(&shapes);
    let input = Tensor::random(&[3, 224, 224], 0x7716);

    let got = runner.forward(&input).unwrap();
    let mut act = input.clone();
    for (s, k) in shapes.iter().zip(&kernels) {
        let adapted = adapt_nchw(&act, s.c_i, s.h_i, s.w_i).unwrap();
        act = conv_naive(&adapted, k, s).unwrap();
    }
    assert_eq!(got.shape(), act.shape());
    assert!(
        got.allclose(&act, 1e-2, 1e-2),
        "full vgg16 graph forward diverged: {}",
        got.max_abs_diff(&act)
    );
}

//! Conformance for the int8 quantized engine (`rust/src/quant`):
//!
//! * **exact-integer goldens** — the `alexnet_i8` / `resnet_micro_i8`
//!   fixture entries carry per-node activation params chosen by the
//!   independent NumPy reference (`python/golden_gen.py`) plus the
//!   integer outputs of the full quantized forward; the Rust executor
//!   must reproduce every byte (no tolerances: the integer contract is
//!   pinned, not approximated). The `resnet_micro_i8_fused` /
//!   `mobilenet_micro_i8` entries pin the FUSED schedule the same way:
//!   conv+BN[+add]+ReLU chains collapsed into single-rounding fused
//!   requantizes (`QuantNet::with_node_params_fused`), quantizing
//!   straight to the chain-tail edges — deliberately different integers
//!   from the unfused chained roundings, so each path carries its own
//!   golden;
//! * randomized quantize→dequantize round-trip error bound (≤ scale/2
//!   per element inside the calibrated range);
//! * the i8 `NetRunner` forward performs **zero** heap allocations
//!   after planning (counting allocator), and `direct_i8` keeps
//!   `workspace_bytes() == 0` / network `overhead_bytes() == 0` — the
//!   paper's claim at a quarter of the bytes (alexnet + resnet_micro
//!   here; the heavier googlenet/vgg16 calibrations run in the
//!   `--include-ignored` CI job);
//! * end-to-end f32-vs-i8 accuracy on alexnet and resnet_micro
//!   (rel-tol 5e-2 on the output abs-sum);
//! * the i8 activation arena is exactly 4x smaller than the f32 arena
//!   over the same graph (same element count, 1 byte per element).

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;

use dconv::arch::haswell;
use dconv::engine::{ConvPlan as _, NetRunner};
use dconv::json::Json;
use dconv::nets::{fuse, model_by_name, NetPlans};
use dconv::quant::{
    dequantize, quantize, DType, QuantNet, QuantParams, CALIBRATION_SEED,
};
use dconv::tensor::{Tensor, XorShiftRng};

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as conformance.rs).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Exact-integer goldens
// ---------------------------------------------------------------------

fn fixture() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/net_golden.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("missing golden fixture {} ({e}); run python/golden_gen.py", path.display())
    });
    Json::parse(&text).unwrap()
}

/// Run a built-in net quantized with the fixture's *prescribed* params
/// — through the unfused schedule, or (`fused`) through the fusion pass
/// + `with_node_params_fused` — and return the raw i8 NCHW output.
fn run_i8_with_fixture_params(net: &str, entry: &Json, fused: bool) -> (Vec<i8>, Vec<usize>) {
    let model = model_by_name(net).unwrap();
    let params: Vec<QuantParams> = entry
        .get("node_params")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("{net}: fixture lacks node_params"))
        .iter()
        .map(|p| {
            let pair = p.as_arr().unwrap();
            QuantParams {
                // The generator writes f64(np.float32(s)): the cast
                // back to f32 is lossless, so both sides hold the same
                // scale bit for bit.
                scale: pair[0].as_f64().unwrap() as f32,
                zero_point: pair[1].as_f64().unwrap() as i32,
            }
        })
        .collect();
    assert_eq!(params.len(), model.graph.len(), "{net}: fixture node count drifted");
    let m = haswell();
    let runner = if fused {
        let f = fuse(&model).unwrap();
        let q = QuantNet::with_node_params_fused(
            &model.name,
            &model.graph,
            &model.shapes,
            &m,
            1,
            params,
            &f,
        )
        .unwrap();
        q.runner_fused(1, &f).unwrap()
    } else {
        let q = QuantNet::with_node_params(
            &model.name,
            &model.graph,
            &model.shapes,
            &m,
            1,
            params,
        )
        .unwrap();
        q.runner(1).unwrap()
    };
    assert_eq!(runner.dtype(), DType::I8);
    let d = runner.input_dims();
    let input = Tensor::random(&[d.c, d.h, d.w], CALIBRATION_SEED);
    let o = runner.output_dims();
    let mut arena = runner.arena();
    let mut out = vec![0i8; runner.output_len()];
    runner.forward_q8_with(&mut arena, input.data(), &mut out).unwrap();
    (out, vec![o.c, o.h, o.w])
}

fn check_i8_golden(net: &str, key: &str, fused: bool) {
    let root = fixture();
    let entry = root.get(key).unwrap_or_else(|| panic!("{key}: no fixture entry"));
    let (out, shape) = run_i8_with_fixture_params(net, entry, fused);

    let want_shape: Vec<usize> = entry.get("shape").unwrap().as_arr().unwrap()
        .iter()
        .map(|j| j.as_usize().unwrap())
        .collect();
    assert_eq!(shape, want_shape, "{key}: output shape drifted");

    let sum: i64 = out.iter().map(|&q| q as i64).sum();
    let abs_sum: i64 = out.iter().map(|&q| (q as i64).abs()).sum();
    let want_sum = entry.get("sum_q").unwrap().as_f64().unwrap() as i64;
    let want_abs = entry.get("abs_sum_q").unwrap().as_f64().unwrap() as i64;
    assert_eq!(sum, want_sum, "{key}: integer sum drifted (exact-match contract)");
    assert_eq!(abs_sum, want_abs, "{key}: integer abs-sum drifted");

    for s in entry.get("samples").unwrap().as_arr().unwrap() {
        let pair = s.as_arr().unwrap();
        let (i, want) = (pair[0].as_usize().unwrap(), pair[1].as_f64().unwrap() as i64);
        assert_eq!(
            out[i] as i64, want,
            "{key}: output[{i}] diverged from the NumPy integer reference"
        );
    }
}

#[test]
fn alexnet_i8_matches_numpy_integers_exactly() {
    check_i8_golden("alexnet", "alexnet_i8", false);
}

#[test]
fn resnet_micro_i8_matches_numpy_integers_exactly() {
    check_i8_golden("resnet_micro", "resnet_micro_i8", false);
}

/// The FUSED i8 schedule: five conv+BN[+add]+ReLU chains collapse to
/// single-rounding fused requantizes. NOT bit-comparable to the
/// unfused entry (one rounding vs a chain of them) — pinned by its own
/// NumPy integer program.
#[test]
fn resnet_micro_i8_fused_matches_numpy_integers_exactly() {
    check_i8_golden("resnet_micro", "resnet_micro_i8_fused", true);
}

/// Depthwise, strided and dilated fused convs through the same
/// exact-integer contract.
#[test]
fn mobilenet_micro_i8_fused_matches_numpy_integers_exactly() {
    check_i8_golden("mobilenet_micro", "mobilenet_micro_i8", true);
}

// ---------------------------------------------------------------------
// Randomized properties
// ---------------------------------------------------------------------

/// Quantize→dequantize round-trip error is bounded by scale/2 for any
/// value inside the calibrated range (the textbook affine-quantization
/// guarantee — and the reason `from_range` spends 253 of the 254
/// budget steps with a midpoint-anchored zero point: the endpoints can
/// round outward without ever hitting the clamp).
#[test]
fn prop_quantize_round_trip_error_bounded_by_half_scale() {
    let mut rng = XorShiftRng::new(0x0812);
    for case in 0..200 {
        let a = rng.next_f32() * 20.0 - 10.0;
        let b = rng.next_f32() * 20.0 - 10.0;
        let (lo, hi) = (a.min(b), a.max(b));
        let qp = QuantParams::from_range(lo, hi);
        // from_range widens to include 0; test over the widened range.
        let (lo, hi) = (lo.min(0.0), hi.max(0.0));
        for i in 0..=100 {
            let x = lo + (hi - lo) * i as f32 / 100.0;
            let back = dequantize(quantize(x, &qp), &qp);
            assert!(
                (back - x).abs() <= 0.5 * qp.scale * (1.0 + 1e-5),
                "case {case}: x={x} range=[{lo},{hi}] err={} > scale/2={}",
                (back - x).abs(),
                0.5 * qp.scale
            );
        }
        // Zero must always be exact (padding correctness).
        assert_eq!(dequantize(quantize(0.0, &qp), &qp), 0.0, "case {case}");
    }
}

// ---------------------------------------------------------------------
// Zero allocations + zero overhead + arena shrink
// ---------------------------------------------------------------------

fn quant_runner(net: &str) -> NetRunner {
    QuantNet::build(net, &haswell(), 1).unwrap().runner(1).unwrap()
}

fn assert_zero_alloc_forward(net: &str) {
    let runner = quant_runner(net);
    assert_eq!(runner.dtype(), DType::I8, "{net}");
    let mut arena = runner.arena();
    let input = vec![0.1f32; runner.input_len()];
    let mut output = vec![0.0f32; runner.output_len()];
    // Warm up once (first touch), then count a full forward.
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let before = allocs_now();
    runner.forward_with(&mut arena, &input, &mut output).unwrap();
    let after = allocs_now();
    assert_eq!(after - before, 0, "{net}: i8 whole-network forward allocated on the hot path");
}

fn assert_zero_overhead(net: &str) {
    let runner = quant_runner(net);
    for l in &runner.plans().layers {
        assert_eq!(l.backend, "direct_i8", "{net}/{}", l.layer.name);
        assert_eq!(l.plan.workspace_bytes(), 0, "{net}/{}", l.layer.name);
        assert_eq!(l.plan.retained_bytes(), 0, "{net}/{}", l.layer.name);
    }
    assert_eq!(runner.overhead_bytes(), 0, "{net}: int8 must stay zero-overhead network-wide");
    assert_eq!(runner.arena_floats(), runner.max_live_floats(), "{net}: placement fragmented");
}

/// f32 and i8 schedules share layouts, so the arenas hold identical
/// element counts — the i8 arena is exactly 4x fewer bytes (>= the
/// 3.5x the acceptance bar asks for).
fn assert_arena_shrink(net: &str) {
    let model = model_by_name(net).unwrap();
    let f32_plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
    let f32_runner = NetRunner::from_graph(f32_plans, model.graph.clone(), 1).unwrap();
    let i8_runner = quant_runner(net);
    assert_eq!(f32_runner.arena_floats(), i8_runner.arena_floats(), "{net}: element counts");
    let ratio = f32_runner.activation_bytes() as f64 / i8_runner.activation_bytes() as f64;
    assert!(ratio >= 3.5, "{net}: activation arena shrank only {ratio:.2}x");
    assert_eq!(ratio, 4.0, "{net}: 1-byte elements make the shrink exactly 4x");
}

#[test]
fn i8_forward_is_allocation_free_on_alexnet_and_resnet_micro() {
    for net in ["alexnet", "resnet_micro"] {
        assert_zero_alloc_forward(net);
    }
}

#[test]
#[ignore = "googlenet/vgg16 i8 calibration runs a full-size f32 forward; see CI slow-tests"]
fn i8_forward_is_allocation_free_on_all_paper_nets() {
    for net in ["googlenet", "vgg16"] {
        assert_zero_alloc_forward(net);
    }
}

#[test]
fn i8_overhead_and_arena_shrink_on_alexnet_and_resnet_micro() {
    for net in ["alexnet", "resnet_micro"] {
        assert_zero_overhead(net);
        assert_arena_shrink(net);
    }
}

/// The fusion pass must not cost the paper's headline number: a FUSED
/// i8 net keeps every plan workspace-free, reports network-wide
/// `overhead_bytes() == 0`, and a full fused forward performs zero
/// heap allocations (counting allocator) — epilogues fold into the
/// requantize step instead of buying scratch buffers.
#[test]
fn fused_i8_forward_is_allocation_free_and_zero_overhead() {
    for net in ["resnet_micro", "mobilenet_micro"] {
        let model = model_by_name(net).unwrap();
        let f = fuse(&model).unwrap();
        let runner = QuantNet::build_model_fused(&model, &f, &haswell(), 1)
            .unwrap()
            .runner_fused(1, &f)
            .unwrap();
        assert_eq!(runner.dtype(), DType::I8, "{net}");
        for l in &runner.plans().layers {
            assert_eq!(l.plan.workspace_bytes(), 0, "{net}/{}", l.layer.name);
        }
        assert_eq!(runner.overhead_bytes(), 0, "{net}: fused i8 must stay zero-overhead");
        let mut arena = runner.arena();
        let input = vec![0.1f32; runner.input_len()];
        let mut output = vec![0.0f32; runner.output_len()];
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let before = allocs_now();
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let after = allocs_now();
        assert_eq!(after - before, 0, "{net}: fused i8 forward allocated on the hot path");
    }
}

#[test]
#[ignore = "googlenet/vgg16 i8 calibration runs a full-size f32 forward; see CI slow-tests"]
fn i8_overhead_and_arena_shrink_on_all_paper_nets() {
    for net in ["googlenet", "vgg16"] {
        assert_zero_overhead(net);
        assert_arena_shrink(net);
    }
}

// ---------------------------------------------------------------------
// End-to-end accuracy: i8 vs f32
// ---------------------------------------------------------------------

#[test]
fn i8_tracks_f32_end_to_end_on_alexnet_and_resnet_micro() {
    for net in ["alexnet", "resnet_micro"] {
        let model = model_by_name(net).unwrap();
        let f32_plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let f32_runner = NetRunner::from_graph(f32_plans, model.graph.clone(), 1).unwrap();
        let i8_runner = quant_runner(net);
        let d = f32_runner.input_dims();
        let input = Tensor::random(&[d.c, d.h, d.w], CALIBRATION_SEED);
        let want = f32_runner.forward(&input).unwrap();
        let got = i8_runner.forward(&input).unwrap();
        assert_eq!(got.shape(), want.shape(), "{net}");
        let sum = |t: &Tensor| t.data().iter().map(|v| v.abs() as f64).sum::<f64>();
        let (a, b) = (sum(&got), sum(&want));
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(
            rel <= 5e-2,
            "{net}: i8 abs_sum {a:.4e} vs f32 {b:.4e} (rel {rel:.3e} > 5e-2)"
        );
    }
}

// ---------------------------------------------------------------------
// Branch-parallel lanes on a quantized concat DAG
// ---------------------------------------------------------------------

/// A small two-lane fan-out re-joined by a concat, quantized: lanes
/// must be bit-identical to the serial schedule (the i8 byte arena
/// inherits the disjoint-region proof).
#[test]
fn i8_branch_lanes_match_serial_bitwise() {
    use dconv::nets::GraphBuilder;
    let mut b = GraphBuilder::new("mini_i8");
    let x = b.input(8, 16, 16).unwrap();
    let stem = b.conv("stem", x, 16, 3, 1, 1).unwrap();
    b.lane(0, 0);
    let l0 = b.conv("lane0", stem, 8, 1, 1, 0).unwrap();
    b.lane(0, 1);
    let r1 = b.conv("lane1_reduce", stem, 4, 1, 1, 0).unwrap();
    let l1 = b.conv("lane1", r1, 8, 3, 1, 1).unwrap();
    b.backbone();
    let cat = b.concat("join", &[l0, l1]).unwrap();
    let model = b.build(cat).unwrap();

    let m = haswell();
    let serial = QuantNet::build_model(&model, &m, 1).unwrap().runner(1).unwrap();
    let lanes = QuantNet::build_model(&model, &m, 1).unwrap().runner(2).unwrap();
    assert_eq!(lanes.branch_lanes(), 2);
    let input = Tensor::random(&[8, 16, 16], 0x1A9E5);
    let mut a1 = serial.arena();
    let mut a2 = lanes.arena();
    let mut q1 = vec![0i8; serial.output_len()];
    let mut q2 = vec![0i8; lanes.output_len()];
    serial.forward_q8_with(&mut a1, input.data(), &mut q1).unwrap();
    lanes.forward_q8_with(&mut a2, input.data(), &mut q2).unwrap();
    assert_eq!(q1, q2, "lane scheduling must not change a single quantized bit");
}

// ---------------------------------------------------------------------
// i8 average pooling
// ---------------------------------------------------------------------

/// The fused i8 average-pool gather, checked against an independent
/// in-test evaluation of the documented contract: gather the conv's
/// *raw integers* (from a conv-only twin model sharing the same edge
/// params, so both nets produce identical conv bytes), sum the
/// centered values over the in-bounds window cells only, and
/// requantize the sum through `m / count`. Exact equality — the window
/// walk and valid-cell counting are re-derived here, independent of
/// `Adapt::apply_i8`.
#[test]
fn i8_avg_pool_matches_documented_integer_contract() {
    use dconv::nets::GraphBuilder;
    use dconv::quant::requantize;
    let m = haswell();
    let p_in = QuantParams::from_range(-1.0, 1.0);
    let p_conv = QuantParams::from_range(-6.0, 6.0);
    let p_pool = QuantParams::from_range(-3.0, 4.0);

    let conv_model = {
        let mut b = GraphBuilder::new("conv_only");
        let x = b.input(4, 8, 8).unwrap();
        let c = b.conv("c0", x, 8, 3, 1, 1).unwrap();
        b.build(c).unwrap()
    };
    let pool_model = {
        let mut b = GraphBuilder::new("with_avg");
        let x = b.input(4, 8, 8).unwrap();
        let c = b.conv("c0", x, 8, 3, 1, 1).unwrap();
        // 3x3/s2/p1: border windows hold fewer than 9 valid cells, so
        // the reciprocal-count path is exercised, not just 1/9.
        let p = b.avg_pool("head", c, 3, 2, 1).unwrap();
        b.build(p).unwrap()
    };

    let input = Tensor::random(&[4, 8, 8], 0xA59);
    let run = |model: &dconv::nets::Model, params: Vec<QuantParams>| {
        let q = QuantNet::with_node_params(
            &model.name,
            &model.graph,
            &model.shapes,
            &m,
            1,
            params,
        )
        .unwrap();
        let runner = q.runner(1).unwrap();
        let mut arena = runner.arena();
        let mut out = vec![0i8; runner.output_len()];
        runner.forward_q8_with(&mut arena, input.data(), &mut out).unwrap();
        out
    };
    let q_conv = run(&conv_model, vec![p_in, p_conv]);
    let got = run(&pool_model, vec![p_in, p_conv, p_pool]);

    let m_req = p_conv.scale as f64 / p_pool.scale as f64;
    let (ch, h, w, h_o, w_o) = (8usize, 8usize, 8usize, 4usize, 4usize);
    for c in 0..ch {
        for y in 0..h_o {
            for x in 0..w_o {
                let mut sum = 0i32;
                let mut n = 0i64;
                for dy in 0..3isize {
                    for dx in 0..3isize {
                        let yy = (y * 2) as isize + dy - 1;
                        let xx = (x * 2) as isize + dx - 1;
                        if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                            continue;
                        }
                        let q = q_conv[(c * h + yy as usize) * w + xx as usize];
                        sum += q as i32 - p_conv.zero_point;
                        n += 1;
                    }
                }
                let want = requantize(sum, m_req / n as f64, p_pool.zero_point);
                assert_eq!(
                    got[(c * h_o + y) * w_o + x],
                    want,
                    "i8 avg pool diverged at ({c},{y},{x}) with {n} valid cells"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Schedule guards
// ---------------------------------------------------------------------

#[test]
fn quant_compile_rejects_f32_plans_and_f32_runners_reject_q8_calls() {
    let model = model_by_name("resnet_micro").unwrap();
    let m = haswell();
    // An f32 plan table cannot form an i8 schedule...
    let f32_plans = NetPlans::build_model(&model, "direct", &m, 1).unwrap();
    let params = vec![QuantParams::IDENT; model.graph.len()];
    assert!(NetRunner::from_graph_quant(f32_plans, model.graph.clone(), 1, &params).is_err());
    // ...and an f32 runner has no raw-integer output surface.
    let f32_plans = NetPlans::build_model(&model, "direct", &m, 1).unwrap();
    let runner = NetRunner::from_graph(f32_plans, model.graph.clone(), 1).unwrap();
    let mut arena = runner.arena();
    let input = vec![0.0f32; runner.input_len()];
    let mut out_q = vec![0i8; runner.output_len()];
    assert!(runner.forward_q8_with(&mut arena, &input, &mut out_q).is_err());
}

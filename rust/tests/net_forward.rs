//! Whole-network conformance for [`NetRunner`] / [`NetEngine`] over the
//! graph executor (chain-shaped nets; the inception DAG is covered by
//! `tests/net_graph.rs`):
//!
//! * the network-wide forward matches a layer-by-layer `conv_naive`
//!   chain (with the same `adapt_nchw` pooling glue) on paper nets;
//! * after planning, the forward pass performs **zero** heap
//!   allocations on *every* benchmark net (counting allocator),
//!   GoogLeNet running as a real branch/concat graph;
//! * the aggregate overhead (`retained + shared workspace`) is **0**
//!   for the direct backend on every net — the paper's claim asserted
//!   network-wide — and the liveness arena places without
//!   fragmentation (arena == max live-set);
//! * the coordinator serves whole-network requests through `NetEngine`
//!   with batching, every reply correct for its own input.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;

use dconv::arch::haswell;
use dconv::conv::{conv_naive, ConvShape};
use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::engine::{adapt_nchw, NetEngine, NetRunner};
use dconv::nets::{self, net_kernel, NetPlans};
use dconv::tensor::Tensor;

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as conformance.rs: the
// parallel test harness's other threads cannot perturb the assertion).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

/// Build a custom chain plus the (regenerated) kernels its plans hold —
/// for nets where the full-size naive reference would be too slow.
fn custom_plans(shapes: &[ConvShape], backend: &str, seed: u64) -> (NetPlans, Vec<Tensor>) {
    let plans = NetPlans::from_shapes("custom", shapes, backend, &haswell(), seed).unwrap();
    let kernels = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + i as u64))
        .collect();
    (plans, kernels)
}

/// Layer-by-layer naive reference: `adapt_nchw` glue then `conv_naive`,
/// per layer — independent of the arena/layout machinery under test.
fn naive_chain(shapes: &[ConvShape], kernels: &[Tensor], input: &Tensor) -> Tensor {
    let mut act = input.clone();
    for (s, k) in shapes.iter().zip(kernels) {
        let adapted = adapt_nchw(&act, s.c_i, s.h_i, s.w_i).unwrap();
        act = conv_naive(&adapted, k, s).unwrap();
    }
    act
}

// ---------------------------------------------------------------------
// Network-wide output vs the naive reference
// ---------------------------------------------------------------------

#[test]
fn alexnet_forward_matches_layerwise_naive_reference() {
    let plans = NetPlans::build("alexnet", "auto", &haswell(), 1).unwrap();
    let runner = NetRunner::new(plans).unwrap();
    let layers = nets::alexnet();
    let shapes: Vec<ConvShape> = layers.iter().map(|l| l.shape.clone()).collect();
    let kernels: Vec<Tensor> = shapes.iter().enumerate().map(|(i, s)| net_kernel(i, s)).collect();
    let input = Tensor::random(&[3, 227, 227], 0xA1EF);

    let got = runner.forward(&input).unwrap();
    let want = naive_chain(&shapes, &kernels, &input);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.allclose(&want, 1e-2, 1e-2),
        "alexnet network forward diverged from the naive chain: {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn downscaled_vgg16_forward_matches_layerwise_naive_reference() {
    // The full 224x224 VGG naive reference is minutes of work; shrink
    // the spatial extent 4x (channel structure, kernels and the
    // between-block 2x2/s2 pooling geometry are all preserved).
    let shapes: Vec<ConvShape> = nets::vgg16()
        .iter()
        .map(|l| {
            let mut s = l.shape.clone();
            s.h_i /= 4;
            s.w_i /= 4;
            s
        })
        .collect();
    let (plans, kernels) = custom_plans(&shapes, "auto", 0xB0);
    let runner = NetRunner::new(plans).unwrap();
    let input = Tensor::random(&[3, 56, 56], 0xB1);

    let got = runner.forward(&input).unwrap();
    let want = naive_chain(&shapes, &kernels, &input);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.allclose(&want, 1e-2, 1e-2),
        "vgg16 (downscaled) network forward diverged: {}",
        got.max_abs_diff(&want)
    );
}

// ---------------------------------------------------------------------
// Every paper net: end-to-end execution, zero allocations, overhead 0
// ---------------------------------------------------------------------

#[test]
fn every_paper_net_runs_end_to_end_with_zero_allocations_after_planning() {
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "auto", &haswell(), 1).unwrap();
        let n_layers = plans.layers.len();
        let runner = NetRunner::new(plans).unwrap();
        assert_eq!(runner.layers(), n_layers, "{net}");

        let mut arena = runner.arena();
        let input = vec![0.1f32; runner.input_len()];
        let mut output = vec![0.0f32; runner.output_len()];

        // Warm up once (first touch), then count a full forward.
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let before = allocs_now();
        runner.forward_with(&mut arena, &input, &mut output).unwrap();
        let after = allocs_now();
        assert_eq!(after - before, 0, "{net}: whole-network forward allocated on the hot path");
        // Activations of the deep synthetic chains can saturate f32
        // (random +-1 weights grow magnitudes geometrically), so only
        // assert that the forward actually produced output.
        assert!(output.iter().any(|v| *v != 0.0), "{net}: forward produced no output");
    }
}

#[test]
fn aggregate_overhead_is_zero_for_direct_on_every_net() {
    for net in ["alexnet", "googlenet", "vgg16"] {
        let plans = NetPlans::build(net, "direct", &haswell(), 1).unwrap();
        let runner = NetRunner::new(plans).unwrap();
        assert_eq!(
            runner.retained_bytes(),
            0,
            "{net}: direct plans must retain nothing beyond conventional weights"
        );
        assert_eq!(runner.workspace_bytes(), 0, "{net}: direct needs no workspace");
        assert_eq!(runner.overhead_bytes(), 0, "{net}: zero-memory-overhead, network-wide");
        // The arena is intrinsic state (activations), not overhead; the
        // liveness-driven region allocator must place it at exactly the
        // max live-set of the schedule (no fragmentation).
        assert!(runner.arena_bytes() > 0);
        assert_eq!(runner.arena_bytes(), runner.activation_bytes());
        assert_eq!(
            runner.arena_floats(),
            runner.max_live_floats(),
            "{net}: arena placement fragmented beyond the max live-set"
        );
    }
}

// ---------------------------------------------------------------------
// Serving: whole-network requests through the coordinator
// ---------------------------------------------------------------------

#[test]
fn coordinator_serves_whole_network_requests_through_net_engine() {
    let shapes = [
        ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
        ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
    ];
    let (plans, kernels) = custom_plans(&shapes, "auto", 0xC0);
    let runner = NetRunner::new(plans).unwrap();
    let image_out = runner.output_len();
    let engine = NetEngine::new(runner, 2, &[1, 2, 4], "net").unwrap();
    assert_eq!(engine.workers(), 2);
    let cfg = CoordinatorConfig { model_prefix: "net".into(), ..Default::default() };
    let coord = Coordinator::start(engine, cfg).unwrap();

    // A burst larger than the largest compiled batch exercises the
    // batcher's multi-execution split; every reply must be correct for
    // its own input (padding slots must not leak across requests).
    let inputs: Vec<Tensor> =
        (0..11).map(|i| Tensor::random(&[8, 12, 12], 900 + i as u64)).collect();
    let pendings: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit_blocking(x.data().to_vec()).unwrap())
        .collect();
    for (x, p) in inputs.iter().zip(pendings) {
        // Deadline-bound wait: a wedged worker fails the test instead
        // of hanging it.
        let out = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(out.len(), image_out);
        let want = naive_chain(&shapes, &kernels, x);
        let got = Tensor::from_vec(&[16, 6, 6], out).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "served net output differs from reference");
    }
    let stats = coord.stats();
    assert_eq!(stats.requests, 11);
    assert_eq!(stats.latency.count(), 11);
}

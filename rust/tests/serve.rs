//! Production serving subsystem (`dconv::serve`) under load:
//!
//! * **multi-model** — an f32 and an i8 compile of the same spec
//!   resident behind one server, each with its own queue, workers and
//!   telemetry; f32 replies match a directly-driven [`NetRunner`], the
//!   i8 arena is ~4x smaller;
//! * **overload** — a burst far beyond the bounded queue sheds with the
//!   typed [`Rejected::QueueFull`] and never deadlocks (every accepted
//!   request still completes);
//! * **deadlines** — expired requests are dropped *before* execution
//!   (zero batches dispatched when every request is stale);
//! * **graceful drain** — shutdown completes all in-flight work before
//!   the workers exit;
//! * **zero-alloc execute path** — the exact staged-execute function
//!   the workers run performs no heap allocations in steady state, for
//!   f32 and i8 (counting allocator);
//! * **coordinator parity** — the legacy coordinator sheds with the
//!   same typed rejection vocabulary;
//! * **loadgen** — seeded schedules are bit-reproducible across fresh
//!   servers and the JSON artifact round-trips.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::cell::Cell;
use std::time::Duration;

use dconv::arch::haswell;
use dconv::conv::ConvShape;
use dconv::coordinator::{Coordinator, CoordinatorConfig};
use dconv::engine::{NetRunner, PlanEngine};
use dconv::nets::builder::resnet_micro;
use dconv::nets::{Model, NetPlans};
use dconv::quant::DType;
use dconv::runtime::{Manifest, ModelExecutor};
use dconv::serve::{
    loadgen, LoadSpec, ModelLoad, Rejected, ServeConfig, Server, ServerBuilder,
};
use dconv::sim::ArrivalPattern;
use dconv::tensor::Tensor;
use dconv::{Error, Result};

// ---------------------------------------------------------------------
// Thread-local allocation counter (same design as net_forward.rs: the
// parallel test harness's other threads cannot perturb the assertion).
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: AllocLayout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: AllocLayout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn tiny_cfg(queue_depth: usize) -> ServeConfig {
    ServeConfig {
        queue_depth,
        batch_wait: Duration::from_millis(1),
        workers: 1,
        batch_sizes: vec![1, 2, 4],
        ..Default::default()
    }
}

fn i8_model() -> Model {
    let mut m = resnet_micro();
    m.dtype = DType::I8;
    m
}

/// One-model f32 server over resnet_micro with the direct backend.
fn f32_server(queue_depth: usize) -> Server {
    let mut b = ServerBuilder::new(&haswell(), tiny_cfg(queue_depth)).backend("direct");
    b.add_model("rm", &resnet_micro()).unwrap();
    b.start().unwrap()
}

const WATCHDOG: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// Multi-model: f32 + i8 behind one server
// ---------------------------------------------------------------------

#[test]
fn f32_and_i8_models_serve_concurrently_with_per_model_stats() {
    let machine = haswell();
    let mut b = ServerBuilder::new(&machine, tiny_cfg(32)).backend("direct");
    b.add_model("rm_f32", &resnet_micro()).unwrap();
    b.add_model("rm_i8", &i8_model()).unwrap();
    let server = b.start().unwrap();
    assert_eq!(server.models(), vec!["rm_f32", "rm_i8"]);

    let hf = server.model("rm_f32").unwrap();
    let hq = server.model("rm_i8").unwrap();
    assert_ne!(hf.spec_hash(), hq.spec_hash(), "dtype is part of the plan-cache key");
    assert!(!hf.shares_plans_with(&hq));
    let ratio = hf.runner().arena_bytes() as f64 / hq.runner().arena_bytes() as f64;
    assert!(ratio > 3.5, "i8 activation arena should be ~4x smaller, got {ratio:.2}x");
    assert_eq!(hf.runner().overhead_bytes(), 0, "direct f32 plans stay zero-overhead");
    assert_eq!(hq.runner().overhead_bytes(), 0, "direct_i8 plans stay zero-overhead");

    // The f32 replies must match a directly-driven runner over the same
    // (deterministically regenerated) plans.
    let model = resnet_micro();
    let plans = NetPlans::build_model(&model, "direct", &machine, 1).unwrap();
    let reference = NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap();
    let mut arena = reference.arena();
    let mut want = vec![0.0f32; reference.output_len()];

    let inputs: Vec<Vec<f32>> = (0..6)
        .map(|i| Tensor::random(&[hf.image_in()], 900 + i as u64).into_vec())
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| {
            (
                server.submit("rm_f32", x.clone()).unwrap(),
                server.submit("rm_i8", x.clone()).unwrap(),
            )
        })
        .collect();
    for (x, (tf, tq)) in inputs.iter().zip(tickets) {
        let got = tf.wait_timeout(WATCHDOG).unwrap();
        reference.forward_with(&mut arena, x, &mut want).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-4 + 1e-4 * w.abs(), "served f32 differs: {g} vs {w}");
        }
        let qout = tq.wait_timeout(WATCHDOG).unwrap();
        assert_eq!(qout.len(), hq.image_out());
        assert!(qout.iter().all(|v| v.is_finite()));
    }

    let (sf, sq) = (hf.stats(), hq.stats());
    assert_eq!(sf.completed, 6);
    assert_eq!(sq.completed, 6);
    assert_eq!(sf.in_flight(), 0);
    assert_eq!(sq.in_flight(), 0);
    assert!(sf.e2e.count() == 6 && sf.queue_wait.count() == 6, "latency split is recorded");
    server.shutdown().unwrap();
}

#[test]
fn identical_specs_share_one_compiled_plan_across_served_names() {
    let mut b = ServerBuilder::new(&haswell(), tiny_cfg(8)).backend("direct");
    b.add_model("a", &resnet_micro()).unwrap();
    b.add_model("b", &resnet_micro()).unwrap();
    assert_eq!(b.cached_plans(), 1, "same spec + dtype compiles once");
    let server = b.start().unwrap();
    let (ha, hb) = (server.model("a").unwrap(), server.model("b").unwrap());
    assert!(ha.shares_plans_with(&hb));
    // Both served names still answer independently.
    let x = Tensor::random(&[ha.image_in()], 4).into_vec();
    let oa = server.submit("a", x.clone()).unwrap().wait_timeout(WATCHDOG).unwrap();
    let ob = server.submit("b", x).unwrap().wait_timeout(WATCHDOG).unwrap();
    assert_eq!(oa, ob, "one shared plan, same answer under either name");
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// Overload: bounded queue + explicit shedding, no deadlock
// ---------------------------------------------------------------------

#[test]
fn burst_beyond_capacity_sheds_queue_full_and_never_deadlocks() {
    let server = f32_server(2);
    let h = server.model("rm").unwrap();
    let x = Tensor::random(&[h.image_in()], 7).into_vec();

    let mut tickets = Vec::new();
    let mut shed = 0u64;
    for _ in 0..64 {
        match server.submit("rm", x.clone()) {
            Ok(t) => tickets.push(t),
            Err(Error::Rejected(Rejected::QueueFull { depth })) => {
                assert_eq!(depth, 2, "rejection reports the configured bound");
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "a 64-deep burst into a depth-2 queue must shed");
    assert!(h.queue_len() <= h.queue_depth(), "queue never exceeds its bound");

    // Every accepted request still completes — bounded waits prove the
    // burst wedged nothing.
    for t in tickets {
        t.wait_timeout(WATCHDOG).unwrap();
    }
    let st = h.stats();
    assert_eq!(st.shed_queue_full, shed);
    assert_eq!(st.submitted, 64);
    assert_eq!(st.completed, 64 - shed);
    assert_eq!(st.in_flight(), 0, "accounting identity closes after the burst");
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// Deadlines: stale requests dropped before execution
// ---------------------------------------------------------------------

#[test]
fn expired_deadlines_are_dropped_before_execution() {
    let server = f32_server(16);
    let h = server.model("rm").unwrap();
    let x = Tensor::random(&[h.image_in()], 3).into_vec();

    // A zero deadline has always expired by the time a worker picks the
    // request up, deterministically.
    let tickets: Vec<_> = (0..4)
        .map(|_| {
            server.submit_with_deadline("rm", x.clone(), Some(Duration::ZERO)).unwrap()
        })
        .collect();
    for t in tickets {
        match t.wait_timeout(WATCHDOG) {
            Err(Error::Rejected(Rejected::DeadlineExceeded)) => {}
            other => panic!("expected a typed deadline rejection, got {other:?}"),
        }
    }
    let st = h.stats();
    assert_eq!(st.deadline_missed, 4);
    assert_eq!(st.batches, 0, "stale requests never reached execution");
    assert_eq!(st.completed, 0);

    // A generous deadline still serves normally afterwards.
    let out = server
        .submit_with_deadline("rm", x, Some(Duration::from_secs(30)))
        .unwrap()
        .wait_timeout(WATCHDOG)
        .unwrap();
    assert_eq!(out.len(), h.image_out());
    assert_eq!(h.stats().completed, 1);
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// Graceful drain
// ---------------------------------------------------------------------

#[test]
fn shutdown_drains_in_flight_work_before_workers_exit() {
    let server = f32_server(32);
    let h = server.model("rm").unwrap();
    let x = Tensor::random(&[h.image_in()], 5).into_vec();
    let tickets: Vec<_> =
        (0..8).map(|_| server.submit("rm", x.clone()).unwrap()).collect();
    // Close admission immediately; the accepted backlog must still be
    // served (shutdown joins the workers only after the queues drain).
    server.shutdown().unwrap();
    for t in tickets {
        let out = t.wait_timeout(WATCHDOG).expect("accepted work completes during drain");
        assert_eq!(out.len(), h.image_out());
    }
    assert_eq!(h.stats().completed, 8);
    assert_eq!(h.stats().in_flight(), 0);
}

// ---------------------------------------------------------------------
// Zero-allocation execute path (counting allocator)
// ---------------------------------------------------------------------

#[test]
fn steady_state_execute_path_is_allocation_free_for_f32_and_i8() {
    let mut b = ServerBuilder::new(&haswell(), tiny_cfg(8)).backend("direct");
    b.add_model("rm_f32", &resnet_micro()).unwrap();
    b.add_model("rm_i8", &i8_model()).unwrap();
    let server = b.start().unwrap();

    for name in ["rm_f32", "rm_i8"] {
        let h = server.model(name).unwrap();
        // The one allocation site: arena + staging, built once per
        // worker. Drive the exact function the workers run, on this
        // thread, so the thread-local counter sees it.
        let mut state = h.worker_state();
        let imgs: Vec<Vec<f32>> =
            (0..2).map(|i| Tensor::random(&[h.image_in()], 40 + i).into_vec()).collect();
        for (slot, img) in imgs.iter().enumerate() {
            h.stage(&mut state, slot, img).unwrap();
        }
        h.execute_staged(&mut state, 2).unwrap(); // warm-up
        let before = allocs_now();
        h.execute_staged(&mut state, 2).unwrap();
        let after = allocs_now();
        assert_eq!(
            after - before,
            0,
            "{name}: steady-state staged execute must not allocate"
        );
        assert_eq!(h.staged_output(&state, 0).len(), h.image_out());
    }
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// Coordinator parity: typed shedding on the legacy path
// ---------------------------------------------------------------------

/// Wraps any executor with a fixed per-batch delay, so the coordinator
/// queue reliably fills during a synchronous submit burst.
struct SlowExec<E: ModelExecutor> {
    inner: E,
    delay: Duration,
}

impl<E: ModelExecutor> ModelExecutor for SlowExec<E> {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }
    fn run(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.run(model, input)
    }
}

#[test]
fn coordinator_sheds_with_typed_queue_full_rejection() {
    let s = ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1);
    let machine = haswell();
    let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 9);
    let engine = SlowExec {
        inner: PlanEngine::new(&s, &kernel, "auto", &machine, 1, &[1, 2, 4], "conv").unwrap(),
        delay: Duration::from_millis(20),
    };
    let cfg = CoordinatorConfig {
        queue_depth: 1,
        model_prefix: "conv".into(),
        ..Default::default()
    };
    let coord = Coordinator::start(engine, cfg).unwrap();
    let x = vec![0.5f32; s.c_i * s.h_i * s.w_i];

    let mut pendings = Vec::new();
    let mut saw_queue_full = false;
    for _ in 0..64 {
        match coord.submit(x.clone()) {
            Ok(p) => pendings.push(p),
            Err(Error::Rejected(Rejected::QueueFull { depth })) => {
                assert_eq!(depth, 1);
                saw_queue_full = true;
                break;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(saw_queue_full, "a depth-1 queue behind a 20ms executor must shed");
    for p in pendings {
        p.wait_timeout(WATCHDOG).unwrap();
    }
    // submit_blocking rides out the backpressure instead of failing.
    coord.submit_blocking(x).unwrap().wait_timeout(WATCHDOG).unwrap();
}

// ---------------------------------------------------------------------
// Loadgen: deterministic schedules, JSON artifact
// ---------------------------------------------------------------------

#[test]
fn loadgen_schedules_are_reproducible_across_fresh_servers() {
    let spec = LoadSpec::one(
        ModelLoad::new("rm", ArrivalPattern::Burst, 2000.0, 24).seed(0xFEED),
    );
    let a = {
        let server = f32_server(16);
        let report = loadgen::run(&server, &spec).unwrap();
        server.shutdown().unwrap();
        report
    };
    let b = {
        let server = f32_server(16);
        let report = loadgen::run(&server, &spec).unwrap();
        server.shutdown().unwrap();
        report
    };
    assert_eq!(
        a.results[0].fingerprint, b.results[0].fingerprint,
        "identical seeds replay bit-identical arrival schedules"
    );
    for r in [&a.results[0], &b.results[0]] {
        assert_eq!(r.accepted + r.shed + r.rejected_other, 24);
        assert_eq!(r.completed + r.deadline_missed + r.failed, r.accepted);
        assert!(r.completed > 0);
    }
}

#[test]
fn loadgen_artifact_round_trips_through_json() {
    let server = f32_server(16);
    let spec = LoadSpec::one(
        ModelLoad::new("rm", ArrivalPattern::Pareto, 1000.0, 10).seed(21),
    );
    let report = loadgen::run(&server, &spec).unwrap();
    server.shutdown().unwrap();

    let path = std::env::temp_dir().join("dconv_loadgen_test.json");
    let path = path.to_str().unwrap();
    report.write_artifact(path).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    std::fs::remove_file(path).ok();
    let parsed = dconv::json::Json::parse(&text).unwrap();
    assert_eq!(parsed.get("kind").and_then(|k| k.as_str()), Some("loadgen"));
    let results = parsed.get("results").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert_eq!(r.get("model").and_then(|m| m.as_str()), Some("rm"));
    assert_eq!(r.get("requests").and_then(|n| n.as_usize()), Some(10));
    let fp = r.get("fingerprint").and_then(|f| f.as_str()).unwrap();
    assert_eq!(fp.len(), 16);
    assert_eq!(fp, format!("{:016x}", report.results[0].fingerprint));
    assert!(r.get("server").and_then(|s| s.get("e2e_p50_ms")).is_some());
}

//! [`GraphBuilder`] — the public model-description API.
//!
//! Until this module existed, the zero-memory-overhead executor was only
//! reachable through three hardcoded shape tables; defining a new
//! network meant editing library internals. The builder opens the graph
//! IR: any CNN over the supported node set (conv — dense, grouped,
//! depthwise or dilated — / max-pool / channel concat / residual add /
//! ReLU / batch-norm) can be described as a short validated program
//! and handed straight to [`super::NetPlans::build_model`] and
//! [`crate::engine::NetRunner`] — planned once, served allocation-free.
//!
//! ```
//! use dconv::nets::GraphBuilder;
//!
//! let mut b = GraphBuilder::new("tiny_resnet");
//! let image = b.input(3, 32, 32).unwrap();
//! let stem = b.conv("stem", image, 16, 3, 1, 1).unwrap();
//! let c1 = b.conv("c1", stem, 16, 3, 1, 1).unwrap();
//! let join = b.add("join", &[stem, c1]).unwrap();
//! let model = b.build(join).unwrap();
//! assert_eq!(model.shapes.len(), 2);
//! ```
//!
//! Every method validates as it goes — dangling predecessors, duplicate
//! names, shape mismatches, bad pool geometry and join-arity errors are
//! reported at the call site with the node's name — and [`build`]
//! (which runs [`NetGraph::validate`]) catches whole-graph properties:
//! dead nodes, branch-lane crossings, the output convention.
//!
//! Shape inference is implicit: a conv node takes its input channel
//! count and extents from its predecessor, so a builder program only
//! states what the layer *adds* (output channels, kernel, stride, pad),
//! exactly like the JSON spec format in [`super::spec`].
//!
//! The three paper nets are builder programs here ([`alexnet`],
//! [`vgg16`], [`googlenet`]) and the legacy shape-table constructors
//! ([`NetGraph::chain`], [`NetGraph::inception`], [`NetGraph::for_net`])
//! are thin wrappers over the builder, so there is exactly one graph
//! construction path.
//!
//! [`build`]: GraphBuilder::build

use std::collections::BTreeMap;

use crate::conv::ConvShape;
use crate::{Error, Result};

use super::graph::{pool_out, pool_spec, BranchTag, Dims, GraphNode, GraphOp, NetGraph, PoolKind};
use super::spec::Model;
use super::INCEPTION;

/// Handle to a node under construction. Only the builder that returned
/// it can consume it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(usize);

/// Fluent, validated constructor for [`Model`]s. See the module docs.
pub struct GraphBuilder {
    net: String,
    nodes: Vec<GraphNode>,
    shapes: Vec<ConvShape>,
    dims: Vec<Dims>,
    names: BTreeMap<String, usize>,
    branch: Option<BranchTag>,
}

impl GraphBuilder {
    /// Start a model named `net`.
    pub fn new(net: &str) -> GraphBuilder {
        GraphBuilder {
            net: net.to_string(),
            nodes: Vec::new(),
            shapes: Vec::new(),
            dims: Vec::new(),
            names: BTreeMap::new(),
            branch: None,
        }
    }

    fn err(&self, msg: String) -> Error {
        Error::Shape(format!("builder '{}': {msg}", self.net))
    }

    fn check_pred(&self, node: &str, id: NodeId) -> Result<Dims> {
        self.dims.get(id.0).copied().ok_or_else(|| {
            self.err(format!("node '{node}': predecessor id is not from this builder"))
        })
    }

    fn push(&mut self, name: &str, op: GraphOp, preds: Vec<usize>, d: Dims) -> Result<NodeId> {
        if name.is_empty() {
            return Err(self.err("node names must be non-empty".into()));
        }
        if self.names.contains_key(name) {
            return Err(self.err(format!("duplicate node name '{name}'")));
        }
        self.names.insert(name.to_string(), self.nodes.len());
        self.nodes.push(GraphNode {
            name: name.to_string(),
            op,
            preds,
            branch: self.branch,
        });
        self.dims.push(d);
        Ok(NodeId(self.nodes.len() - 1))
    }

    /// The network input image, named `input` — must be the first node.
    pub fn input(&mut self, c: usize, h: usize, w: usize) -> Result<NodeId> {
        self.input_named("input", c, h, w)
    }

    /// The network input image with an explicit node name.
    pub fn input_named(&mut self, name: &str, c: usize, h: usize, w: usize) -> Result<NodeId> {
        if !self.nodes.is_empty() {
            return Err(self.err(format!(
                "input '{name}' must be the first node (and there is exactly one input)"
            )));
        }
        if c == 0 || h == 0 || w == 0 {
            return Err(self.err(format!("input '{name}': zero dimension in {c}x{h}x{w}")));
        }
        self.push(name, GraphOp::Input { c, h, w }, Vec::new(), Dims { c, h, w })
    }

    /// Square-kernel convolution: `c_o` output channels, `k x k` kernel,
    /// symmetric `stride`/`pad`. Input channels and extents are inferred
    /// from `pred`.
    pub fn conv(
        &mut self,
        name: &str,
        pred: NodeId,
        c_o: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Result<NodeId> {
        self.conv_rect(name, pred, c_o, k, k, stride, pad)
    }

    /// Rectangular-kernel convolution (`kh x kw`).
    #[allow(clippy::too_many_arguments)] // the conv geometry tuple
    pub fn conv_rect(
        &mut self,
        name: &str,
        pred: NodeId,
        c_o: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    ) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        let shape = ConvShape::new(d.c, d.h, d.w, c_o, kh, kw, stride, pad);
        self.conv_with(name, pred, shape)
    }

    /// Grouped and/or dilated square-kernel convolution: `groups` must
    /// divide both the inferred input channels and `c_o`; `dilation`
    /// spreads the kernel taps (effective extent
    /// `(k-1)*dilation + 1`). `groups == 1, dilation == 1` is
    /// [`GraphBuilder::conv`].
    #[allow(clippy::too_many_arguments)] // the conv geometry tuple
    pub fn conv_opts(
        &mut self,
        name: &str,
        pred: NodeId,
        c_o: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        dilation: usize,
    ) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        let shape = ConvShape::new(d.c, d.h, d.w, c_o, k, k, stride, pad)
            .with_groups(groups)
            .with_dilation(dilation);
        self.conv_with(name, pred, shape)
    }

    /// Depthwise convolution: one `k x k` filter per channel
    /// (`groups == c_i == c_o`, inferred from `pred`).
    pub fn depthwise(
        &mut self,
        name: &str,
        pred: NodeId,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        self.conv_opts(name, pred, d.c, k, stride, pad, d.c, 1)
    }

    /// Convolution from an explicit [`ConvShape`] (the shape-table entry
    /// points use this); its declared input must match `pred`'s output
    /// exactly.
    pub fn conv_with(&mut self, name: &str, pred: NodeId, shape: ConvShape) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        if (d.c, d.h, d.w) != (shape.c_i, shape.h_i, shape.w_i) {
            return Err(self.err(format!(
                "conv '{name}' wants {}x{}x{} but its input produces {}x{}x{}",
                shape.c_i, shape.h_i, shape.w_i, d.c, d.h, d.w
            )));
        }
        shape.validate().map_err(|e| self.err(format!("conv '{name}': {e}")))?;
        let out = Dims { c: shape.c_o, h: shape.h_o(), w: shape.w_o() };
        // Push the node first: if it is rejected (duplicate name), the
        // shape table must not grow an orphan entry.
        let layer = self.shapes.len();
        let id = self.push(name, GraphOp::Conv { layer }, vec![pred.0], out)?;
        self.shapes.push(shape);
        Ok(id)
    }

    /// Square max-pool: `k x k` window, stride `s`, symmetric pad `p`
    /// (padding cells act as `-inf`).
    pub fn pool(
        &mut self,
        name: &str,
        pred: NodeId,
        k: usize,
        s: usize,
        p: usize,
    ) -> Result<NodeId> {
        self.pool_geom(name, pred, k, k, s, s, p, p)
    }

    /// Square average-pool (classifier-head semantics: the mean over
    /// the in-bounds window cells; padding is excluded from sum and
    /// count).
    pub fn avg_pool(
        &mut self,
        name: &str,
        pred: NodeId,
        k: usize,
        s: usize,
        p: usize,
    ) -> Result<NodeId> {
        self.pool_kind_geom(name, pred, PoolKind::Avg, k, k, s, s, p, p)
    }

    /// Max-pool with full geometry.
    #[allow(clippy::too_many_arguments)] // the pool geometry tuple
    pub fn pool_geom(
        &mut self,
        name: &str,
        pred: NodeId,
        kh: usize,
        kw: usize,
        sh: usize,
        sw: usize,
        ph: usize,
        pw: usize,
    ) -> Result<NodeId> {
        self.pool_kind_geom(name, pred, PoolKind::Max, kh, kw, sh, sw, ph, pw)
    }

    /// Pool with full geometry and an explicit [`PoolKind`].
    #[allow(clippy::too_many_arguments)] // the pool geometry tuple
    pub fn pool_kind_geom(
        &mut self,
        name: &str,
        pred: NodeId,
        kind: PoolKind,
        kh: usize,
        kw: usize,
        sh: usize,
        sw: usize,
        ph: usize,
        pw: usize,
    ) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        let h = pool_out(d.h, kh, sh, ph).map_err(|e| self.err(format!("pool '{name}': {e}")))?;
        let w = pool_out(d.w, kw, sw, pw).map_err(|e| self.err(format!("pool '{name}': {e}")))?;
        self.push(
            name,
            GraphOp::Pool { kind, kh, kw, sh, sw, ph, pw },
            vec![pred.0],
            Dims { c: d.c, h, w },
        )
    }

    /// Derived down-pool: reduce `pred`'s extents onto `h x w` with the
    /// [`pool_spec`] max-pool geometry (what the paper nets use between
    /// blocks). Errors if the target extent is larger (upsampling glue
    /// is not modeled).
    pub fn pool_to(&mut self, name: &str, pred: NodeId, h: usize, w: usize) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        let (kh, sh) = pool_spec(d.h, h).map_err(|e| self.err(format!("pool '{name}': {e}")))?;
        let (kw, sw) = pool_spec(d.w, w).map_err(|e| self.err(format!("pool '{name}': {e}")))?;
        self.pool_geom(name, pred, kh, kw, sh, sw, 0, 0)
    }

    /// Channel concatenation of two or more equal-extent maps.
    pub fn concat(&mut self, name: &str, preds: &[NodeId]) -> Result<NodeId> {
        if preds.len() < 2 {
            return Err(self.err(format!(
                "concat '{name}' needs at least two operands, got {}",
                preds.len()
            )));
        }
        let first = self.check_pred(name, preds[0])?;
        let mut c = 0usize;
        for &p in preds {
            let d = self.check_pred(name, p)?;
            if (d.h, d.w) != (first.h, first.w) {
                return Err(self.err(format!(
                    "concat '{name}' mixes extents {}x{} and {}x{}",
                    first.h, first.w, d.h, d.w
                )));
            }
            c += d.c;
        }
        let preds = preds.iter().map(|p| p.0).collect();
        self.push(name, GraphOp::Concat, preds, Dims { c, h: first.h, w: first.w })
    }

    /// Elementwise residual join of two or more identically shaped maps.
    pub fn add(&mut self, name: &str, preds: &[NodeId]) -> Result<NodeId> {
        if preds.len() < 2 {
            return Err(self.err(format!(
                "add '{name}' needs at least two operands, got {}",
                preds.len()
            )));
        }
        let first = self.check_pred(name, preds[0])?;
        for &p in preds {
            let d = self.check_pred(name, p)?;
            if d != first {
                return Err(self.err(format!(
                    "add '{name}' mixes shapes {}x{}x{} and {}x{}x{} \
                     (residual joins need identical operands)",
                    first.c, first.h, first.w, d.c, d.h, d.w
                )));
            }
        }
        let preds = preds.iter().map(|p| p.0).collect();
        self.push(name, GraphOp::Add, preds, first)
    }

    /// Elementwise ReLU (`max(0, x)`), optionally clamped above
    /// (ReLU6-style: pass `Some(6.0)`). Dims pass through.
    pub fn relu(&mut self, name: &str, pred: NodeId, clamp: Option<f32>) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        if let Some(c) = clamp {
            if !c.is_finite() || c <= 0.0 {
                return Err(self.err(format!("relu '{name}': clamp {c} must be finite and > 0")));
            }
        }
        self.push(name, GraphOp::Relu { clamp }, vec![pred.0], d)
    }

    /// Per-channel batch normalization, pre-folded to scale/shift form.
    /// Parameters are deterministic ([`super::net_bn_params`], seeded by
    /// the node's BatchNorm ordinal), like the synthetic conv weights.
    pub fn batch_norm(&mut self, name: &str, pred: NodeId) -> Result<NodeId> {
        let d = self.check_pred(name, pred)?;
        self.push(name, GraphOp::BatchNorm, vec![pred.0], d)
    }

    /// Tag subsequently added nodes as `lane` of fan-out group `group`
    /// (lanes of one group must be mutually independent and may execute
    /// on concurrent threads). Clear with [`GraphBuilder::backbone`].
    pub fn lane(&mut self, group: usize, lane: usize) -> &mut Self {
        self.branch = Some(BranchTag { group, lane });
        self
    }

    /// Return to untagged (serial backbone) node construction.
    pub fn backbone(&mut self) -> &mut Self {
        self.branch = None;
        self
    }

    /// Inferred `C x H x W` output dims of a node built so far.
    pub fn dims_of(&self, id: NodeId) -> Dims {
        self.dims[id.0]
    }

    /// Finish the model. `output` must be the last node added (the graph
    /// convention: the final node is the network output); the whole
    /// graph is then re-checked with [`NetGraph::validate`] — dead
    /// nodes, lane crossings and every shape are verified against the
    /// inferred conv table.
    pub fn build(self, output: NodeId) -> Result<Model> {
        if self.nodes.is_empty() {
            return Err(Error::Shape(format!("builder '{}': the model has no nodes", self.net)));
        }
        if output.0 != self.nodes.len() - 1 {
            return Err(Error::Shape(format!(
                "builder '{}': output '{}' must be the last node added ('{}' is)",
                self.net,
                self.nodes.get(output.0).map(|n| n.name.as_str()).unwrap_or("<foreign id>"),
                self.nodes[self.nodes.len() - 1].name
            )));
        }
        let graph = NetGraph { net: self.net.clone(), nodes: self.nodes };
        graph.validate(&self.shapes)?;
        Ok(Model {
            name: self.net,
            graph,
            shapes: self.shapes,
            dtype: crate::quant::DType::F32,
        })
    }
}

// ---------------------------------------------------------------------
// The paper nets as builder programs
// ---------------------------------------------------------------------

/// AlexNet's five conv layers with the two real 3x3/s2 inter-block
/// max-pools, as a builder program.
pub fn alexnet() -> Model {
    let build = || -> Result<Model> {
        let mut b = GraphBuilder::new("alexnet");
        let x = b.input(3, 227, 227)?;
        let x = b.conv("conv1", x, 96, 11, 4, 0)?;
        let x = b.pool_to("pool1", x, 27, 27)?;
        let x = b.conv("conv2", x, 256, 5, 1, 2)?;
        let x = b.pool_to("pool2", x, 13, 13)?;
        let x = b.conv("conv3", x, 384, 3, 1, 1)?;
        let x = b.conv("conv4", x, 384, 3, 1, 1)?;
        let x = b.conv("conv5", x, 256, 3, 1, 1)?;
        b.build(x)
    };
    build().expect("alexnet builder program is statically valid")
}

/// VGG-16's thirteen 3x3/s1/p1 layers in five blocks joined by 2x2/s2
/// max-pools, as a builder program.
pub fn vgg16() -> Model {
    let build = || -> Result<Model> {
        let mut b = GraphBuilder::new("vgg16");
        let mut x = b.input(3, 224, 224)?;
        let mut h = 224;
        for (block, &(c_o, convs)) in
            [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)].iter().enumerate()
        {
            if block > 0 {
                h /= 2;
                x = b.pool_to(&format!("pool{block}"), x, h, h)?;
            }
            for i in 0..convs {
                x = b.conv(&format!("conv{}_{}", block + 1, i + 1), x, c_o, 3, 1, 1)?;
            }
        }
        b.build(x)
    };
    build().expect("vgg16 builder program is statically valid")
}

/// GoogLeNet — the three stem convs and all nine inception modules as
/// genuine four-lane fan-outs re-joined by channel concats — as a
/// builder program (same `INCEPTION` table as the layer list in
/// [`super::googlenet`]).
pub fn googlenet() -> Model {
    let build = || -> Result<Model> {
        let mut b = GraphBuilder::new("googlenet");
        let x = b.input(3, 224, 224)?;
        let x = b.conv("conv1/7x7_s2", x, 64, 7, 2, 3)?;
        let x = b.pool_to("pool1", x, 56, 56)?;
        let x = b.conv("conv2/3x3_reduce", x, 64, 1, 1, 0)?;
        let mut x = b.conv("conv2/3x3", x, 192, 3, 1, 1)?;
        for (m, &(tag, h, _c_in, n)) in INCEPTION.iter().enumerate() {
            if b.dims_of(x).h != h {
                x = b.pool_to(&format!("pool_before_{tag}"), x, h, h)?;
            }
            let name = |part: &str| format!("inception_{tag}/{part}");
            b.lane(m, 0);
            let b0 = b.conv(&name("1x1"), x, n[0], 1, 1, 0)?;
            b.lane(m, 1);
            let r1 = b.conv(&name("3x3_reduce"), x, n[1], 1, 1, 0)?;
            let b1 = b.conv(&name("3x3"), r1, n[2], 3, 1, 1)?;
            b.lane(m, 2);
            let r2 = b.conv(&name("5x5_reduce"), x, n[3], 1, 1, 0)?;
            let b2 = b.conv(&name("5x5"), r2, n[4], 5, 1, 2)?;
            b.lane(m, 3);
            let p3 = b.pool(&name("pool"), x, 3, 1, 1)?;
            let b3 = b.conv(&name("pool_proj"), p3, n[5], 1, 1, 0)?;
            b.backbone();
            x = b.concat(&name("output"), &[b0, b1, b2, b3])?;
        }
        b.build(x)
    };
    build().expect("googlenet builder program is statically valid")
}

/// A ResNet-style micro-net with two residual [`GraphOp::Add`] joins
/// and real conv→BN→ReLU / conv→BN→Add→ReLU block structure — the
/// committed example model (`examples/models/resnet_micro.json` is
/// this program's JSON serialization, golden-pinned in `net_golden`).
/// The `nets::fuse` pass folds every BN/ReLU/Add of this net into its
/// producing conv's epilogue (see its tests).
pub fn resnet_micro() -> Model {
    let build = || -> Result<Model> {
        let mut b = GraphBuilder::new("resnet_micro");
        let x = b.input(3, 32, 32)?;
        let c0 = b.conv("conv0", x, 16, 3, 1, 1)?;
        let b0 = b.batch_norm("bn0", c0)?;
        let stem = b.relu("relu0", b0, None)?;
        let c1 = b.conv("conv1", stem, 16, 3, 1, 1)?;
        let b1 = b.batch_norm("bn1", c1)?;
        let r1 = b.relu("relu1", b1, None)?;
        let c2 = b.conv("conv2", r1, 16, 3, 1, 1)?;
        let b2 = b.batch_norm("bn2", c2)?;
        let j1 = b.add("add1", &[stem, b2])?;
        let rj1 = b.relu("relu_add1", j1, None)?;
        let c3 = b.conv("conv3", rj1, 16, 3, 1, 1)?;
        let b3 = b.batch_norm("bn3", c3)?;
        let r3 = b.relu("relu3", b3, None)?;
        let c4 = b.conv("conv4", r3, 16, 3, 1, 1)?;
        let b4 = b.batch_norm("bn4", c4)?;
        let j2 = b.add("add2", &[rj1, b4])?;
        let rj2 = b.relu("relu_add2", j2, None)?;
        let p = b.pool("pool", rj2, 2, 2, 0)?;
        let out = b.conv("conv5", p, 32, 3, 1, 1)?;
        b.build(out)
    };
    build().expect("resnet_micro builder program is statically valid")
}

/// A MobileNet-style micro-net: conv stem plus two depthwise-separable
/// blocks (depthwise 3x3 + pointwise 1x1, each BN + ReLU6) and a
/// dilated 3x3 head — the committed example model
/// (`examples/models/mobilenet_micro.json`), exercising grouped,
/// depthwise and dilated convolution through the fused pipeline.
pub fn mobilenet_micro() -> Model {
    let build = || -> Result<Model> {
        let mut b = GraphBuilder::new("mobilenet_micro");
        let x = b.input(3, 16, 16)?;
        let c0 = b.conv("conv0", x, 8, 3, 1, 1)?;
        let b0 = b.batch_norm("bn0", c0)?;
        let mut x = b.relu("relu0", b0, Some(6.0))?;
        for (i, (c_o, stride)) in [(16usize, 1usize), (32, 2)].iter().enumerate() {
            let dw = b.depthwise(&format!("dw{i}"), x, 3, *stride, 1)?;
            let dbn = b.batch_norm(&format!("dw{i}_bn"), dw)?;
            let dr = b.relu(&format!("dw{i}_relu"), dbn, Some(6.0))?;
            let pw = b.conv(&format!("pw{i}"), dr, *c_o, 1, 1, 0)?;
            let pbn = b.batch_norm(&format!("pw{i}_bn"), pw)?;
            x = b.relu(&format!("pw{i}_relu"), pbn, Some(6.0))?;
        }
        let hd = b.conv_opts("head", x, 32, 3, 1, 2, 1, 2)?;
        let out = b.relu("head_relu", hd, None)?;
        b.build(out)
    };
    build().expect("mobilenet_micro builder program is statically valid")
}

/// Built-in builder-program models by name. The CLI's `plan-net`/`serve
/// --net NAME` fall back to this when NAME is not one of the
/// [`super::by_name`] layer tables — which is how `--net resnet_micro`
/// resolves.
pub fn model_by_name(net: &str) -> Option<Model> {
    match net {
        "alexnet" => Some(alexnet()),
        "googlenet" => Some(googlenet()),
        "vgg16" | "vgg" => Some(vgg16()),
        "resnet_micro" => Some(resnet_micro()),
        "mobilenet_micro" => Some(mobilenet_micro()),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Legacy shape-table constructors — thin wrappers over the builder
// ---------------------------------------------------------------------

impl NetGraph {
    /// Linear chain: `Input -> conv_0 -> [pool] -> conv_1 -> ...`, with a
    /// max-pool inserted (geometry from [`pool_spec`]) wherever a layer's
    /// spatial input is smaller than its predecessor's output. Channel
    /// counts must match exactly — a table that is not channel-chainable
    /// (e.g. GoogLeNet's branch traversal) is rejected. Thin wrapper
    /// over [`GraphBuilder`].
    pub fn chain(net: &str, shapes: &[ConvShape]) -> Result<NetGraph> {
        let mut b = GraphBuilder::new(net);
        let x = chain_onto(&mut b, net, shapes)?;
        Ok(b.build(x)?.graph)
    }

    /// GoogLeNet-style DAG over a layer table shaped `3 stem convs +
    /// 6 convs per inception module` (the order [`super::googlenet`]
    /// emits: `1x1, 3x3_reduce, 3x3, 5x5_reduce, 5x5, pool_proj`). Each
    /// module fans four tagged branches out of its input and re-joins
    /// them with a channel concat; inter-block max-pools are derived
    /// from the shape table, the branch pool is the classic 3x3/s1/p1.
    /// Works for any table with that structure (e.g. downscaled test
    /// nets), not just the full 57-layer GoogLeNet. Thin wrapper over
    /// [`GraphBuilder`].
    pub fn inception(net: &str, shapes: &[ConvShape]) -> Result<NetGraph> {
        const STEM: usize = 3;
        const PER_MODULE: usize = 6;
        if shapes.len() < STEM + PER_MODULE || (shapes.len() - STEM) % PER_MODULE != 0 {
            return Err(Error::Shape(format!(
                "inception table must hold {STEM} stem convs plus a multiple of {PER_MODULE} \
                 module convs, got {} layers",
                shapes.len()
            )));
        }
        let modules = (shapes.len() - STEM) / PER_MODULE;
        let mut b = GraphBuilder::new(net);
        let mut x = chain_onto(&mut b, net, &shapes[..STEM])?;
        for m in 0..modules {
            let base = STEM + m * PER_MODULE;
            let s1x1 = &shapes[base];
            let d = b.dims_of(x);
            if (d.h, d.w) != (s1x1.h_i, s1x1.w_i) {
                x = b.pool_to(&format!("pool_before_m{m}"), x, s1x1.h_i, s1x1.w_i)?;
            }
            b.lane(m, 0);
            let b0 = b.conv_with(&format!("m{m}/conv0"), x, shapes[base].clone())?;
            b.lane(m, 1);
            let r1 = b.conv_with(&format!("m{m}/conv1"), x, shapes[base + 1].clone())?;
            let b1 = b.conv_with(&format!("m{m}/conv2"), r1, shapes[base + 2].clone())?;
            b.lane(m, 2);
            let r2 = b.conv_with(&format!("m{m}/conv3"), x, shapes[base + 3].clone())?;
            let b2 = b.conv_with(&format!("m{m}/conv4"), r2, shapes[base + 4].clone())?;
            b.lane(m, 3);
            let p3 = b.pool(&format!("m{m}/pool"), x, 3, 1, 1)?;
            let b3 = b.conv_with(&format!("m{m}/conv5"), p3, shapes[base + 5].clone())?;
            b.backbone();
            x = b.concat(&format!("m{m}/concat"), &[b0, b1, b2, b3])?;
        }
        Ok(b.build(x)?.graph)
    }

    /// Build the canonical graph for a named net's layer table:
    /// GoogLeNet gets the inception DAG, everything else (AlexNet, VGG,
    /// ad-hoc test chains) lowers to a trivial chain so all nets share
    /// one executor.
    pub fn for_net(net: &str, shapes: &[ConvShape]) -> Result<NetGraph> {
        if net == "googlenet" {
            NetGraph::inception(net, shapes)
        } else {
            NetGraph::chain(net, shapes)
        }
    }
}

/// Append a conv chain over `shapes` to the builder (creating the input
/// node), returning the last node. Layer names are `l{i}` with derived
/// `pool_before_l{i}` glue — the legacy table-constructor naming.
fn chain_onto(b: &mut GraphBuilder, net: &str, shapes: &[ConvShape]) -> Result<NodeId> {
    let first = shapes
        .first()
        .ok_or_else(|| Error::Shape(format!("net '{net}' has no conv layers")))?;
    let mut x = b.input(first.c_i, first.h_i, first.w_i)?;
    for (i, s) in shapes.iter().enumerate() {
        let d = b.dims_of(x);
        if d.c != s.c_i {
            return Err(Error::Shape(format!(
                "net '{net}' is not a chain: layer {i} wants {} input channels but the \
                 previous node produces {} (branch structure needs an explicit graph)",
                s.c_i, d.c
            )));
        }
        if (d.h, d.w) != (s.h_i, s.w_i) {
            x = b.pool_to(&format!("pool_before_l{i}"), x, s.h_i, s.w_i)?;
        }
        x = b.conv_with(&format!("l{i}"), x, s.clone())?;
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets;

    #[test]
    fn builder_infers_shapes_and_counts_layers() {
        let m = resnet_micro();
        assert_eq!(m.shapes.len(), 6);
        assert_eq!(m.shapes[0], ConvShape::new(3, 32, 32, 16, 3, 3, 1, 1));
        assert_eq!(m.shapes[5], ConvShape::new(16, 16, 16, 32, 3, 3, 1, 1));
        let dims = m.validate().unwrap();
        let out = dims[m.graph.output()];
        assert_eq!((out.c, out.h, out.w), (32, 16, 16));
        let adds = m.graph.nodes.iter().filter(|n| matches!(n.op, GraphOp::Add)).count();
        assert_eq!(adds, 2);
        let bns =
            m.graph.nodes.iter().filter(|n| matches!(n.op, GraphOp::BatchNorm)).count();
        assert_eq!(bns, 5, "one BN per residual-block conv");
        let relus =
            m.graph.nodes.iter().filter(|n| matches!(n.op, GraphOp::Relu { .. })).count();
        assert_eq!(relus, 5);
    }

    #[test]
    fn mobilenet_micro_has_depthwise_and_dilated_layers() {
        let m = mobilenet_micro();
        assert_eq!(m.shapes.len(), 6);
        // dw0: depthwise 3x3 over the 8-channel stem output.
        assert_eq!((m.shapes[1].groups, m.shapes[1].c_o), (8, 8));
        assert!(m.shapes[1].is_depthwise());
        // dw1: stride-2 depthwise over 16 channels.
        assert_eq!((m.shapes[3].groups, m.shapes[3].stride), (16, 2));
        // head: dilated dense 3x3, pad 2 keeps 8x8 spatial.
        assert_eq!(m.shapes[5].dilation, 2);
        let dims = m.validate().unwrap();
        let out = dims[m.graph.output()];
        assert_eq!((out.c, out.h, out.w), (32, 8, 8));
        // Every ReLU except the head carries the ReLU6 clamp.
        let clamps: Vec<Option<f32>> = m
            .graph
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                GraphOp::Relu { clamp } => Some(clamp),
                _ => None,
            })
            .collect();
        assert_eq!(clamps.len(), 6);
        assert_eq!(clamps[5], None);
        assert!(clamps[..5].iter().all(|c| *c == Some(6.0)));
    }

    #[test]
    fn builder_rejects_invalid_groups_and_dilation() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(6, 8, 8).unwrap();
        // groups must divide both channel counts...
        assert!(b.conv_opts("g4", x, 8, 3, 1, 1, 4, 1).is_err(), "4 does not divide 6");
        assert!(b.conv_opts("g6", x, 8, 3, 1, 1, 6, 1).is_err(), "6 does not divide c_o=8");
        // ...and be nonzero.
        assert!(b.conv_opts("g0", x, 6, 3, 1, 1, 0, 1).is_err(), "zero groups");
        // Dilation 0 is meaningless; huge dilation exceeds the padded input.
        assert!(b.conv_opts("d0", x, 6, 3, 1, 1, 1, 0).is_err(), "zero dilation");
        assert!(b.conv_opts("d9", x, 6, 3, 1, 1, 1, 9).is_err(), "dilated kernel too large");
        // The valid depthwise convenience still works on the same node.
        let dw = b.depthwise("dw", x, 3, 1, 1).unwrap();
        assert_eq!(b.dims_of(dw), Dims { c: 6, h: 8, w: 8 });
    }

    #[test]
    fn builder_rejects_bad_relu_clamp() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(4, 8, 8).unwrap();
        assert!(b.relu("r_neg", x, Some(-1.0)).is_err(), "negative clamp");
        assert!(b.relu("r_zero", x, Some(0.0)).is_err(), "zero clamp");
        assert!(b.relu("r_nan", x, Some(f32::NAN)).is_err(), "NaN clamp");
        let r = b.relu("r", x, Some(6.0)).unwrap();
        assert_eq!(b.dims_of(r), Dims { c: 4, h: 8, w: 8 });
        let bn = b.batch_norm("bn", r).unwrap();
        assert_eq!(b.dims_of(bn), Dims { c: 4, h: 8, w: 8 });
    }

    #[test]
    fn paper_net_programs_match_their_layer_tables() {
        for (model, net) in [(alexnet(), "alexnet"), (vgg16(), "vgg16"), (googlenet(), "googlenet")]
        {
            let table: Vec<ConvShape> =
                nets::by_name(net).unwrap().into_iter().map(|l| l.shape).collect();
            assert_eq!(model.shapes, table, "{net}: builder shapes drifted from the table");
            model.validate().unwrap();
        }
    }

    #[test]
    fn builder_rejects_structural_mistakes() {
        // Input not first.
        let mut b = GraphBuilder::new("t");
        let x = b.input(3, 8, 8).unwrap();
        assert!(b.input(3, 8, 8).is_err(), "second input");
        // Duplicate name.
        let _c = b.conv("c", x, 8, 3, 1, 1).unwrap();
        assert!(b.conv("c", x, 8, 3, 1, 1).is_err(), "duplicate node name");
        // Kernel larger than padded input.
        assert!(b.conv("big", x, 8, 11, 1, 0).is_err(), "kernel exceeds input");
        // Pool pad >= kernel.
        assert!(b.pool("p", x, 2, 1, 2).is_err(), "pad >= kernel");
        // Upsampling pool_to.
        assert!(b.pool_to("up", x, 16, 16).is_err(), "upsampling glue");
        // Join arity.
        assert!(b.concat("cat1", &[x]).is_err(), "concat of one");
        assert!(b.add("add1", &[x]).is_err(), "add of one");
    }

    #[test]
    fn build_enforces_output_convention_and_dead_nodes() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(4, 8, 8).unwrap();
        let c0 = b.conv("c0", x, 8, 3, 1, 1).unwrap();
        let _c1 = b.conv("c1", c0, 8, 3, 1, 1).unwrap();
        // c1 is the last node; naming c0 the output leaves c1 dead.
        assert!(b.build(c0).is_err());
    }

    #[test]
    fn avg_pool_builds_and_rejects_bad_geometry() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(4, 8, 8).unwrap();
        let p = b.avg_pool("head", x, 2, 2, 0).unwrap();
        assert_eq!(b.dims_of(p), Dims { c: 4, h: 4, w: 4 });
        // Bad geometry is rejected exactly like max pooling: pad >=
        // kernel leaves windows fully outside the image...
        assert!(b.avg_pool("bad_pad", x, 2, 1, 2).is_err());
        // ...and a window larger than the padded input cannot gather.
        assert!(b.pool_kind_geom("bad_k", x, PoolKind::Avg, 11, 11, 1, 1, 0, 0).is_err());
        // zero stride
        assert!(b.pool_kind_geom("bad_s", x, PoolKind::Avg, 2, 2, 0, 0, 0, 0).is_err());
    }

    #[test]
    fn add_requires_identical_shapes() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(4, 8, 8).unwrap();
        let a = b.conv("a", x, 8, 3, 1, 1).unwrap();
        let c = b.conv("b", x, 16, 3, 1, 1).unwrap();
        assert!(b.add("join", &[a, c]).is_err(), "channel mismatch across add");
    }
}

//! [`NetGraph`] — the static dataflow IR whole networks execute through.
//!
//! The benchmark tables in [`super`] list *conv layers*; a network is a
//! graph over them. AlexNet and VGG are chains with max-pools between
//! blocks, but GoogLeNet's nine inception modules are genuine DAGs: four
//! branches fan out of each module input and re-join through a channel
//! concatenation. Running that structure as a flattened sequence (what
//! the pre-graph `NetRunner` did, with channel-cycling glue) measures
//! the zero-memory-overhead claim against the wrong dataflow; the graph
//! IR makes the branch/concat structure first-class so the network-wide
//! accounting is honest.
//!
//! Nodes are deliberately minimal — the things CNN topologies need:
//!
//! * [`GraphOp::Input`] — the network image (exactly one, node 0);
//! * [`GraphOp::Conv`] — one row of the layer table, by index, so a
//!   [`super::NetPlans`] table maps 1:1 onto the graph;
//! * [`GraphOp::Pool`] — pooling glue with explicit kernel/stride/pad
//!   and a [`PoolKind`] (max for the paper nets' inter-block and branch
//!   pools — derived from the shape tables via [`pool_spec`], or the
//!   classic 3x3/s1/p1 — average for classifier heads);
//! * [`GraphOp::Concat`] — channel concatenation of same-extent maps;
//! * [`GraphOp::Add`] — elementwise residual join of identically shaped
//!   maps (the ResNet skip connection), which keeps *both* operands
//!   live until the join in the executor's arena accounting;
//! * [`GraphOp::Relu`] / [`GraphOp::BatchNorm`] — elementwise
//!   activation / pre-folded per-channel normalization. Standalone they
//!   execute as runner eltwise passes; the `nets::fuse` pass folds
//!   conv→BN→(Add)→ReLU chains into the conv's epilogue so the
//!   intermediate is never materialized.
//!
//! Graphs are built through [`super::GraphBuilder`] (the public
//! model-description API) — [`NetGraph::chain`] and
//! [`NetGraph::inception`] are thin wrappers over it that keep the
//! legacy shape-table entry points working.
//!
//! Nodes are stored in topological order (every predecessor index is
//! smaller than the node's own), and the last node is the network
//! output. [`NetGraph::validate`] infers and checks every activation
//! shape against the conv table — channel counts must match *exactly*;
//! there is no cycling fallback.
//!
//! Branch tags ([`BranchTag`]) mark the independent lanes of a module
//! (set by the inception builder) so the executor may schedule sibling
//! branches across threads; lanes of one group must be mutually
//! independent, which [`NetGraph::validate`] enforces.

use crate::conv::ConvShape;
use crate::{Error, Result};

/// Kernel/stride of the adaptive max-pool mapping a spatial extent of
/// `from` onto `to` (`to <= from`): `stride = from / to`,
/// `kernel = from - (to-1)*stride`, which tiles `from` exactly and
/// reproduces the real AlexNet (3x3/s2), VGG (2x2/s2) and GoogLeNet
/// (2x2/s2 inter-module) pooling geometry from the shape tables alone.
pub fn pool_spec(from: usize, to: usize) -> Result<(usize, usize)> {
    if to == 0 || from == 0 {
        return Err(Error::Shape("zero spatial extent in net graph".into()));
    }
    if from < to {
        return Err(Error::Shape(format!(
            "cannot chain: next layer needs spatial extent {to} > previous output {from} \
             (upsampling glue is not modeled)"
        )));
    }
    let stride = from / to;
    let kernel = from - (to - 1) * stride;
    Ok((kernel, stride))
}

/// Parallel-schedulable branch marker: nodes sharing `(group, lane)`
/// depend only on each other (and on untagged nodes); different lanes of
/// one group are mutually independent and may execute concurrently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BranchTag {
    /// Fan-out region (one inception module = one group).
    pub group: usize,
    /// Branch index within the group.
    pub lane: usize,
}

/// Pooling reduction of a [`GraphOp::Pool`] node.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PoolKind {
    /// Max pooling; padding cells act as `-inf` (never win the max).
    #[default]
    Max,
    /// Average pooling over the *in-bounds* window cells (running sum
    /// scaled by the reciprocal valid-cell count; padding cells are
    /// excluded from both sum and count — classifier-head semantics).
    Avg,
}

impl PoolKind {
    /// The JSON spec spelling (`"max"` / `"avg"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            PoolKind::Max => "max",
            PoolKind::Avg => "avg",
        }
    }

    /// Parse the JSON spec spelling.
    pub fn from_str_opt(s: &str) -> Option<PoolKind> {
        match s {
            "max" => Some(PoolKind::Max),
            "avg" | "average" => Some(PoolKind::Avg),
            _ => None,
        }
    }
}

/// What a graph node computes.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphOp {
    /// The network input image (`C x H x W`). Exactly one, at node 0.
    Input { c: usize, h: usize, w: usize },
    /// One conv layer: an index into the net's layer/plan table.
    Conv { layer: usize },
    /// Pooling with explicit geometry (max or average, see
    /// [`PoolKind`]).
    Pool { kind: PoolKind, kh: usize, kw: usize, sh: usize, sw: usize, ph: usize, pw: usize },
    /// Channel concatenation of all predecessors (equal `H x W`).
    Concat,
    /// Elementwise sum of all predecessors (identical `C x H x W`) —
    /// the residual join.
    Add,
    /// Elementwise `max(0, x)`, with an optional upper clamp
    /// (ReLU6-style `min(clamp, x)`). Dims pass through; the fusion
    /// pass folds eligible Relu nodes into their producing conv's
    /// [`crate::conv::Epilogue`].
    Relu { clamp: Option<f32> },
    /// Per-channel batch normalization, pre-folded to scale/shift form
    /// (`y = x * scale[c] + shift[c]`). Parameters are indexed by the
    /// node's ordinal among BatchNorm nodes (node order) and generated
    /// deterministically at plan time ([`super::net_bn_params`]) like
    /// the synthetic weights — specs stay weight-free.
    BatchNorm,
}

/// One node of the dataflow graph.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphNode {
    pub name: String,
    pub op: GraphOp,
    /// Predecessor node indices (all smaller than this node's index).
    pub preds: Vec<usize>,
    /// Branch lane for parallel scheduling (`None` = serial backbone).
    pub branch: Option<BranchTag>,
}

/// A whole network as a static DAG over a conv-layer table. Construct
/// with [`super::GraphBuilder`] (or the [`NetGraph::chain`] /
/// [`NetGraph::inception`] / [`NetGraph::for_net`] table wrappers);
/// check with [`NetGraph::validate`].
#[derive(Clone, Debug, PartialEq)]
pub struct NetGraph {
    pub net: String,
    pub nodes: Vec<GraphNode>,
}

/// Inferred `C x H x W` dims of one node's output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Dims {
    pub fn floats(&self) -> usize {
        self.c * self.h * self.w
    }
}

pub(crate) fn pool_out(extent: usize, k: usize, s: usize, p: usize) -> Result<usize> {
    if k == 0 || s == 0 {
        return Err(Error::Shape("pool kernel/stride must be >= 1".into()));
    }
    if p >= k {
        return Err(Error::Shape(format!(
            "pool pad {p} >= kernel {k} would leave windows entirely outside the image"
        )));
    }
    if extent + 2 * p < k {
        return Err(Error::Shape(format!(
            "pool kernel {k} larger than padded extent {extent}+2*{p}"
        )));
    }
    Ok((extent + 2 * p - k) / s + 1)
}

impl NetGraph {
    // NB: the `chain` / `inception` / `for_net` shape-table constructors
    // live in `super::builder` — they are thin wrappers over
    // [`super::GraphBuilder`], the public model-description API.

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Index of the network output node (the last node).
    pub fn output(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Per-node ordinal among [`GraphOp::BatchNorm`] nodes, in node
    /// order (`None` for every other op). The ordinal seeds the
    /// deterministic per-channel parameters ([`super::net_bn_params`]),
    /// exactly like conv layer indices seed the synthetic weights — so
    /// the fusion pass, the runner, the calibrator and the NumPy golden
    /// reference all regenerate identical tensors.
    pub fn bn_ordinals(&self) -> Vec<Option<usize>> {
        let mut ord = 0usize;
        self.nodes
            .iter()
            .map(|n| {
                matches!(n.op, GraphOp::BatchNorm).then(|| {
                    ord += 1;
                    ord - 1
                })
            })
            .collect()
    }

    /// Consumer count per node (how many nodes list it as predecessor).
    pub fn consumer_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for &p in &n.preds {
                counts[p] += 1;
            }
        }
        counts
    }

    /// Check the graph against a conv table and infer every node's
    /// output dims. Verifies: topological node order, exactly one
    /// `Input` (node 0), every conv layer used exactly once with its
    /// predecessor dims matching the table *exactly* (no channel
    /// adaptation), pool geometry validity, concat extent agreement,
    /// add operand-shape identity, no dead nodes, and branch-tag
    /// independence (a tagged node's predecessors are untagged or share
    /// its tag).
    pub fn validate(&self, shapes: &[ConvShape]) -> Result<Vec<Dims>> {
        if self.nodes.is_empty() {
            return Err(Error::Shape(format!("net '{}' graph is empty", self.net)));
        }
        let mut dims: Vec<Dims> = Vec::with_capacity(self.nodes.len());
        let mut layer_used = vec![false; shapes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &p in &n.preds {
                if p >= i {
                    return Err(Error::Shape(format!(
                        "{}: node {i} ('{}') lists predecessor {p} at or after itself \
                         (nodes must be topologically ordered)",
                        self.net, n.name
                    )));
                }
                if let Some(tag) = n.branch {
                    let pt = self.nodes[p].branch;
                    if pt.is_some() && pt != Some(tag) {
                        return Err(Error::Shape(format!(
                            "{}: node '{}' (group {} lane {}) depends on another lane — \
                             branch lanes must be independent",
                            self.net, n.name, tag.group, tag.lane
                        )));
                    }
                }
            }
            let d = match &n.op {
                GraphOp::Input { c, h, w } => {
                    if i != 0 || !n.preds.is_empty() {
                        return Err(Error::Shape(format!(
                            "{}: Input must be node 0 with no predecessors",
                            self.net
                        )));
                    }
                    Dims { c: *c, h: *h, w: *w }
                }
                GraphOp::Conv { layer } => {
                    let [p] = n.preds[..] else {
                        return Err(Error::Shape(format!(
                            "{}: conv node '{}' needs exactly one predecessor",
                            self.net, n.name
                        )));
                    };
                    let s = shapes.get(*layer).ok_or_else(|| {
                        Error::Shape(format!(
                            "{}: node '{}' references layer {layer} but the table has {}",
                            self.net,
                            n.name,
                            shapes.len()
                        ))
                    })?;
                    if layer_used[*layer] {
                        return Err(Error::Shape(format!(
                            "{}: layer {layer} used by more than one conv node",
                            self.net
                        )));
                    }
                    layer_used[*layer] = true;
                    let pd = dims[p];
                    if (pd.c, pd.h, pd.w) != (s.c_i, s.h_i, s.w_i) {
                        return Err(Error::Shape(format!(
                            "{}: conv '{}' wants {}x{}x{} but its input produces {}x{}x{}",
                            self.net, n.name, s.c_i, s.h_i, s.w_i, pd.c, pd.h, pd.w
                        )));
                    }
                    Dims { c: s.c_o, h: s.h_o(), w: s.w_o() }
                }
                GraphOp::Pool { kind: _, kh, kw, sh, sw, ph, pw } => {
                    let [p] = n.preds[..] else {
                        return Err(Error::Shape(format!(
                            "{}: pool node '{}' needs exactly one predecessor",
                            self.net, n.name
                        )));
                    };
                    let pd = dims[p];
                    Dims {
                        c: pd.c,
                        h: pool_out(pd.h, *kh, *sh, *ph)?,
                        w: pool_out(pd.w, *kw, *sw, *pw)?,
                    }
                }
                GraphOp::Add => {
                    if n.preds.len() < 2 {
                        return Err(Error::Shape(format!(
                            "{}: add node '{}' needs at least two operands, got {}",
                            self.net,
                            n.name,
                            n.preds.len()
                        )));
                    }
                    let first = dims[n.preds[0]];
                    for &p in &n.preds[1..] {
                        let pd = dims[p];
                        if pd != first {
                            return Err(Error::Shape(format!(
                                "{}: add '{}' mixes shapes {}x{}x{} and {}x{}x{} \
                                 (residual joins need identical operands)",
                                self.net, n.name, first.c, first.h, first.w, pd.c, pd.h, pd.w
                            )));
                        }
                    }
                    first
                }
                GraphOp::Relu { clamp } => {
                    let [p] = n.preds[..] else {
                        return Err(Error::Shape(format!(
                            "{}: relu node '{}' needs exactly one predecessor",
                            self.net, n.name
                        )));
                    };
                    if let Some(c) = clamp {
                        if !c.is_finite() || *c <= 0.0 {
                            return Err(Error::Shape(format!(
                                "{}: relu node '{}' clamp {c} must be finite and > 0",
                                self.net, n.name
                            )));
                        }
                    }
                    dims[p]
                }
                GraphOp::BatchNorm => {
                    let [p] = n.preds[..] else {
                        return Err(Error::Shape(format!(
                            "{}: batch_norm node '{}' needs exactly one predecessor",
                            self.net, n.name
                        )));
                    };
                    dims[p]
                }
                GraphOp::Concat => {
                    if n.preds.is_empty() {
                        return Err(Error::Shape(format!(
                            "{}: concat node '{}' has no inputs",
                            self.net, n.name
                        )));
                    }
                    let first = dims[n.preds[0]];
                    let mut c = 0usize;
                    for &p in &n.preds {
                        let pd = dims[p];
                        if (pd.h, pd.w) != (first.h, first.w) {
                            return Err(Error::Shape(format!(
                                "{}: concat '{}' mixes extents {}x{} and {}x{}",
                                self.net, n.name, first.h, first.w, pd.h, pd.w
                            )));
                        }
                        c += pd.c;
                    }
                    Dims { c, h: first.h, w: first.w }
                }
            };
            dims.push(d);
        }
        if let Some(missing) = layer_used.iter().position(|u| !u) {
            return Err(Error::Shape(format!(
                "{}: conv layer {missing} of the table is not reachable in the graph",
                self.net
            )));
        }
        let counts = self.consumer_counts();
        for (i, &c) in counts.iter().enumerate().take(self.nodes.len() - 1) {
            if c == 0 {
                return Err(Error::Shape(format!(
                    "{}: node {i} ('{}') has no consumers and is not the output",
                    self.net, self.nodes[i].name
                )));
            }
        }
        Ok(dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets;

    fn shapes_of(net: &str) -> Vec<ConvShape> {
        nets::by_name(net).unwrap().into_iter().map(|l| l.shape).collect()
    }

    #[test]
    fn pool_spec_reproduces_real_pools() {
        assert_eq!(pool_spec(55, 27).unwrap(), (3, 2)); // AlexNet 3x3/s2
        assert_eq!(pool_spec(27, 13).unwrap(), (3, 2));
        assert_eq!(pool_spec(224, 112).unwrap(), (2, 2)); // VGG 2x2/s2
        assert_eq!(pool_spec(14, 14).unwrap(), (1, 1)); // identity
        assert_eq!(pool_spec(7, 1).unwrap(), (7, 7)); // global pool
        assert!(pool_spec(13, 14).is_err()); // upsampling is not modeled
    }

    #[test]
    fn alexnet_chain_validates() {
        let shapes = shapes_of("alexnet");
        let g = NetGraph::for_net("alexnet", &shapes).unwrap();
        let dims = g.validate(&shapes).unwrap();
        // input + 5 convs + pools after conv1 and conv2
        assert_eq!(g.len(), 1 + 5 + 2);
        assert_eq!(dims[g.output()], Dims { c: 256, h: 13, w: 13 });
    }

    #[test]
    fn vgg_chain_has_four_interblock_pools() {
        let shapes = shapes_of("vgg16");
        let g = NetGraph::for_net("vgg16", &shapes).unwrap();
        let pools = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, GraphOp::Pool { .. }))
            .count();
        assert_eq!(pools, 4, "blocks 1-5 are joined by 2x2/s2 pools");
        let dims = g.validate(&shapes).unwrap();
        assert_eq!(dims[g.output()], Dims { c: 512, h: 14, w: 14 });
    }

    #[test]
    fn googlenet_graph_is_a_dag_with_nine_concats() {
        let shapes = shapes_of("googlenet");
        let g = NetGraph::for_net("googlenet", &shapes).unwrap();
        let dims = g.validate(&shapes).unwrap();
        let concats = g.nodes.iter().filter(|n| matches!(n.op, GraphOp::Concat)).count();
        assert_eq!(concats, 9);
        // 1024 = 384 + 384 + 128 + 128 channels out of inception 5b.
        assert_eq!(dims[g.output()], Dims { c: 1024, h: 7, w: 7 });
        // Every module input fans out to four consumers (the branches).
        let counts = g.consumer_counts();
        let fan_outs = counts.iter().filter(|&&c| c >= 4).count();
        assert_eq!(fan_outs, 9, "nine module inputs feed four branches each");
        // Inter-module pools at 3b->4a and 4e->5a plus the two stem
        // pools, plus nine 3x3/s1 branch pools.
        let pools = g.nodes.iter().filter(|n| matches!(n.op, GraphOp::Pool { .. })).count();
        assert_eq!(pools, 2 + 2 + 9);
    }

    #[test]
    fn googlenet_rejected_as_chain() {
        let shapes = shapes_of("googlenet");
        assert!(NetGraph::chain("googlenet", &shapes).is_err(), "branch table is not a chain");
    }

    #[test]
    fn chain_rejects_upsampling_and_empty() {
        let shapes = [
            ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1),
            ConvShape::new(8, 16, 16, 8, 3, 3, 1, 1),
        ];
        assert!(NetGraph::chain("bad", &shapes).is_err());
        assert!(NetGraph::chain("empty", &[]).is_err());
    }

    #[test]
    fn validate_catches_structural_errors() {
        let shapes = [ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1)];
        let mut g = NetGraph::chain("t", &shapes).unwrap();
        // Dead node: insert an unused pool at the end... (pool after the
        // output conv, then the conv is no longer the output but the
        // pool has no consumers either way it is fine as output; instead
        // make a node reference a missing layer.)
        g.nodes.push(GraphNode {
            name: "bogus".into(),
            op: GraphOp::Conv { layer: 7 },
            preds: vec![1],
            branch: None,
        });
        assert!(g.validate(&shapes).is_err(), "layer index out of table");

        let g2 = NetGraph {
            net: "t".into(),
            nodes: vec![GraphNode {
                name: "i".into(),
                op: GraphOp::Input { c: 1, h: 1, w: 1 },
                preds: vec![],
                branch: None,
            }],
        };
        assert!(g2.validate(&[]).is_ok(), "input-only graph with empty table is degenerate-ok");
    }

    #[test]
    fn branch_tags_must_stay_in_lane() {
        // Two 1x1 convs chained but tagged as *different* lanes of one
        // group: validate must reject the cross-lane dependency.
        let shapes = [
            ConvShape::new(4, 4, 4, 8, 1, 1, 1, 0),
            ConvShape::new(8, 4, 4, 8, 1, 1, 1, 0),
        ];
        let g = NetGraph {
            net: "t".into(),
            nodes: vec![
                GraphNode {
                    name: "i".into(),
                    op: GraphOp::Input { c: 4, h: 4, w: 4 },
                    preds: vec![],
                    branch: None,
                },
                GraphNode {
                    name: "a".into(),
                    op: GraphOp::Conv { layer: 0 },
                    preds: vec![0],
                    branch: Some(BranchTag { group: 0, lane: 0 }),
                },
                GraphNode {
                    name: "b".into(),
                    op: GraphOp::Conv { layer: 1 },
                    preds: vec![1],
                    branch: Some(BranchTag { group: 0, lane: 1 }),
                },
            ],
        };
        assert!(g.validate(&shapes).is_err());
    }
}

//! Graph fusion pass: fold conv→BN→Add→ReLU chains into conv epilogues.
//!
//! The paper's zero-memory-overhead argument is about *layers*; real
//! networks interleave convolutions with cheap elementwise tails
//! (batch-norm, residual adds, activations). Executed standalone, every
//! tail materializes (and re-reads) a full activation map — pure memory
//! traffic the direct convolution already paid for. This pass rewrites
//! the *schedule* instead of the arithmetic: each eligible chain
//!
//! ```text
//! conv -> [batch_norm] -> [add] -> [relu]      (every stage optional)
//! ```
//!
//! is annotated for the executor so the conv applies the whole tail
//! in-register via its [`Epilogue`] (see [`crate::conv::epilogue`]) and
//! writes the chain *tail*'s value directly — the intermediates are
//! never materialized. The stage order above is exactly the epilogue's
//! fixed application order, so fusion is a pure scheduling change: f32
//! results are **bitwise identical** to the unfused graph (scale and
//! shift are two separately-rounded ops on every path, and IEEE-754
//! addition is commutative, which covers both `x + shortcut` operand
//! orders of a residual join).
//!
//! # Eligibility
//!
//! Walking from each conv node, a candidate stage is absorbed when:
//!
//! * the current chain tail has **exactly one consumer** (the
//!   candidate) — otherwise the intermediate value is observable and
//!   must materialize;
//! * the candidate's op fits the remaining stage order (`batch_norm`
//!   before `add` before `relu`, each at most once);
//! * an `add` has exactly two operands, one of which is the chain tail;
//!   the other (the shortcut) must be an **earlier** node than the conv
//!   itself, so it is already computed when the fused conv runs;
//! * the candidate carries the same [`BranchTag`] as the conv (fusing
//!   across lane boundaries would move work between parallel branches).
//!
//! Because absorption requires single-consumer intermediates, an
//! absorbed intermediate can never be referenced anywhere else — only
//! chain *tails* materialize, and shortcut operands always point at
//! materialized values.
//!
//! The graph itself is not rewritten: [`fuse`] returns a [`FusedNet`]
//! annotation layer ([`NodeRole`] per node, one [`LayerFusion`] per
//! conv layer) that [`crate::engine::NetRunner`]'s fused compile mode
//! consumes, plus an auditable [`FusionReport`] (printed by
//! `dconv plan-net`). Nodes left standalone (`relu` after a pool, a
//! three-way add, a fan-out BN) keep executing as runner eltwise ops —
//! fusion is an optimization, never a semantic requirement.
//!
//! [`BranchTag`]: super::BranchTag

use std::fmt;

use crate::conv::Epilogue;
use crate::{Error, Result};

use super::graph::GraphOp;
use super::plans::net_bn_params;
use super::spec::Model;

/// What the fused schedule does with one graph node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    /// The node executes as its own op (conv, pool, eltwise, join...).
    Kept,
    /// The node's work was folded into the epilogue of the conv at graph
    /// node index `into`; the node itself is skipped by the scheduler.
    /// If the node is its chain's tail, its *value* is still produced —
    /// written directly by the fused conv.
    Absorbed { into: usize },
}

/// The fused epilogue of one conv layer (indexed like the model's shape
/// table). A conv with nothing folded in holds the all-`None` default.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerFusion {
    /// Ordinal of the absorbed `batch_norm` node among the graph's BN
    /// nodes ([`super::NetGraph::bn_ordinals`]) — the seed for its
    /// deterministic scale/shift parameters ([`net_bn_params`]).
    pub bn: Option<usize>,
    /// Graph node index of the residual shortcut operand (the non-chain
    /// input of the absorbed `add`). Always an already-materialized
    /// value computed before the conv.
    pub res_node: Option<usize>,
    /// An absorbed trailing `relu`.
    pub relu: bool,
    /// The absorbed relu's upper clamp (ReLU6-style).
    pub clamp: Option<f32>,
}

impl LayerFusion {
    /// True when nothing was folded into this conv.
    pub fn is_none(&self) -> bool {
        self.bn.is_none() && self.res_node.is_none() && !self.relu
    }

    /// Materialize the [`Epilogue`] for a conv with `c_o` output
    /// channels (BN parameters regenerated from the ordinal).
    pub fn epilogue(&self, c_o: usize) -> Epilogue {
        let mut ep = match self.bn {
            Some(ord) => {
                let (scale, shift) = net_bn_params(ord, c_o);
                Epilogue::bn(scale, shift)
            }
            None => Epilogue::none(),
        };
        if self.res_node.is_some() {
            ep = ep.with_residual();
        }
        if self.relu {
            ep = ep.with_relu(self.clamp);
        }
        ep
    }
}

/// One merge of the report: a conv and the chain it absorbed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FusionMerge {
    /// Conv node name.
    pub conv: String,
    /// Absorbed node names, in chain order.
    pub absorbed: Vec<String>,
    /// Stable merge signature: `conv` plus `+bn` / `+add` / `+relu` in
    /// stage order (e.g. `conv+bn+add+relu`) — what CI greps for.
    pub kind: String,
}

/// Auditable summary of what [`fuse`] did to a model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FusionReport {
    pub net: String,
    pub merges: Vec<FusionMerge>,
    /// Graph nodes before fusion.
    pub nodes_before: usize,
    /// Nodes the fused schedule actually executes (tails are written by
    /// their convs, intermediates disappear).
    pub nodes_scheduled: usize,
}

impl fmt::Display for FusionReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "fusion report for {}: {} merges, {} -> {} scheduled nodes",
            self.net,
            self.merges.len(),
            self.nodes_before,
            self.nodes_scheduled
        )?;
        for m in &self.merges {
            writeln!(f, "  {} <- {} ({})", m.conv, m.absorbed.join(", "), m.kind)?;
        }
        Ok(())
    }
}

/// The annotation layer the fused executor consumes — the model graph is
/// unchanged; this says how to *schedule* it.
#[derive(Clone, Debug, PartialEq)]
pub struct FusedNet {
    /// Per graph node: kept, or absorbed into a conv.
    pub roles: Vec<NodeRole>,
    /// Per graph node: the node whose *value* this node's output lives
    /// in. For a conv that absorbed a chain this is the chain tail (the
    /// conv writes the tail's value directly); for every other node,
    /// itself.
    pub tail: Vec<usize>,
    /// Per conv layer (shape-table order): what its epilogue fuses.
    pub fusions: Vec<LayerFusion>,
    pub report: FusionReport,
}

impl FusedNet {
    /// Convenience: the epilogue of conv layer `layer` with `c_o`
    /// output channels.
    pub fn epilogue(&self, layer: usize, c_o: usize) -> Epilogue {
        self.fusions[layer].epilogue(c_o)
    }
}

/// Run the fusion pass over a validated model. Pure analysis — the
/// model is untouched; the returned [`FusedNet`] annotates it.
pub fn fuse(model: &Model) -> Result<FusedNet> {
    model.validate()?;
    let graph = &model.graph;
    let n = graph.nodes.len();
    let counts = graph.consumer_counts();
    let bn_ords = graph.bn_ordinals();
    // consumers[i] = indices of nodes that read node i.
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in graph.nodes.iter().enumerate() {
        for &p in &node.preds {
            consumers[p].push(i);
        }
    }

    let mut roles = vec![NodeRole::Kept; n];
    let mut tail: Vec<usize> = (0..n).collect();
    let mut fusions = vec![LayerFusion::default(); model.shapes.len()];
    let mut merges = Vec::new();

    for (ci, node) in graph.nodes.iter().enumerate() {
        let GraphOp::Conv { layer } = node.op else { continue };
        let mut fusion = LayerFusion::default();
        let mut absorbed: Vec<usize> = Vec::new();
        // Stages still available, in epilogue order.
        let (mut bn_open, mut add_open) = (true, true);
        let mut cur = ci;
        loop {
            // The chain extends only through a sole consumer...
            if counts[cur] != 1 {
                break;
            }
            let cand = consumers[cur][0];
            // ...in the same branch lane as the conv.
            if graph.nodes[cand].branch != node.branch {
                break;
            }
            match &graph.nodes[cand].op {
                GraphOp::BatchNorm if bn_open => {
                    fusion.bn = Some(bn_ords[cand].expect("BN node has an ordinal"));
                    bn_open = false;
                }
                GraphOp::Add if add_open => {
                    let [a, b] = graph.nodes[cand].preds[..] else { break };
                    let shortcut = if a == cur { b } else { a };
                    // Both operands being the chain tail (a == b) fails
                    // the ordering requirement below, since cur >= ci.
                    if shortcut >= ci {
                        break; // not computed before the conv runs
                    }
                    fusion.res_node = Some(shortcut);
                    bn_open = false;
                    add_open = false;
                }
                GraphOp::Relu { clamp } => {
                    fusion.relu = true;
                    fusion.clamp = *clamp;
                    absorbed.push(cand);
                    roles[cand] = NodeRole::Absorbed { into: ci };
                    cur = cand;
                    break; // relu is the last stage
                }
                _ => break,
            }
            absorbed.push(cand);
            roles[cand] = NodeRole::Absorbed { into: ci };
            cur = cand;
        }
        if absorbed.is_empty() {
            continue;
        }
        tail[ci] = cur;
        let mut kind = String::from("conv");
        if fusion.bn.is_some() {
            kind.push_str("+bn");
        }
        if fusion.res_node.is_some() {
            kind.push_str("+add");
        }
        if fusion.relu {
            kind.push_str("+relu");
        }
        merges.push(FusionMerge {
            conv: node.name.clone(),
            absorbed: absorbed.iter().map(|&i| graph.nodes[i].name.clone()).collect(),
            kind,
        });
        fusions[layer] = fusion;
    }

    // Sanity: the output node must stay materialized — it is always a
    // chain tail or kept, never an absorbed intermediate (intermediates
    // have exactly one consumer; the output has zero).
    let out = graph.output();
    if roles[out] != NodeRole::Kept && tail.iter().all(|&t| t != out) {
        return Err(Error::Shape(format!(
            "fusion pass absorbed the output node of '{}' as an intermediate (bug)",
            model.name
        )));
    }

    let scheduled = roles.iter().filter(|r| matches!(r, NodeRole::Kept)).count();
    Ok(FusedNet {
        roles,
        tail,
        fusions,
        report: FusionReport {
            net: model.name.clone(),
            merges,
            nodes_before: n,
            nodes_scheduled: scheduled,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::builder::{mobilenet_micro, resnet_micro, GraphBuilder};

    #[test]
    fn resnet_micro_fuses_every_tail() {
        let model = resnet_micro();
        let fused = fuse(&model).unwrap();
        let r = &fused.report;
        assert_eq!(r.merges.len(), 5, "{r}");
        let kinds: Vec<&str> = r.merges.iter().map(|m| m.kind.as_str()).collect();
        assert_eq!(
            kinds,
            [
                "conv+bn+relu",     // conv0
                "conv+bn+relu",     // conv1
                "conv+bn+add+relu", // conv2 absorbs the first residual join
                "conv+bn+relu",     // conv3
                "conv+bn+add+relu", // conv4
            ]
        );
        // 20 nodes; 12 absorbed -> input + 6 convs + pool scheduled.
        assert_eq!((r.nodes_before, r.nodes_scheduled), (20, 8));
        // conv2's shortcut is relu0 (the stem chain's tail), whose value
        // conv0 writes directly.
        let names: Vec<&str> = model.graph.nodes.iter().map(|n| n.name.as_str()).collect();
        let conv0 = names.iter().position(|&n| n == "conv0").unwrap();
        let relu0 = names.iter().position(|&n| n == "relu0").unwrap();
        let conv2_layer = 2;
        assert_eq!(fused.fusions[conv2_layer].res_node, Some(relu0));
        assert_eq!(fused.tail[conv0], relu0);
        assert_eq!(fused.roles[relu0], NodeRole::Absorbed { into: conv0 });
        // BN ordinals follow node order: conv2's BN is bn2, ordinal 2.
        assert_eq!(fused.fusions[conv2_layer].bn, Some(2));
        // The epilogue materializes with the right shape and stages.
        let ep = fused.epilogue(conv2_layer, model.shapes[conv2_layer].c_o);
        assert_eq!(ep.scale.len(), 16);
        assert!(ep.residual && ep.relu && ep.clamp.is_none());
        // Final conv feeds the output unfused.
        assert!(fused.fusions[5].is_none());
    }

    #[test]
    fn mobilenet_micro_fuses_depthwise_and_dilated_heads() {
        let model = mobilenet_micro();
        let fused = fuse(&model).unwrap();
        let r = &fused.report;
        assert_eq!(r.merges.len(), 6, "{r}");
        assert!(r.merges[..5].iter().all(|m| m.kind == "conv+bn+relu"));
        assert_eq!(r.merges[5].kind, "conv+relu", "dilated head has no BN");
        assert_eq!((r.nodes_before, r.nodes_scheduled), (18, 7));
        // ReLU6 clamps ride into the epilogues.
        assert_eq!(fused.fusions[0].clamp, Some(6.0));
        assert_eq!(fused.fusions[5].clamp, None);
        // The report is greppable.
        let text = r.to_string();
        assert!(text.contains("fusion report for mobilenet_micro: 6 merges"));
        assert!(text.contains("conv+bn+relu"));
    }

    #[test]
    fn plain_nets_report_zero_merges() {
        for model in
            [crate::nets::builder::alexnet(), crate::nets::builder::googlenet()]
        {
            let fused = fuse(&model).unwrap();
            assert!(fused.report.merges.is_empty(), "{}", model.name);
            assert_eq!(fused.report.nodes_scheduled, fused.report.nodes_before);
            assert!(fused.roles.iter().all(|r| *r == NodeRole::Kept));
            assert!(fused.fusions.iter().all(LayerFusion::is_none));
            assert!((0..fused.tail.len()).all(|i| fused.tail[i] == i));
        }
    }

    #[test]
    fn fan_out_and_misordered_stages_stay_standalone() {
        // conv feeding both a relu and a second conv: the intermediate
        // is observable, nothing fuses into conv "c".
        let mut b = GraphBuilder::new("fanout");
        let x = b.input(4, 8, 8).unwrap();
        let c = b.conv("c", x, 8, 3, 1, 1).unwrap();
        let r = b.relu("r", c, None).unwrap();
        let c2 = b.conv("c2", c, 8, 3, 1, 1).unwrap();
        let j = b.add("j", &[r, c2]).unwrap();
        let model = b.build(j).unwrap();
        let fused = fuse(&model).unwrap();
        // Only c2 -> j fuses (c2 has one consumer, shortcut r precedes
        // c2); the relu after the fan-out conv stays standalone.
        assert_eq!(fused.report.merges.len(), 1, "{}", fused.report);
        assert_eq!(fused.report.merges[0].kind, "conv+add");
        assert_eq!(fused.roles[model.graph.nodes.len() - 1], NodeRole::Absorbed { into: 3 });

        // relu BEFORE batch_norm does not match the epilogue order: the
        // relu fuses, the BN stays standalone.
        let mut b = GraphBuilder::new("misorder");
        let x = b.input(4, 8, 8).unwrap();
        let c = b.conv("c", x, 8, 3, 1, 1).unwrap();
        let r = b.relu("r", c, None).unwrap();
        let bn = b.batch_norm("bn", r).unwrap();
        let model = b.build(bn).unwrap();
        let fused = fuse(&model).unwrap();
        assert_eq!(fused.report.merges.len(), 1);
        assert_eq!(fused.report.merges[0].kind, "conv+relu");
        assert_eq!(*fused.roles.last().unwrap(), NodeRole::Kept, "BN survives");
    }

    #[test]
    fn three_way_add_and_pool_tails_are_not_fused() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(4, 8, 8).unwrap();
        let a = b.conv("a", x, 4, 3, 1, 1).unwrap();
        let c = b.conv("c", a, 4, 3, 1, 1).unwrap();
        let j = b.add("j", &[x, a, c]).unwrap();
        let p = b.pool("p", j, 2, 2, 0).unwrap();
        let r = b.relu("r", p, None).unwrap();
        let model = b.build(r).unwrap();
        let fused = fuse(&model).unwrap();
        assert!(fused.report.merges.is_empty(), "{}", fused.report);
        // Standalone relu-after-pool is kept for the runner's eltwise.
        assert_eq!(*fused.roles.last().unwrap(), NodeRole::Kept);
    }
}

//! Benchmark networks — every convolution layer of AlexNet, GoogLeNet and
//! VGG-16, the three suites the paper evaluates (§5.1 Benchmarks).
//!
//! Only layer *shapes* matter for performance reproduction (the paper runs
//! synthetic data through the layers); shapes follow the standard Caffe
//! deploy definitions.

pub mod builder;
pub mod fuse;
pub mod graph;
pub mod plans;
pub mod spec;

pub use builder::{model_by_name, GraphBuilder, NodeId};
pub use fuse::{fuse, FusedNet, FusionReport, LayerFusion, NodeRole};
pub use graph::{pool_spec, BranchTag, Dims, GraphNode, GraphOp, NetGraph, PoolKind};
pub use plans::{net_bn_params, net_kernel, AutotuneChoice, NetPlans, PlannedLayer, TunedChoice};
pub use spec::Model;

use crate::conv::ConvShape;

/// One convolution layer of a benchmark network.
#[derive(Clone, Debug)]
pub struct Layer {
    pub net: String,
    pub name: String,
    pub shape: ConvShape,
}

impl Layer {
    #[allow(clippy::too_many_arguments)] // one row of the Caffe deploy table
    fn new(
        net: &str,
        name: impl Into<String>,
        c_i: usize,
        h_i: usize,
        c_o: usize,
        f: usize,
        stride: usize,
        pad: usize,
    ) -> Layer {
        Layer {
            net: net.to_string(),
            name: name.into(),
            shape: ConvShape::new(c_i, h_i, h_i, c_o, f, f, stride, pad),
        }
    }

    /// GFLOP count of the layer (2 FLOPs per MAC).
    pub fn gflops(&self) -> f64 {
        self.shape.flops() as f64 / 1e9
    }
}

/// AlexNet (Krizhevsky et al. 2012) — the five convolution layers
/// (ungrouped, as in the NNPACK/caffe benchmark shapes the paper uses).
pub fn alexnet() -> Vec<Layer> {
    vec![
        Layer::new("alexnet", "conv1", 3, 227, 96, 11, 4, 0),
        Layer::new("alexnet", "conv2", 96, 27, 256, 5, 1, 2),
        Layer::new("alexnet", "conv3", 256, 13, 384, 3, 1, 1),
        Layer::new("alexnet", "conv4", 384, 13, 384, 3, 1, 1),
        Layer::new("alexnet", "conv5", 384, 13, 256, 3, 1, 1),
    ]
}

/// VGG-16 (Simonyan & Zisserman 2014) — thirteen 3x3/s1/p1 layers.
pub fn vgg16() -> Vec<Layer> {
    let cfg: [(usize, usize, usize); 13] = [
        (3, 224, 64),
        (64, 224, 64),
        (64, 112, 128),
        (128, 112, 128),
        (128, 56, 256),
        (256, 56, 256),
        (256, 56, 256),
        (256, 28, 512),
        (512, 28, 512),
        (512, 28, 512),
        (512, 14, 512),
        (512, 14, 512),
        (512, 14, 512),
    ];
    cfg.iter()
        .enumerate()
        .map(|(i, &(c_i, h, c_o))| {
            let name = format!("conv{}_{}", block_of(i), idx_in_block(i));
            Layer::new("vgg16", name, c_i, h, c_o, 3, 1, 1)
        })
        .collect()
}

fn block_of(i: usize) -> usize {
    match i {
        0..=1 => 1,
        2..=3 => 2,
        4..=6 => 3,
        7..=9 => 4,
        _ => 5,
    }
}
fn idx_in_block(i: usize) -> usize {
    match i {
        0 | 2 | 4 | 7 | 10 => 1,
        1 | 3 | 5 | 8 | 11 => 2,
        _ => 3,
    }
}

/// The nine inception modules:
/// `(name, H, C_in, [n1x1, n3x3red, n3x3, n5x5red, n5x5, pool_proj])`.
/// Shared by the [`googlenet`] layer table and the
/// [`builder::googlenet`] builder program — one source of truth.
pub(crate) const INCEPTION: [(&str, usize, usize, [usize; 6]); 9] = [
    ("3a", 28, 192, [64, 96, 128, 16, 32, 32]),
    ("3b", 28, 256, [128, 128, 192, 32, 96, 64]),
    ("4a", 14, 480, [192, 96, 208, 16, 48, 64]),
    ("4b", 14, 512, [160, 112, 224, 24, 64, 64]),
    ("4c", 14, 512, [128, 128, 256, 24, 64, 64]),
    ("4d", 14, 512, [112, 144, 288, 32, 64, 64]),
    ("4e", 14, 528, [256, 160, 320, 32, 128, 128]),
    ("5a", 7, 832, [256, 160, 320, 32, 128, 128]),
    ("5b", 7, 832, [384, 192, 384, 48, 128, 128]),
];

/// GoogLeNet (Szegedy et al. 2015) — stem convolutions plus all six
/// convolutions of each of the nine inception modules (57 conv layers).
pub fn googlenet() -> Vec<Layer> {
    let mut layers = vec![
        Layer::new("googlenet", "conv1/7x7_s2", 3, 224, 64, 7, 2, 3),
        Layer::new("googlenet", "conv2/3x3_reduce", 64, 56, 64, 1, 1, 0),
        Layer::new("googlenet", "conv2/3x3", 64, 56, 192, 3, 1, 1),
    ];
    for (tag, h, c_in, n) in INCEPTION {
        let mut push = |name: String, c_i: usize, c_o: usize, f: usize, s: usize, p: usize| {
            layers.push(Layer::new("googlenet", name, c_i, h, c_o, f, s, p));
        };
        push(format!("inception_{tag}/1x1"), c_in, n[0], 1, 1, 0);
        push(format!("inception_{tag}/3x3_reduce"), c_in, n[1], 1, 1, 0);
        push(format!("inception_{tag}/3x3"), n[1], n[2], 3, 1, 1);
        push(format!("inception_{tag}/5x5_reduce"), c_in, n[3], 1, 1, 0);
        push(format!("inception_{tag}/5x5"), n[3], n[4], 5, 1, 2);
        push(format!("inception_{tag}/pool_proj"), c_in, n[5], 1, 1, 0);
    }
    layers
}

/// Every conv layer of the three benchmark networks.
pub fn all_layers() -> Vec<Layer> {
    let mut v = alexnet();
    v.extend(googlenet());
    v.extend(vgg16());
    v
}

/// Look a network up by name (`alexnet`, `googlenet`, `vgg16`).
pub fn by_name(net: &str) -> Option<Vec<Layer>> {
    match net {
        "alexnet" => Some(alexnet()),
        "googlenet" => Some(googlenet()),
        "vgg16" | "vgg" => Some(vgg16()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_output_sizes() {
        let l = alexnet();
        assert_eq!(l[0].shape.h_o(), 55);
        assert_eq!(l[1].shape.h_o(), 27);
        assert_eq!(l[2].shape.h_o(), 13);
        assert_eq!(l[4].shape.c_o, 256);
    }

    #[test]
    fn counts() {
        assert_eq!(alexnet().len(), 5);
        assert_eq!(vgg16().len(), 13);
        assert_eq!(googlenet().len(), 3 + 9 * 6);
        assert_eq!(all_layers().len(), 5 + 13 + 57);
    }

    #[test]
    fn vgg_layers_all_3x3_s1_p1() {
        for l in vgg16() {
            assert_eq!(l.shape.h_f, 3);
            assert_eq!(l.shape.stride, 1);
            assert_eq!(l.shape.pad, 1);
            assert_eq!(l.shape.h_o(), l.shape.h_i, "same-padding");
        }
    }

    #[test]
    fn all_shapes_valid() {
        for l in all_layers() {
            l.shape.validate().unwrap_or_else(|e| panic!("{}: {e}", l.name));
            assert!(l.shape.h_o() >= 1 && l.shape.w_o() >= 1);
        }
    }

    #[test]
    fn all_c_o_divisible_by_8() {
        // Paper layouts rely on power-of-two C_o blocks; the three nets
        // all choose C_o as multiples of 8 or better.
        for l in all_layers() {
            assert_eq!(l.shape.c_o % 8, 0, "{}", l.name);
        }
    }

    #[test]
    fn vgg_flops_dominate_alexnet() {
        let a: f64 = alexnet().iter().map(|l| l.gflops()).sum();
        let v: f64 = vgg16().iter().map(|l| l.gflops()).sum();
        assert!(v > 10.0 * a, "VGG ({v:.1}) should dwarf AlexNet ({a:.1})");
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("alexnet").is_some());
        assert!(by_name("vgg").is_some());
        assert!(by_name("resnet").is_none());
    }
}

//! Per-layer plan tables: a whole benchmark network planned through the
//! engine, one cached [`ConvPlan`] per conv layer.
//!
//! This is the deployment shape the paper's §4.3 describes — weights
//! pre-transformed once per layer at load time, every execution running
//! against retained per-layer state — and what `dconv plan-net` prints,
//! including the uniform memory-overhead accounting.

use super::{Layer, Model};
use crate::arch::Machine;
use crate::conv::ConvShape;
use crate::engine::{BackendRegistry, ConvPlan};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// The deterministic synthetic OIHW weights [`NetPlans::build`] plans
/// layer `index` with (seeded xorshift; only shapes matter for the
/// reproduction). Grouped layers hold `C_i/groups` input channels per
/// filter, so the tensor is `[c_o, c_i/groups, h_f, w_f]` — identical
/// to before for dense layers. Exposed so reference implementations —
/// the naive layer-by-layer cross-check in the `NetRunner` conformance
/// tests, the NumPy golden generator — can regenerate bit-identical
/// tensors.
pub fn net_kernel(index: usize, shape: &ConvShape) -> Tensor {
    Tensor::random(
        &[shape.c_o, shape.c_i_per_group(), shape.h_f, shape.w_f],
        0x5EED + index as u64,
    )
}

/// Deterministic per-channel batch-norm parameters for the BN node with
/// ordinal `ordinal` (its index among the graph's BatchNorm nodes in
/// node order, [`super::NetGraph::bn_ordinals`]) over `c` channels.
/// Returns `(scale, shift)` for the pre-folded inference form
/// `y = x * scale[c] + shift[c]`.
///
/// Like [`net_kernel`], parameters are seeded synthetic values so model
/// specs stay weight-free and independent references (the NumPy golden
/// generator) can regenerate bit-identical tensors: scale is drawn from
/// `[0.5, 1.5)` (never zero — BN folding divides by nothing, but a
/// zero scale would erase the conv's contribution and make tests
/// vacuous), shift from `[-0.25, 0.25)`.
pub fn net_bn_params(ordinal: usize, c: usize) -> (Vec<f32>, Vec<f32>) {
    let raw_scale = Tensor::random(&[c], 0xB070 + ordinal as u64);
    let raw_shift = Tensor::random(&[c], 0x5417 + ordinal as u64);
    let scale = raw_scale.data().iter().map(|r| 1.0 + 0.5 * r).collect();
    let shift = raw_shift.data().iter().map(|r| 0.25 * r).collect();
    (scale, shift)
}

/// One planned conv layer of a network.
pub struct PlannedLayer {
    pub layer: Layer,
    /// Backend the plan was produced by (resolved from `auto` if used).
    pub backend: &'static str,
    /// Thread count the plan was built with (what the per-layer
    /// autotuner selected, when [`NetPlans::build_autotuned`] was used).
    pub threads: usize,
    pub plan: Box<dyn ConvPlan>,
}

/// One row of the [`NetPlans::build_autotuned`] measurement report.
#[derive(Clone, Debug)]
pub struct AutotuneChoice {
    pub layer: String,
    /// Selected thread count (fastest measured candidate).
    pub threads: usize,
    /// Measured execute seconds at the selected count.
    pub secs: f64,
}

/// One row of the [`NetPlans::build_tuned`] report: how the tuner
/// resolved one layer's backend.
#[derive(Clone, Debug)]
pub struct TunedChoice {
    pub layer: String,
    /// Backend the layer was actually planned on.
    pub backend: String,
    /// True when the decision came from the autotune cache.
    pub cache_hit: bool,
    /// True when this build measured the layer's candidates.
    pub measured: bool,
    /// The winning measured record, when one exists (`None` for
    /// heuristic fallbacks).
    pub best: Option<crate::tune::BestHeuristic>,
    /// Every measured candidate, fastest first.
    pub candidates: Vec<crate::tune::BestHeuristic>,
}

/// A benchmark network with every conv layer planned.
pub struct NetPlans {
    pub net: String,
    pub layers: Vec<PlannedLayer>,
}

impl NetPlans {
    /// Plan every conv layer of `net` (`alexnet`, `googlenet`, `vgg16`)
    /// on `backend` (a registry name or `"auto"`). Weights are seeded
    /// synthetic tensors — only shapes matter for the reproduction.
    pub fn build(net: &str, backend: &str, machine: &Machine, threads: usize) -> Result<NetPlans> {
        let layers = super::by_name(net)
            .ok_or_else(|| Error::Parse(format!("unknown net '{net}' (alexnet|googlenet|vgg16)")))?;
        Self::plan_table(net, layers, backend, machine, threads)
    }

    /// Plan every conv layer of a builder- or spec-produced [`Model`]
    /// (the graph is validated against its shape table first). Weights
    /// use the same deterministic [`net_kernel`] seeds as the built-in
    /// nets, so independent references can regenerate them.
    pub fn build_model(
        model: &Model,
        backend: &str,
        machine: &Machine,
        threads: usize,
    ) -> Result<NetPlans> {
        model.validate()?;
        Self::plan_table(&model.name, model.layers(), backend, machine, threads)
    }

    fn plan_table(
        net: &str,
        layers: Vec<Layer>,
        backend: &str,
        machine: &Machine,
        threads: usize,
    ) -> Result<NetPlans> {
        let registry = BackendRegistry::shared();
        let mut planned = Vec::with_capacity(layers.len());
        for (i, layer) in layers.into_iter().enumerate() {
            let s = &layer.shape;
            let kernel = net_kernel(i, s);
            let plan = registry.plan(backend, s, &kernel, machine, threads)?;
            planned.push(PlannedLayer { backend: plan.backend(), layer, threads, plan });
        }
        Ok(NetPlans { net: net.to_string(), layers: planned })
    }

    /// Plan every conv layer of `net` through a [`crate::tune::Tuner`]:
    /// each layer independently gets the backend the tuner resolves
    /// (cache hit, fresh measurement, or heuristic fallback, per its
    /// [`crate::tune::TunePolicy`]), so one net can **mix backends
    /// across layers** — e.g. `fft`/`winograd` on big early layers,
    /// `direct` on the blocked tail. The graph executor's Adapt
    /// staging already converts any layout to any other between
    /// layers, so mixed plans execute unchanged, keeping the
    /// zero-alloc forward and per-plan `overhead_bytes()` accounting.
    /// Returns the plans plus a per-layer [`TunedChoice`] report.
    pub fn build_tuned(
        net: &str,
        machine: &Machine,
        tuner: &mut crate::tune::Tuner,
        threads: usize,
    ) -> Result<(NetPlans, Vec<TunedChoice>)> {
        let layers = super::by_name(net)
            .ok_or_else(|| Error::Parse(format!("unknown net '{net}' (alexnet|googlenet|vgg16)")))?;
        Self::tuned_table(net, layers, machine, tuner, threads)
    }

    /// [`NetPlans::build_tuned`] for a builder- or spec-produced
    /// [`Model`].
    pub fn build_model_tuned(
        model: &Model,
        machine: &Machine,
        tuner: &mut crate::tune::Tuner,
        threads: usize,
    ) -> Result<(NetPlans, Vec<TunedChoice>)> {
        model.validate()?;
        Self::tuned_table(&model.name, model.layers(), machine, tuner, threads)
    }

    fn tuned_table(
        net: &str,
        layers: Vec<Layer>,
        machine: &Machine,
        tuner: &mut crate::tune::Tuner,
        threads: usize,
    ) -> Result<(NetPlans, Vec<TunedChoice>)> {
        let registry = BackendRegistry::shared();
        let mut planned = Vec::with_capacity(layers.len());
        let mut report = Vec::with_capacity(layers.len());
        for (i, layer) in layers.into_iter().enumerate() {
            let s = &layer.shape;
            let kernel = net_kernel(i, s);
            // Representative activation for measurement (same seeds as
            // the thread autotuner, so timings are comparable).
            let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 0xA070 + i as u64);
            let choice = tuner.choose(s, &kernel, &input, machine, threads)?;
            let plan = match registry.plan(&choice.backend, s, &kernel, machine, threads) {
                Ok(p) => p,
                Err(e) => {
                    // A tuned winner that fails to plan (e.g. a stale
                    // cache naming a backend whose parameters no
                    // longer fit) must not sink the net: re-resolve
                    // through `auto`, which self-heals to `direct`.
                    eprintln!(
                        "tune: winner '{}' failed to plan {} ({e}); replanning via auto",
                        choice.backend, layer.name
                    );
                    registry.plan("auto", s, &kernel, machine, threads)?
                }
            };
            report.push(TunedChoice {
                layer: layer.name.clone(),
                backend: plan.backend().to_string(),
                cache_hit: choice.cache_hit,
                measured: choice.measured,
                best: choice.best,
                candidates: choice.candidates,
            });
            planned.push(PlannedLayer { backend: plan.backend(), layer, threads, plan });
        }
        Ok((NetPlans { net: net.to_string(), layers: planned }, report))
    }

    /// Plan every conv layer of `net`, choosing each layer's thread
    /// count by measurement: every candidate in `candidates` is planned
    /// and timed once (one warm-up + one timed `execute`), and the
    /// fastest plan is kept — measure-once-at-plan-time, stored in the
    /// plan. This is what stops narrow 1x1 branch convs from
    /// over-subscribing threads inside a whole-net schedule: small
    /// layers measure fastest at 1 thread and keep it, while the wide
    /// stem/3x3 layers keep the high counts. Returns the planned net
    /// plus the per-layer measurement report. Thread counts do not
    /// change results (each output element keeps its summation order),
    /// so autotuned plans stay bitwise-deterministic.
    pub fn build_autotuned(
        net: &str,
        backend: &str,
        machine: &Machine,
        candidates: &[usize],
    ) -> Result<(NetPlans, Vec<AutotuneChoice>)> {
        let layers = super::by_name(net)
            .ok_or_else(|| Error::Parse(format!("unknown net '{net}' (alexnet|googlenet|vgg16)")))?;
        Self::autotune_table(net, layers, backend, machine, candidates)
    }

    /// [`NetPlans::build_autotuned`] for a builder- or spec-produced
    /// [`Model`]: per-layer thread counts measured once at plan time.
    pub fn build_model_autotuned(
        model: &Model,
        backend: &str,
        machine: &Machine,
        candidates: &[usize],
    ) -> Result<(NetPlans, Vec<AutotuneChoice>)> {
        model.validate()?;
        Self::autotune_table(&model.name, model.layers(), backend, machine, candidates)
    }

    fn autotune_table(
        net: &str,
        layers: Vec<Layer>,
        backend: &str,
        machine: &Machine,
        candidates: &[usize],
    ) -> Result<(NetPlans, Vec<AutotuneChoice>)> {
        let mut cand: Vec<usize> = candidates.iter().copied().filter(|&t| t > 0).collect();
        cand.sort_unstable();
        cand.dedup();
        if cand.is_empty() {
            cand.push(1);
        }
        let registry = BackendRegistry::shared();
        let mut planned = Vec::with_capacity(layers.len());
        let mut report = Vec::with_capacity(layers.len());
        for (i, layer) in layers.into_iter().enumerate() {
            let s = &layer.shape;
            let kernel = net_kernel(i, s);
            let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 0xA070 + i as u64);
            let mut best: Option<(f64, usize, Box<dyn ConvPlan>)> = None;
            for &t in &cand {
                let plan = registry.plan(backend, s, &kernel, machine, t)?;
                plan.execute(&input)?; // warm-up (first touch, page faults)
                let (timed, secs) = crate::metrics::time_it(|| plan.execute(&input));
                timed?;
                if best.as_ref().map(|(b, _, _)| secs < *b).unwrap_or(true) {
                    best = Some((secs, t, plan));
                }
            }
            let (secs, threads, plan) = best.expect("at least one candidate");
            report.push(AutotuneChoice { layer: layer.name.clone(), threads, secs });
            planned.push(PlannedLayer { backend: plan.backend(), layer, threads, plan });
        }
        Ok((NetPlans { net: net.to_string(), layers: planned }, report))
    }

    /// Plan an ad-hoc chain of layer shapes (single-threaded plans,
    /// synthetic seeded weights: layer `i` uses `Tensor::random` seed
    /// `seed + i`, regenerable by callers needing a reference) — the
    /// fixture constructor shared by benches and tests; [`Self::build`]
    /// is the paper-net equivalent.
    pub fn from_shapes(
        name: &str,
        shapes: &[ConvShape],
        backend: &str,
        machine: &Machine,
        seed: u64,
    ) -> Result<NetPlans> {
        let registry = BackendRegistry::shared();
        let mut planned = Vec::with_capacity(shapes.len());
        for (i, s) in shapes.iter().enumerate() {
            let kernel = Tensor::random(&[s.c_o, s.c_i_per_group(), s.h_f, s.w_f], seed + i as u64);
            let plan = registry.plan(backend, s, &kernel, machine, 1)?;
            planned.push(PlannedLayer {
                backend: plan.backend(),
                layer: Layer { net: "custom".into(), name: format!("l{i}"), shape: s.clone() },
                threads: 1,
                plan,
            });
        }
        Ok(NetPlans { net: name.to_string(), layers: planned })
    }

    /// Total bytes retained by all plans beyond conventional weights.
    pub fn total_retained_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.plan.retained_bytes()).sum()
    }

    /// Total per-execution workspace bytes across layers (each layer's
    /// workspace is reusable; the peak concurrent need is the max).
    pub fn total_workspace_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.plan.workspace_bytes()).sum()
    }

    /// Largest single-layer workspace — what a serving process that
    /// shares one scratch buffer across layers must allocate.
    pub fn max_workspace_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.plan.workspace_bytes()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;

    #[test]
    fn alexnet_auto_plans_are_all_direct_and_zero_overhead() {
        let plans = NetPlans::build("alexnet", "auto", &haswell(), 1).unwrap();
        assert_eq!(plans.layers.len(), 5);
        for l in &plans.layers {
            assert_eq!(l.backend, "direct", "{}", l.layer.name);
            assert_eq!(
                l.plan.retained_bytes() + l.plan.workspace_bytes(),
                0,
                "{} must be zero-overhead",
                l.layer.name
            );
        }
        assert_eq!(plans.total_retained_bytes() + plans.total_workspace_bytes(), 0);
    }

    #[test]
    fn im2col_plans_report_lowering_workspace() {
        let plans = NetPlans::build("alexnet", "im2col", &haswell(), 1).unwrap();
        for l in &plans.layers {
            assert_eq!(l.plan.workspace_bytes(), l.layer.shape.im2col_bytes(), "{}", l.layer.name);
        }
        assert!(plans.max_workspace_bytes() > 0);
    }

    #[test]
    fn unknown_net_is_rejected() {
        assert!(NetPlans::build("resnet", "auto", &haswell(), 1).is_err());
        assert!(NetPlans::build_autotuned("resnet", "auto", &haswell(), &[1]).is_err());
    }

    #[test]
    fn model_plans_carry_node_names_and_stay_zero_overhead() {
        let model = crate::nets::builder::resnet_micro();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        assert_eq!(plans.net, "resnet_micro");
        assert_eq!(plans.layers.len(), 6);
        assert_eq!(plans.layers[0].layer.name, "conv0");
        assert_eq!(plans.layers[0].layer.net, "resnet_micro");
        assert_eq!(plans.total_retained_bytes() + plans.total_workspace_bytes(), 0);

        let (tuned, report) =
            NetPlans::build_model_autotuned(&model, "direct", &haswell(), &[1]).unwrap();
        assert_eq!(tuned.layers.len(), report.len());
        assert!(tuned.layers.iter().all(|l| l.threads == 1));
    }

    #[test]
    fn mobilenet_micro_plans_grouped_layers_zero_overhead() {
        let model = crate::nets::builder::mobilenet_micro();
        let plans = NetPlans::build_model(&model, "auto", &haswell(), 1).unwrap();
        assert_eq!(plans.layers.len(), 6);
        for l in &plans.layers {
            assert_eq!(l.backend, "direct", "{}", l.layer.name);
            assert_eq!(
                l.plan.retained_bytes() + l.plan.workspace_bytes(),
                0,
                "{} must be zero-overhead",
                l.layer.name
            );
        }
    }

    #[test]
    fn bn_params_are_deterministic_and_well_conditioned() {
        let (s0, b0) = net_bn_params(0, 16);
        let (s0_again, b0_again) = net_bn_params(0, 16);
        assert_eq!((&s0, &b0), (&s0_again, &b0_again), "same ordinal regenerates identically");
        let (s1, _) = net_bn_params(1, 16);
        assert_ne!(s0, s1, "ordinals draw distinct parameters");
        assert!(s0.iter().all(|v| (0.5..1.5).contains(v)), "scale never vanishes");
        assert!(b0.iter().all(|v| (-0.25..0.25).contains(v)));
    }

    #[test]
    fn autotune_selects_and_records_per_layer_threads() {
        let (plans, report) =
            NetPlans::build_autotuned("alexnet", "direct", &haswell(), &[2, 1, 2]).unwrap();
        assert_eq!(plans.layers.len(), 5);
        assert_eq!(report.len(), 5);
        for (l, r) in plans.layers.iter().zip(&report) {
            assert_eq!(l.layer.name, r.layer);
            assert_eq!(l.threads, r.threads, "{}: report and plan disagree", r.layer);
            assert!([1, 2].contains(&l.threads), "{}: candidate list violated", r.layer);
            assert!(r.secs >= 0.0);
        }
        // Degenerate candidate lists fall back to single-threaded.
        let (p1, _) = NetPlans::build_autotuned("alexnet", "direct", &haswell(), &[0]).unwrap();
        assert!(p1.layers.iter().all(|l| l.threads == 1));
    }
}

//! JSON model specs — the serialized form of the model-description API.
//!
//! A [`Model`] is a validated [`NetGraph`] plus its conv-shape table:
//! everything [`super::NetPlans::build_model`] needs to plan a network
//! and [`crate::engine::NetRunner`] needs to execute it allocation-free.
//! Models come from three places: [`super::GraphBuilder`] programs, the
//! built-in paper nets ([`super::builder::alexnet`] and friends), and
//! JSON files parsed here — so any CNN can be described in a text file
//! and served without touching library code
//! (`dconv serve --model my_net.json`).
//!
//! # Schema
//!
//! ```json
//! {
//!   "name": "resnet_micro",
//!   "nodes": [
//!     {"op": "input",  "name": "image", "c": 3, "h": 32, "w": 32},
//!     {"op": "conv",   "name": "conv0", "pred": "image",
//!      "c_o": 16, "k": 3, "stride": 1, "pad": 1},
//!     {"op": "pool",   "name": "pool0", "pred": "conv0", "k": 2},
//!     {"op": "batch_norm", "name": "bn0", "pred": "pool0"},
//!     {"op": "relu",   "name": "relu0", "pred": "bn0", "clamp": 6.0},
//!     {"op": "concat", "name": "cat",   "preds": ["a", "b"]},
//!     {"op": "add",    "name": "join",  "preds": ["a", "b"]}
//!   ]
//! }
//! ```
//!
//! * Nodes appear in topological order; predecessors are referenced by
//!   node name; the **last node is the network output**.
//! * The optional root field `"dtype"` selects the element type the
//!   network is planned and executed in: `"f32"` (default) or `"i8"`
//!   (the quantized engine — per-edge min/max calibration, `direct_i8`
//!   plans, an i8 byte arena; see [`crate::quant`]). The CLI `--dtype`
//!   flag overrides it.
//! * `conv` — `c_o` output channels; kernel `k` (or `kh`/`kw` for
//!   rectangular); `stride` (default 1) and `pad` (default 0) are
//!   symmetric; optional `groups` (default 1, must divide both the
//!   inferred input channels and `c_o`; `groups == c_i == c_o` is
//!   depthwise) and `dilation` (default 1, spreads the kernel taps to
//!   an effective extent of `(k-1)*dilation + 1`). Input channels and
//!   extents are inferred from `pred`. Conv layers are numbered in node
//!   order; that numbering is the plan-table index (and the
//!   deterministic weight seed).
//! * `pool` — kernel `k` (or `kh`/`kw`), stride `s` (or `sh`/`sw`,
//!   default = kernel), pad `p` (or `ph`/`pw`, default 0), and `kind`
//!   (`"max"`, the default, or `"avg"` — average over the in-bounds
//!   window cells, the classifier-head reduction).
//! * `relu` — elementwise `max(0, x)`; the optional `clamp` (finite,
//!   `> 0` — e.g. `6.0` for ReLU6) caps the result from above. The
//!   [`super::fuse`] pass folds a relu that directly follows a conv /
//!   BN / residual-add chain into that conv's epilogue.
//! * `batch_norm` — per-channel `y = x * scale[c] + shift[c]`,
//!   inference-mode (pre-folded) batch normalization. Like conv
//!   weights, parameters are not stored in the spec: they are generated
//!   deterministically at plan time from the node's BN ordinal (see
//!   [`super::net_bn_params`]), keeping specs weight-free.
//! * `concat` / `add` — two or more `preds`; concat joins channels of
//!   equal-extent maps, add sums identically shaped maps (the residual
//!   join).
//! * Any node may carry `"group"` and `"lane"` (together) to tag it as
//!   part of a parallel branch lane — see [`super::BranchTag`].
//!
//! The schema is **strict**: unknown fields on a node are errors (a
//! typoed `"s"` on a conv — which spells `"stride"` — must not silently
//! default), and parsing goes through [`super::GraphBuilder`], so every
//! structural error a builder program would hit (shape mismatch,
//! dangling pred, arity, lane crossing) is reported for JSON input too.

use std::collections::BTreeMap;
use std::path::Path;

use crate::conv::ConvShape;
use crate::json::Json;
use crate::quant::DType;
use crate::{Error, Result};

use super::builder::GraphBuilder;
use super::graph::{Dims, GraphOp, NetGraph, PoolKind};
use super::Layer;

/// A complete model description: the dataflow graph and the conv-layer
/// shape table its `Conv` nodes index, plus the element type the net
/// is planned in ([`DType::F32`] unless the spec opts into `"i8"`).
/// Built by [`GraphBuilder::build`] or parsed from JSON
/// ([`Model::from_json`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    pub name: String,
    pub graph: NetGraph,
    pub shapes: Vec<ConvShape>,
    pub dtype: DType,
}

impl Model {
    /// Re-check the graph against the shape table and return every
    /// node's inferred output dims (see [`NetGraph::validate`]).
    pub fn validate(&self) -> Result<Vec<Dims>> {
        self.graph.validate(&self.shapes)
    }

    /// The conv layers as a [`Layer`] table (plan-table order), names
    /// taken from the graph's conv nodes.
    pub fn layers(&self) -> Vec<Layer> {
        let mut names = vec![String::new(); self.shapes.len()];
        for n in &self.graph.nodes {
            if let GraphOp::Conv { layer } = n.op {
                if let Some(slot) = names.get_mut(layer) {
                    slot.clone_from(&n.name);
                }
            }
        }
        self.shapes
            .iter()
            .zip(names)
            .map(|(s, name)| Layer { net: self.name.clone(), name, shape: s.clone() })
            .collect()
    }

    /// Parse a JSON model spec (schema in the module docs). All graph
    /// construction runs through [`GraphBuilder`], so structural errors
    /// surface with the same messages as builder programs.
    pub fn from_json(text: &str) -> Result<Model> {
        let root = Json::parse(text)?;
        let name = root
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Parse("model spec: missing string field 'name'".into()))?;
        let dtype = match root.get("dtype") {
            None => DType::F32,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    Error::Parse("model spec: 'dtype' must be a string".into())
                })?;
                DType::from_str_opt(s).ok_or_else(|| {
                    Error::Parse(format!("model spec: unknown dtype '{s}' (f32|i8)"))
                })?
            }
        };
        let nodes = root
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Parse("model spec: missing array field 'nodes'".into()))?;
        if nodes.is_empty() {
            return Err(Error::Parse("model spec: 'nodes' is empty".into()));
        }
        let mut b = GraphBuilder::new(name);
        let mut ids = BTreeMap::new();
        let mut last = None;
        for spec in nodes {
            let node_name = field_str(spec, "name")?;
            let op = field_str(spec, "op")?;
            check_keys(spec, node_name, op)?;
            set_lane(&mut b, spec, node_name)?;
            let id = match op {
                "input" => b.input_named(
                    node_name,
                    field_usize(spec, node_name, "c")?,
                    field_usize(spec, node_name, "h")?,
                    field_usize(spec, node_name, "w")?,
                )?,
                "conv" => {
                    let pred = lookup(&ids, spec, node_name)?;
                    let (kh, kw) = kernel_pair(spec, node_name, "k", "kh", "kw", None)?;
                    let d = b.dims_of(pred);
                    let shape = ConvShape::new(
                        d.c,
                        d.h,
                        d.w,
                        field_usize(spec, node_name, "c_o")?,
                        kh,
                        kw,
                        opt_usize(spec, node_name, "stride")?.unwrap_or(1),
                        opt_usize(spec, node_name, "pad")?.unwrap_or(0),
                    )
                    .with_groups(opt_usize(spec, node_name, "groups")?.unwrap_or(1))
                    .with_dilation(opt_usize(spec, node_name, "dilation")?.unwrap_or(1));
                    b.conv_with(node_name, pred, shape)?
                }
                "pool" => {
                    let pred = lookup(&ids, spec, node_name)?;
                    let kind = match spec.get("kind") {
                        None => PoolKind::Max,
                        Some(v) => {
                            let s = v.as_str().ok_or_else(|| {
                                Error::Parse(format!(
                                    "model spec node '{node_name}': 'kind' must be a string"
                                ))
                            })?;
                            PoolKind::from_str_opt(s).ok_or_else(|| {
                                Error::Parse(format!(
                                    "model spec node '{node_name}': unknown pool kind '{s}' \
                                     (max|avg)"
                                ))
                            })?
                        }
                    };
                    let (kh, kw) = kernel_pair(spec, node_name, "k", "kh", "kw", None)?;
                    let (sh, sw) = kernel_pair(spec, node_name, "s", "sh", "sw", Some((kh, kw)))?;
                    let (ph, pw) = kernel_pair(spec, node_name, "p", "ph", "pw", Some((0, 0)))?;
                    b.pool_kind_geom(node_name, pred, kind, kh, kw, sh, sw, ph, pw)?
                }
                "relu" => {
                    let pred = lookup(&ids, spec, node_name)?;
                    b.relu(node_name, pred, opt_f32(spec, node_name, "clamp")?)?
                }
                "batch_norm" => {
                    let pred = lookup(&ids, spec, node_name)?;
                    b.batch_norm(node_name, pred)?
                }
                "concat" => b.concat(node_name, &pred_list(&ids, spec, node_name)?)?,
                "add" => b.add(node_name, &pred_list(&ids, spec, node_name)?)?,
                other => {
                    return Err(Error::Parse(format!(
                        "model spec node '{node_name}': unknown op '{other}' \
                         (input|conv|pool|relu|batch_norm|concat|add)"
                    )));
                }
            };
            ids.insert(node_name.to_string(), id);
            last = Some(id);
        }
        let mut model = b.build(last.expect("nodes checked non-empty"))?;
        model.dtype = dtype;
        Ok(model)
    }

    /// Load a model spec from a JSON file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Model> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Parse(format!("cannot read model spec {}: {e}", path.display())))?;
        Model::from_json(&text)
    }

    /// Serialize back to the JSON schema ([`Model::from_json`] inverts
    /// this; conv layers are renumbered in node order, which is the
    /// order they already hold in any builder-produced graph).
    pub fn to_json(&self) -> String {
        let num = |v: usize| Json::Num(v as f64);
        let nodes = self
            .graph
            .nodes
            .iter()
            .map(|n| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(n.name.clone()));
                if let Some(tag) = n.branch {
                    o.insert("group".into(), num(tag.group));
                    o.insert("lane".into(), num(tag.lane));
                }
                let pred_name = |i: usize| Json::Str(self.graph.nodes[i].name.clone());
                match &n.op {
                    GraphOp::Input { c, h, w } => {
                        o.insert("op".into(), Json::Str("input".into()));
                        o.insert("c".into(), num(*c));
                        o.insert("h".into(), num(*h));
                        o.insert("w".into(), num(*w));
                    }
                    GraphOp::Conv { layer } => {
                        let s = &self.shapes[*layer];
                        o.insert("op".into(), Json::Str("conv".into()));
                        o.insert("pred".into(), pred_name(n.preds[0]));
                        o.insert("c_o".into(), num(s.c_o));
                        o.insert("kh".into(), num(s.h_f));
                        o.insert("kw".into(), num(s.w_f));
                        o.insert("stride".into(), num(s.stride));
                        o.insert("pad".into(), num(s.pad));
                        if s.groups != 1 {
                            // 1 is the default; omitting it keeps
                            // previously committed specs byte-stable.
                            o.insert("groups".into(), num(s.groups));
                        }
                        if s.dilation != 1 {
                            o.insert("dilation".into(), num(s.dilation));
                        }
                    }
                    GraphOp::Pool { kind, kh, kw, sh, sw, ph, pw } => {
                        o.insert("op".into(), Json::Str("pool".into()));
                        o.insert("pred".into(), pred_name(n.preds[0]));
                        if *kind != PoolKind::Max {
                            // Max is the default; omitting it keeps
                            // previously committed specs byte-stable.
                            o.insert("kind".into(), Json::Str(kind.as_str().into()));
                        }
                        o.insert("kh".into(), num(*kh));
                        o.insert("kw".into(), num(*kw));
                        o.insert("sh".into(), num(*sh));
                        o.insert("sw".into(), num(*sw));
                        o.insert("ph".into(), num(*ph));
                        o.insert("pw".into(), num(*pw));
                    }
                    GraphOp::Relu { clamp } => {
                        o.insert("op".into(), Json::Str("relu".into()));
                        o.insert("pred".into(), pred_name(n.preds[0]));
                        if let Some(c) = clamp {
                            o.insert("clamp".into(), Json::Num(f64::from(*c)));
                        }
                    }
                    GraphOp::BatchNorm => {
                        o.insert("op".into(), Json::Str("batch_norm".into()));
                        o.insert("pred".into(), pred_name(n.preds[0]));
                    }
                    GraphOp::Concat | GraphOp::Add => {
                        let kind = if matches!(n.op, GraphOp::Concat) { "concat" } else { "add" };
                        o.insert("op".into(), Json::Str(kind.into()));
                        o.insert(
                            "preds".into(),
                            Json::Arr(n.preds.iter().map(|&p| pred_name(p)).collect()),
                        );
                    }
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("name".into(), Json::Str(self.name.clone()));
        if self.dtype != DType::F32 {
            // f32 is the default; omitting it keeps old specs stable.
            root.insert("dtype".into(), Json::Str(self.dtype.as_str().into()));
        }
        root.insert("nodes".into(), Json::Arr(nodes));
        Json::Obj(root).to_string_pretty()
    }
}

/// Reject unknown fields: the schema is strict, so a mis-keyed or
/// typoed field (e.g. `"s"` on a conv, which spells `"stride"`) is an
/// error instead of a silently dropped default.
fn check_keys(spec: &Json, node: &str, op: &str) -> Result<()> {
    const COMMON: [&str; 4] = ["op", "name", "group", "lane"];
    let allowed: &[&str] = match op {
        "input" => &["c", "h", "w"],
        "conv" => &["pred", "c_o", "k", "kh", "kw", "stride", "pad", "groups", "dilation"],
        "pool" => &["pred", "kind", "k", "kh", "kw", "s", "sh", "sw", "p", "ph", "pw"],
        "relu" => &["pred", "clamp"],
        "batch_norm" => &["pred"],
        "concat" | "add" => &["preds"],
        _ => &[], // unknown op is reported by the caller's match
    };
    let obj = spec
        .as_obj()
        .ok_or_else(|| Error::Parse(format!("model spec node '{node}': not an object")))?;
    for key in obj.keys() {
        if !COMMON.contains(&key.as_str()) && !allowed.contains(&key.as_str()) {
            return Err(Error::Parse(format!(
                "model spec node '{node}' (op '{op}'): unknown field '{key}' \
                 (allowed: {COMMON:?} + {allowed:?})"
            )));
        }
    }
    Ok(())
}

fn field_str<'j>(spec: &'j Json, key: &str) -> Result<&'j str> {
    spec.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse(format!("model spec node: missing string field '{key}'")))
}

fn field_usize(spec: &Json, node: &str, key: &str) -> Result<usize> {
    opt_usize(spec, node, key)?.ok_or_else(|| {
        Error::Parse(format!("model spec node '{node}': missing numeric field '{key}'"))
    })
}

fn opt_usize(spec: &Json, node: &str, key: &str) -> Result<Option<usize>> {
    match spec.get(key) {
        None => Ok(None),
        Some(v) => v.as_usize().map(Some).ok_or_else(|| {
            Error::Parse(format!("model spec node '{node}': field '{key}' must be a number"))
        }),
    }
}

/// Optional float field (the relu `clamp`); range validation is the
/// builder's job, non-numbers are rejected here.
fn opt_f32(spec: &Json, node: &str, key: &str) -> Result<Option<f32>> {
    match spec.get(key) {
        None => Ok(None),
        Some(v) => v.as_f64().map(|f| Some(f as f32)).ok_or_else(|| {
            Error::Parse(format!("model spec node '{node}': field '{key}' must be a number"))
        }),
    }
}

/// Resolve `k`-style geometry: either the square shorthand `short` or
/// the `kh`/`kw` pair (both present); `default` applies when neither
/// form is given.
fn kernel_pair(
    spec: &Json,
    node: &str,
    short: &str,
    h_key: &str,
    w_key: &str,
    default: Option<(usize, usize)>,
) -> Result<(usize, usize)> {
    if let Some(k) = opt_usize(spec, node, short)? {
        return Ok((k, k));
    }
    match (opt_usize(spec, node, h_key)?, opt_usize(spec, node, w_key)?) {
        (Some(h), Some(w)) => Ok((h, w)),
        (None, None) => default.ok_or_else(|| {
            Error::Parse(format!(
                "model spec node '{node}': needs '{short}' or '{h_key}'+'{w_key}'"
            ))
        }),
        _ => Err(Error::Parse(format!(
            "model spec node '{node}': '{h_key}' and '{w_key}' must appear together"
        ))),
    }
}

fn set_lane(b: &mut GraphBuilder, spec: &Json, node: &str) -> Result<()> {
    match (opt_usize(spec, node, "group")?, opt_usize(spec, node, "lane")?) {
        (Some(g), Some(l)) => {
            b.lane(g, l);
            Ok(())
        }
        (None, None) => {
            b.backbone();
            Ok(())
        }
        _ => Err(Error::Parse(format!(
            "model spec node '{node}': 'group' and 'lane' must appear together"
        ))),
    }
}

fn lookup(
    ids: &BTreeMap<String, super::builder::NodeId>,
    spec: &Json,
    node: &str,
) -> Result<super::builder::NodeId> {
    let pred = spec.get("pred").and_then(Json::as_str).ok_or_else(|| {
        Error::Parse(format!("model spec node '{node}': missing string field 'pred'"))
    })?;
    ids.get(pred).copied().ok_or_else(|| {
        Error::Parse(format!(
            "model spec node '{node}': predecessor '{pred}' is not defined above it"
        ))
    })
}

fn pred_list(
    ids: &BTreeMap<String, super::builder::NodeId>,
    spec: &Json,
    node: &str,
) -> Result<Vec<super::builder::NodeId>> {
    let arr = spec.get("preds").and_then(Json::as_arr).ok_or_else(|| {
        Error::Parse(format!("model spec node '{node}': missing array field 'preds'"))
    })?;
    arr.iter()
        .map(|p| {
            let name = p.as_str().ok_or_else(|| {
                Error::Parse(format!("model spec node '{node}': 'preds' entries must be strings"))
            })?;
            ids.get(name).copied().ok_or_else(|| {
                Error::Parse(format!(
                    "model spec node '{node}': predecessor '{name}' is not defined above it"
                ))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::builder;

    const MINI: &str = r#"{
        "name": "mini",
        "nodes": [
            {"op": "input", "name": "image", "c": 4, "h": 8, "w": 8},
            {"op": "conv", "name": "c0", "pred": "image", "c_o": 8, "k": 3, "pad": 1},
            {"op": "conv", "name": "c1", "pred": "c0", "c_o": 8, "k": 3, "pad": 1},
            {"op": "add", "name": "join", "preds": ["c0", "c1"]},
            {"op": "pool", "name": "down", "pred": "join", "k": 2, "s": 2}
        ]
    }"#;

    #[test]
    fn parses_and_infers_shapes() {
        let m = Model::from_json(MINI).unwrap();
        assert_eq!(m.name, "mini");
        assert_eq!(m.shapes.len(), 2);
        assert_eq!(m.shapes[0], ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1));
        let dims = m.validate().unwrap();
        let out = dims[m.graph.output()];
        assert_eq!((out.c, out.h, out.w), (8, 4, 4));
        let layers = m.layers();
        assert_eq!(layers[1].name, "c1");
        assert_eq!(layers[0].net, "mini");
    }

    #[test]
    fn json_round_trip_is_identity() {
        let m = Model::from_json(MINI).unwrap();
        let again = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, again);
    }

    #[test]
    fn paper_net_round_trips_with_lanes() {
        let m = builder::googlenet();
        let again = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, again, "googlenet spec must round-trip including branch tags");
    }

    #[test]
    fn dtype_and_pool_kind_round_trip() {
        let spec = MINI
            .replace("\"name\": \"mini\"", "\"name\": \"mini\", \"dtype\": \"i8\"")
            .replace(
                r#"{"op": "pool", "name": "down", "pred": "join", "k": 2, "s": 2}"#,
                r#"{"op": "pool", "name": "down", "pred": "join", "kind": "avg", "k": 2, "s": 2}"#,
            );
        let m = Model::from_json(&spec).unwrap();
        assert_eq!(m.dtype, DType::I8);
        let pool = m.graph.nodes.iter().find(|n| n.name == "down").unwrap();
        assert!(matches!(pool.op, GraphOp::Pool { kind: PoolKind::Avg, .. }));
        let again = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, again, "dtype + avg kind must survive the round trip");
        // Defaults stay implicit: an f32/max model's JSON has neither key.
        let plain = Model::from_json(MINI).unwrap();
        assert_eq!(plain.dtype, DType::F32);
        assert!(!plain.to_json().contains("dtype"));
        assert!(!plain.to_json().contains("kind"));
    }

    #[test]
    fn rejects_bad_dtype_and_pool_kind() {
        let bad_dtype =
            MINI.replace("\"name\": \"mini\"", "\"name\": \"mini\", \"dtype\": \"f16\"");
        assert!(Model::from_json(&bad_dtype).is_err());
        let bad_kind = MINI.replace(
            r#""name": "down", "pred": "join""#,
            r#""name": "down", "pred": "join", "kind": "median""#,
        );
        assert!(Model::from_json(&bad_kind).is_err());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(Model::from_json("{}").is_err(), "missing name/nodes");
        assert!(Model::from_json(r#"{"name": "x", "nodes": []}"#).is_err(), "no nodes");
        let bad_pred = MINI.replace("\"pred\": \"c0\"", "\"pred\": \"nope\"");
        assert!(Model::from_json(&bad_pred).is_err(), "dangling pred name");
        let bad_op = MINI.replace("\"op\": \"pool\"", "\"op\": \"gelu\"");
        assert!(Model::from_json(&bad_op).is_err(), "unknown op");
        let half_lane = MINI.replace(
            r#"{"op": "input", "name": "image", "c": 4"#,
            r#"{"op": "input", "name": "image", "group": 0, "c": 4"#,
        );
        assert!(Model::from_json(&half_lane).is_err(), "group without lane");
        let typo = MINI.replace("\"pad\": 1", "\"pad\": 1, \"s\": 1");
        assert!(Model::from_json(&typo).is_err(), "strict schema: 's' on a conv is unknown");
    }

    const FUSED: &str = r#"{
        "name": "fused_mini",
        "nodes": [
            {"op": "input", "name": "image", "c": 4, "h": 8, "w": 8},
            {"op": "conv", "name": "c0", "pred": "image", "c_o": 8, "k": 3, "pad": 1},
            {"op": "batch_norm", "name": "bn0", "pred": "c0"},
            {"op": "relu", "name": "r0", "pred": "bn0", "clamp": 6.0},
            {"op": "conv", "name": "dw", "pred": "r0", "c_o": 8, "k": 3, "pad": 1,
             "groups": 8},
            {"op": "relu", "name": "r1", "pred": "dw"},
            {"op": "conv", "name": "head", "pred": "r1", "c_o": 8, "k": 3, "pad": 2,
             "dilation": 2}
        ]
    }"#;

    #[test]
    fn fused_ops_parse_and_round_trip() {
        let m = Model::from_json(FUSED).unwrap();
        let relu = m.graph.nodes.iter().find(|n| n.name == "r0").unwrap();
        assert!(matches!(relu.op, GraphOp::Relu { clamp: Some(c) } if c == 6.0));
        let bare = m.graph.nodes.iter().find(|n| n.name == "r1").unwrap();
        assert!(matches!(bare.op, GraphOp::Relu { clamp: None }));
        assert!(m.graph.nodes.iter().any(|n| matches!(n.op, GraphOp::BatchNorm)));
        assert!(m.shapes[1].is_depthwise());
        assert_eq!(m.shapes[2].dilation, 2);
        let again = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, again, "relu clamp / BN / groups / dilation must round-trip");
        // Defaults stay implicit in the serialized form.
        let text = m.to_json();
        assert_eq!(text.matches("groups").count(), 1);
        assert_eq!(text.matches("dilation").count(), 1);
        assert_eq!(text.matches("clamp").count(), 1);
    }

    #[test]
    fn builder_nets_with_fused_ops_round_trip() {
        for m in [builder::resnet_micro(), builder::mobilenet_micro()] {
            let again = Model::from_json(&m.to_json()).unwrap();
            assert_eq!(m, again, "{} spec must round-trip", m.name);
        }
    }

    #[test]
    fn rejects_invalid_groups_dilation_and_clamp() {
        // groups that do not divide the channel counts.
        let bad_groups = FUSED.replace("\"groups\": 8", "\"groups\": 3");
        assert!(Model::from_json(&bad_groups).is_err(), "groups=3 does not divide 8 channels");
        let zero_groups = FUSED.replace("\"groups\": 8", "\"groups\": 0");
        assert!(Model::from_json(&zero_groups).is_err(), "zero groups");
        // dilation pushing the effective kernel beyond the padded input.
        let big_dil = FUSED.replace("\"dilation\": 2", "\"dilation\": 9");
        assert!(Model::from_json(&big_dil).is_err(), "effective kernel exceeds input");
        let zero_dil = FUSED.replace("\"dilation\": 2", "\"dilation\": 0");
        assert!(Model::from_json(&zero_dil).is_err(), "zero dilation");
        // relu clamp must be a positive number.
        let neg_clamp = FUSED.replace("\"clamp\": 6.0", "\"clamp\": -1.0");
        assert!(Model::from_json(&neg_clamp).is_err(), "negative clamp");
        let str_clamp = FUSED.replace("\"clamp\": 6.0", "\"clamp\": \"six\"");
        assert!(Model::from_json(&str_clamp).is_err(), "clamp must be numeric");
        // Strict schema: clamp is not a batch_norm field, groups is not
        // a relu field.
        let bn_clamp = FUSED.replace(
            r#"{"op": "batch_norm", "name": "bn0", "pred": "c0"}"#,
            r#"{"op": "batch_norm", "name": "bn0", "pred": "c0", "clamp": 1.0}"#,
        );
        assert!(Model::from_json(&bn_clamp).is_err(), "clamp on batch_norm is unknown");
        let relu_groups = FUSED.replace(
            r#"{"op": "relu", "name": "r1", "pred": "dw"}"#,
            r#"{"op": "relu", "name": "r1", "pred": "dw", "groups": 2}"#,
        );
        assert!(Model::from_json(&relu_groups).is_err(), "groups on relu is unknown");
    }
}

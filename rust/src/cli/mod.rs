//! Tiny argument parser (`clap` is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, which covers every binary in this crate.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus `--key [value]` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from(it: impl Iterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut it = it.peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.options.insert(rest.to_string(), String::from("true"));
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        // NB: a bare `--flag` followed by a non-option token would consume
        // it as a value (`--fast model.hlo` means fast=model.hlo); flags
        // therefore come after positionals or use `--flag=true`.
        let a = parse(&["serve", "--threads", "4", "model.hlo", "--fast"]);
        assert_eq!(a.positional, vec!["serve", "model.hlo"]);
        assert_eq!(a.get_usize("threads", 1), 4);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--batch=8", "--rate=2.5"]);
        assert_eq!(a.get_usize("batch", 0), 8);
        assert!((a.get_f64("rate", 0.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn flag_before_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("net", "alexnet"), "alexnet");
        assert_eq!(a.get_usize("threads", 2), 2);
    }
}

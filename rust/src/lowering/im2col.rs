//! Caffe-style `im2col` lowering + SGEMM convolution.

use crate::conv::ConvShape;
use crate::gemm::{sgemm, sgemm_threaded};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Lower `[C_i][H_i][W_i]` into the `(C_i*H_f*W_f) x (H_o*W_o)` matrix.
/// Row `(i*H_f + n)*W_f + m`, column `l*W_o + k` holds
/// `I[i][l*s + n - pad][k*s + m - pad]` (zero outside the image).
pub fn im2col(input: &Tensor, shape: &ConvShape) -> Tensor {
    let mut out = Tensor::zeros(&[
        shape.c_i * shape.h_f * shape.w_f,
        shape.h_o() * shape.w_o(),
    ]);
    im2col_into(input.data(), shape, out.data_mut()).expect("shape pre-checked");
    out
}

/// Allocation-free [`im2col`]: lowers into a caller-owned workspace
/// buffer of `C_i*H_f*W_f * H_o*W_o` floats (overwritten, zeroed
/// internally). This is the workspace the `im2col` engine backend
/// reports via `workspace_bytes()` and reuses across `execute_into`
/// calls.
pub fn im2col_into(src: &[f32], shape: &ConvShape, dst: &mut [f32]) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (h_f, w_f) = (shape.h_f, shape.w_f);
    let (s, p) = (shape.stride, shape.pad as isize);
    if src.len() != c_i * h_i * w_i {
        return Err(Error::Shape(format!(
            "input has {} elements, expected {}",
            src.len(),
            c_i * h_i * w_i
        )));
    }
    if dst.len() != c_i * h_f * w_f * h_o * w_o {
        return Err(Error::Shape(format!(
            "im2col buffer has {} elements, expected {}",
            dst.len(),
            c_i * h_f * w_f * h_o * w_o
        )));
    }
    dst.fill(0.0);
    let cols = h_o * w_o;
    for i in 0..c_i {
        for n in 0..h_f {
            for m in 0..w_f {
                let row = (i * h_f + n) * w_f + m;
                let drow = &mut dst[row * cols..][..cols];
                for l in 0..h_o {
                    let iy = (l * s + n) as isize - p;
                    if iy < 0 || iy >= h_i as isize {
                        continue; // stays zero
                    }
                    let srow = &src[(i * h_i + iy as usize) * w_i..][..w_i];
                    for k in 0..w_o {
                        let ix = (k * s + m) as isize - p;
                        if ix < 0 || ix >= w_i as isize {
                            continue;
                        }
                        drow[l * w_o + k] = srow[ix as usize];
                    }
                }
            }
        }
    }
    Ok(())
}

/// Extra bytes `im2col` materializes for a layer.
pub fn im2col_extra_bytes(shape: &ConvShape) -> u64 {
    shape.im2col_bytes()
}

/// Allocation-free im2col + SGEMM core: lowers into the caller-owned
/// `workspace` (`C_i*H_f*W_f * H_o*W_o` floats) and accumulates the
/// GEMM into `out` (`[C_o][H_o][W_o]`, overwritten). The Goto SGEMM
/// additionally packs panels into small internal buffers (bounded by
/// its cache block sizes, independent of the layer); the paper's
/// overhead accounting counts the lowered matrix, which dominates.
pub fn conv_im2col_into(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    threads: usize,
    out: &mut [f32],
    workspace: &mut [f32],
) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let m = shape.c_o;
    let k = shape.c_i * shape.h_f * shape.w_f;
    let n = h_o * w_o;
    if ker.len() != m * k {
        return Err(Error::Shape(format!(
            "kernel has {} elements, expected {}",
            ker.len(),
            m * k
        )));
    }
    if out.len() != m * n {
        return Err(Error::Shape(format!(
            "output has {} elements, expected {}",
            out.len(),
            m * n
        )));
    }
    im2col_into(inp, shape, workspace)?;
    out.fill(0.0);
    sgemm_threaded(m, n, k, ker, k, workspace, n, out, n, threads);
    Ok(())
}

/// The "GEMM only" upper bound of Figure 1: run the same SGEMM on a
/// pre-lowered matrix (packing cost excluded). Returns (output, gemm fn).
pub fn conv_gemm_only(
    lowered: &Tensor,
    kernel: &Tensor,
    shape: &ConvShape,
    threads: usize,
) -> Tensor {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let m = shape.c_o;
    let k = shape.c_i * shape.h_f * shape.w_f;
    let n = h_o * w_o;
    let mut out = Tensor::zeros(&[shape.c_o, h_o, w_o]);
    if threads > 1 {
        sgemm_threaded(m, n, k, kernel.data(), k, lowered.data(), n, out.data_mut(), n, threads);
    } else {
        sgemm(m, n, k, kernel.data(), k, lowered.data(), n, out.data_mut(), n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;

    /// One-shot lowering + SGEMM over a fresh workspace (what the
    /// removed `conv_im2col[_threaded]` wrappers did; the engine's
    /// `im2col` backend reuses the workspace across calls).
    fn im2col_oneshot(
        input: &Tensor,
        kernel: &Tensor,
        s: &ConvShape,
        threads: usize,
    ) -> Result<Tensor> {
        s.validate()?;
        let (h_o, w_o) = (s.h_o(), s.w_o());
        let mut workspace = vec![0.0f32; s.c_i * s.h_f * s.w_f * h_o * w_o];
        let mut out = Tensor::zeros(&[s.c_o, h_o, w_o]);
        conv_im2col_into(input.data(), kernel.data(), s, threads, out.data_mut(), &mut workspace)?;
        Ok(out)
    }

    fn check(s: &ConvShape, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = im2col_oneshot(&input, &kernel, s, 1).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "mismatch {:?}: {}",
            s,
            got.max_abs_diff(&want)
        );
        let got4 = im2col_oneshot(&input, &kernel, s, 4).unwrap();
        assert!(got4.allclose(&want, 1e-4, 1e-5));
    }

    #[test]
    fn matches_naive() {
        check(&ConvShape::new(3, 8, 8, 4, 3, 3, 1, 0), 50);
        check(&ConvShape::new(2, 9, 7, 5, 3, 3, 1, 1), 51);
        check(&ConvShape::new(3, 23, 23, 8, 11, 11, 4, 0), 52);
        check(&ConvShape::new(16, 7, 7, 8, 1, 1, 1, 0), 53);
    }

    #[test]
    fn lowered_matrix_shape_and_duplication() {
        let s = ConvShape::new(1, 4, 4, 1, 3, 3, 1, 0);
        let input = Tensor::iota(&[1, 4, 4]);
        let low = im2col(&input, &s);
        assert_eq!(low.shape(), &[9, 4]);
        // center element 5 appears in multiple patches (duplication)
        let count = low.data().iter().filter(|&&v| v == 5.0).count();
        assert!(count >= 4, "overlap should duplicate interior elements");
    }

    #[test]
    fn zero_padding_regions_are_zero() {
        let s = ConvShape::new(1, 3, 3, 1, 3, 3, 1, 1);
        let input = Tensor::full(&[1, 3, 3], 1.0);
        let low = im2col(&input, &s);
        // row (n=0,m=0), col (l=0,k=0) reads I[-1][-1] -> 0
        assert_eq!(low.at(&[0, 0]), 0.0);
        // center tap, any output is 1
        assert_eq!(low.at(&[4, 4]), 1.0);
    }

    #[test]
    fn extra_bytes_quadratic_claim() {
        // §2.2: im2col memory grows ~H_f*W_f/s^2 times the input.
        let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        let ratio = im2col_extra_bytes(&s) as f64 / s.input_bytes() as f64;
        assert!(ratio > 8.5 && ratio < 9.5, "ratio={ratio}");
    }
}

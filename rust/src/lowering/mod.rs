//! Lowering-based convolution — the approaches the paper compares against
//! (§2.2).
//!
//! * [`im2col`] — Caffe's lowering: copy the `C_i x H_i x W_i` image into
//!   a `(H_f*W_f*C_i) x (H_o*W_o)` matrix (duplicating overlapped
//!   elements), then one SGEMM. Memory overhead ≈ `H_f*W_f / s^2` times
//!   the input.
//! * [`mec`] — Cho & Brand (2017) memory-efficient convolution: lower to
//!   an `[W_o][H_i][W_f*C_i]` tensor (only column overlap duplicated,
//!   ~`H_f`-fold smaller than im2col) at the price of `H_o` smaller GEMM
//!   calls over strided views.
//!
//! Both report their exact extra bytes so the zero-overhead comparison
//! (Figure 1 / EXPERIMENTS.md memory table) is auditable.

mod im2col;
mod mec;

pub use im2col::{conv_gemm_only, conv_im2col_into, im2col, im2col_extra_bytes, im2col_into};
pub use mec::{conv_mec, mec_extra_bytes};

//! MEC — Memory-Efficient Convolution (Cho & Brand, 2017).
//!
//! The paper cites MEC (§2.2) as the memory-lean alternative lowering:
//! instead of duplicating every `H_f x W_f` patch like im2col, MEC lowers
//! only the *column* overlap, producing an `[W_o][H_p][W_f*C_i]` tensor
//! (~`H_f`-fold smaller), and recovers the remaining reuse by issuing
//! `H_o` GEMM calls over strided row-windows of that tensor.
//!
//! Implementation notes: internally the image is padded and transposed to
//! channel-last once (`P[H_p][W_p][C_i]`) so each lowered pencil is one
//! `memcpy`; the per-`h` GEMM sees `A_h` rows at constant stride
//! `H_p*W_f*C_i` — exactly the `lda` trick the MEC paper feeds BLAS.

use crate::conv::reorder::kernel_to_hwio;
use crate::conv::ConvShape;
use crate::gemm::sgemm;
use crate::layout::nhwc_to_nchw;
use crate::tensor::Tensor;
use crate::Result;

/// Extra bytes MEC materializes: the lowered tensor plus the padded
/// channel-last staging copy.
pub fn mec_extra_bytes(shape: &ConvShape) -> u64 {
    let h_p = shape.h_i + 2 * shape.pad;
    let w_p = shape.w_i + 2 * shape.pad;
    let lowered = shape.w_o() * h_p * shape.w_f * shape.c_i;
    let staging = h_p * w_p * shape.c_i;
    4 * (lowered + staging) as u64
}

/// Convolution via MEC lowering + `H_o` SGEMM calls.
/// Input `[C_i][H_i][W_i]`, kernel `[C_o][C_i][H_f][W_f]`,
/// output `[C_o][H_o][W_o]`.
pub fn conv_mec(input: &Tensor, kernel: &Tensor, shape: &ConvShape) -> Result<Tensor> {
    shape.validate()?;
    crate::conv::naive::check_shapes(input, kernel, shape)?;
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (h_f, w_f) = (shape.h_f, shape.w_f);
    let (s, p) = (shape.stride, shape.pad);
    let (h_p, w_p) = (h_i + 2 * p, w_i + 2 * p);

    // Stage 1: padded channel-last copy P[H_p][W_p][C_i].
    let src = input.data();
    let mut padded = vec![0.0f32; h_p * w_p * c_i];
    for y in 0..h_i {
        for x in 0..w_i {
            let dst = ((y + p) * w_p + (x + p)) * c_i;
            for i in 0..c_i {
                padded[dst + i] = src[(i * h_i + y) * w_i + x];
            }
        }
    }

    // Stage 2: lowered tensor L[W_o][H_p][W_f*C_i]:
    // L[w][y][m*C_i + i] = P[y][w*s + m][i]  (contiguous W_f*C_i memcpy).
    let sec = w_f * c_i;
    let mut lowered = vec![0.0f32; w_o * h_p * sec];
    for w in 0..w_o {
        for y in 0..h_p {
            let srcb = (y * w_p + w * s) * c_i;
            let dstb = (w * h_p + y) * sec;
            lowered[dstb..dstb + sec].copy_from_slice(&padded[srcb..srcb + sec]);
        }
    }

    // Stage 3: H_o GEMMs. A_h rows: L[w][h*s .. h*s+H_f][*] — contiguous
    // length K = H_f*W_f*C_i, stride lda = H_p*W_f*C_i.
    let hwio = kernel_to_hwio(kernel)?; // [(n*W_f+m)*C_i+i][C_o] flattened
    let kdim = h_f * w_f * c_i;
    let lda = h_p * sec;
    let mut out_nhwc = Tensor::zeros(&[h_o, w_o, shape.c_o]);
    for h in 0..h_o {
        let a_h = &lowered[h * s * sec..];
        let c_h = &mut out_nhwc.data_mut()[h * w_o * shape.c_o..][..w_o * shape.c_o];
        sgemm(w_o, shape.c_o, kdim, a_h, lda, hwio.data(), shape.c_o, c_h, shape.c_o);
    }
    nhwc_to_nchw(&out_nhwc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;
    use crate::lowering::im2col_extra_bytes;

    fn check(s: &ConvShape, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = conv_mec(&input, &kernel, s).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "mismatch {:?}: {}",
            s,
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive() {
        check(&ConvShape::new(3, 8, 8, 4, 3, 3, 1, 0), 60);
        check(&ConvShape::new(2, 9, 7, 5, 3, 3, 1, 1), 61);
        check(&ConvShape::new(4, 13, 13, 8, 5, 5, 2, 2), 62);
        check(&ConvShape::new(8, 6, 6, 8, 1, 1, 1, 0), 63);
    }

    #[test]
    fn memory_saving_vs_im2col() {
        // Cho & Brand report ~3.2x average reduction; for a 3x3/s1 layer
        // the lowered tensor alone is H_f = 3x smaller.
        let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        let ratio = im2col_extra_bytes(&s) as f64 / mec_extra_bytes(&s) as f64;
        assert!(ratio > 2.0, "MEC should be much leaner than im2col: {ratio}");
    }

    #[test]
    fn still_nonzero_overhead() {
        // The paper's point: MEC is leaner, but not zero.
        let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        assert!(mec_extra_bytes(&s) > s.input_bytes());
    }
}

//! Input/output feature-map layout conversions (Figure 3 left).

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Linear index of logical element `(c, y, x)` in the blocked layout
/// `[C/c_b][H][W][c_b]`.
#[inline]
pub fn blocked_io_index(c: usize, y: usize, x: usize, h: usize, w: usize, c_b: usize) -> usize {
    let blk = c / c_b;
    let cc = c % c_b;
    ((blk * h + y) * w + x) * c_b + cc
}

/// Element count of the blocked layout (equals `c*h*w`: zero overhead).
pub fn io_layout_len(c: usize, h: usize, w: usize, c_b: usize) -> usize {
    assert_eq!(c % c_b, 0);
    c * h * w
}

fn check_cb(c: usize, c_b: usize) -> Result<()> {
    if c_b == 0 || c % c_b != 0 {
        return Err(Error::Layout(format!("pencil c_b={c_b} must divide C={c}")));
    }
    Ok(())
}

fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        return Err(Error::Layout(format!("{what} has {got} elements, expected {want}")));
    }
    Ok(())
}

/// Element-generic `[C][H][W]` -> `[C/c_b][H][W][c_b]` pack into a
/// caller-owned buffer. The layouts are pure permutations, so the pack
/// is element-type agnostic — the quantized engine runs it over `i8`
/// maps, the f32 stack over `f32`.
pub fn pack_io_slice_t<T: Copy>(
    src: &[T],
    c: usize,
    h: usize,
    w: usize,
    c_b: usize,
    dst: &mut [T],
) -> Result<()> {
    check_cb(c, c_b)?;
    check_len("pack_io_slice src", src.len(), c * h * w)?;
    check_len("pack_io_slice dst", dst.len(), c * h * w)?;
    for blk in 0..c / c_b {
        for y in 0..h {
            for x in 0..w {
                let dst_base = ((blk * h + y) * w + x) * c_b;
                for cc in 0..c_b {
                    dst[dst_base + cc] = src[((blk * c_b + cc) * h + y) * w + x];
                }
            }
        }
    }
    Ok(())
}

/// Element-generic `[C/c_b][H][W][c_b]` -> `[C][H][W]` unpack into a
/// caller-owned buffer (see [`pack_io_slice_t`]).
pub fn unpack_io_slice_t<T: Copy>(
    src: &[T],
    c: usize,
    h: usize,
    w: usize,
    c_b: usize,
    dst: &mut [T],
) -> Result<()> {
    check_cb(c, c_b)?;
    check_len("unpack_io_slice src", src.len(), c * h * w)?;
    check_len("unpack_io_slice dst", dst.len(), c * h * w)?;
    for blk in 0..c / c_b {
        for y in 0..h {
            for x in 0..w {
                let src_base = ((blk * h + y) * w + x) * c_b;
                for cc in 0..c_b {
                    dst[((blk * c_b + cc) * h + y) * w + x] = src[src_base + cc];
                }
            }
        }
    }
    Ok(())
}

/// Slice-based `[C][H][W]` -> `[C/c_b][H][W][c_b]` pack into a
/// caller-owned buffer — the allocation-free primitive the serving hot
/// path ([`crate::engine::PlanEngine`]) stages inputs with.
pub fn pack_io_slice(
    src: &[f32],
    c: usize,
    h: usize,
    w: usize,
    c_b: usize,
    dst: &mut [f32],
) -> Result<()> {
    pack_io_slice_t(src, c, h, w, c_b, dst)
}

/// Slice-based `[C/c_b][H][W][c_b]` -> `[C][H][W]` unpack into a
/// caller-owned buffer.
pub fn unpack_io_slice(
    src: &[f32],
    c: usize,
    h: usize,
    w: usize,
    c_b: usize,
    dst: &mut [f32],
) -> Result<()> {
    unpack_io_slice_t(src, c, h, w, c_b, dst)
}

/// Slice-based `[C][H][W]` -> `[H][W][C]` into a caller-owned buffer.
pub fn nchw_to_nhwc_slice(
    src: &[f32],
    c: usize,
    h: usize,
    w: usize,
    dst: &mut [f32],
) -> Result<()> {
    check_len("nchw_to_nhwc_slice src", src.len(), c * h * w)?;
    check_len("nchw_to_nhwc_slice dst", dst.len(), c * h * w)?;
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                dst[(y * w + x) * c + ch] = src[(ch * h + y) * w + x];
            }
        }
    }
    Ok(())
}

/// Slice-based `[H][W][C]` -> `[C][H][W]` into a caller-owned buffer.
pub fn nhwc_to_nchw_slice(
    src: &[f32],
    c: usize,
    h: usize,
    w: usize,
    dst: &mut [f32],
) -> Result<()> {
    check_len("nhwc_to_nchw_slice src", src.len(), c * h * w)?;
    check_len("nhwc_to_nchw_slice dst", dst.len(), c * h * w)?;
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                dst[(ch * h + y) * w + x] = src[(y * w + x) * c + ch];
            }
        }
    }
    Ok(())
}

/// `[C][H][W]` -> `[C/c_b][H][W][c_b]`.
pub fn to_blocked_io(nchw: &Tensor, c_b: usize) -> Result<Tensor> {
    let &[c, h, w] = nchw.shape() else {
        return Err(Error::Layout(format!("expected [C][H][W], got {:?}", nchw.shape())));
    };
    let mut out = vec![0.0f32; c * h * w];
    pack_io_slice(nchw.data(), c, h, w, c_b, &mut out)?;
    Tensor::from_vec(&[c / c_b, h, w, c_b], out)
}

/// `[C/c_b][H][W][c_b]` -> `[C][H][W]`.
pub fn from_blocked_io(blocked: &Tensor) -> Result<Tensor> {
    let &[nblk, h, w, c_b] = blocked.shape() else {
        return Err(Error::Layout(format!(
            "expected [C/c_b][H][W][c_b], got {:?}",
            blocked.shape()
        )));
    };
    let c = nblk * c_b;
    let mut out = vec![0.0f32; c * h * w];
    unpack_io_slice(blocked.data(), c, h, w, c_b, &mut out)?;
    Tensor::from_vec(&[c, h, w], out)
}

/// `[H][W][C]` -> `[C/c_b][H][W][c_b]` — the cheap repack (only block
/// transposition of the channel dimension; used by the first layer's
/// backward-compatibility path, §4.3).
pub fn to_blocked_io_nhwc(nhwc: &Tensor, c_b: usize) -> Result<Tensor> {
    let &[h, w, c] = nhwc.shape() else {
        return Err(Error::Layout(format!("expected [H][W][C], got {:?}", nhwc.shape())));
    };
    check_cb(c, c_b)?;
    let src = nhwc.data();
    let mut out = vec![0.0f32; c * h * w];
    for blk in 0..c / c_b {
        for y in 0..h {
            for x in 0..w {
                let dst = ((blk * h + y) * w + x) * c_b;
                let srcb = (y * w + x) * c + blk * c_b;
                out[dst..dst + c_b].copy_from_slice(&src[srcb..srcb + c_b]);
            }
        }
    }
    Tensor::from_vec(&[c / c_b, h, w, c_b], out)
}

/// `[C][H][W]` -> `[H][W][C]`.
pub fn nchw_to_nhwc(nchw: &Tensor) -> Result<Tensor> {
    let &[c, h, w] = nchw.shape() else {
        return Err(Error::Layout(format!("expected [C][H][W], got {:?}", nchw.shape())));
    };
    let mut out = vec![0.0f32; c * h * w];
    nchw_to_nhwc_slice(nchw.data(), c, h, w, &mut out)?;
    Tensor::from_vec(&[h, w, c], out)
}

/// `[H][W][C]` -> `[C][H][W]`.
pub fn nhwc_to_nchw(nhwc: &Tensor) -> Result<Tensor> {
    let &[h, w, c] = nhwc.shape() else {
        return Err(Error::Layout(format!("expected [H][W][C], got {:?}", nhwc.shape())));
    };
    let mut out = vec![0.0f32; c * h * w];
    nhwc_to_nchw_slice(nhwc.data(), c, h, w, &mut out)?;
    Tensor::from_vec(&[c, h, w], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocked_round_trip() {
        let t = Tensor::random(&[32, 5, 7], 1);
        for &cb in &[1, 2, 4, 8, 16, 32] {
            let b = to_blocked_io(&t, cb).unwrap();
            assert_eq!(b.len(), t.len(), "zero overhead");
            let back = from_blocked_io(&b).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn blocked_index_agrees_with_converter() {
        let t = Tensor::iota(&[8, 3, 4]);
        let b = to_blocked_io(&t, 4).unwrap();
        for c in 0..8 {
            for y in 0..3 {
                for x in 0..4 {
                    let i = blocked_io_index(c, y, x, 3, 4, 4);
                    assert_eq!(b.data()[i], t.at(&[c, y, x]));
                }
            }
        }
    }

    #[test]
    fn nhwc_round_trip() {
        let t = Tensor::random(&[6, 4, 5], 2);
        let n = nchw_to_nhwc(&t).unwrap();
        assert_eq!(n.shape(), &[4, 5, 6]);
        assert_eq!(nhwc_to_nchw(&n).unwrap(), t);
    }

    #[test]
    fn nhwc_to_blocked_matches_nchw_path() {
        let t = Tensor::random(&[8, 3, 3], 3);
        let via_nhwc = to_blocked_io_nhwc(&nchw_to_nhwc(&t).unwrap(), 4).unwrap();
        let direct = to_blocked_io(&t, 4).unwrap();
        assert_eq!(via_nhwc, direct);
    }

    #[test]
    fn pencil_contiguity() {
        // Channel pencils must be contiguous: elements (c..c+cb, y, x).
        let t = Tensor::iota(&[8, 2, 2]);
        let b = to_blocked_io(&t, 4).unwrap();
        let d = b.data();
        // first pencil = channels 0..4 at (0,0) = values {0, 4, 8, 12}
        assert_eq!(&d[0..4], &[0.0, 4.0, 8.0, 12.0]);
    }

    #[test]
    fn rejects_bad_pencil() {
        let t = Tensor::zeros(&[6, 2, 2]);
        assert!(to_blocked_io(&t, 4).is_err());
        assert!(to_blocked_io(&t, 0).is_err());
    }

    #[test]
    fn slice_helpers_round_trip_into_caller_buffers() {
        let t = Tensor::random(&[8, 3, 5], 9);
        let mut packed = vec![0.0f32; t.len()];
        let mut back = vec![0.0f32; t.len()];
        pack_io_slice(t.data(), 8, 3, 5, 4, &mut packed).unwrap();
        assert_eq!(packed, to_blocked_io(&t, 4).unwrap().into_vec());
        unpack_io_slice(&packed, 8, 3, 5, 4, &mut back).unwrap();
        assert_eq!(back, t.data());

        let mut nhwc = vec![0.0f32; t.len()];
        nchw_to_nhwc_slice(t.data(), 8, 3, 5, &mut nhwc).unwrap();
        assert_eq!(nhwc, nchw_to_nhwc(&t).unwrap().into_vec());
        nhwc_to_nchw_slice(&nhwc, 8, 3, 5, &mut back).unwrap();
        assert_eq!(back, t.data());
    }

    #[test]
    fn generic_pack_round_trips_i8() {
        // The §4 layouts are element-type agnostic permutations: the
        // quantized engine packs i8 maps through the same helpers.
        let src: Vec<i8> = (0..8 * 3 * 5).map(|v| (v % 251) as i8).collect();
        let mut packed = vec![0i8; src.len()];
        let mut back = vec![0i8; src.len()];
        pack_io_slice_t(&src, 8, 3, 5, 4, &mut packed).unwrap();
        unpack_io_slice_t(&packed, 8, 3, 5, 4, &mut back).unwrap();
        assert_eq!(back, src);
        // Same permutation as the f32 path, element for element.
        let as_f: Vec<f32> = src.iter().map(|&v| v as f32).collect();
        let mut packed_f = vec![0.0f32; src.len()];
        pack_io_slice(&as_f, 8, 3, 5, 4, &mut packed_f).unwrap();
        assert!(packed.iter().zip(&packed_f).all(|(&q, &f)| q as f32 == f));
    }

    #[test]
    fn slice_helpers_reject_bad_lengths() {
        let t = Tensor::zeros(&[8, 2, 2]);
        let mut short = vec![0.0f32; t.len() - 1];
        assert!(pack_io_slice(t.data(), 8, 2, 2, 4, &mut short).is_err());
        assert!(unpack_io_slice(t.data(), 8, 2, 2, 4, &mut short).is_err());
        assert!(nchw_to_nhwc_slice(t.data(), 8, 2, 2, &mut short).is_err());
        assert!(nhwc_to_nchw_slice(t.data(), 8, 2, 2, &mut short).is_err());
    }
}

//! The paper's convolution-friendly data layouts (§4, Figure 3) and
//! conversions between them and the conventional layouts.
//!
//! * **Input/Output layout** (Fig. 3 left): the `C x H x W` feature map is
//!   stored as `[C/C_b][H][W][C_b]` — sequential blocks of `H x W x C_b`
//!   in which a *pencil* of `C_b` channels is the fastest dimension,
//!   followed by columns and rows. Input and output share this layout so
//!   layers chain with **zero repacking**.
//! * **Kernel layout** (Fig. 3 right): `C_o x C_i x H_f x W_f` weights are
//!   stored as `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]` with the
//!   blocked output channel fastest.
//!
//! Both layouts are pure permutations: they occupy exactly the same number
//! of bytes as the unpacked tensors (the paper's zero-memory-overhead
//! claim); `io_layout_len` / `kernel_layout_len` make that auditable.

mod io;
mod kernel;

pub use io::{
    blocked_io_index, from_blocked_io, io_layout_len, nchw_to_nhwc, nchw_to_nhwc_slice,
    nhwc_to_nchw, nhwc_to_nchw_slice, pack_io_slice, pack_io_slice_t, to_blocked_io,
    to_blocked_io_nhwc, unpack_io_slice, unpack_io_slice_t,
};
pub use kernel::{
    blocked_kernel_index, from_blocked_kernel, kernel_layout_len, to_blocked_kernel,
};

/// Identifies the memory layout of a feature-map tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoLayout {
    /// `[C][H][W]` — Caffe/paper "original input" layout.
    Nchw,
    /// `[H][W][C]`.
    Nhwc,
    /// `[C/c_b][H][W][c_b]` — the paper's blocked layout with pencil `c_b`.
    Blocked { c_b: usize },
}

impl IoLayout {
    /// Element count for a `C x H x W` map in this layout (always equal:
    /// the layouts are permutations — asserted in tests).
    pub fn len(&self, c: usize, h: usize, w: usize) -> usize {
        match self {
            IoLayout::Nchw | IoLayout::Nhwc => c * h * w,
            IoLayout::Blocked { c_b } => {
                assert_eq!(c % c_b, 0, "pencil {c_b} must divide C={c}");
                (c / c_b) * h * w * c_b
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts_are_zero_overhead() {
        for &(c, h, w, cb) in &[(32, 7, 7, 8), (96, 55, 55, 16), (3, 9, 9, 3)] {
            let base = IoLayout::Nchw.len(c, h, w);
            assert_eq!(IoLayout::Nhwc.len(c, h, w), base);
            assert_eq!(IoLayout::Blocked { c_b: cb }.len(c, h, w), base);
        }
    }

    #[test]
    #[should_panic]
    fn blocked_requires_divisibility() {
        IoLayout::Blocked { c_b: 16 }.len(24, 4, 4);
    }
}

//! Kernel-weight layout conversions (Figure 3 right).
//!
//! Logical weights are `[C_o][C_i][H_f][W_f]` (Caffe order). The paper's
//! layout is `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]`: blocked output
//! channel fastest (it feeds the FMA vector), then the cache-blocked input
//! channel, kernel column, kernel row, and the block loops outermost.
//! This is the one-time repack a trained network pays for backward
//! compatibility (§4.3).

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Linear index of logical weight `(o, i, n, m)` (output channel, input
/// channel, kernel row, kernel col) in the blocked kernel layout.
#[inline]
#[allow(clippy::too_many_arguments)] // four logical coords + three layout params
pub fn blocked_kernel_index(
    o: usize,
    i: usize,
    n: usize,
    m: usize,
    c_i: usize,
    h_f: usize,
    w_f: usize,
    c_ib: usize,
    c_ob: usize,
) -> usize {
    let _ = c_i;
    let ob = o / c_ob;
    let oo = o % c_ob;
    let ib = i / c_ib;
    let ii = i % c_ib;
    ((((ob * (c_i / c_ib) + ib) * h_f + n) * w_f + m) * c_ib + ii) * c_ob + oo
}

/// Element count of the blocked kernel layout (equals the unpacked count).
pub fn kernel_layout_len(c_o: usize, c_i: usize, h_f: usize, w_f: usize) -> usize {
    c_o * c_i * h_f * w_f
}

/// `[C_o][C_i][H_f][W_f]` -> `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]`.
pub fn to_blocked_kernel(k: &Tensor, c_ob: usize, c_ib: usize) -> Result<Tensor> {
    let &[c_o, c_i, h_f, w_f] = k.shape() else {
        return Err(Error::Layout(format!(
            "expected [C_o][C_i][H_f][W_f], got {:?}",
            k.shape()
        )));
    };
    if c_ob == 0 || c_o % c_ob != 0 {
        return Err(Error::Layout(format!("c_ob={c_ob} must divide C_o={c_o}")));
    }
    if c_ib == 0 || c_i % c_ib != 0 {
        return Err(Error::Layout(format!("c_ib={c_ib} must divide C_i={c_i}")));
    }
    let src = k.data();
    let mut out = vec![0.0f32; c_o * c_i * h_f * w_f];
    for o in 0..c_o {
        for i in 0..c_i {
            for n in 0..h_f {
                for m in 0..w_f {
                    let d = blocked_kernel_index(o, i, n, m, c_i, h_f, w_f, c_ib, c_ob);
                    out[d] = src[((o * c_i + i) * h_f + n) * w_f + m];
                }
            }
        }
    }
    Tensor::from_vec(&[c_o / c_ob, c_i / c_ib, h_f, w_f, c_ib, c_ob], out)
}

/// Inverse of [`to_blocked_kernel`].
pub fn from_blocked_kernel(k: &Tensor) -> Result<Tensor> {
    let &[nob, nib, h_f, w_f, c_ib, c_ob] = k.shape() else {
        return Err(Error::Layout(format!(
            "expected 6-d blocked kernel, got {:?}",
            k.shape()
        )));
    };
    let c_o = nob * c_ob;
    let c_i = nib * c_ib;
    let src = k.data();
    let mut out = vec![0.0f32; c_o * c_i * h_f * w_f];
    for o in 0..c_o {
        for i in 0..c_i {
            for n in 0..h_f {
                for m in 0..w_f {
                    let s = blocked_kernel_index(o, i, n, m, c_i, h_f, w_f, c_ib, c_ob);
                    out[((o * c_i + i) * h_f + n) * w_f + m] = src[s];
                }
            }
        }
    }
    Tensor::from_vec(&[c_o, c_i, h_f, w_f], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let k = Tensor::random(&[16, 6, 3, 3], 9);
        for &(cob, cib) in &[(4, 2), (8, 3), (16, 6), (4, 1), (1, 1)] {
            let b = to_blocked_kernel(&k, cob, cib).unwrap();
            assert_eq!(b.len(), k.len(), "zero overhead");
            assert_eq!(from_blocked_kernel(&b).unwrap(), k);
        }
    }

    #[test]
    fn index_agrees_with_converter() {
        let k = Tensor::iota(&[8, 4, 2, 3]);
        let b = to_blocked_kernel(&k, 4, 2).unwrap();
        for o in 0..8 {
            for i in 0..4 {
                for n in 0..2 {
                    for m in 0..3 {
                        let idx = blocked_kernel_index(o, i, n, m, 4, 2, 3, 2, 4);
                        assert_eq!(b.data()[idx], k.at(&[o, i, n, m]));
                    }
                }
            }
        }
    }

    #[test]
    fn c_ob_is_fastest_dimension() {
        // Weights for consecutive output channels (same i,n,m) must be
        // adjacent — that is what the FMA broadcast-multiply consumes.
        let k = Tensor::iota(&[8, 2, 1, 1]);
        let b = to_blocked_kernel(&k, 4, 2).unwrap();
        let d = b.data();
        // o=0..4, i=0, n=0, m=0 -> logical values k[o][0][0][0] = o*2
        assert_eq!(&d[0..4], &[0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn rejects_non_divisible() {
        let k = Tensor::zeros(&[6, 4, 3, 3]);
        assert!(to_blocked_kernel(&k, 4, 2).is_err());
        assert!(to_blocked_kernel(&k, 3, 3).is_err());
        assert!(to_blocked_kernel(&k, 0, 1).is_err());
    }
}

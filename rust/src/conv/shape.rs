//! Convolution-layer shape descriptors and blocking parameters.

use crate::{Error, Result};

/// Shape of a single convolution layer (one image; batching is an outer
/// dimension handled by the caller / coordinator).
///
/// Follows the paper's notation: input `C_i x H_i x W_i`, kernel
/// `C_o x C_i/groups x H_f x W_f`, output `C_o x H_o x W_o`, stride `s`,
/// symmetric zero padding `pad`. `groups` partitions the channels into
/// independent convolutions (depthwise when `groups == c_i == c_o`);
/// `dilation` spaces the filter taps, giving an effective extent of
/// `(H_f - 1) * dilation + 1`. Both default to 1 under [`ConvShape::new`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub c_i: usize,
    pub h_i: usize,
    pub w_i: usize,
    pub c_o: usize,
    pub h_f: usize,
    pub w_f: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub dilation: usize,
}

impl ConvShape {
    #[allow(clippy::too_many_arguments)] // mirrors the paper's 8-parameter layer tuple
    pub fn new(
        c_i: usize,
        h_i: usize,
        w_i: usize,
        c_o: usize,
        h_f: usize,
        w_f: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        ConvShape { c_i, h_i, w_i, c_o, h_f, w_f, stride, pad, groups: 1, dilation: 1 }
    }

    /// Grouped variant (depthwise when `groups == c_i == c_o`).
    pub fn with_groups(mut self, groups: usize) -> Self {
        self.groups = groups;
        self
    }

    /// Dilated variant (`dilation == 1` is the dense filter).
    pub fn with_dilation(mut self, dilation: usize) -> Self {
        self.dilation = dilation;
        self
    }

    /// Effective filter height after dilation: `(H_f - 1) * d + 1`.
    pub fn eff_h_f(&self) -> usize {
        (self.h_f - 1) * self.dilation + 1
    }

    /// Effective filter width after dilation: `(W_f - 1) * d + 1`.
    pub fn eff_w_f(&self) -> usize {
        (self.w_f - 1) * self.dilation + 1
    }

    /// Input channels per group.
    pub fn c_i_per_group(&self) -> usize {
        self.c_i / self.groups
    }

    /// Output channels per group.
    pub fn c_o_per_group(&self) -> usize {
        self.c_o / self.groups
    }

    /// Depthwise = one input and one output channel per group.
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.c_i && self.groups == self.c_o
    }

    /// Output height `(H_i + 2 pad - eff_H_f) / s + 1`.
    pub fn h_o(&self) -> usize {
        (self.h_i + 2 * self.pad - self.eff_h_f()) / self.stride + 1
    }

    /// Output width `(W_i + 2 pad - eff_W_f) / s + 1`.
    pub fn w_o(&self) -> usize {
        (self.w_i + 2 * self.pad - self.eff_w_f()) / self.stride + 1
    }

    /// Multiply-accumulate FLOPs (2 per MAC, the convention used by the
    /// paper's GFLOPS plots); each output channel reduces over
    /// `C_i/groups` input channels.
    pub fn flops(&self) -> u64 {
        2 * self.c_o as u64
            * self.h_o() as u64
            * self.w_o() as u64
            * self.c_i_per_group() as u64
            * self.h_f as u64
            * self.w_f as u64
    }

    /// Bytes of the (unpacked) input, kernel and output — the paper's
    /// zero-overhead budget.
    pub fn input_bytes(&self) -> u64 {
        4 * (self.c_i * self.h_i * self.w_i) as u64
    }
    pub fn kernel_bytes(&self) -> u64 {
        4 * (self.c_o * self.c_i_per_group() * self.h_f * self.w_f) as u64
    }
    pub fn output_bytes(&self) -> u64 {
        4 * (self.c_o * self.h_o() * self.w_o()) as u64
    }

    /// Extra bytes an `im2col` lowering materializes:
    /// `(H_f*W_f*C_i) x (H_o*W_o)` floats.
    pub fn im2col_bytes(&self) -> u64 {
        4 * (self.h_f * self.w_f * self.c_i) as u64 * (self.h_o() * self.w_o()) as u64
    }

    /// Sanity checks used by every kernel entry point.
    pub fn validate(&self) -> Result<()> {
        if self.stride == 0 {
            return Err(Error::Shape("stride must be >= 1".into()));
        }
        if self.groups == 0 {
            return Err(Error::Shape("groups must be >= 1".into()));
        }
        if self.dilation == 0 {
            return Err(Error::Shape("dilation must be >= 1".into()));
        }
        if [self.c_i, self.h_i, self.w_i, self.c_o, self.h_f, self.w_f]
            .iter()
            .any(|&d| d == 0)
        {
            return Err(Error::Shape("zero dimension".into()));
        }
        if self.c_i % self.groups != 0 || self.c_o % self.groups != 0 {
            return Err(Error::Shape(format!(
                "groups={} must divide C_i={} and C_o={}",
                self.groups, self.c_i, self.c_o
            )));
        }
        if self.eff_h_f() > self.h_i + 2 * self.pad || self.eff_w_f() > self.w_i + 2 * self.pad {
            return Err(Error::Shape(format!(
                "effective kernel {}x{} (dilation {}) larger than padded input {}x{}",
                self.eff_h_f(),
                self.eff_w_f(),
                self.dilation,
                self.h_i + 2 * self.pad,
                self.w_i + 2 * self.pad
            )));
        }
        Ok(())
    }
}

/// Blocking parameters of Algorithm 3.
///
/// * `c_ob` — register-block of the output channel (paper: a multiple of
///   `N_vec`); the fastest dimension of both proposed layouts.
/// * `w_ob` — register-block of the output row; together `c_ob * w_ob`
///   accumulators must satisfy `E >= N_vec * N_fma * L_fma` (paper eq. 1)
///   while fitting in `N_reg` registers (paper eq. 2).
/// * `c_ib` — cache-block of the input channel (the `i'` loop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockParams {
    pub c_ob: usize,
    pub w_ob: usize,
    pub c_ib: usize,
}

impl BlockParams {
    pub fn new(c_ob: usize, w_ob: usize, c_ib: usize) -> Self {
        BlockParams { c_ob, w_ob, c_ib }
    }

    /// Check divisibility against a layer shape (the zero-overhead layouts
    /// require exact blocking; see `conv::params::select` which always
    /// returns divisible parameters). Grouped layers block each group's
    /// channel range independently, so the per-group counts must divide;
    /// the depthwise fast path instead requires `c_ob == c_ib` lanes that
    /// divide the (shared) channel count.
    pub fn validate_for(&self, s: &ConvShape) -> Result<()> {
        if self.c_ob == 0 || self.w_ob == 0 || self.c_ib == 0 {
            return Err(Error::Shape("zero block parameter".into()));
        }
        if s.is_depthwise() {
            if self.c_ob != self.c_ib {
                return Err(Error::Shape(format!(
                    "depthwise blocking needs c_ob == c_ib, got {} and {}",
                    self.c_ob, self.c_ib
                )));
            }
            if s.c_o % self.c_ob != 0 {
                return Err(Error::Shape(format!(
                    "c_b={} does not divide depthwise C={}",
                    self.c_ob, s.c_o
                )));
            }
            return Ok(());
        }
        if s.c_o_per_group() % self.c_ob != 0 {
            return Err(Error::Shape(format!(
                "c_ob={} does not divide C_o/groups={}",
                self.c_ob,
                s.c_o_per_group()
            )));
        }
        if s.c_i_per_group() % self.c_ib != 0 {
            return Err(Error::Shape(format!(
                "c_ib={} does not divide C_i/groups={}",
                self.c_ib,
                s.c_i_per_group()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alexnet_conv1() -> ConvShape {
        // AlexNet conv1: 3x227x227 -> 96x55x55, 11x11 stride 4.
        ConvShape::new(3, 227, 227, 96, 11, 11, 4, 0)
    }

    #[test]
    fn output_dims() {
        let s = alexnet_conv1();
        assert_eq!(s.h_o(), 55);
        assert_eq!(s.w_o(), 55);
        let p = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!(p.h_o(), 56);
        assert_eq!(p.w_o(), 56);
    }

    #[test]
    fn flops_match_hand_count() {
        let s = ConvShape::new(2, 4, 4, 3, 3, 3, 1, 0);
        // H_o = W_o = 2; 2 * 3*2*2 * 2*3*3 = 432
        assert_eq!(s.flops(), 432);
    }

    #[test]
    fn im2col_overhead_grows() {
        let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        // im2col matrix is H_f*W_f = 9x the input size for stride 1.
        assert!(s.im2col_bytes() > 8 * s.input_bytes());
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(ConvShape::new(1, 4, 4, 1, 3, 3, 0, 0).validate().is_err());
        assert!(ConvShape::new(1, 2, 2, 1, 3, 3, 1, 0).validate().is_err());
        assert!(ConvShape::new(1, 2, 2, 1, 3, 3, 1, 1).validate().is_ok());
        assert!(ConvShape::new(0, 4, 4, 1, 3, 3, 1, 0).validate().is_err());
    }

    #[test]
    fn block_params_divisibility() {
        let s = alexnet_conv1();
        assert!(BlockParams::new(16, 4, 3).validate_for(&s).is_ok());
        assert!(BlockParams::new(5, 4, 3).validate_for(&s).is_err());
        assert!(BlockParams::new(16, 4, 2).validate_for(&s).is_err());
    }

    #[test]
    fn dilation_shrinks_output() {
        // 3x3 d=2 has effective extent 5.
        let s = ConvShape::new(8, 16, 16, 8, 3, 3, 1, 0).with_dilation(2);
        assert_eq!(s.eff_h_f(), 5);
        assert_eq!(s.h_o(), 12);
        assert_eq!(s.w_o(), 12);
        // Same-padding dilated conv: pad = dilation for 3x3.
        let p = ConvShape::new(8, 16, 16, 8, 3, 3, 1, 2).with_dilation(2);
        assert_eq!(p.h_o(), 16);
        assert!(p.validate().is_ok());
        // Effective extent larger than padded input is rejected.
        let bad = ConvShape::new(1, 4, 4, 1, 3, 3, 1, 0).with_dilation(2);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn groups_divide_channels_and_scale_flops() {
        let g = ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1).with_groups(4);
        assert!(g.validate().is_ok());
        assert_eq!(g.c_i_per_group(), 2);
        assert_eq!(g.c_o_per_group(), 4);
        assert!(!g.is_depthwise());
        let dense = ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1);
        assert_eq!(dense.flops(), 4 * g.flops());
        assert_eq!(dense.kernel_bytes(), 4 * g.kernel_bytes());
        assert!(ConvShape::new(8, 8, 8, 15, 3, 3, 1, 1).with_groups(4).validate().is_err());
        assert!(ConvShape::new(6, 8, 8, 16, 3, 3, 1, 1).with_groups(4).validate().is_err());
        assert!(ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1).with_groups(0).validate().is_err());
        assert!(ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1).with_dilation(0).validate().is_err());
    }

    #[test]
    fn depthwise_detection_and_blocking() {
        let dw = ConvShape::new(16, 8, 8, 16, 3, 3, 1, 1).with_groups(16);
        assert!(dw.is_depthwise());
        assert!(dw.validate().is_ok());
        assert!(BlockParams::new(8, 4, 8).validate_for(&dw).is_ok());
        assert!(BlockParams::new(8, 4, 1).validate_for(&dw).is_err(), "lanes must match");
        assert!(BlockParams::new(3, 4, 3).validate_for(&dw).is_err(), "must divide C");
        // Grouped (non-depthwise) blocks each group's range.
        let g = ConvShape::new(16, 8, 8, 32, 3, 3, 1, 1).with_groups(4);
        assert!(BlockParams::new(8, 4, 4).validate_for(&g).is_ok());
        assert!(BlockParams::new(16, 4, 4).validate_for(&g).is_err());
        assert!(BlockParams::new(8, 4, 8).validate_for(&g).is_err());
    }
}

//! Convolution-layer shape descriptors and blocking parameters.

use crate::{Error, Result};

/// Shape of a single convolution layer (one image; batching is an outer
/// dimension handled by the caller / coordinator).
///
/// Follows the paper's notation: input `C_i x H_i x W_i`, kernel
/// `C_o x C_i x H_f x W_f`, output `C_o x H_o x W_o`, stride `s`,
/// symmetric zero padding `pad`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub c_i: usize,
    pub h_i: usize,
    pub w_i: usize,
    pub c_o: usize,
    pub h_f: usize,
    pub w_f: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    #[allow(clippy::too_many_arguments)] // mirrors the paper's 8-parameter layer tuple
    pub fn new(
        c_i: usize,
        h_i: usize,
        w_i: usize,
        c_o: usize,
        h_f: usize,
        w_f: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        ConvShape { c_i, h_i, w_i, c_o, h_f, w_f, stride, pad }
    }

    /// Output height `(H_i + 2 pad - H_f) / s + 1`.
    pub fn h_o(&self) -> usize {
        (self.h_i + 2 * self.pad - self.h_f) / self.stride + 1
    }

    /// Output width `(W_i + 2 pad - W_f) / s + 1`.
    pub fn w_o(&self) -> usize {
        (self.w_i + 2 * self.pad - self.w_f) / self.stride + 1
    }

    /// Multiply-accumulate FLOPs (2 per MAC, the convention used by the
    /// paper's GFLOPS plots).
    pub fn flops(&self) -> u64 {
        2 * self.c_o as u64
            * self.h_o() as u64
            * self.w_o() as u64
            * self.c_i as u64
            * self.h_f as u64
            * self.w_f as u64
    }

    /// Bytes of the (unpacked) input, kernel and output — the paper's
    /// zero-overhead budget.
    pub fn input_bytes(&self) -> u64 {
        4 * (self.c_i * self.h_i * self.w_i) as u64
    }
    pub fn kernel_bytes(&self) -> u64 {
        4 * (self.c_o * self.c_i * self.h_f * self.w_f) as u64
    }
    pub fn output_bytes(&self) -> u64 {
        4 * (self.c_o * self.h_o() * self.w_o()) as u64
    }

    /// Extra bytes an `im2col` lowering materializes:
    /// `(H_f*W_f*C_i) x (H_o*W_o)` floats.
    pub fn im2col_bytes(&self) -> u64 {
        4 * (self.h_f * self.w_f * self.c_i) as u64 * (self.h_o() * self.w_o()) as u64
    }

    /// Sanity checks used by every kernel entry point.
    pub fn validate(&self) -> Result<()> {
        if self.stride == 0 {
            return Err(Error::Shape("stride must be >= 1".into()));
        }
        if self.h_f > self.h_i + 2 * self.pad || self.w_f > self.w_i + 2 * self.pad {
            return Err(Error::Shape(format!(
                "kernel {}x{} larger than padded input {}x{}",
                self.h_f,
                self.w_f,
                self.h_i + 2 * self.pad,
                self.w_i + 2 * self.pad
            )));
        }
        if [self.c_i, self.h_i, self.w_i, self.c_o, self.h_f, self.w_f]
            .iter()
            .any(|&d| d == 0)
        {
            return Err(Error::Shape("zero dimension".into()));
        }
        Ok(())
    }
}

/// Blocking parameters of Algorithm 3.
///
/// * `c_ob` — register-block of the output channel (paper: a multiple of
///   `N_vec`); the fastest dimension of both proposed layouts.
/// * `w_ob` — register-block of the output row; together `c_ob * w_ob`
///   accumulators must satisfy `E >= N_vec * N_fma * L_fma` (paper eq. 1)
///   while fitting in `N_reg` registers (paper eq. 2).
/// * `c_ib` — cache-block of the input channel (the `i'` loop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockParams {
    pub c_ob: usize,
    pub w_ob: usize,
    pub c_ib: usize,
}

impl BlockParams {
    pub fn new(c_ob: usize, w_ob: usize, c_ib: usize) -> Self {
        BlockParams { c_ob, w_ob, c_ib }
    }

    /// Check divisibility against a layer shape (the zero-overhead layouts
    /// require exact blocking; see `conv::params::select` which always
    /// returns divisible parameters).
    pub fn validate_for(&self, s: &ConvShape) -> Result<()> {
        if self.c_ob == 0 || self.w_ob == 0 || self.c_ib == 0 {
            return Err(Error::Shape("zero block parameter".into()));
        }
        if s.c_o % self.c_ob != 0 {
            return Err(Error::Shape(format!(
                "c_ob={} does not divide C_o={}",
                self.c_ob, s.c_o
            )));
        }
        if s.c_i % self.c_ib != 0 {
            return Err(Error::Shape(format!(
                "c_ib={} does not divide C_i={}",
                self.c_ib, s.c_i
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alexnet_conv1() -> ConvShape {
        // AlexNet conv1: 3x227x227 -> 96x55x55, 11x11 stride 4.
        ConvShape::new(3, 227, 227, 96, 11, 11, 4, 0)
    }

    #[test]
    fn output_dims() {
        let s = alexnet_conv1();
        assert_eq!(s.h_o(), 55);
        assert_eq!(s.w_o(), 55);
        let p = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!(p.h_o(), 56);
        assert_eq!(p.w_o(), 56);
    }

    #[test]
    fn flops_match_hand_count() {
        let s = ConvShape::new(2, 4, 4, 3, 3, 3, 1, 0);
        // H_o = W_o = 2; 2 * 3*2*2 * 2*3*3 = 432
        assert_eq!(s.flops(), 432);
    }

    #[test]
    fn im2col_overhead_grows() {
        let s = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
        // im2col matrix is H_f*W_f = 9x the input size for stride 1.
        assert!(s.im2col_bytes() > 8 * s.input_bytes());
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(ConvShape::new(1, 4, 4, 1, 3, 3, 0, 0).validate().is_err());
        assert!(ConvShape::new(1, 2, 2, 1, 3, 3, 1, 0).validate().is_err());
        assert!(ConvShape::new(1, 2, 2, 1, 3, 3, 1, 1).validate().is_ok());
        assert!(ConvShape::new(0, 4, 4, 1, 3, 3, 1, 0).validate().is_err());
    }

    #[test]
    fn block_params_divisibility() {
        let s = alexnet_conv1();
        assert!(BlockParams::new(16, 4, 3).validate_for(&s).is_ok());
        assert!(BlockParams::new(5, 4, 3).validate_for(&s).is_err());
        assert!(BlockParams::new(16, 4, 2).validate_for(&s).is_err());
    }
}

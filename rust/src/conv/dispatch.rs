//! Runtime ISA dispatch for the convolution microkernels.
//!
//! The hot cores ship in two flavours: the always-compiled scalar
//! pencils (the conformance oracle, in [`super::microkernel`] and
//! `quant::direct`) and explicit `std::arch` register-tile kernels
//! (AVX2+FMA, optionally AVX-512, NEON). This module decides — once
//! per process — which flavour every backend runs:
//!
//! 1. `CONV_FORCE_SCALAR` set to anything but `0`/empty pins the
//!    scalar oracle (used by CI to prove the SIMD arms change nothing).
//! 2. On `x86_64`, `avx512f` selects [`SimdLevel::Avx512`] — but only
//!    when the crate is built with the `avx512` feature (the AVX-512
//!    intrinsics need a newer rustc than our MSRV); otherwise
//!    `avx2 && fma` selects [`SimdLevel::Avx2`].
//! 3. On `aarch64`, NEON is architecturally guaranteed:
//!    [`SimdLevel::Neon`].
//! 4. Everything else runs the scalar oracle.
//!
//! The result is cached in a [`OnceLock`], so detection (and the env
//! read) happens on the first planned convolution and never again.
//! Individual kernels still fall back per call site when the channel
//! block is narrower than a vector — see [`kernel_label_f32`].
//!
//! Every SIMD kernel vectorizes the *output-channel* (`C_o,b`) lane
//! dimension only and keeps the scalar `(n, m, ii, kk)` reduction
//! order, so f32 results are bitwise identical to the oracle (a lane's
//! fused multiply-add chain is the same chain), and the i8 cores are
//! exact integer arithmetic. `CONV_FORCE_SCALAR=1` therefore
//! reproduces — bitwise — what dispatch produces; the toggle exists to
//! *prove* that, not to paper over drift.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// The vector ISA the dispatched microkernels target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Plain-Rust pencil cores — the conformance oracle.
    Scalar,
    /// 128-bit NEON fused multiply-add kernels (baseline on aarch64).
    Neon,
    /// 256-bit AVX2+FMA kernels.
    Avx2,
    /// 512-bit AVX-512F kernels (needs the `avx512` crate feature).
    Avx512,
}

impl SimdLevel {
    /// f32 lanes per vector register (1 for the scalar oracle).
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Neon => 4,
            SimdLevel::Avx2 => 8,
            SimdLevel::Avx512 => 16,
        }
    }

    /// Human-readable ISA name (matches `arch::Machine::isa` spelling).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Neon => "NEON",
            SimdLevel::Avx2 => "AVX2",
            SimdLevel::Avx512 => "AVX-512",
        }
    }
}

/// Test-only override, checked before the cached detection. 0 = none,
/// 1 = force scalar. An atomic (not the `OnceLock`) so tests can flip
/// it back and forth within one process.
static TEST_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Pin (or unpin) the scalar oracle process-wide, bypassing the cached
/// detection. For the SIMD-vs-scalar conformance battery only — both
/// arms conform, so a concurrent test observing the toggle mid-flight
/// still computes correct results; serialize on a lock for
/// discriminating comparisons. Never forces a level *up*: upgrading
/// past what the CPU supports would be unsound.
#[doc(hidden)]
pub fn _force_scalar_for_tests(on: bool) {
    TEST_OVERRIDE.store(u8::from(on), Ordering::Relaxed);
}

/// Was `CONV_FORCE_SCALAR` set (to anything but empty / `"0"`)?
fn force_scalar_env() -> bool {
    match std::env::var("CONV_FORCE_SCALAR") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    }
}

/// One-time hardware + env detection (see the module docs for order).
fn detect() -> SimdLevel {
    if force_scalar_env() {
        return SimdLevel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        #[cfg(feature = "avx512")]
        if std::arch::is_x86_feature_detected!("avx512f") {
            return SimdLevel::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    #[allow(unreachable_code)]
    SimdLevel::Scalar
}

/// The ISA level every dispatched kernel call runs at. Cached after
/// the first call; `CONV_FORCE_SCALAR` is honoured at detection time.
pub fn active() -> SimdLevel {
    if TEST_OVERRIDE.load(Ordering::Relaxed) == 1 {
        return SimdLevel::Scalar;
    }
    static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// Label of the f32 tile-reduction kernel that will run for an
/// output-channel block of width `c_ob` (the fall-back rule the
/// kernels themselves apply: a block narrower than a vector register
/// runs the scalar oracle).
pub fn kernel_label_f32(c_ob: usize) -> &'static str {
    match active() {
        SimdLevel::Avx512 if c_ob % 16 == 0 => "avx512-fma",
        SimdLevel::Avx512 | SimdLevel::Avx2 if c_ob % 8 == 0 => "avx2-fma",
        SimdLevel::Neon if c_ob % 4 == 0 => "neon-fma",
        _ => "scalar",
    }
}

/// Label of the depthwise f32 tile kernel for a `c_b`-wide channel
/// block. Depthwise ships an AVX2 kernel only: on NEON the 4-lane tap
/// loop is memory-bound and LLVM already vectorizes the oracle.
pub fn kernel_label_f32_dw(c_b: usize) -> &'static str {
    match active() {
        SimdLevel::Avx512 | SimdLevel::Avx2 if c_b % 8 == 0 => "avx2-fma",
        _ => "scalar",
    }
}

/// Label of the i8 tile-reduction kernel for a `c_ob`-wide block. The
/// AVX2 core emulates a VNNI-style dot product with widening
/// multiplies; there is no NEON i8 kernel yet (the centered-input
/// loads dominate), so aarch64 reports the oracle.
pub fn kernel_label_i8(c_ob: usize) -> &'static str {
    match active() {
        SimdLevel::Avx512 | SimdLevel::Avx2 if c_ob % 8 == 0 => "avx2-widen",
        _ => "scalar",
    }
}

/// One-line description of the dispatch decision, for the CLI.
pub fn describe() -> String {
    let lvl = active();
    let forced = if lvl == SimdLevel::Scalar && force_scalar_env() {
        " (forced by CONV_FORCE_SCALAR)"
    } else {
        ""
    };
    format!("{} microkernels, {} f32 lanes{forced}", lvl.name(), lvl.lanes())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The override is process-global; serialize the tests that read
    /// or write it so neither observes the other's toggle.
    static OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn detection_is_stable_and_labels_are_consistent() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        let a = active();
        assert_eq!(a, active());
        // Vector labels only appear for vector-divisible blocks.
        assert_eq!(kernel_label_f32(5), "scalar");
        assert_eq!(kernel_label_i8(5), "scalar");
        match a {
            SimdLevel::Avx512 => assert_eq!(kernel_label_f32(16), "avx512-fma"),
            SimdLevel::Avx2 => assert_eq!(kernel_label_f32(16), "avx2-fma"),
            SimdLevel::Neon => assert_eq!(kernel_label_f32(16), "neon-fma"),
            SimdLevel::Scalar => assert_eq!(kernel_label_f32(16), "scalar"),
        }
        assert!(describe().contains(a.name()));
    }

    #[test]
    fn scalar_override_wins_and_resets() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        _force_scalar_for_tests(true);
        assert_eq!(active(), SimdLevel::Scalar);
        assert_eq!(kernel_label_f32(16), "scalar");
        assert_eq!(kernel_label_i8(16), "scalar");
        _force_scalar_for_tests(false);
        assert_eq!(active(), active());
    }
}

//! Register-tile FMA microkernels for Algorithm 3.
//!
//! A microkernel owns a `W_o,b x C_o,b` accumulator tile (the paper's
//! `E = N_vec * N_fma * L_fma` independent output elements, eq. 1) and
//! accumulates the **entire** `(n, m, C_i,b)` reduction of one
//! input-channel cache block into it before touching memory again.
//!
//! Both tile dimensions are const generics (`COB`, `TW`): with fixed
//! trip counts LLVM promotes the whole tile to vector registers and
//! emits pure FMAs — with a dynamic width the accumulators spill to the
//! stack on every iteration, which measured ~2x slower (see
//! EXPERIMENTS.md §Perf iteration 2). Edge tiles (row remainder) use the
//! dynamic-width fallback [`tap_full`]/[`tap_one_col`] path.
//!
//! [`reduce_tile`] is the scalar *oracle*; the hot paths call
//! [`reduce_tile_auto`], which routes to the explicit `std::arch`
//! variants in [`x86`]/[`neon`] when [`crate::conv::dispatch`] detects
//! the ISA at runtime (`CONV_FORCE_SCALAR=1` pins the oracle). The
//! SIMD kernels vectorize the `COB` lane dimension only and keep the
//! exact scalar `(n, m, ii, kk)` chain order, so their results are
//! bitwise identical to the oracle's.

/// Hard cap on `W_o,b`; accumulator tiles are stack arrays of this height.
pub const MAX_WOB: usize = 8;

/// Accumulator tile for the dynamic-width fallback path.
pub type AccTile<const COB: usize> = [[f32; COB]; MAX_WOB];

/// Geometry of one register-tile reduction (all in elements, not bytes).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub h_f: usize,
    pub w_f: usize,
    pub c_ib: usize,
    pub h_i: usize,
    pub w_i: usize,
    pub stride: usize,
    pub pad: usize,
    /// Filter-tap spacing (1 = dense).
    pub dil: usize,
    /// Output row this tile belongs to.
    pub l: usize,
    /// First output column of the tile.
    pub k0: usize,
}

/// Fully-unrolled tile reduction: accumulate every kernel tap of one
/// input-channel block into a `TW x COB` register tile.
///
/// * `inp` — the input block `[H_i][W_i][C_ib]` (this `ib`'s slab).
/// * `ker` — the kernel slab `[H_f][W_f][C_ib][COB]` for `(jb, ib)`.
#[inline(always)]
pub fn reduce_tile<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
    inp: &[f32],
    ker: &[f32],
    g: &TileGeom,
) {
    let c_ib = g.c_ib;
    let row_stride = g.w_i * c_ib;
    for n in 0..g.h_f {
        let iy = (g.l * g.stride + n * g.dil) as isize - g.pad as isize;
        if iy < 0 || iy >= g.h_i as isize {
            continue; // whole kernel row outside the image
        }
        let row = &inp[iy as usize * row_stride..][..row_stride];
        for m in 0..g.w_f {
            let kptr = &ker[(n * g.w_f + m) * c_ib * COB..][..c_ib * COB];
            let x0 = (g.k0 * g.stride + m * g.dil) as isize - g.pad as isize;
            let x_last = x0 + ((TW - 1) * g.stride) as isize;
            if x0 >= 0 && x_last < g.w_i as isize {
                // Interior fast path: every tile column valid.
                let base = x0 as usize * c_ib;
                for ii in 0..c_ib {
                    let w = &kptr[ii * COB..][..COB];
                    for kk in 0..TW {
                        let xv = row[base + kk * g.stride * c_ib + ii];
                        let a = &mut acc[kk];
                        for j in 0..COB {
                            a[j] = xv.mul_add(w[j], a[j]);
                        }
                    }
                }
            } else {
                // Border tap: guard each (const-unrolled) column.
                for kk in 0..TW {
                    let x = x0 + (kk * g.stride) as isize;
                    if x < 0 || x >= g.w_i as isize {
                        continue;
                    }
                    let base = x as usize * c_ib;
                    for ii in 0..c_ib {
                        let w = &kptr[ii * COB..][..COB];
                        let xv = row[base + ii];
                        let a = &mut acc[kk];
                        for j in 0..COB {
                            a[j] = xv.mul_add(w[j], a[j]);
                        }
                    }
                }
            }
        }
    }
}

/// Runtime-dispatched twin of [`reduce_tile`]: an AVX-512 / AVX2+FMA /
/// NEON register tile when [`crate::conv::dispatch::active`] says the
/// host has one *and* `COB` fills whole vectors, else the scalar
/// oracle. Bitwise-equal to [`reduce_tile`] on every path.
#[inline(always)]
pub fn reduce_tile_auto<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
    inp: &[f32],
    ker: &[f32],
    g: &TileGeom,
) {
    #[cfg(target_arch = "x86_64")]
    {
        use super::dispatch::{active, SimdLevel};
        let lvl = active();
        #[cfg(feature = "avx512")]
        if lvl == SimdLevel::Avx512 && COB % 16 == 0 {
            // SAFETY: avx512f was runtime-detected; the flat view is
            // the tile's own contiguous storage.
            unsafe {
                let flat = tile_as_flat::<COB, TW>(acc);
                match COB / 16 {
                    1 => x86::reduce_tile_f32_avx512::<1, TW>(flat, inp, ker, g),
                    _ => x86::reduce_tile_f32_avx512::<2, TW>(flat, inp, ker, g),
                }
            }
            return;
        }
        if matches!(lvl, SimdLevel::Avx2 | SimdLevel::Avx512) && COB % 8 == 0 {
            // SAFETY: avx2+fma were runtime-detected (Avx512 implies
            // both); the flat view is the tile's contiguous storage.
            unsafe {
                let flat = tile_as_flat::<COB, TW>(acc);
                match COB / 8 {
                    1 => x86::reduce_tile_f32_avx2::<1, TW>(flat, inp, ker, g),
                    2 => x86::reduce_tile_f32_avx2::<2, TW>(flat, inp, ker, g),
                    _ => x86::reduce_tile_f32_avx2::<4, TW>(flat, inp, ker, g),
                }
            }
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        use super::dispatch::{active, SimdLevel};
        if active() == SimdLevel::Neon && COB % 4 == 0 {
            // SAFETY: NEON is architecturally guaranteed on aarch64.
            unsafe {
                let flat = tile_as_flat::<COB, TW>(acc);
                match COB / 4 {
                    1 => neon::reduce_tile_f32_neon::<1, TW>(flat, inp, ker, g),
                    2 => neon::reduce_tile_f32_neon::<2, TW>(flat, inp, ker, g),
                    4 => neon::reduce_tile_f32_neon::<4, TW>(flat, inp, ker, g),
                    _ => neon::reduce_tile_f32_neon::<8, TW>(flat, inp, ker, g),
                }
            }
            return;
        }
    }
    reduce_tile::<COB, TW>(acc, inp, ker, g);
}

/// View the accumulator tile as its flat `TW * COB` element storage
/// (`[[f32; COB]; TW]` is contiguous row-major by layout guarantee) —
/// how the SIMD kernels address it, since `[[T; COB / LANES]; TW]`
/// vector-array types cannot be expressed over `COB` on stable Rust.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
pub(crate) fn tile_as_flat<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
) -> &mut [f32] {
    // SAFETY: the array-of-arrays is exactly TW*COB adjacent f32s.
    unsafe { core::slice::from_raw_parts_mut(acc.as_mut_ptr().cast::<f32>(), TW * COB) }
}

/// Explicit AVX2 / AVX-512 `std::arch` twins of [`reduce_tile`].
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use super::TileGeom;
    use core::arch::x86_64::*;

    /// AVX2+FMA tile reduction over `NV` ymm registers per tile row
    /// (`COB = 8 * NV`). Each output lane's fused multiply-add chain
    /// runs in exactly the scalar `(n, m, ii)` order — vectorization
    /// widens only the independent `j` lane dimension — so the result
    /// is bitwise identical to [`super::reduce_tile`]
    /// (`_mm256_fmadd_ps` is lane-wise `f32::mul_add`).
    ///
    /// # Safety
    /// Caller must have runtime-detected `avx2` and `fma`, and `acc`
    /// must hold `TW * NV * 8` floats (the flat tile).
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn reduce_tile_f32_avx2<const NV: usize, const TW: usize>(
        acc: &mut [f32],
        inp: &[f32],
        ker: &[f32],
        g: &TileGeom,
    ) {
        let cob = NV * 8;
        debug_assert_eq!(acc.len(), TW * cob);
        let c_ib = g.c_ib;
        let row_stride = g.w_i * c_ib;
        let mut va = [[_mm256_setzero_ps(); NV]; TW];
        for kk in 0..TW {
            for v in 0..NV {
                va[kk][v] = _mm256_loadu_ps(acc.as_ptr().add(kk * cob + v * 8));
            }
        }
        for n in 0..g.h_f {
            let iy = (g.l * g.stride + n * g.dil) as isize - g.pad as isize;
            if iy < 0 || iy >= g.h_i as isize {
                continue;
            }
            let row = &inp[iy as usize * row_stride..][..row_stride];
            for m in 0..g.w_f {
                let kptr = &ker[(n * g.w_f + m) * c_ib * cob..][..c_ib * cob];
                let x0 = (g.k0 * g.stride + m * g.dil) as isize - g.pad as isize;
                let x_last = x0 + ((TW - 1) * g.stride) as isize;
                if x0 >= 0 && x_last < g.w_i as isize {
                    let base = x0 as usize * c_ib;
                    for ii in 0..c_ib {
                        let mut w = [_mm256_setzero_ps(); NV];
                        for v in 0..NV {
                            w[v] = _mm256_loadu_ps(kptr.as_ptr().add(ii * cob + v * 8));
                        }
                        for kk in 0..TW {
                            let xv = _mm256_set1_ps(row[base + kk * g.stride * c_ib + ii]);
                            for v in 0..NV {
                                va[kk][v] = _mm256_fmadd_ps(xv, w[v], va[kk][v]);
                            }
                        }
                    }
                } else {
                    for kk in 0..TW {
                        let x = x0 + (kk * g.stride) as isize;
                        if x < 0 || x >= g.w_i as isize {
                            continue;
                        }
                        let base = x as usize * c_ib;
                        for ii in 0..c_ib {
                            let xv = _mm256_set1_ps(row[base + ii]);
                            for v in 0..NV {
                                let w = _mm256_loadu_ps(kptr.as_ptr().add(ii * cob + v * 8));
                                va[kk][v] = _mm256_fmadd_ps(xv, w, va[kk][v]);
                            }
                        }
                    }
                }
            }
        }
        for kk in 0..TW {
            for v in 0..NV {
                _mm256_storeu_ps(acc.as_mut_ptr().add(kk * cob + v * 8), va[kk][v]);
            }
        }
    }

    /// AVX-512F tile reduction (`COB = 16 * NV`); same chain order and
    /// bitwise guarantee as the AVX2 variant. Feature-gated because
    /// the zmm intrinsics need a newer rustc than the crate's MSRV.
    ///
    /// # Safety
    /// Caller must have runtime-detected `avx512f`, and `acc` must
    /// hold `TW * NV * 16` floats.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn reduce_tile_f32_avx512<const NV: usize, const TW: usize>(
        acc: &mut [f32],
        inp: &[f32],
        ker: &[f32],
        g: &TileGeom,
    ) {
        let cob = NV * 16;
        debug_assert_eq!(acc.len(), TW * cob);
        let c_ib = g.c_ib;
        let row_stride = g.w_i * c_ib;
        let mut va = [[_mm512_setzero_ps(); NV]; TW];
        for kk in 0..TW {
            for v in 0..NV {
                va[kk][v] = _mm512_loadu_ps(acc.as_ptr().add(kk * cob + v * 16));
            }
        }
        for n in 0..g.h_f {
            let iy = (g.l * g.stride + n * g.dil) as isize - g.pad as isize;
            if iy < 0 || iy >= g.h_i as isize {
                continue;
            }
            let row = &inp[iy as usize * row_stride..][..row_stride];
            for m in 0..g.w_f {
                let kptr = &ker[(n * g.w_f + m) * c_ib * cob..][..c_ib * cob];
                let x0 = (g.k0 * g.stride + m * g.dil) as isize - g.pad as isize;
                let x_last = x0 + ((TW - 1) * g.stride) as isize;
                if x0 >= 0 && x_last < g.w_i as isize {
                    let base = x0 as usize * c_ib;
                    for ii in 0..c_ib {
                        let mut w = [_mm512_setzero_ps(); NV];
                        for v in 0..NV {
                            w[v] = _mm512_loadu_ps(kptr.as_ptr().add(ii * cob + v * 16));
                        }
                        for kk in 0..TW {
                            let xv = _mm512_set1_ps(row[base + kk * g.stride * c_ib + ii]);
                            for v in 0..NV {
                                va[kk][v] = _mm512_fmadd_ps(xv, w[v], va[kk][v]);
                            }
                        }
                    }
                } else {
                    for kk in 0..TW {
                        let x = x0 + (kk * g.stride) as isize;
                        if x < 0 || x >= g.w_i as isize {
                            continue;
                        }
                        let base = x as usize * c_ib;
                        for ii in 0..c_ib {
                            let xv = _mm512_set1_ps(row[base + ii]);
                            for v in 0..NV {
                                let w = _mm512_loadu_ps(kptr.as_ptr().add(ii * cob + v * 16));
                                va[kk][v] = _mm512_fmadd_ps(xv, w, va[kk][v]);
                            }
                        }
                    }
                }
            }
        }
        for kk in 0..TW {
            for v in 0..NV {
                _mm512_storeu_ps(acc.as_mut_ptr().add(kk * cob + v * 16), va[kk][v]);
            }
        }
    }
}

/// NEON `std::arch` twin of [`reduce_tile`] for aarch64.
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::TileGeom;
    use core::arch::aarch64::*;

    /// NEON tile reduction over `NV` q-registers per tile row
    /// (`COB = 4 * NV`); `vfmaq_f32` is lane-wise fused `mul_add`, and
    /// the chain order matches [`super::reduce_tile`], so results are
    /// bitwise identical to the scalar oracle.
    ///
    /// # Safety
    /// `acc` must hold `TW * NV * 4` floats (NEON itself is baseline
    /// on aarch64).
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn reduce_tile_f32_neon<const NV: usize, const TW: usize>(
        acc: &mut [f32],
        inp: &[f32],
        ker: &[f32],
        g: &TileGeom,
    ) {
        let cob = NV * 4;
        debug_assert_eq!(acc.len(), TW * cob);
        let c_ib = g.c_ib;
        let row_stride = g.w_i * c_ib;
        let mut va = [[vdupq_n_f32(0.0); NV]; TW];
        for kk in 0..TW {
            for v in 0..NV {
                va[kk][v] = vld1q_f32(acc.as_ptr().add(kk * cob + v * 4));
            }
        }
        for n in 0..g.h_f {
            let iy = (g.l * g.stride + n * g.dil) as isize - g.pad as isize;
            if iy < 0 || iy >= g.h_i as isize {
                continue;
            }
            let row = &inp[iy as usize * row_stride..][..row_stride];
            for m in 0..g.w_f {
                let kptr = &ker[(n * g.w_f + m) * c_ib * cob..][..c_ib * cob];
                let x0 = (g.k0 * g.stride + m * g.dil) as isize - g.pad as isize;
                let x_last = x0 + ((TW - 1) * g.stride) as isize;
                if x0 >= 0 && x_last < g.w_i as isize {
                    let base = x0 as usize * c_ib;
                    for ii in 0..c_ib {
                        let mut w = [vdupq_n_f32(0.0); NV];
                        for v in 0..NV {
                            w[v] = vld1q_f32(kptr.as_ptr().add(ii * cob + v * 4));
                        }
                        for kk in 0..TW {
                            let xv = vdupq_n_f32(row[base + kk * g.stride * c_ib + ii]);
                            for v in 0..NV {
                                va[kk][v] = vfmaq_f32(va[kk][v], xv, w[v]);
                            }
                        }
                    }
                } else {
                    for kk in 0..TW {
                        let x = x0 + (kk * g.stride) as isize;
                        if x < 0 || x >= g.w_i as isize {
                            continue;
                        }
                        let base = x as usize * c_ib;
                        for ii in 0..c_ib {
                            let xv = vdupq_n_f32(row[base + ii]);
                            for v in 0..NV {
                                let w = vld1q_f32(kptr.as_ptr().add(ii * cob + v * 4));
                                va[kk][v] = vfmaq_f32(va[kk][v], xv, w);
                            }
                        }
                    }
                }
            }
        }
        for kk in 0..TW {
            for v in 0..NV {
                vst1q_f32(acc.as_mut_ptr().add(kk * cob + v * 4), va[kk][v]);
            }
        }
    }
}

/// Load `TW` pencils of the accumulator tile from the blocked output.
#[inline(always)]
#[allow(clippy::manual_memcpy)] // explicit loop keeps the tile in registers
pub fn load_tile_c<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
    out: &[f32],
) {
    for kk in 0..TW {
        let src = &out[kk * COB..][..COB];
        for j in 0..COB {
            acc[kk][j] = src[j];
        }
    }
}

/// Store `TW` pencils of the accumulator tile back.
#[inline(always)]
#[allow(clippy::manual_memcpy)] // explicit loop keeps the tile in registers
pub fn store_tile_c<const COB: usize, const TW: usize>(
    acc: &[[f32; COB]; TW],
    out: &mut [f32],
) {
    for kk in 0..TW {
        let dst = &mut out[kk * COB..][..COB];
        for j in 0..COB {
            dst[j] = acc[kk][j];
        }
    }
}

// ---------------------------------------------------------------------
// Dynamic-width fallback (row-remainder tiles and tests).
// ---------------------------------------------------------------------

/// Load `tw` rows of the accumulator tile from the blocked output buffer.
#[inline(always)]
#[allow(clippy::manual_memcpy)] // explicit loop keeps the tile in registers
pub fn load_tile<const COB: usize>(acc: &mut AccTile<COB>, out: &[f32], tw: usize) {
    for kk in 0..tw {
        let src = &out[kk * COB..][..COB];
        for j in 0..COB {
            acc[kk][j] = src[j];
        }
    }
}

/// Store `tw` rows of the accumulator tile back to the blocked output.
#[inline(always)]
#[allow(clippy::manual_memcpy)] // explicit loop keeps the tile in registers
pub fn store_tile<const COB: usize>(acc: &AccTile<COB>, out: &mut [f32], tw: usize) {
    for kk in 0..tw {
        let dst = &mut out[kk * COB..][..COB];
        for j in 0..COB {
            dst[j] = acc[kk][j];
        }
    }
}

/// Apply one kernel tap over a full input-channel block (interior fast
/// path, dynamic tile width).
///
/// * `inp` — input pencils for this tap: element `(kk, ii)` is at
///   `inp[kk * x_stride + ii]` with `x_stride = stride * c_ib`.
/// * `ker` — `c_ib` weight pencils of `COB` each (`[C_ib][C_ob]`).
#[inline(always)]
pub fn tap_full<const COB: usize>(
    acc: &mut AccTile<COB>,
    inp: &[f32],
    ker: &[f32],
    c_ib: usize,
    x_stride: usize,
    tw: usize,
) {
    for ii in 0..c_ib {
        let w = &ker[ii * COB..][..COB];
        for kk in 0..tw {
            let xv = inp[kk * x_stride + ii];
            let a = &mut acc[kk];
            for j in 0..COB {
                a[j] = xv.mul_add(w[j], a[j]);
            }
        }
    }
}

/// Apply one kernel tap to a single tile column (edge slow path).
#[inline(always)]
pub fn tap_one_col<const COB: usize>(
    acc: &mut [f32; COB],
    inp: &[f32],
    ker: &[f32],
    c_ib: usize,
) {
    for ii in 0..c_ib {
        let w = &ker[ii * COB..][..COB];
        let xv = inp[ii];
        for j in 0..COB {
            acc[j] = xv.mul_add(w[j], acc[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_round_trip() {
        let mut buf: Vec<f32> = (0..4 * 8).map(|i| i as f32).collect();
        let mut acc: AccTile<8> = [[0.0; 8]; MAX_WOB];
        load_tile::<8>(&mut acc, &buf, 4);
        assert_eq!(acc[0][0], 0.0);
        assert_eq!(acc[3][7], 31.0);
        for row in acc.iter_mut().take(4) {
            for v in row.iter_mut() {
                *v += 1.0;
            }
        }
        store_tile::<8>(&acc, &mut buf, 4);
        assert_eq!(buf[0], 1.0);
        assert_eq!(buf[31], 32.0);
    }

    #[test]
    fn const_load_store_round_trip() {
        let mut buf: Vec<f32> = (0..3 * 4).map(|i| i as f32).collect();
        let mut acc = [[0.0f32; 4]; 3];
        load_tile_c::<4, 3>(&mut acc, &buf);
        assert_eq!(acc[2][3], 11.0);
        acc[1][0] = 99.0;
        store_tile_c::<4, 3>(&acc, &mut buf);
        assert_eq!(buf[4], 99.0);
    }

    #[test]
    fn tap_full_accumulates_correctly() {
        // 2 input channels, 3 tile columns, COB=4, stride 1.
        let c_ib = 2;
        let tw = 3;
        let inp = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let ker = [0.5, 0.5, 0.5, 0.5, 2.0, 2.0, 2.0, 2.0];
        let mut acc: AccTile<4> = [[0.0; 4]; MAX_WOB];
        tap_full::<4>(&mut acc, &inp, &ker, c_ib, c_ib, tw);
        for kk in 0..tw {
            let want = 0.5 * inp[kk * 2] + 2.0 * inp[kk * 2 + 1];
            for j in 0..4 {
                assert!((acc[kk][j] - want).abs() < 1e-6);
            }
        }
        assert_eq!(acc[3], [0.0; 4]);
    }

    #[test]
    fn tap_full_respects_x_stride() {
        let inp = [10.0, 99.0, 20.0, 99.0, 30.0];
        let ker = [1.0, 1.0];
        let mut acc: AccTile<2> = [[0.0; 2]; MAX_WOB];
        tap_full::<2>(&mut acc, &inp, &ker, 1, 2, 3);
        assert_eq!(acc[0], [10.0, 10.0]);
        assert_eq!(acc[1], [20.0, 20.0]);
        assert_eq!(acc[2], [30.0, 30.0]);
    }

    #[test]
    fn tap_one_col_matches_full() {
        let c_ib = 3;
        let inp = [1.0, -2.0, 0.5];
        let ker: Vec<f32> = (0..3 * 4).map(|i| i as f32 * 0.25).collect();
        let mut a: [f32; 4] = [0.0; 4];
        tap_one_col::<4>(&mut a, &inp, &ker, c_ib);
        let mut acc: AccTile<4> = [[0.0; 4]; MAX_WOB];
        tap_full::<4>(&mut acc, &inp, &ker, c_ib, c_ib, 1);
        assert_eq!(a, acc[0]);
    }

    #[test]
    fn reduce_tile_matches_manual() {
        // 1x1 image region semantics: 2x2 kernel over a 4x4 single-channel
        // image, tile of TW=2 at l=0, k0=0, stride 1, no pad.
        let g = TileGeom {
            h_f: 2,
            w_f: 2,
            c_ib: 1,
            h_i: 4,
            w_i: 4,
            stride: 1,
            pad: 0,
            dil: 1,
            l: 0,
            k0: 0,
        };
        let inp: Vec<f32> = (0..16).map(|v| v as f32).collect();
        // kernel [2][2][1][2]: tap (n,m) weight = (n*2+m+1) for both lanes
        let ker = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 4.0, 4.0];
        let mut acc = [[0.0f32; 2]; 2];
        reduce_tile::<2, 2>(&mut acc, &inp, &ker, &g);
        // out[k] = 1*in[0][k] + 2*in[0][k+1] + 3*in[1][k] + 4*in[1][k+1]
        for k in 0..2 {
            let want = 1.0 * inp[k] + 2.0 * inp[k + 1] + 3.0 * inp[4 + k] + 4.0 * inp[4 + k + 1];
            assert_eq!(acc[k][0], want);
            assert_eq!(acc[k][1], want);
        }
    }

    #[test]
    fn reduce_tile_skips_padding() {
        // pad=1: at l=0 the n=0 kernel row is outside; at k0=0 the m=0
        // column of kk=0 is outside.
        let g = TileGeom {
            h_f: 3,
            w_f: 3,
            c_ib: 1,
            h_i: 3,
            w_i: 3,
            stride: 1,
            pad: 1,
            dil: 1,
            l: 0,
            k0: 0,
        };
        let inp = [1.0f32; 9];
        let ker = [1.0f32; 9]; // COB = 1
        let mut acc = [[0.0f32; 1]; 3];
        reduce_tile::<1, 3>(&mut acc, &inp, &ker, &g);
        // corner output: 2x2 valid taps; top edge: 2x3; corner: 2x2
        assert_eq!(acc[0][0], 4.0);
        assert_eq!(acc[1][0], 6.0);
        assert_eq!(acc[2][0], 4.0);
    }

    #[test]
    fn reduce_tile_dilation_spaces_taps() {
        // 2x2 kernel, dilation 2 over a 5x5 ramp image: taps land on
        // (0,0),(0,2),(2,0),(2,2) for output (0,0).
        let g = TileGeom {
            h_f: 2,
            w_f: 2,
            c_ib: 1,
            h_i: 5,
            w_i: 5,
            stride: 1,
            pad: 0,
            dil: 2,
            l: 0,
            k0: 0,
        };
        let inp: Vec<f32> = (0..25).map(|v| v as f32).collect();
        let ker = [1.0f32, 2.0, 3.0, 4.0]; // COB = 1, taps (0,0),(0,1),(1,0),(1,1)
        let mut acc = [[0.0f32; 1]; 2];
        reduce_tile::<1, 2>(&mut acc, &inp, &ker, &g);
        for k in 0..2 {
            let want = 1.0 * inp[k] + 2.0 * inp[k + 2] + 3.0 * inp[10 + k] + 4.0 * inp[10 + k + 2];
            assert_eq!(acc[k][0], want);
        }
    }

    /// Seeded pseudo-random fill (no external crates; LCG is plenty).
    fn fill(buf: &mut [f32], mut state: u64) {
        for v in buf.iter_mut() {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *v = ((state >> 40) as i32 - (1 << 23)) as f32 / (1 << 20) as f32;
        }
    }

    /// The whole SIMD story rests on this: whatever kernel
    /// `reduce_tile_auto` dispatches to must be *bitwise* equal to the
    /// scalar oracle, across interior, border and strided/dilated
    /// tiles. On hosts without vector units this degenerates to
    /// oracle-vs-oracle and still guards the dispatch plumbing.
    #[test]
    fn reduce_tile_auto_is_bitwise_equal_to_oracle() {
        const COB: usize = 16; // 2 ymm / 1 zmm / 4 q-regs per row
        const TW: usize = 4;
        let g0 = TileGeom {
            h_f: 3,
            w_f: 3,
            c_ib: 5,
            h_i: 9,
            w_i: 11,
            stride: 2,
            pad: 2,
            dil: 2,
            l: 0,
            k0: 0,
        };
        let mut inp = vec![0.0f32; g0.h_i * g0.w_i * g0.c_ib];
        let mut ker = vec![0.0f32; g0.h_f * g0.w_f * g0.c_ib * COB];
        fill(&mut inp, 0x5eed);
        fill(&mut ker, 0xf00d);
        for (l, k0) in [(0, 0), (1, 0), (2, 1), (3, 2)] {
            let g = TileGeom { l, k0, ..g0 };
            let mut want = [[0.1f32; COB]; TW];
            let mut got = want;
            reduce_tile::<COB, TW>(&mut want, &inp, &ker, &g);
            reduce_tile_auto::<COB, TW>(&mut got, &inp, &ker, &g);
            for kk in 0..TW {
                for j in 0..COB {
                    assert_eq!(
                        want[kk][j].to_bits(),
                        got[kk][j].to_bits(),
                        "lane ({kk},{j}) at l={l} k0={k0}"
                    );
                }
            }
        }
    }

    /// Narrow blocks (no whole vector) must fall back to the oracle.
    #[test]
    fn reduce_tile_auto_falls_back_on_narrow_blocks() {
        let g = TileGeom {
            h_f: 2,
            w_f: 2,
            c_ib: 3,
            h_i: 6,
            w_i: 6,
            stride: 1,
            pad: 0,
            dil: 1,
            l: 1,
            k0: 1,
        };
        let mut inp = vec![0.0f32; g.h_i * g.w_i * g.c_ib];
        let mut ker = vec![0.0f32; g.h_f * g.w_f * g.c_ib * 2];
        fill(&mut inp, 7);
        fill(&mut ker, 11);
        let mut want = [[0.0f32; 2]; 3];
        let mut got = want;
        reduce_tile::<2, 3>(&mut want, &inp, &ker, &g);
        reduce_tile_auto::<2, 3>(&mut got, &inp, &ker, &g);
        assert_eq!(want, got);
    }
}

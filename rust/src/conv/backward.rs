//! Backward convolution — the paper's §6 future-work direction
//! ("optimize the backward process to update both image and kernel...
//! only minor changes to the loop ordering are required").
//!
//! Two gradients, both in the same loop-reordered, channel-last style as
//! Algorithm 2 (the register/cache blocking of Algorithm 3 applies
//! identically; the oracle-grade versions here are the reference the
//! blocked variants would be tested against):
//!
//! * [`conv_backward_input`] — `dL/dI`: correlation of the output
//!   gradient with the *spatially flipped* kernel, with stride handled
//!   by input dilation (transposed convolution);
//! * [`conv_backward_kernel`] — `dL/dF`: a correlation of the input with
//!   the output gradient over the spatial dims, reduced per `(i, j)`.

use super::ConvShape;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// `dL/dI` for `out = conv(input, kernel)` (Algorithm-1 semantics).
/// `grad_out` is `[C_o][H_o][W_o]`; returns `[C_i][H_i][W_i]`.
pub fn conv_backward_input(
    grad_out: &Tensor,
    kernel: &Tensor,
    shape: &ConvShape,
) -> Result<Tensor> {
    shape.validate()?;
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    if grad_out.shape() != [shape.c_o, h_o, w_o] {
        return Err(Error::Shape(format!(
            "grad_out shape {:?} != expected {:?}",
            grad_out.shape(),
            [shape.c_o, h_o, w_o]
        )));
    }
    if kernel.shape() != [shape.c_o, shape.c_i, shape.h_f, shape.w_f] {
        return Err(Error::Shape("kernel shape mismatch".into()));
    }
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (c_o, h_f, w_f) = (shape.c_o, shape.h_f, shape.w_f);
    let (s, p) = (shape.stride, shape.pad as isize);
    let go = grad_out.data();
    let ker = kernel.data();
    let mut gi = Tensor::zeros(&[c_i, h_i, w_i]);
    let gid = gi.data_mut();

    // dI[i][y][x] = sum_{j,n,m : y = l*s + n - p, x = k*s + m - p}
    //              dO[j][l][k] * F[j][i][n][m]
    // Iterate the forward loop nest and scatter — the reordering
    // (l, n, m, i, k, j) keeps the j reduction innermost.
    for l in 0..h_o {
        for n in 0..h_f {
            let y = (l * s + n) as isize - p;
            if y < 0 || y >= h_i as isize {
                continue;
            }
            for m in 0..w_f {
                for i in 0..c_i {
                    for k in 0..w_o {
                        let x = (k * s + m) as isize - p;
                        if x < 0 || x >= w_i as isize {
                            continue;
                        }
                        let mut acc = 0.0f32;
                        for j in 0..c_o {
                            acc += go[(j * h_o + l) * w_o + k]
                                * ker[((j * c_i + i) * h_f + n) * w_f + m];
                        }
                        gid[(i * h_i + y as usize) * w_i + x as usize] += acc;
                    }
                }
            }
        }
    }
    Ok(gi)
}

/// `dL/dF` for `out = conv(input, kernel)`.
/// Returns `[C_o][C_i][H_f][W_f]`.
pub fn conv_backward_kernel(
    input: &Tensor,
    grad_out: &Tensor,
    shape: &ConvShape,
) -> Result<Tensor> {
    shape.validate()?;
    let kshape = [shape.c_o, shape.c_i, shape.h_f, shape.w_f];
    super::naive::check_shapes(input, &Tensor::zeros(&kshape), shape)?;
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    if grad_out.shape() != [shape.c_o, h_o, w_o] {
        return Err(Error::Shape("grad_out shape mismatch".into()));
    }
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (c_o, h_f, w_f) = (shape.c_o, shape.h_f, shape.w_f);
    let (s, p) = (shape.stride, shape.pad as isize);
    let inp = input.data();
    let go = grad_out.data();
    let mut gk = Tensor::zeros(&[c_o, c_i, h_f, w_f]);
    let gkd = gk.data_mut();

    // dF[j][i][n][m] = sum_{l,k} dO[j][l][k] * I[i][l*s+n-p][k*s+m-p]
    for n in 0..h_f {
        for m in 0..w_f {
            for l in 0..h_o {
                let y = (l * s + n) as isize - p;
                if y < 0 || y >= h_i as isize {
                    continue;
                }
                for k in 0..w_o {
                    let x = (k * s + m) as isize - p;
                    if x < 0 || x >= w_i as isize {
                        continue;
                    }
                    for i in 0..c_i {
                        let xv = inp[(i * h_i + y as usize) * w_i + x as usize];
                        for j in 0..c_o {
                            gkd[((j * c_i + i) * h_f + n) * w_f + m] +=
                                go[(j * h_o + l) * w_o + k] * xv;
                        }
                    }
                }
            }
        }
    }
    Ok(gk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;
    use crate::tensor::XorShiftRng;

    /// <conv(x), gy> == <x, conv_backward_input(gy)> — the adjoint
    /// identity that defines the input gradient exactly.
    #[test]
    fn adjoint_identity_input() {
        let mut rng = XorShiftRng::new(77);
        for s in [
            ConvShape::new(3, 8, 8, 4, 3, 3, 1, 0),
            ConvShape::new(2, 9, 7, 5, 3, 3, 1, 1),
            ConvShape::new(4, 11, 11, 2, 5, 5, 2, 2),
        ] {
            let x = Tensor::random(&[s.c_i, s.h_i, s.w_i], rng.next_u64());
            let k = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], rng.next_u64());
            let gy = Tensor::random(&[s.c_o, s.h_o(), s.w_o()], rng.next_u64());
            let y = conv_naive(&x, &k, &s).unwrap();
            let gx = conv_backward_input(&gy, &k, &s).unwrap();
            let lhs: f64 = y.data().iter().zip(gy.data()).map(|(a, b)| (a * b) as f64).sum();
            let rhs: f64 = x.data().iter().zip(gx.data()).map(|(a, b)| (a * b) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "{s:?}: {lhs} vs {rhs}"
            );
        }
    }

    /// Finite-difference check of the kernel gradient.
    #[test]
    fn kernel_gradient_matches_finite_difference() {
        let s = ConvShape::new(2, 6, 6, 3, 3, 3, 1, 1);
        let x = Tensor::random(&[s.c_i, s.h_i, s.w_i], 1);
        let mut k = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 2);
        let gy = Tensor::random(&[s.c_o, s.h_o(), s.w_o()], 3);
        let gk = conv_backward_kernel(&x, &gy, &s).unwrap();
        let loss = |k: &Tensor| -> f64 {
            let y = conv_naive(&x, k, &s).unwrap();
            y.data().iter().zip(gy.data()).map(|(a, b)| (a * b) as f64).sum()
        };
        let eps = 1e-3f32;
        let mut rng = XorShiftRng::new(9);
        for _ in 0..10 {
            let idx = rng.next_usize(k.len());
            let orig = k.data()[idx];
            k.data_mut()[idx] = orig + eps;
            let up = loss(&k);
            k.data_mut()[idx] = orig - eps;
            let down = loss(&k);
            k.data_mut()[idx] = orig;
            let fd = (up - down) / (2.0 * eps as f64);
            let an = gk.data()[idx] as f64;
            assert!((fd - an).abs() < 1e-2 * an.abs().max(1.0), "idx {idx}: fd {fd} vs {an}");
        }
    }

    /// Finite-difference check of the input gradient.
    #[test]
    fn input_gradient_matches_finite_difference() {
        let s = ConvShape::new(2, 6, 6, 3, 3, 3, 2, 1);
        let mut x = Tensor::random(&[s.c_i, s.h_i, s.w_i], 4);
        let k = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 5);
        let gy = Tensor::random(&[s.c_o, s.h_o(), s.w_o()], 6);
        let gx = conv_backward_input(&gy, &k, &s).unwrap();
        let loss = |x: &Tensor| -> f64 {
            let y = conv_naive(x, &k, &s).unwrap();
            y.data().iter().zip(gy.data()).map(|(a, b)| (a * b) as f64).sum()
        };
        let eps = 1e-3f32;
        let mut rng = XorShiftRng::new(10);
        for _ in 0..10 {
            let idx = rng.next_usize(x.len());
            let orig = x.data()[idx];
            x.data_mut()[idx] = orig + eps;
            let up = loss(&x);
            x.data_mut()[idx] = orig - eps;
            let down = loss(&x);
            x.data_mut()[idx] = orig;
            let fd = (up - down) / (2.0 * eps as f64);
            let an = gx.data()[idx] as f64;
            assert!((fd - an).abs() < 1e-2 * an.abs().max(1.0), "idx {idx}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn shape_validation() {
        let s = ConvShape::new(2, 6, 6, 3, 3, 3, 1, 0);
        let bad_gy = Tensor::zeros(&[3, 5, 5]);
        let k = Tensor::zeros(&[3, 2, 3, 3]);
        assert!(conv_backward_input(&bad_gy, &k, &s).is_err());
        let x = Tensor::zeros(&[2, 6, 6]);
        assert!(conv_backward_kernel(&x, &bad_gy, &s).is_err());
    }
}

//! Fused convolution epilogues.
//!
//! An [`Epilogue`] describes the element-wise tail a convolution applies
//! to its accumulator tile **before** storing it — the fusion target of
//! conv→bias / conv→batch-norm / conv→ReLU / conv→residual-Add chains
//! (see `nets::fuse`). Applying the tail inside the register tile means
//! the unfused intermediate is never materialized, so fused networks
//! keep the paper's zero-memory-overhead accounting intact:
//! `workspace_bytes()` stays 0 and the epilogue parameters are model
//! parameters (like the weights), not overhead.
//!
//! Application order is fixed (and shared by every execution path —
//! in-tile, the generic [`apply_post`] fallback, and the standalone
//! `Relu`/`BatchNorm` graph ops executed through the runner's Adapt
//! gathers — so fused and unfused composes agree **bitwise** in f32):
//!
//! 1. per-channel scale (`y = y * scale[c]`) — batch-norm, pre-folded to
//!    `gamma / sqrt(var + eps)`;
//! 2. per-channel shift (`y = y + shift[c]`) — bias, or the folded
//!    batch-norm `beta - mean * scale`;
//! 3. residual add (`y = y + r`) — the fused shortcut operand, in the
//!    same layout as the output;
//! 4. ReLU (`y = max(0, y)`), with an optional upper clamp (ReLU6-style
//!    `y = min(clamp, y)`).
//!
//! Scale and shift are applied as two separately-rounded f32 ops (mul
//! then add, no FMA contraction) so every path produces identical bits.

use crate::layout::IoLayout;
use crate::{Error, Result};

/// The fused post-op tail of one convolution. `Epilogue::none()` is the
/// identity (and the hot paths skip all epilogue work entirely for it).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Epilogue {
    /// Per-output-channel multiplier (len `c_o`); empty = no scaling.
    pub scale: Vec<f32>,
    /// Per-output-channel addend (len `c_o`); empty = no shift.
    pub shift: Vec<f32>,
    /// Add a residual operand (caller supplies it in the output layout).
    pub residual: bool,
    /// `max(0, y)` after scale/shift/residual.
    pub relu: bool,
    /// Optional upper clamp (requires `relu`).
    pub clamp: Option<f32>,
}

impl Epilogue {
    /// The identity epilogue.
    pub const fn none() -> Epilogue {
        Epilogue { scale: Vec::new(), shift: Vec::new(), residual: false, relu: false, clamp: None }
    }

    /// True when this epilogue is the identity (fast-path skip).
    pub fn is_none(&self) -> bool {
        self.scale.is_empty()
            && self.shift.is_empty()
            && !self.residual
            && !self.relu
            && self.clamp.is_none()
    }

    /// Bias-only epilogue (per-channel shift).
    pub fn bias(shift: Vec<f32>) -> Epilogue {
        Epilogue { shift, ..Epilogue::none() }
    }

    /// Pre-folded batch-norm scale/shift epilogue.
    pub fn bn(scale: Vec<f32>, shift: Vec<f32>) -> Epilogue {
        Epilogue { scale, shift, ..Epilogue::none() }
    }

    /// Add a trailing ReLU (optionally clamped).
    pub fn with_relu(mut self, clamp: Option<f32>) -> Epilogue {
        self.relu = true;
        self.clamp = clamp;
        self
    }

    /// Add a fused residual operand.
    pub fn with_residual(mut self) -> Epilogue {
        self.residual = true;
        self
    }

    /// Validate against the conv's output channel count.
    pub fn validate(&self, c_o: usize) -> Result<()> {
        if !self.scale.is_empty() && self.scale.len() != c_o {
            return Err(Error::Shape(format!(
                "epilogue scale has {} channels, conv has {c_o}",
                self.scale.len()
            )));
        }
        if !self.shift.is_empty() && self.shift.len() != c_o {
            return Err(Error::Shape(format!(
                "epilogue shift has {} channels, conv has {c_o}",
                self.shift.len()
            )));
        }
        if self.clamp.is_some() && !self.relu {
            return Err(Error::Shape("epilogue clamp requires relu".into()));
        }
        if let Some(c) = self.clamp {
            if !c.is_finite() || c <= 0.0 {
                return Err(Error::Shape(format!("epilogue clamp {c} must be finite and > 0")));
            }
        }
        Ok(())
    }

    /// Borrowed per-channel-range view (used by the grouped kernels,
    /// which see a `[c0, c0+n)` slice of the output channels).
    pub fn view(&self, c0: usize, n: usize) -> EpView<'_> {
        EpView {
            scale: if self.scale.is_empty() { &[] } else { &self.scale[c0..c0 + n] },
            shift: if self.shift.is_empty() { &[] } else { &self.shift[c0..c0 + n] },
            relu: self.relu,
            clamp: self.clamp,
        }
    }

    /// Bytes of the per-channel parameter vectors (model parameters,
    /// reported by accounting surfaces alongside the weights).
    pub fn param_bytes(&self) -> u64 {
        4 * (self.scale.len() + self.shift.len()) as u64
    }
}

/// Borrowed view of an [`Epilogue`]'s channel-dependent pieces, offset
/// to a channel range (the residual operand is passed separately as an
/// `Option<&[f32]>` aligned with the output slice).
#[derive(Clone, Copy, Debug)]
pub struct EpView<'a> {
    pub scale: &'a [f32],
    pub shift: &'a [f32],
    pub relu: bool,
    pub clamp: Option<f32>,
}

impl EpView<'_> {
    /// True when this view carries any work (an inactive view means the
    /// tile stores straight back, zero-cost).
    #[inline(always)]
    pub fn is_active(&self) -> bool {
        !self.scale.is_empty() || !self.shift.is_empty() || self.relu
    }

    /// Apply the channel-dependent tail to one value of channel `c`
    /// (relative to this view's base); `r` is the residual addend.
    /// This is THE scalar semantic every execution path shares.
    #[inline(always)]
    pub fn apply(&self, mut v: f32, c: usize, r: Option<f32>) -> f32 {
        if !self.scale.is_empty() {
            v *= self.scale[c];
        }
        if !self.shift.is_empty() {
            v += self.shift[c];
        }
        if let Some(r) = r {
            v += r;
        }
        if self.relu {
            v = v.max(0.0);
            if let Some(cl) = self.clamp {
                v = v.min(cl);
            }
        }
        v
    }
}

/// Apply an epilogue view to a register tile (channel base `c0` relative
/// to the view; `res` aligned with the tile; `tw` rows live — `tw == TW`
/// on full tiles, narrower on the monomorphized remainder path).
#[inline(always)]
pub fn apply_tile<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
    ep: &EpView<'_>,
    c0: usize,
    res: Option<&[f32]>,
    tw: usize,
) {
    for kk in 0..tw {
        for j in 0..COB {
            let r = res.map(|r| r[kk * COB + j]);
            acc[kk][j] = ep.apply(acc[kk][j], c0 + j, r);
        }
    }
}

/// Runtime-dispatched [`apply_tile`]: when the reduction ran on a
/// vector tile (AVX2/AVX-512 host, vector-width `COB`) the epilogue
/// runs on that same tile vectorized — identical ops in identical
/// order to [`EpView::apply`] (separate mul and add, lane-wise
/// max/min), so fused and unfused paths stay bitwise-equal.
#[inline(always)]
pub fn apply_tile_auto<const COB: usize, const TW: usize>(
    acc: &mut [[f32; COB]; TW],
    ep: &EpView<'_>,
    c0: usize,
    res: Option<&[f32]>,
    tw: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        use super::dispatch::{active, SimdLevel};
        if matches!(active(), SimdLevel::Avx2 | SimdLevel::Avx512) && COB % 8 == 0 {
            // SAFETY: AVX2 runtime-detected; the flat view is the
            // tile's contiguous TW*COB storage and the channel range
            // c0..c0+COB is in-bounds for the view's vectors (the
            // scalar path indexes the same range).
            unsafe {
                apply_tile_avx2(
                    super::microkernel::tile_as_flat::<COB, TW>(acc),
                    COB,
                    ep,
                    c0,
                    res,
                    tw,
                );
            }
            return;
        }
    }
    apply_tile::<COB, TW>(acc, ep, c0, res, tw);
}

/// AVX2 epilogue over the flat accumulator tile (`tw` live rows of
/// `cob` channels). Not monomorphized: it runs once per tile, so the
/// dynamic loops cost nothing next to the reduction.
///
/// Bitwise notes: the mul and add stay separate (no FMA contraction,
/// matching [`EpView::apply`]); `_mm256_max_ps(v, 0)`/`min_ps(v, cl)`
/// return the second operand on NaN exactly like `f32::max`/`min`
/// with this argument order, and a `-0.0`-vs-`+0.0` divergence at the
/// ReLU knee compares equal under `f32 == f32`.
///
/// # Safety
/// Caller must have runtime-detected `avx2`; `acc` holds at least
/// `tw * cob` floats, `cob % 8 == 0`, `ep`'s non-empty vectors cover
/// `c0 + cob` channels, and `res` (if present) covers `tw * cob`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn apply_tile_avx2(
    acc: &mut [f32],
    cob: usize,
    ep: &EpView<'_>,
    c0: usize,
    res: Option<&[f32]>,
    tw: usize,
) {
    use core::arch::x86_64::*;
    debug_assert!(ep.scale.is_empty() || c0 + cob <= ep.scale.len());
    debug_assert!(ep.shift.is_empty() || c0 + cob <= ep.shift.len());
    debug_assert!(res.map_or(true, |r| r.len() >= tw * cob));
    let zero = _mm256_setzero_ps();
    for kk in 0..tw {
        for v in 0..cob / 8 {
            let at = kk * cob + v * 8;
            let mut y = _mm256_loadu_ps(acc.as_ptr().add(at));
            if !ep.scale.is_empty() {
                y = _mm256_mul_ps(y, _mm256_loadu_ps(ep.scale.as_ptr().add(c0 + v * 8)));
            }
            if !ep.shift.is_empty() {
                y = _mm256_add_ps(y, _mm256_loadu_ps(ep.shift.as_ptr().add(c0 + v * 8)));
            }
            if let Some(r) = res {
                y = _mm256_add_ps(y, _mm256_loadu_ps(r.as_ptr().add(at)));
            }
            if ep.relu {
                y = _mm256_max_ps(y, zero);
                if let Some(cl) = ep.clamp {
                    y = _mm256_min_ps(y, _mm256_set1_ps(cl));
                }
            }
            _mm256_storeu_ps(acc.as_mut_ptr().add(at), y);
        }
    }
}

/// Apply an epilogue over an already-computed output buffer — the
/// layout-aware fallback used by backends without in-tile fusion (the
/// default `ConvPlan::execute_fused_into`). `res`, when present, must
/// be in the same layout as `out`. In-place, allocation-free; bitwise
/// identical to the in-tile application (same scalar ops, same order).
pub fn apply_post(
    out: &mut [f32],
    layout: IoLayout,
    c_o: usize,
    hw: usize,
    ep: &Epilogue,
    res: Option<&[f32]>,
) -> Result<()> {
    ep.validate(c_o)?;
    if out.len() != c_o * hw {
        return Err(Error::Shape(format!(
            "epilogue output has {} elements, expected {}",
            out.len(),
            c_o * hw
        )));
    }
    if ep.residual != res.is_some() {
        return Err(Error::Shape("epilogue residual operand mismatch".into()));
    }
    if let Some(r) = res {
        if r.len() != out.len() {
            return Err(Error::Shape(format!(
                "epilogue residual has {} elements, expected {}",
                r.len(),
                out.len()
            )));
        }
    }
    if ep.is_none() {
        return Ok(());
    }
    let v = ep.view(0, c_o);
    match layout {
        IoLayout::Nchw => {
            for c in 0..c_o {
                let base = c * hw;
                for i in 0..hw {
                    let r = res.map(|r| r[base + i]);
                    out[base + i] = v.apply(out[base + i], c, r);
                }
            }
        }
        IoLayout::Nhwc => {
            for i in 0..hw {
                let base = i * c_o;
                for c in 0..c_o {
                    let r = res.map(|r| r[base + c]);
                    out[base + c] = v.apply(out[base + c], c, r);
                }
            }
        }
        IoLayout::Blocked { c_b } => {
            if c_o % c_b != 0 {
                return Err(Error::Shape(format!(
                    "epilogue blocked layout c_b={c_b} does not divide c_o={c_o}"
                )));
            }
            for cb in 0..c_o / c_b {
                let base_c = cb * c_b;
                let base = cb * hw * c_b;
                for i in 0..hw {
                    for j in 0..c_b {
                        let idx = base + i * c_b + j;
                        let r = res.map(|r| r[idx]);
                        out[idx] = v.apply(out[idx], base_c + j, r);
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_none() {
        assert!(Epilogue::none().is_none());
        assert!(Epilogue::default().is_none());
        assert!(!Epilogue::bias(vec![1.0]).is_none());
        assert!(!Epilogue::none().with_relu(None).is_none());
    }

    #[test]
    fn validate_checks_lengths_and_clamp() {
        assert!(Epilogue::bias(vec![0.0; 4]).validate(4).is_ok());
        assert!(Epilogue::bias(vec![0.0; 3]).validate(4).is_err());
        assert!(Epilogue::bn(vec![1.0; 4], vec![0.0; 3]).validate(4).is_err());
        let mut ep = Epilogue::none();
        ep.clamp = Some(6.0);
        assert!(ep.validate(4).is_err(), "clamp without relu");
        assert!(Epilogue::none().with_relu(Some(0.0)).validate(4).is_err());
        assert!(Epilogue::none().with_relu(Some(6.0)).validate(4).is_ok());
    }

    #[test]
    fn scalar_order_scale_shift_res_relu() {
        let ep = Epilogue::bn(vec![2.0], vec![-3.0]).with_relu(Some(6.0));
        let v = ep.view(0, 1);
        // 4*2 - 3 = 5 -> relu -> 5; +res 4 would clamp at 6.
        assert_eq!(v.apply(4.0, 0, None), 5.0);
        assert_eq!(v.apply(4.0, 0, Some(4.0)), 6.0);
        assert_eq!(v.apply(-4.0, 0, None), 0.0);
    }

    #[test]
    fn apply_post_layouts_agree() {
        // 2 channels, 2x2 spatial, channel-dependent scale/shift.
        let ep = Epilogue::bn(vec![1.0, -1.0], vec![0.5, 0.25]).with_relu(None);
        let nchw: Vec<f32> = vec![1.0, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0];
        let res_nchw: Vec<f32> = (0..8).map(|i| i as f32 * 0.125).collect();
        let mut ep_r = ep.clone();
        ep_r.residual = true;

        let mut a = nchw.clone();
        apply_post(&mut a, IoLayout::Nchw, 2, 4, &ep_r, Some(&res_nchw)).unwrap();

        // NHWC permutation of the same data + residual.
        let to_nhwc = |v: &[f32]| -> Vec<f32> {
            (0..4).flat_map(|i| (0..2).map(move |c| v[c * 4 + i])).collect()
        };
        let mut b = to_nhwc(&nchw);
        let res_nhwc = to_nhwc(&res_nchw);
        apply_post(&mut b, IoLayout::Nhwc, 2, 4, &ep_r, Some(&res_nhwc)).unwrap();
        assert_eq!(to_nhwc(&a), b);

        // Blocked c_b=2 == NHWC here (single block).
        let mut c = to_nhwc(&nchw);
        apply_post(&mut c, IoLayout::Blocked { c_b: 2 }, 2, 4, &ep_r, Some(&res_nhwc)).unwrap();
        assert_eq!(b, c);
    }

    #[test]
    fn apply_post_rejects_mismatches() {
        let mut out = vec![0.0; 8];
        let ep = Epilogue::bias(vec![0.0; 2]);
        assert!(apply_post(&mut out, IoLayout::Nchw, 2, 4, &ep, Some(&out.clone())).is_err());
        let mut ep_r = ep.clone();
        ep_r.residual = true;
        assert!(apply_post(&mut out, IoLayout::Nchw, 2, 4, &ep_r, None).is_err());
        let short = vec![0.0; 4];
        assert!(apply_post(&mut out, IoLayout::Nchw, 2, 4, &ep_r, Some(&short)).is_err());
    }

    #[test]
    fn view_offsets_channel_ranges() {
        let ep = Epilogue::bn((0..8).map(|c| c as f32).collect(), vec![0.0; 8]);
        let v = ep.view(4, 4);
        assert_eq!(v.apply(1.0, 0, None), 4.0);
        assert_eq!(v.apply(1.0, 3, None), 7.0);
    }
}

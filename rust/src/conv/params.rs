//! Analytical blocking-parameter selection.
//!
//! The paper selects `C_o,b`, `W_o,b` and `C_i,b` from the machine model
//! (following the analytical BLIS methodology of Low et al. 2016) rather
//! than by autotuning:
//!
//! * eq. 1 — `E = C_o,b * W_o,b >= N_vec * N_fma * L_fma` so every FMA
//!   pipeline stays full despite the `L_fma`-cycle latency;
//! * eq. 2 — the accumulator tile plus one weight pencil and one broadcast
//!   operand must fit in the `N_reg` logical registers;
//! * `C_o,b` is a multiple of `N_vec` (footnote 3) and must divide `C_o`
//!   exactly (zero-overhead layouts do not pad);
//! * `C_i,b` blocks the reduction so a kernel slab `H_f*W_f*C_i,b*C_o,b`
//!   stays resident in L1 while the register tile streams over it.

use super::microkernel::MAX_WOB;
use super::{BlockParams, ConvShape};
use crate::arch::Machine;

/// `C_o,b` values the direct-convolution dispatcher is monomorphized for.
pub const SUPPORTED_COB: [usize; 6] = [32, 16, 8, 4, 2, 1];

/// Largest supported register-block of the output channel that divides
/// `c_o`, preferring multiples of the machine vector width.
pub fn select_c_ob(machine: &Machine, c_o: usize) -> usize {
    // Prefer 2*N_vec (two vector registers per FMA chain; what hand-tuned
    // kernels on AVX2/NEON use), then N_vec, then anything that divides.
    let pref = [2 * machine.n_vec, machine.n_vec, 4 * machine.n_vec];
    for &c in &pref {
        if SUPPORTED_COB.contains(&c) && c_o % c == 0 {
            return c;
        }
    }
    for &c in &SUPPORTED_COB {
        if c_o % c == 0 {
            return c;
        }
    }
    1
}

/// Smallest `W_o,b` satisfying eq. 1 under the eq. 2 register budget.
pub fn select_w_ob(machine: &Machine, c_ob: usize, w_o: usize) -> usize {
    let e_min = machine.min_independent_outputs();
    let mut w_ob = e_min.div_ceil(c_ob).max(1);
    // eq. 2: accumulators + weight pencil + broadcast must fit N_reg.
    let regs_per_row = (c_ob / machine.n_vec).max(1);
    let operand_regs = regs_per_row + 1;
    let max_rows = ((machine.n_reg.saturating_sub(operand_regs)) / regs_per_row).max(1);
    w_ob = w_ob.min(max_rows).min(MAX_WOB);
    // No point tiling wider than the output row.
    w_ob.min(w_o).max(1)
}

/// Largest divisor of `c_i` whose kernel slab (`H_f*W_f*C_i,b*C_o,b`
/// floats) fits in L1 alongside the streamed input/output pencils (the
/// slab dominates; pencils are a few lines — measured best at a full-L1
/// budget, see the blocking ablation).
pub fn select_c_ib(machine: &Machine, shape: &ConvShape, c_ob: usize) -> usize {
    let l1 = machine.caches.first().map(|c| c.bytes).unwrap_or(32 << 10);
    let budget = l1; // measured optimum: slab ~ one L1's worth (see ablation)
    let slab_per_ci = shape.h_f * shape.w_f * c_ob * 4; // bytes per input channel
    let max_cib = (budget / slab_per_ci.max(1)).max(1);
    // largest divisor of the per-group reduction depth that is <= max_cib
    let c_i = shape.c_i_per_group();
    let mut best = 1;
    for d in 1..=c_i {
        if c_i % d == 0 && d <= max_cib {
            best = d;
        }
    }
    best
}

/// Full analytical parameter selection for a layer on a machine.
///
/// Grouped layers block each group's channel range independently, so
/// `c_ob`/`c_ib` are chosen against the per-group counts. The depthwise
/// fast path (`conv::depthwise`) keeps a single `c_b` lane dimension
/// shared by input and output (`c_ob == c_ib == c_b` dividing `C`).
pub fn select_params(machine: &Machine, shape: &ConvShape) -> BlockParams {
    if shape.is_depthwise() {
        let c_b = select_c_ob(machine, shape.c_o);
        let w_ob = select_w_ob(machine, c_b, shape.w_o());
        return BlockParams { c_ob: c_b, w_ob, c_ib: c_b };
    }
    let c_ob = select_c_ob(machine, shape.c_o_per_group());
    let w_ob = select_w_ob(machine, c_ob, shape.w_o());
    let c_ib = select_c_ib(machine, shape, c_ob);
    BlockParams { c_ob, w_ob, c_ib }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{cortex_a57, haswell, piledriver};
    use crate::nets;

    #[test]
    fn haswell_picks_16x6() {
        // E_min = 80; c_ob = 2*8 = 16 -> w_ob = ceil(80/16) = 5,
        // register cap: (16-3)/2 = 6 rows -> w_ob = 5.
        let m = haswell();
        let s = ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1);
        let bp = select_params(&m, &s);
        assert_eq!(bp.c_ob, 16);
        assert_eq!(bp.w_ob, 5);
        assert!(m.tile_feasible(bp.c_ob, bp.w_ob));
    }

    #[test]
    fn a57_uses_narrow_vectors_many_regs() {
        let m = cortex_a57();
        let s = ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1);
        let bp = select_params(&m, &s);
        // N_vec = 4 -> c_ob = 8; E_min = 20 -> w_ob = ceil(20/8)=3.
        assert_eq!(bp.c_ob, 8);
        assert_eq!(bp.w_ob, 3);
    }

    #[test]
    fn c_ob_divides_awkward_channel_counts() {
        let m = haswell();
        assert_eq!(select_c_ob(&m, 96), 16);
        assert_eq!(select_c_ob(&m, 24), 8);
        assert_eq!(select_c_ob(&m, 20), 4);
        assert_eq!(select_c_ob(&m, 7), 1);
    }

    #[test]
    fn c_ib_divides_and_fits_l1() {
        let m = piledriver();
        let s = ConvShape::new(256, 13, 13, 384, 3, 3, 1, 1);
        let c_ob = select_c_ob(&m, s.c_o);
        let c_ib = select_c_ib(&m, &s, c_ob);
        assert_eq!(s.c_i % c_ib, 0);
        assert!(s.h_f * s.w_f * c_ib * c_ob * 4 <= m.caches[0].bytes);
    }

    #[test]
    fn grouped_and_depthwise_selection_is_valid() {
        let m = haswell();
        // Depthwise: one lane dimension, c_ob == c_ib, divides C.
        let dw = ConvShape::new(8, 32, 32, 8, 3, 3, 1, 1).with_groups(8);
        let bp = select_params(&m, &dw);
        assert_eq!(bp.c_ob, bp.c_ib);
        assert_eq!(dw.c_o % bp.c_ob, 0);
        bp.validate_for(&dw).unwrap();
        // Grouped: per-group divisibility.
        let g = ConvShape::new(32, 16, 16, 64, 3, 3, 1, 1).with_groups(4);
        let bp = select_params(&m, &g);
        bp.validate_for(&g).unwrap();
        assert_eq!(g.c_o_per_group() % bp.c_ob, 0);
        assert_eq!(g.c_i_per_group() % bp.c_ib, 0);
        // Dilated dense layer still selects like the dense one.
        let d = ConvShape::new(32, 16, 16, 32, 3, 3, 1, 2).with_dilation(2);
        select_params(&m, &d).validate_for(&d).unwrap();
    }

    #[test]
    fn every_net_layer_gets_valid_params() {
        for m in [haswell(), piledriver(), cortex_a57()] {
            for layer in nets::all_layers() {
                let bp = select_params(&m, &layer.shape);
                bp.validate_for(&layer.shape).unwrap_or_else(|e| {
                    panic!("{} on {}: {:?} -> {e}", layer.name, m.name, bp)
                });
                assert!(bp.w_ob >= 1 && bp.w_ob <= MAX_WOB);
                assert!(SUPPORTED_COB.contains(&bp.c_ob));
            }
        }
    }
}

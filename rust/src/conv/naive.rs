//! Algorithm 1 — the naive direct convolution.
//!
//! Six perfectly-nested loops around one multiply-accumulate, in the
//! paper's original `(i, j, k, l, m, n)` order over NCHW data. Any loop
//! permutation computes the same result; this one is kept verbatim as the
//! correctness oracle (every other kernel in the crate is tested against
//! it) and as the baseline of the loop-order ablation.

use super::ConvShape;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Convolve `input` (`[C_i][H_i][W_i]`) with `kernel`
/// (`[C_o][C_i/groups][H_f][W_f]`), producing `[C_o][H_o][W_o]`.
/// Zero padding of `shape.pad` on all four image borders; grouped and
/// dilated shapes are supported (this is the oracle for those paths).
pub fn conv_naive(input: &Tensor, kernel: &Tensor, shape: &ConvShape) -> Result<Tensor> {
    shape.validate()?;
    check_shapes(input, kernel, shape)?;
    let mut out = Tensor::zeros(&[shape.c_o, shape.h_o(), shape.w_o()]);
    conv_naive_into(input.data(), kernel.data(), shape, out.data_mut())?;
    Ok(out)
}

/// Allocation-free core of [`conv_naive`]: writes the `[C_o][H_o][W_o]`
/// result into a caller-owned buffer (overwritten, zeroed internally).
/// This is the `execute_into` path of the `naive` engine backend.
pub fn conv_naive_into(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    o: &mut [f32],
) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (c_o, h_f, w_f) = (shape.c_o, shape.h_f, shape.w_f);
    let (s, p, d) = (shape.stride, shape.pad as isize, shape.dilation);
    let (c_ipg, c_opg) = (shape.c_i_per_group(), shape.c_o_per_group());
    if inp.len() != c_i * h_i * w_i {
        return Err(Error::Shape(format!(
            "input has {} elements, expected {}",
            inp.len(),
            c_i * h_i * w_i
        )));
    }
    if ker.len() != c_o * c_ipg * h_f * w_f {
        return Err(Error::Shape(format!(
            "kernel has {} elements, expected {}",
            ker.len(),
            c_o * c_ipg * h_f * w_f
        )));
    }
    if o.len() != c_o * h_o * w_o {
        return Err(Error::Shape(format!(
            "output has {} elements, expected {}",
            o.len(),
            c_o * h_o * w_o
        )));
    }
    o.fill(0.0);

    // Paper Algorithm 1: for i, j, k, l, m, n (plus padding guards).
    // Output channel j reduces over its group's input channels only;
    // filter taps are spaced by the dilation.
    for ii in 0..c_ipg {
        for j in 0..c_o {
            let i = (j / c_opg) * c_ipg + ii; // absolute input channel
            for k in 0..w_o {
                for l in 0..h_o {
                    for m in 0..w_f {
                        for n in 0..h_f {
                            let iy = (l * s + n * d) as isize - p;
                            let ix = (k * s + m * d) as isize - p;
                            if iy < 0 || iy >= h_i as isize || ix < 0 || ix >= w_i as isize {
                                continue;
                            }
                            o[(j * h_o + l) * w_o + k] += inp
                                [(i * h_i + iy as usize) * w_i + ix as usize]
                                * ker[((j * c_ipg + ii) * h_f + n) * w_f + m];
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

pub(crate) fn check_shapes(input: &Tensor, kernel: &Tensor, shape: &ConvShape) -> Result<()> {
    let want_in = [shape.c_i, shape.h_i, shape.w_i];
    if input.shape() != want_in {
        return Err(Error::Shape(format!(
            "input shape {:?} != expected {:?}",
            input.shape(),
            want_in
        )));
    }
    let want_k = [shape.c_o, shape.c_i_per_group(), shape.h_f, shape.w_f];
    if kernel.shape() != want_k {
        return Err(Error::Shape(format!(
            "kernel shape {:?} != expected {:?}",
            kernel.shape(),
            want_k
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1x1 input/kernel: conv degenerates to a dot product over channels.
    #[test]
    fn pointwise_is_dot_product() {
        let s = ConvShape::new(3, 1, 1, 2, 1, 1, 1, 0);
        let input = Tensor::from_vec(&[3, 1, 1], vec![1.0, 2.0, 3.0]).unwrap();
        let kernel =
            Tensor::from_vec(&[2, 3, 1, 1], vec![1.0, 1.0, 1.0, 0.5, 0.5, 0.5]).unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        assert_eq!(out.data(), &[6.0, 3.0]);
    }

    /// Hand-computed 1-channel 3x3 * 2x2 valid convolution.
    #[test]
    fn hand_example() {
        let s = ConvShape::new(1, 3, 3, 1, 2, 2, 1, 0);
        let input =
            Tensor::from_vec(&[1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let kernel = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        // out[y][x] = in[y][x] + in[y+1][x+1]
        assert_eq!(out.data(), &[1.0 + 5.0, 2.0 + 6.0, 4.0 + 8.0, 5.0 + 9.0]);
    }

    /// Identity kernel (1x1, weight 1) with padding reproduces the input
    /// framed by zeros at stride 2 sampling positions.
    #[test]
    fn stride_and_padding() {
        let s = ConvShape::new(1, 4, 4, 1, 1, 1, 2, 0);
        let input = Tensor::iota(&[1, 4, 4]);
        let kernel = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        assert_eq!(out.shape(), &[1, 2, 2]);
        assert_eq!(out.data(), &[0.0, 2.0, 8.0, 10.0]);
    }

    /// With pad=1 and a 3x3 sum kernel, the corner output only sums the
    /// 2x2 valid region.
    #[test]
    fn padding_corners() {
        let s = ConvShape::new(1, 3, 3, 1, 3, 3, 1, 1);
        let input = Tensor::full(&[1, 3, 3], 1.0);
        let kernel = Tensor::full(&[1, 1, 3, 3], 1.0);
        let out = conv_naive(&input, &kernel, &s).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3]);
        assert_eq!(out.at(&[0, 0, 0]), 4.0); // corner: 2x2 taps valid
        assert_eq!(out.at(&[0, 0, 1]), 6.0); // edge: 2x3
        assert_eq!(out.at(&[0, 1, 1]), 9.0); // center: 3x3
    }

    /// Grouped conv == two independent half-channel convs, hand-checked
    /// through the pointwise dot-product degenerate case.
    #[test]
    fn grouped_pointwise() {
        let s = ConvShape::new(4, 1, 1, 2, 1, 1, 1, 0).with_groups(2);
        let input = Tensor::from_vec(&[4, 1, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        // group 0: out0 = 1*1 + 2*2 = 5; group 1: out1 = 0.5*3 + 0.5*4 = 3.5
        let kernel = Tensor::from_vec(&[2, 2, 1, 1], vec![1.0, 2.0, 0.5, 0.5]).unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        assert_eq!(out.data(), &[5.0, 3.5]);
    }

    /// Depthwise: each channel convolves with its own filter only.
    #[test]
    fn depthwise_channels_stay_separate() {
        let s = ConvShape::new(2, 3, 3, 2, 2, 2, 1, 0).with_groups(2);
        let mut v = vec![0.0; 18];
        v[0] = 1.0; // channel 0 top-left
        v[9] = 2.0; // channel 1 top-left
        let input = Tensor::from_vec(&[2, 3, 3], v).unwrap();
        let kernel =
            Tensor::from_vec(&[2, 1, 2, 2], vec![1.0, 0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0])
                .unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        assert_eq!(out.at(&[0, 0, 0]), 1.0);
        assert_eq!(out.at(&[1, 0, 0]), 6.0); // 2 * 3, no cross-channel mixing
    }

    /// Dilation 2 spreads a 2x2 kernel over a 3x3 receptive field.
    #[test]
    fn dilated_taps() {
        let s = ConvShape::new(1, 3, 3, 1, 2, 2, 1, 0).with_dilation(2);
        let input =
            Tensor::from_vec(&[1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let kernel = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let out = conv_naive(&input, &kernel, &s).unwrap();
        // Single output: corners of the 3x3 image = 1 + 3 + 7 + 9.
        assert_eq!(out.shape(), &[1, 1, 1]);
        assert_eq!(out.data(), &[20.0]);
    }

    #[test]
    fn rejects_mismatched_tensors() {
        let s = ConvShape::new(2, 4, 4, 2, 3, 3, 1, 0);
        let bad_in = Tensor::zeros(&[3, 4, 4]);
        let k = Tensor::zeros(&[2, 2, 3, 3]);
        assert!(conv_naive(&bad_in, &k, &s).is_err());
        let good_in = Tensor::zeros(&[2, 4, 4]);
        let bad_k = Tensor::zeros(&[2, 2, 3, 2]);
        assert!(conv_naive(&good_in, &bad_k, &s).is_err());
    }
}

//! Algorithm 2 — the reordered direct convolution.
//!
//! Same computation as Algorithm 1 but with the paper's derived loop order
//! `(l, n, m, i, k, j)`: the output-channel loop `j` innermost (unit
//! stride, vectorizable), `k` next (independent FMA chains), then the
//! reduction loops `i, m, n` ordered for input reuse, and the output row
//! `l` outermost.
//!
//! To give the loop order its intended memory behaviour the operands are
//! channel-last: input `[H_i][W_i][C_i]`, kernel `[H_f][W_f][C_i][C_o]`,
//! output `[H_o][W_o][C_o]`. This is the unblocked midpoint of the
//! loop-order ablation (`benches/ablation_loop_order.rs`).

use super::ConvShape;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Repack a `[C_o][C_i][H_f][W_f]` kernel to the `[H_f][W_f][C_i][C_o]`
/// order this algorithm consumes.
pub fn kernel_to_hwio(kernel: &Tensor) -> Result<Tensor> {
    let &[c_o, c_i, h_f, w_f] = kernel.shape() else {
        return Err(Error::Layout(format!(
            "expected [C_o][C_i][H_f][W_f], got {:?}",
            kernel.shape()
        )));
    };
    let src = kernel.data();
    let mut out = vec![0.0f32; c_o * c_i * h_f * w_f];
    for o in 0..c_o {
        for i in 0..c_i {
            for n in 0..h_f {
                for m in 0..w_f {
                    out[((n * w_f + m) * c_i + i) * c_o + o] =
                        src[((o * c_i + i) * h_f + n) * w_f + m];
                }
            }
        }
    }
    Tensor::from_vec(&[h_f, w_f, c_i, c_o], out)
}

/// Allocation-free core of Algorithm 2: flat channel-last slices
/// (`[H_i][W_i][C_i]` input, `[H_f][W_f][C_i][C_o]` kernel,
/// `[H_o][W_o][C_o]` output). The output buffer is overwritten (zeroed
/// internally). This is the `execute_into` path of the `reorder` engine
/// backend.
pub fn conv_reorder_into(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    o: &mut [f32],
) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let (c_o, h_f, w_f) = (shape.c_o, shape.h_f, shape.w_f);
    let (s, p) = (shape.stride, shape.pad as isize);
    if inp.len() != c_i * h_i * w_i {
        return Err(Error::Shape(format!(
            "input has {} elements, expected {}",
            inp.len(),
            c_i * h_i * w_i
        )));
    }
    if ker.len() != c_o * c_i * h_f * w_f {
        return Err(Error::Shape(format!(
            "kernel has {} elements, expected {}",
            ker.len(),
            c_o * c_i * h_f * w_f
        )));
    }
    if o.len() != c_o * h_o * w_o {
        return Err(Error::Shape(format!(
            "output has {} elements, expected {}",
            o.len(),
            c_o * h_o * w_o
        )));
    }
    o.fill(0.0);

    // Paper Algorithm 2: for l, n, m, i, k, j.
    for l in 0..h_o {
        for n in 0..h_f {
            let iy = (l * s + n) as isize - p;
            if iy < 0 || iy >= h_i as isize {
                continue;
            }
            let iy = iy as usize;
            for m in 0..w_f {
                for i in 0..c_i {
                    for k in 0..w_o {
                        let ix = (k * s + m) as isize - p;
                        if ix < 0 || ix >= w_i as isize {
                            continue;
                        }
                        let xv = inp[(iy * w_i + ix as usize) * c_i + i];
                        let wrow = &ker[((n * w_f + m) * c_i + i) * c_o..][..c_o];
                        let orow = &mut o[(l * w_o + k) * c_o..][..c_o];
                        // j loop: unit stride over C_o — vectorizes.
                        for j in 0..c_o {
                            orow[j] += xv * wrow[j];
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;
    use crate::layout::{nchw_to_nhwc, nhwc_to_nchw};

    /// Channel-last one-shot over `conv_reorder_into` (what the removed
    /// `conv_reorder` wrapper did; the engine's `reorder` backend owns
    /// the HWIO pre-transform in production).
    fn reorder_oneshot(nhwc: &Tensor, hwio: &Tensor, s: &ConvShape) -> Result<Tensor> {
        s.validate()?;
        let mut out = Tensor::zeros(&[s.h_o(), s.w_o(), s.c_o]);
        conv_reorder_into(nhwc.data(), hwio.data(), s, out.data_mut())?;
        Ok(out)
    }

    fn check_against_naive(s: &ConvShape, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();

        let got_nhwc = reorder_oneshot(
            &nchw_to_nhwc(&input).unwrap(),
            &kernel_to_hwio(&kernel).unwrap(),
            s,
        )
        .unwrap();
        let got = nhwc_to_nchw(&got_nhwc).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "mismatch {:?}: {}",
            s,
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive_basic() {
        check_against_naive(&ConvShape::new(3, 8, 8, 4, 3, 3, 1, 0), 11);
    }

    #[test]
    fn matches_naive_padded() {
        check_against_naive(&ConvShape::new(2, 7, 9, 5, 3, 3, 1, 1), 12);
    }

    #[test]
    fn matches_naive_strided() {
        check_against_naive(&ConvShape::new(4, 11, 11, 8, 3, 3, 2, 0), 13);
        check_against_naive(&ConvShape::new(3, 13, 13, 2, 5, 5, 2, 2), 14);
    }

    #[test]
    fn matches_naive_asymmetric_kernel() {
        check_against_naive(&ConvShape::new(2, 9, 9, 3, 1, 3, 1, 0), 15);
        check_against_naive(&ConvShape::new(2, 9, 9, 3, 3, 1, 1, 0), 16);
    }

    #[test]
    fn hwio_repack_round_values() {
        let k = Tensor::iota(&[2, 3, 2, 2]);
        let h = kernel_to_hwio(&k).unwrap();
        assert_eq!(h.shape(), &[2, 2, 3, 2]);
        // h[n][m][i][o] == k[o][i][n][m]
        assert_eq!(h.at(&[1, 0, 2, 1]), k.at(&[1, 2, 1, 0]));
    }
}

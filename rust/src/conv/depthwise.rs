//! Depthwise convolution (`groups == C_i == C_o`) over the §4 blocked
//! layouts.
//!
//! Each output channel reduces over exactly its own input channel, so
//! the generic per-group core would degenerate to `c_ob == c_ib == 1`
//! scalar lanes. This kernel instead keeps the block's `c_b` channels
//! as SIMD lanes: input `[C/c_b][H_i][W_i][c_b]`, kernel
//! `[C/c_b][H_f][W_f][c_b]` (the standard blocked kernel layout with a
//! single one-channel reduction slab), output `[C/c_b][H_o][W_o][c_b]`
//! — every tap is a lane-wise `acc[j] += x[j] * w[j]`, unit-stride in
//! both operands. There is no input-channel reduction loop, so the
//! accumulator tile is written exactly once and the fused
//! [`Epilogue`] always fires right before that single store.
//!
//! Zero-memory-overhead story is identical to the dense core: no
//! workspace, borders by tap skipping, parallelism over channel blocks.

use super::epilogue::{apply_tile_auto, EpView, Epilogue};
use super::microkernel::MAX_WOB;
use super::{BlockParams, ConvShape};
use crate::{Error, Result};

/// Allocation-free depthwise core. Callers (`conv_direct_blocked_ep_into`)
/// have already validated shape/blocking/epilogue/lengths; this checks
/// only what is depthwise-specific. `bp.c_ob == bp.c_ib == c_b`.
#[allow(clippy::too_many_arguments)] // the full fused-conv operand set
pub(super) fn depthwise_blocked_core(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    out: &mut [f32],
    ep: &Epilogue,
    res: Option<&[f32]>,
) -> Result<()> {
    if !shape.is_depthwise() {
        return Err(Error::Shape("depthwise core on non-depthwise shape".into()));
    }
    let view = ep.view(0, shape.c_o);
    match bp.c_ob {
        1 => run::<1>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        2 => run::<2>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        4 => run::<4>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        8 => run::<8>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        16 => run::<16>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        32 => run::<32>(inp, ker, shape, bp.w_ob, threads, out, view, res),
        other => Err(Error::Shape(format!(
            "unsupported depthwise c_b={other} (supported: 1,2,4,8,16,32)"
        ))),
    }
}

#[allow(clippy::too_many_arguments)]
fn run<const CB: usize>(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    w_ob: usize,
    threads: usize,
    out: &mut [f32],
    ep: EpView<'_>,
    res: Option<&[f32]>,
) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let n_cb = shape.c_o / CB;
    let blk_out = h_o * w_o * CB;
    let blk_in = shape.h_i * shape.w_i * CB;
    let blk_ker = shape.h_f * shape.w_f * CB;
    if threads <= 1 || n_cb <= 1 {
        for (cb, out_blk) in out.chunks_mut(blk_out).enumerate() {
            let res_blk = res.map(|r| &r[cb * blk_out..][..blk_out]);
            dw_block::<CB>(
                &inp[cb * blk_in..][..blk_in],
                &ker[cb * blk_ker..][..blk_ker],
                shape,
                w_ob,
                cb * CB,
                out_blk,
                ep,
                res_blk,
            );
        }
    } else {
        let mut per_thread: Vec<Vec<(usize, &mut [f32])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (idx, b) in out.chunks_mut(blk_out).enumerate() {
            per_thread[idx % threads].push((idx, b));
        }
        std::thread::scope(|scope| {
            for chunk in per_thread {
                scope.spawn(move || {
                    for (cb, out_blk) in chunk {
                        let res_blk = res.map(|r| &r[cb * blk_out..][..blk_out]);
                        dw_block::<CB>(
                            &inp[cb * blk_in..][..blk_in],
                            &ker[cb * blk_ker..][..blk_ker],
                            shape,
                            w_ob,
                            cb * CB,
                            out_blk,
                            ep,
                            res_blk,
                        );
                    }
                });
            }
        });
    }
    Ok(())
}

/// One channel block: `inp_blk [H_i][W_i][CB]`, `ker_blk [H_f][W_f][CB]`,
/// `out_blk [H_o][W_o][CB]`; `c0` is the block's absolute channel base.
#[allow(clippy::too_many_arguments)]
fn dw_block<const CB: usize>(
    inp_blk: &[f32],
    ker_blk: &[f32],
    shape: &ConvShape,
    w_ob: usize,
    c0: usize,
    out_blk: &mut [f32],
    ep: EpView<'_>,
    res_blk: Option<&[f32]>,
) {
    match w_ob.min(MAX_WOB) {
        1 => dw_block_t::<CB, 1>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        2 => dw_block_t::<CB, 2>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        3 => dw_block_t::<CB, 3>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        4 => dw_block_t::<CB, 4>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        5 => dw_block_t::<CB, 5>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        6 => dw_block_t::<CB, 6>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        7 => dw_block_t::<CB, 7>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
        _ => dw_block_t::<CB, 8>(inp_blk, ker_blk, shape, c0, out_blk, ep, res_blk),
    }
}

/// Accumulate one `TW x CB` register tile of depthwise outputs (taps
/// are lane-wise products; borders skipped like the dense core).
#[inline(always)]
fn dw_tile<const CB: usize, const TW: usize>(
    acc: &mut [[f32; CB]; TW],
    inp_blk: &[f32],
    ker_blk: &[f32],
    shape: &ConvShape,
    l: usize,
    k0: usize,
    tw: usize,
) {
    let (h_i, w_i) = (shape.h_i, shape.w_i);
    let (s, p, d) = (shape.stride, shape.pad, shape.dilation);
    let row_stride = w_i * CB;
    for n in 0..shape.h_f {
        let iy = (l * s + n * d) as isize - p as isize;
        if iy < 0 || iy >= h_i as isize {
            continue;
        }
        let row = &inp_blk[iy as usize * row_stride..][..row_stride];
        for m in 0..shape.w_f {
            let w = &ker_blk[(n * shape.w_f + m) * CB..][..CB];
            let x0 = (k0 * s + m * d) as isize - p as isize;
            let x_last = x0 + ((tw - 1) * s) as isize;
            if x0 >= 0 && x_last < w_i as isize {
                let base = x0 as usize * CB;
                for kk in 0..tw {
                    let x = &row[base + kk * s * CB..][..CB];
                    let a = &mut acc[kk];
                    for j in 0..CB {
                        a[j] = x[j].mul_add(w[j], a[j]);
                    }
                }
            } else {
                for kk in 0..tw {
                    let x = x0 + (kk * s) as isize;
                    if x < 0 || x >= w_i as isize {
                        continue;
                    }
                    let xp = &row[x as usize * CB..][..CB];
                    let a = &mut acc[kk];
                    for j in 0..CB {
                        a[j] = xp[j].mul_add(w[j], a[j]);
                    }
                }
            }
        }
    }
}

/// Runtime-dispatched [`dw_tile`]: the AVX2 variant when the host has
/// it and the channel block fills whole ymm registers, else the scalar
/// oracle. Both operands of every tap are full-vector loads (this is
/// what the blocked depthwise layout buys), and the per-lane fused
/// multiply-add chains run in the scalar `(n, m, kk)` order, so the
/// variants are bitwise identical. There is no NEON depthwise kernel:
/// at `CB = 4` the tap loop is memory-bound and LLVM already
/// vectorizes the oracle's lane loop.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn dw_tile_auto<const CB: usize, const TW: usize>(
    acc: &mut [[f32; CB]; TW],
    inp_blk: &[f32],
    ker_blk: &[f32],
    shape: &ConvShape,
    l: usize,
    k0: usize,
    tw: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        use super::dispatch::{active, SimdLevel};
        if matches!(active(), SimdLevel::Avx2 | SimdLevel::Avx512) && CB % 8 == 0 {
            // SAFETY: avx2+fma runtime-detected; the flat view is the
            // tile's contiguous TW*CB storage.
            unsafe {
                dw_tile_avx2(
                    super::microkernel::tile_as_flat::<CB, TW>(acc),
                    CB,
                    inp_blk,
                    ker_blk,
                    shape,
                    l,
                    k0,
                    tw,
                );
            }
            return;
        }
    }
    dw_tile::<CB, TW>(acc, inp_blk, ker_blk, shape, l, k0, tw);
}

/// AVX2+FMA depthwise tile over the flat accumulator (`tw` live rows
/// of `cb` lanes, `cb % 8 == 0`). Dynamic loop bounds are fine here:
/// with no input-channel reduction the tile is touched once per tap,
/// not once per `(ib, ii)`, so register-resident accumulators buy far
/// less than in the dense core.
///
/// # Safety
/// Caller must have runtime-detected `avx2` and `fma`; `acc` must hold
/// at least `tw * cb` floats and the operand slabs must be full
/// `[H_i][W_i][cb]` / `[H_f][W_f][cb]` blocks for `shape`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn dw_tile_avx2(
    acc: &mut [f32],
    cb: usize,
    inp_blk: &[f32],
    ker_blk: &[f32],
    shape: &ConvShape,
    l: usize,
    k0: usize,
    tw: usize,
) {
    use core::arch::x86_64::*;
    let (h_i, w_i) = (shape.h_i, shape.w_i);
    let (s, p, d) = (shape.stride, shape.pad, shape.dilation);
    let row_stride = w_i * cb;
    debug_assert!(acc.len() >= tw * cb);
    for n in 0..shape.h_f {
        let iy = (l * s + n * d) as isize - p as isize;
        if iy < 0 || iy >= h_i as isize {
            continue;
        }
        let row = &inp_blk[iy as usize * row_stride..][..row_stride];
        for m in 0..shape.w_f {
            let wp = &ker_blk[(n * shape.w_f + m) * cb..][..cb];
            let x0 = (k0 * s + m * d) as isize - p as isize;
            let x_last = x0 + ((tw - 1) * s) as isize;
            if x0 >= 0 && x_last < w_i as isize {
                let base = x0 as usize * cb;
                for kk in 0..tw {
                    for v in 0..cb / 8 {
                        let x = _mm256_loadu_ps(row.as_ptr().add(base + kk * s * cb + v * 8));
                        let w = _mm256_loadu_ps(wp.as_ptr().add(v * 8));
                        let at = kk * cb + v * 8;
                        let a = _mm256_loadu_ps(acc.as_ptr().add(at));
                        _mm256_storeu_ps(acc.as_mut_ptr().add(at), _mm256_fmadd_ps(x, w, a));
                    }
                }
            } else {
                for kk in 0..tw {
                    let x = x0 + (kk * s) as isize;
                    if x < 0 || x >= w_i as isize {
                        continue;
                    }
                    let xb = x as usize * cb;
                    for v in 0..cb / 8 {
                        let xv = _mm256_loadu_ps(row.as_ptr().add(xb + v * 8));
                        let w = _mm256_loadu_ps(wp.as_ptr().add(v * 8));
                        let at = kk * cb + v * 8;
                        let a = _mm256_loadu_ps(acc.as_ptr().add(at));
                        _mm256_storeu_ps(acc.as_mut_ptr().add(at), _mm256_fmadd_ps(xv, w, a));
                    }
                }
            }
        }
    }
}

#[allow(clippy::manual_memcpy)] // explicit loop keeps the tile in registers
fn dw_block_t<const CB: usize, const TW: usize>(
    inp_blk: &[f32],
    ker_blk: &[f32],
    shape: &ConvShape,
    c0: usize,
    out_blk: &mut [f32],
    ep: EpView<'_>,
    res_blk: Option<&[f32]>,
) {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let full_tiles = w_o / TW;
    let rem = w_o % TW;
    let fuse = ep.is_active() || res_blk.is_some();
    for l in 0..h_o {
        let out_row = l * w_o * CB;
        for t in 0..full_tiles {
            let k0 = t * TW;
            let mut acc = [[0.0f32; CB]; TW];
            dw_tile_auto::<CB, TW>(&mut acc, inp_blk, ker_blk, shape, l, k0, TW);
            if fuse {
                let r = res_blk.map(|r| &r[out_row + k0 * CB..][..TW * CB]);
                apply_tile_auto::<CB, TW>(&mut acc, &ep, c0, r, TW);
            }
            let tile = &mut out_blk[out_row + k0 * CB..][..TW * CB];
            for kk in 0..TW {
                let dst = &mut tile[kk * CB..][..CB];
                for j in 0..CB {
                    dst[j] = acc[kk][j];
                }
            }
        }
        if rem > 0 {
            // Remainder columns: same tile type, only `rem` rows live
            // (no partial-sum reload here — depthwise has a single
            // reduction slab, so the tile is written exactly once).
            let k0 = full_tiles * TW;
            let mut acc = [[0.0f32; CB]; TW];
            dw_tile_auto::<CB, TW>(&mut acc, inp_blk, ker_blk, shape, l, k0, rem);
            if fuse {
                let r = res_blk.map(|r| &r[out_row + k0 * CB..][..rem * CB]);
                apply_tile_auto::<CB, TW>(&mut acc, &ep, c0, r, rem);
            }
            let tile = &mut out_blk[out_row + k0 * CB..][..rem * CB];
            for kk in 0..rem {
                let dst = &mut tile[kk * CB..][..CB];
                for j in 0..CB {
                    dst[j] = acc[kk][j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::conv_naive;
    use super::super::direct::conv_direct_blocked_ep_into;
    use super::*;
    use crate::layout::{from_blocked_io, to_blocked_io, to_blocked_kernel};
    use crate::tensor::Tensor;

    fn dw_oneshot(
        input: &Tensor,
        kernel: &Tensor,
        s: &ConvShape,
        bp: BlockParams,
        threads: usize,
        ep: &Epilogue,
        res_nchw: Option<&Tensor>,
    ) -> Tensor {
        let bi = to_blocked_io(input, bp.c_ib).unwrap();
        let bk = to_blocked_kernel(kernel, bp.c_ob, 1).unwrap();
        let mut out = Tensor::zeros(&[s.c_o / bp.c_ob, s.h_o(), s.w_o(), bp.c_ob]);
        let br = res_nchw.map(|r| to_blocked_io(r, bp.c_ob).unwrap());
        conv_direct_blocked_ep_into(
            bi.data(),
            bk.data(),
            s,
            bp,
            threads,
            out.data_mut(),
            ep,
            br.as_ref().map(|b| b.data()),
        )
        .unwrap();
        from_blocked_io(&out).unwrap()
    }

    fn check(s: &ConvShape, bp: BlockParams, threads: usize, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, 1, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = dw_oneshot(&input, &kernel, s, bp, threads, &Epilogue::none(), None);
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "depthwise mismatch {s:?} bp={bp:?}: {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive_basic() {
        let s = ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1).with_groups(8);
        check(&s, BlockParams::new(8, 4, 8), 1, 50);
        check(&s, BlockParams::new(4, 3, 4), 1, 51);
        check(&s, BlockParams::new(1, 4, 1), 1, 52);
    }

    #[test]
    fn matches_naive_strided_dilated_threaded() {
        let s = ConvShape::new(16, 12, 12, 16, 3, 3, 2, 1).with_groups(16);
        check(&s, BlockParams::new(8, 4, 8), 4, 53);
        let d = ConvShape::new(8, 14, 14, 8, 3, 3, 1, 2).with_groups(8).with_dilation(2);
        check(&d, BlockParams::new(8, 5, 8), 1, 54);
        check(&d, BlockParams::new(2, 7, 2), 3, 55);
    }

    #[test]
    fn fused_epilogue_matches_post_pass() {
        use crate::conv::epilogue::apply_post;
        use crate::layout::IoLayout;
        let s = ConvShape::new(8, 9, 9, 8, 3, 3, 1, 1).with_groups(8);
        let bp = BlockParams::new(8, 4, 8);
        let input = Tensor::random(&[8, 9, 9], 60);
        let kernel = Tensor::random(&[8, 1, 3, 3], 61);
        let res = Tensor::random(&[8, 9, 9], 62);
        let ep = Epilogue::bn(
            (0..8).map(|c| 0.5 + c as f32 * 0.25).collect(),
            (0..8).map(|c| c as f32 * 0.1 - 0.4).collect(),
        )
        .with_relu(Some(6.0))
        .with_residual();
        let fused = dw_oneshot(&input, &kernel, &s, bp, 1, &ep, Some(&res));
        // Reference: unfused conv, then the layout-aware post pass.
        let mut want = conv_naive(&input, &kernel, &s).unwrap();
        apply_post(
            want.data_mut(),
            IoLayout::Nchw,
            8,
            81,
            &ep,
            Some(res.data()),
        )
        .unwrap();
        assert!(
            fused.allclose(&want, 1e-4, 1e-5),
            "fused depthwise epilogue mismatch: {}",
            fused.max_abs_diff(&want)
        );
        // ReLU clamp actually bites somewhere (guards a vacuous test).
        assert!(fused.data().iter().all(|&v| (0.0..=6.0).contains(&v)));
    }
}

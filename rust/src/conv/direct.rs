//! Algorithm 3 — the paper's high-performance direct convolution.
//!
//! Loop structure (paper notation; `j' = jb` output-channel block,
//! `i' = ib` input-channel block, `k' = k0` output-column block):
//!
//! ```text
//! for jb in 0..C_o/C_ob   in parallel        (thread partition)
//!   for ib in 0..C_i/C_ib                    (cache blocking)
//!     for l in 0..H_o                        (output row)
//!       for k0 in 0..W_o step W_ob           (register tile column)
//!         load accumulator tile  O[jb, l, k0.., :]
//!         for n in 0..H_f; for m in 0..W_f   (kernel taps)
//!           for ii in 0..C_ib                (reduction)
//!             acc[kk][:] += I[ib, y, x(kk), ii] * F[jb, ib, n, m, ii, :]
//!         store accumulator tile
//! ```
//!
//! Operands are in the §4 layouts ([`crate::layout`]): input/output
//! `[C/c_b][H][W][c_b]`, kernel `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]`.
//! Zero extra memory is allocated beyond the output itself.
//!
//! Image borders (when `pad > 0`) are handled by tap skipping: a kernel
//! tap whose input row/column falls outside the image contributes nothing,
//! so rows are skipped per `(l, n)` and an edge tile falls back to a
//! per-column guarded path — never by materializing a padded copy.
//!
//! **Epilogues** ([`conv_direct_blocked_ep_into`]): the fused post-op
//! tail of a conv (bias / batch-norm scale+shift / residual add / ReLU)
//! is applied to the accumulator tile in registers, on the **last**
//! input-channel block only (earlier `ib` iterations hold partial sums
//! that round-trip through the output), right before the final store —
//! the unfused intermediate never exists in memory.
//!
//! **Groups / dilation**: dilation flows into the tap geometry
//! ([`TileGeom::dil`]); grouped convolution runs the same core once per
//! group over block-aligned slices of the §4 layouts (each group's
//! channel blocks are contiguous), and the depthwise case
//! (`groups == C_i == C_o`) takes the dedicated
//! [`super::depthwise`] register-tile kernel.

use super::epilogue::{apply_tile_auto, EpView, Epilogue};
use super::microkernel::{
    load_tile_c, reduce_tile_auto, store_tile_c, TileGeom, MAX_WOB,
};
use super::{BlockParams, ConvShape};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Direct convolution over blocked operands. `input` is
/// `[C_i/c_ib][H_i][W_i][c_ib]`, `kernel` is
/// `[C_o/c_ob][C_i/c_ib][H_f][W_f][c_ib][c_ob]`; returns the blocked
/// output `[C_o/c_ob][H_o][W_o][c_ob]`.
pub fn conv_direct_blocked(
    input: &Tensor,
    kernel: &Tensor,
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
) -> Result<Tensor> {
    // Validate before any h_o()/division so bad shapes return Err
    // instead of panicking (stride 0, non-dividing blocks, ...).
    shape.validate()?;
    bp.validate_for(shape)?;
    let want_in = [shape.c_i / bp.c_ib, shape.h_i, shape.w_i, bp.c_ib];
    if input.shape() != want_in {
        return Err(Error::Shape(format!(
            "blocked input shape {:?} != expected {:?}",
            input.shape(),
            want_in
        )));
    }
    // Depthwise kernels pack with a single input lane ([C/c_b][1][H_f]
    // [W_f][1][c_b]); everything else blocks the per-group reduction.
    let k_cib = if shape.is_depthwise() { 1 } else { bp.c_ib };
    let want_k = [
        shape.c_o / bp.c_ob,
        shape.c_i_per_group() / k_cib,
        shape.h_f,
        shape.w_f,
        k_cib,
        bp.c_ob,
    ];
    if kernel.shape() != want_k {
        return Err(Error::Shape(format!(
            "blocked kernel shape {:?} != expected {:?}",
            kernel.shape(),
            want_k
        )));
    }
    let mut out = Tensor::zeros(&[shape.c_o / bp.c_ob, shape.h_o(), shape.w_o(), bp.c_ob]);
    conv_direct_blocked_into(input.data(), kernel.data(), shape, bp, threads, out.data_mut())?;
    Ok(out)
}

/// Allocation-free core of Algorithm 3: operands and output are flat
/// slices in the §4 blocked layouts (`[C_i/c_ib][H_i][W_i][c_ib]` input,
/// `[C_o/c_ob][C_i/c_ib][H_f][W_f][c_ib][c_ob]` kernel,
/// `[C_o/c_ob][H_o][W_o][c_ob]` output, all row-major). The output is
/// overwritten (zeroed internally); nothing is allocated when
/// `threads <= 1` — this is the `execute_into` hot path of the `direct`
/// engine backend. With `threads > 1` the only allocations are the
/// per-call thread-partition bookkeeping (independent of tensor sizes).
pub fn conv_direct_blocked_into(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    out: &mut [f32],
) -> Result<()> {
    conv_direct_blocked_ep_into(inp, ker, shape, bp, threads, out, &Epilogue::none(), None)
}

/// [`conv_direct_blocked_into`] with a fused [`Epilogue`] applied to the
/// register tile before the final store (and, for `ep.residual`, a
/// residual operand `res` in the **output's** blocked layout). Grouped
/// and depthwise shapes route through the per-group / depthwise cores.
/// Still allocation-free when `threads <= 1`.
#[allow(clippy::too_many_arguments)] // the full fused-conv operand set
pub fn conv_direct_blocked_ep_into(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    out: &mut [f32],
    ep: &Epilogue,
    res: Option<&[f32]>,
) -> Result<()> {
    shape.validate()?;
    bp.validate_for(shape)?;
    ep.validate(shape.c_o)?;
    if bp.w_ob == 0 || bp.w_ob > MAX_WOB {
        return Err(Error::Shape(format!("w_ob={} out of range 1..={}", bp.w_ob, MAX_WOB)));
    }
    let n_img = shape.c_i * shape.h_i * shape.w_i;
    if inp.len() != n_img {
        return Err(Error::Shape(format!(
            "blocked input has {} elements, expected {n_img}",
            inp.len()
        )));
    }
    let n_ker = shape.c_o * shape.c_i_per_group() * shape.h_f * shape.w_f;
    if ker.len() != n_ker {
        return Err(Error::Shape(format!(
            "blocked kernel has {} elements, expected {n_ker}",
            ker.len()
        )));
    }
    let n_out = shape.c_o * shape.h_o() * shape.w_o();
    if out.len() != n_out {
        return Err(Error::Shape(format!(
            "blocked output has {} elements, expected {n_out}",
            out.len()
        )));
    }
    if ep.residual != res.is_some() {
        return Err(Error::Shape("fused residual operand mismatch".into()));
    }
    if let Some(r) = res {
        if r.len() != n_out {
            return Err(Error::Shape(format!(
                "fused residual has {} elements, expected {n_out}",
                r.len()
            )));
        }
    }
    let threads = threads.max(1);
    if shape.is_depthwise() {
        return super::depthwise::depthwise_blocked_core(inp, ker, shape, bp, threads, out, ep, res);
    }
    if shape.groups == 1 {
        return run_group(inp, ker, shape, bp, threads, out, ep.view(0, shape.c_o), res);
    }
    // Grouped: each group's channel blocks are contiguous in every §4
    // layout, so the groups==1 core runs unchanged over slices.
    let (c_ipg, c_opg) = (shape.c_i_per_group(), shape.c_o_per_group());
    let gs = ConvShape { c_i: c_ipg, c_o: c_opg, groups: 1, ..shape.clone() };
    let (in_len, k_len) = (c_ipg * shape.h_i * shape.w_i, c_opg * c_ipg * shape.h_f * shape.w_f);
    let out_len = c_opg * shape.h_o() * shape.w_o();
    for g in 0..shape.groups {
        let inp_g = &inp[g * in_len..][..in_len];
        let ker_g = &ker[g * k_len..][..k_len];
        let out_g = &mut out[g * out_len..][..out_len];
        let res_g = res.map(|r| &r[g * out_len..][..out_len]);
        run_group(inp_g, ker_g, &gs, bp, threads, out_g, ep.view(g * c_opg, c_opg), res_g)?;
    }
    Ok(())
}

/// Monomorphization dispatch for one (groups == 1) channel range.
#[allow(clippy::too_many_arguments)]
fn run_group(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    out: &mut [f32],
    ep: EpView<'_>,
    res: Option<&[f32]>,
) -> Result<()> {
    match bp.c_ob {
        1 => run_into::<1>(inp, ker, shape, bp, threads, out, ep, res),
        2 => run_into::<2>(inp, ker, shape, bp, threads, out, ep, res),
        4 => run_into::<4>(inp, ker, shape, bp, threads, out, ep, res),
        8 => run_into::<8>(inp, ker, shape, bp, threads, out, ep, res),
        16 => run_into::<16>(inp, ker, shape, bp, threads, out, ep, res),
        32 => run_into::<32>(inp, ker, shape, bp, threads, out, ep, res),
        other => Err(Error::Shape(format!(
            "unsupported c_ob={other} (supported: 1,2,4,8,16,32)"
        ))),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_into<const COB: usize>(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    out: &mut [f32],
    ep: EpView<'_>,
    res: Option<&[f32]>,
) -> Result<()> {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let n_ob = shape.c_o / COB;
    let blk_len = h_o * w_o * COB;
    out.fill(0.0);
    if threads <= 1 || n_ob <= 1 {
        // Serial path: no allocation of any kind.
        for (jb, out_blk) in out.chunks_mut(blk_len).enumerate() {
            let res_blk = res.map(|r| &r[jb * blk_len..][..blk_len]);
            conv_block::<COB>(inp, ker, shape, bp, jb, out_blk, ep, res_blk);
        }
    } else {
        // Paper §3.2: parallelism over the C_o dimension; each thread
        // owns whole output-channel blocks (disjoint output, no
        // synchronization on the hot path).
        let mut per_thread: Vec<Vec<(usize, &mut [f32])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (idx, b) in out.chunks_mut(blk_len).enumerate() {
            per_thread[idx % threads].push((idx, b));
        }
        std::thread::scope(|scope| {
            for chunk in per_thread {
                scope.spawn(move || {
                    for (jb, out_blk) in chunk {
                        let res_blk = res.map(|r| &r[jb * blk_len..][..blk_len]);
                        conv_block::<COB>(inp, ker, shape, bp, jb, out_blk, ep, res_blk);
                    }
                });
            }
        });
    }
    Ok(())
}

/// Compute one output-channel block `jb` (all rows/columns, all input
/// channels) into `out_blk` (`[H_o][W_o][COB]`). Dispatches the tile
/// width to a monomorphized kernel so the accumulator tile stays in
/// registers for the whole `(n, m, C_i,b)` reduction.
#[allow(clippy::too_many_arguments)]
fn conv_block<const COB: usize>(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    jb: usize,
    out_blk: &mut [f32],
    ep: EpView<'_>,
    res_blk: Option<&[f32]>,
) {
    match bp.w_ob {
        1 => conv_block_t::<COB, 1>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        2 => conv_block_t::<COB, 2>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        3 => conv_block_t::<COB, 3>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        4 => conv_block_t::<COB, 4>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        5 => conv_block_t::<COB, 5>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        6 => conv_block_t::<COB, 6>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        7 => conv_block_t::<COB, 7>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
        _ => conv_block_t::<COB, 8>(inp, ker, shape, bp, jb, out_blk, ep, res_blk),
    }
}

#[allow(clippy::too_many_arguments)]
fn conv_block_t<const COB: usize, const TW: usize>(
    inp: &[f32],
    ker: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    jb: usize,
    out_blk: &mut [f32],
    ep: EpView<'_>,
    res_blk: Option<&[f32]>,
) {
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (h_i, w_i) = (shape.h_i, shape.w_i);
    let (h_f, w_f) = (shape.h_f, shape.w_f);
    let (s, p, d) = (shape.stride, shape.pad, shape.dilation);
    let c_ib = bp.c_ib;
    let n_ib = shape.c_i / c_ib;

    // Kernel slab strides (layout [C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]).
    let ker_ib = h_f * w_f * c_ib * COB;
    let ker_jb = n_ib * ker_ib;
    let full_tiles = w_o / TW;
    let rem = w_o % TW;

    for ib in 0..n_ib {
        let kslab = &ker[jb * ker_jb + ib * ker_ib..][..ker_ib];
        let islab = &inp[ib * (h_i * w_i * c_ib)..][..h_i * w_i * c_ib];
        // The epilogue fires only once the reduction is complete: earlier
        // ib iterations hold partial sums (they round-trip through out).
        let fuse = ib == n_ib - 1 && (ep.is_active() || res_blk.is_some());
        for l in 0..h_o {
            let out_row = l * w_o * COB;
            // Full-width tiles: register-resident reduction.
            for t in 0..full_tiles {
                let k0 = t * TW;
                let tile = &mut out_blk[out_row + k0 * COB..][..TW * COB];
                let mut acc = [[0.0f32; COB]; TW];
                load_tile_c::<COB, TW>(&mut acc, tile);
                let g = TileGeom { h_f, w_f, c_ib, h_i, w_i, stride: s, pad: p, dil: d, l, k0 };
                reduce_tile_auto::<COB, TW>(&mut acc, islab, kslab, &g);
                if fuse {
                    let r = res_blk.map(|r| &r[out_row + k0 * COB..][..TW * COB]);
                    apply_tile_auto::<COB, TW>(&mut acc, &ep, jb * COB, r, TW);
                }
                store_tile_c::<COB, TW>(&acc, tile);
            }
            // Row remainder: dispatch to a narrower const-width kernel
            // (keeps the accumulators in registers; the dynamic-width
            // fallback measured ~4x slower and dominated rows whose
            // W_o % W_o,b was large — §Perf iteration 4).
            if rem > 0 {
                let k0 = full_tiles * TW;
                let tile = &mut out_blk[out_row + k0 * COB..][..rem * COB];
                let g = TileGeom { h_f, w_f, c_ib, h_i, w_i, stride: s, pad: p, dil: d, l, k0 };
                let r = if fuse {
                    res_blk.map(|r| &r[out_row + k0 * COB..][..rem * COB])
                } else {
                    None
                };
                reduce_rem::<COB>(tile, islab, kslab, &g, rem, fuse.then_some((&ep, jb * COB)), r);
            }
        }
    }
}

/// Remainder-tile reduction: monomorphized per width so narrow edge
/// tiles run the same register-resident kernel as full tiles. `fuse`
/// carries the epilogue view + channel base when this is the last
/// input-channel block of a fused conv.
fn reduce_rem<const COB: usize>(
    tile: &mut [f32],
    islab: &[f32],
    kslab: &[f32],
    g: &TileGeom,
    rem: usize,
    fuse: Option<(&EpView<'_>, usize)>,
    res: Option<&[f32]>,
) {
    macro_rules! go {
        ($tw:literal) => {{
            let mut acc = [[0.0f32; COB]; $tw];
            load_tile_c::<COB, $tw>(&mut acc, tile);
            reduce_tile_auto::<COB, $tw>(&mut acc, islab, kslab, g);
            if let Some((ep, c0)) = fuse {
                apply_tile_auto::<COB, $tw>(&mut acc, ep, c0, res, $tw);
            }
            store_tile_c::<COB, $tw>(&acc, tile);
        }};
    }
    match rem {
        1 => go!(1),
        2 => go!(2),
        3 => go!(3),
        4 => go!(4),
        5 => go!(5),
        6 => go!(6),
        _ => go!(7),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;
    use crate::layout::{from_blocked_io, to_blocked_io, to_blocked_kernel};

    /// One-shot pack -> blocked conv -> unpack over conventional
    /// operands (what the removed `conv_direct` wrapper did; production
    /// code plans through the engine's `direct` backend instead).
    fn direct_oneshot(
        input: &Tensor,
        kernel: &Tensor,
        s: &ConvShape,
        bp: BlockParams,
        threads: usize,
    ) -> Result<Tensor> {
        let bi = to_blocked_io(input, bp.c_ib)?;
        let bk = to_blocked_kernel(kernel, bp.c_ob, bp.c_ib)?;
        let bo = conv_direct_blocked(&bi, &bk, s, bp, threads)?;
        from_blocked_io(&bo)
    }

    fn check(s: &ConvShape, bp: BlockParams, threads: usize, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = direct_oneshot(&input, &kernel, s, bp, threads).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "mismatch {:?} bp={:?}: {}",
            s,
            bp,
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive_3x3() {
        check(&ConvShape::new(8, 10, 10, 16, 3, 3, 1, 0), BlockParams::new(8, 4, 4), 1, 21);
    }

    #[test]
    fn matches_naive_padded() {
        check(&ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1), BlockParams::new(16, 3, 8), 1, 22);
        check(&ConvShape::new(4, 7, 7, 8, 5, 5, 1, 2), BlockParams::new(8, 4, 4), 1, 23);
    }

    #[test]
    fn matches_naive_strided() {
        check(&ConvShape::new(3, 23, 23, 16, 11, 11, 4, 0), BlockParams::new(16, 4, 3), 1, 24);
        check(&ConvShape::new(8, 14, 14, 8, 3, 3, 2, 1), BlockParams::new(8, 2, 8), 1, 25);
    }

    #[test]
    fn matches_naive_threaded() {
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 4, 26);
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 7, 27);
    }

    #[test]
    fn tile_width_edge_cases() {
        // W_o = 5 with w_ob = 4 leaves a width-1 edge tile.
        check(&ConvShape::new(4, 7, 7, 8, 3, 3, 1, 0), BlockParams::new(8, 4, 4), 1, 28);
        // w_ob = 1 (degenerate tile)
        check(&ConvShape::new(4, 7, 7, 8, 3, 3, 1, 0), BlockParams::new(8, 1, 4), 1, 29);
        // w_ob wider than W_o
        check(&ConvShape::new(4, 6, 6, 8, 3, 3, 1, 0), BlockParams::new(8, 8, 4), 1, 30);
    }

    #[test]
    fn all_cob_variants() {
        for &cob in &[1usize, 2, 4, 8, 16, 32] {
            let s = ConvShape::new(4, 8, 8, 32, 3, 3, 1, 1);
            check(&s, BlockParams::new(cob, 4, 2), 1, 31 + cob as u64);
        }
    }

    #[test]
    fn pointwise_1x1() {
        check(&ConvShape::new(16, 7, 7, 32, 1, 1, 1, 0), BlockParams::new(16, 4, 8), 1, 40);
    }

    #[test]
    fn rejects_bad_params() {
        let s = ConvShape::new(8, 8, 8, 16, 3, 3, 1, 0);
        let input = Tensor::zeros(&[8, 8, 8]);
        let kernel = Tensor::zeros(&[16, 8, 3, 3]);
        // w_ob beyond MAX_WOB
        assert!(direct_oneshot(&input, &kernel, &s, BlockParams::new(8, 9, 4), 1).is_err());
        // c_ob not dividing C_o
        assert!(direct_oneshot(&input, &kernel, &s, BlockParams::new(5, 4, 4), 1).is_err());
    }

    #[test]
    fn blocked_entry_checks_shapes() {
        let s = ConvShape::new(8, 8, 8, 16, 3, 3, 1, 0);
        let bp = BlockParams::new(8, 4, 4);
        let bad_in = Tensor::zeros(&[1, 8, 8, 8]); // wrong c_ib split
        let k = to_blocked_kernel(&Tensor::zeros(&[16, 8, 3, 3]), 8, 4).unwrap();
        assert!(conv_direct_blocked(&bad_in, &k, &s, bp, 1).is_err());
    }

    #[test]
    fn matches_naive_dilated() {
        let s = ConvShape::new(8, 14, 14, 16, 3, 3, 1, 2).with_dilation(2);
        check(&s, BlockParams::new(8, 4, 4), 1, 70);
        let s2 = ConvShape::new(4, 15, 15, 8, 3, 3, 2, 2).with_dilation(2);
        check(&s2, BlockParams::new(8, 3, 4), 2, 71);
    }

    /// Grouped (non-depthwise) conv vs the naive grouped oracle.
    fn check_grouped(s: &ConvShape, bp: BlockParams, threads: usize, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i_per_group(), s.h_f, s.w_f], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let bi = to_blocked_io(&input, bp.c_ib).unwrap();
        let bk = to_blocked_kernel(&kernel, bp.c_ob, bp.c_ib).unwrap();
        let mut out = Tensor::zeros(&[s.c_o / bp.c_ob, s.h_o(), s.w_o(), bp.c_ob]);
        conv_direct_blocked_into(bi.data(), bk.data(), s, bp, threads, out.data_mut()).unwrap();
        let got = from_blocked_io(&out).unwrap();
        assert!(
            got.allclose(&want, 1e-4, 1e-5),
            "grouped mismatch {s:?} bp={bp:?}: {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive_grouped() {
        check_grouped(&ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1).with_groups(2), BlockParams::new(8, 4, 4), 1, 72);
        check_grouped(&ConvShape::new(16, 8, 8, 16, 3, 3, 1, 1).with_groups(4), BlockParams::new(4, 4, 2), 1, 73);
        check_grouped(&ConvShape::new(8, 10, 10, 8, 3, 3, 2, 1).with_groups(2), BlockParams::new(2, 3, 4), 3, 74);
    }

    /// In-tile fused epilogue is bitwise identical to computing the conv
    /// unfused and applying the same scalar post-pass — the property the
    /// graph-level fusion pass relies on for f32 parity.
    #[test]
    fn fused_epilogue_bitwise_matches_post_pass() {
        use crate::conv::epilogue::apply_post;
        use crate::layout::IoLayout;
        // c_i blocking (c_ib=4 of 8) exercises the "fire on last ib" rule;
        // W_o=7 with w_ob=4 exercises the remainder-tile epilogue path.
        let s = ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1);
        let bp = BlockParams::new(8, 4, 4);
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 80);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 81);
        let res = Tensor::random(&[s.c_o, s.h_o(), s.w_o()], 82);
        let ep = Epilogue::bn(
            (0..16).map(|c| 0.25 + c as f32 * 0.125).collect(),
            (0..16).map(|c| c as f32 * 0.05 - 0.3).collect(),
        )
        .with_relu(Some(4.0))
        .with_residual();

        let bi = to_blocked_io(&input, bp.c_ib).unwrap();
        let bk = to_blocked_kernel(&kernel, bp.c_ob, bp.c_ib).unwrap();
        let br = to_blocked_io(&res, bp.c_ob).unwrap();

        let mut fused = Tensor::zeros(&[s.c_o / bp.c_ob, s.h_o(), s.w_o(), bp.c_ob]);
        conv_direct_blocked_ep_into(
            bi.data(), bk.data(), &s, bp, 1, fused.data_mut(), &ep, Some(br.data()),
        )
        .unwrap();

        let mut unfused = Tensor::zeros(&[s.c_o / bp.c_ob, s.h_o(), s.w_o(), bp.c_ob]);
        conv_direct_blocked_into(bi.data(), bk.data(), &s, bp, 1, unfused.data_mut()).unwrap();
        apply_post(
            unfused.data_mut(),
            IoLayout::Blocked { c_b: bp.c_ob },
            s.c_o,
            s.h_o() * s.w_o(),
            &ep,
            Some(br.data()),
        )
        .unwrap();
        assert_eq!(fused.data(), unfused.data(), "fused epilogue must be bitwise");
        // And the clamp actually bites somewhere (guards a vacuous test).
        assert!(fused.data().iter().all(|&v| (0.0..=4.0).contains(&v)));
        assert!(fused.data().iter().any(|&v| v == 4.0 || v == 0.0));
    }

    #[test]
    fn fused_rejects_bad_operands() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let bp = BlockParams::new(8, 4, 4);
        let inp = vec![0.0f32; 4 * 6 * 6];
        let ker = vec![0.0f32; 8 * 4 * 3 * 3];
        let mut out = vec![0.0f32; 8 * 6 * 6];
        // Epilogue channel-count mismatch.
        let bad = Epilogue::bias(vec![0.0; 7]);
        assert!(conv_direct_blocked_ep_into(&inp, &ker, &s, bp, 1, &mut out, &bad, None).is_err());
        // Residual flag without operand, and operand of the wrong size.
        let ep = Epilogue::none().with_residual();
        assert!(conv_direct_blocked_ep_into(&inp, &ker, &s, bp, 1, &mut out, &ep, None).is_err());
        let short = vec![0.0f32; 8];
        assert!(
            conv_direct_blocked_ep_into(&inp, &ker, &s, bp, 1, &mut out, &ep, Some(&short))
                .is_err()
        );
    }
}

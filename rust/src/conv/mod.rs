//! The paper's direct-convolution algorithms.
//!
//! * [`naive`] — Algorithm 1: the textbook six-loop nest over NCHW data.
//!   Slow by design; it is the correctness oracle for everything else.
//! * [`reorder`] — Algorithm 2: the same computation with the paper's
//!   `(l, n, m, i, k, j)` loop order over channel-last data, which makes
//!   the output-channel loop `j` the unit-stride innermost loop.
//! * [`direct`] — Algorithm 3: register blocking (`C_o,b x W_o,b`
//!   accumulator tile), cache blocking over input channels (`C_i,b`),
//!   the §4 blocked layouts, and parallelism over output-channel blocks.
//! * [`microkernel`] — the register-tile FMA kernels `direct` dispatches to.
//! * [`dispatch`] — runtime ISA detection selecting the `std::arch`
//!   SIMD variants of those kernels (AVX2/AVX-512/NEON), with the
//!   scalar cores kept as the always-compiled conformance oracle.
//! * [`depthwise`] — the depthwise (`groups == C_i == C_o`) register-tile
//!   kernel keeping the blocked `c_b` channels as SIMD lanes.
//! * [`epilogue`] — fused conv post-ops (bias/BN scale+shift/residual/ReLU)
//!   applied to the accumulator tile before its final store.
//! * [`params`] — analytical blocking-parameter selection (Low et al. 2016
//!   style) from an [`crate::arch::Machine`] descriptor.
//! * [`backward`] — the §6 future-work backward pass (input + kernel
//!   gradients) with adjoint/finite-difference verification.

pub mod backward;
pub mod depthwise;
pub mod direct;
pub mod dispatch;
pub mod epilogue;
pub mod microkernel;
pub mod naive;
pub mod params;
pub mod reorder;
mod shape;

pub use backward::{conv_backward_input, conv_backward_kernel};
pub use direct::{
    conv_direct_blocked, conv_direct_blocked_ep_into, conv_direct_blocked_into,
};
pub use epilogue::{apply_post, EpView, Epilogue};
pub use naive::{conv_naive, conv_naive_into};
pub use params::select_params;
pub use reorder::conv_reorder_into;
pub use shape::{BlockParams, ConvShape};

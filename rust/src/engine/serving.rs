//! [`PlanEngine`] — the native serving executor: a cached [`ConvPlan`]
//! behind the coordinator's [`ModelExecutor`] interface.
//!
//! This is the zero-overhead hot path the ROADMAP's serving north-star
//! needs: the plan (pre-transformed weights), the layout staging
//! buffers, the native output buffer and the workspace are all built
//! once at construction and reused for every request of every batch —
//! per request, the conv path allocates nothing. (The reply buffer
//! handed back through the coordinator's channel is the one per-batch
//! allocation; it is the message, not conv state.)

use super::{BackendRegistry, ConvPlan};
use crate::arch::Machine;
use crate::conv::ConvShape;
use crate::layout::{nchw_to_nhwc_slice, nhwc_to_nchw_slice, pack_io_slice, unpack_io_slice, IoLayout};
use crate::runtime::{Artifact, Manifest, ModelExecutor};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::Mutex;

/// Reused per-execution buffers (one set per engine; requests are
/// serialized by the coordinator's single worker).
struct Scratch {
    /// Native-layout input staging (unused when the plan consumes NCHW).
    staged_in: Vec<f32>,
    /// Native-layout output.
    native_out: Vec<f32>,
    /// Plan workspace ([`ConvPlan::workspace_len`] floats).
    workspace: Vec<f32>,
}

/// A single conv layer served through a cached plan, at a set of
/// batch sizes the coordinator's batcher can pad to.
pub struct PlanEngine {
    manifest: Manifest,
    shape: ConvShape,
    plan: Box<dyn ConvPlan>,
    scratch: Mutex<Scratch>,
    image_in: usize,
    image_out: usize,
    h_o: usize,
    w_o: usize,
}

impl PlanEngine {
    /// Plan `shape` x `kernel` on `backend` (a registry name or
    /// `"auto"`) and expose it as batch models `{prefix}_b{N}` for each
    /// `N` in `batch_sizes`. Inputs/outputs cross the interface in
    /// conventional flat NCHW per image; layout packing happens inside,
    /// against the cached staging buffers.
    pub fn new(
        shape: &ConvShape,
        kernel: &Tensor,
        backend: &str,
        machine: &Machine,
        threads: usize,
        batch_sizes: &[usize],
        prefix: &str,
    ) -> Result<PlanEngine> {
        if batch_sizes.is_empty() || batch_sizes.contains(&0) {
            return Err(Error::Runtime("batch_sizes must be non-empty and non-zero".into()));
        }
        let registry = BackendRegistry::default();
        let plan = registry.plan(backend, shape, kernel, machine, threads)?;
        let image_in = shape.c_i * shape.h_i * shape.w_i;
        let (h_o, w_o) = (shape.h_o(), shape.w_o());
        let image_out = shape.c_o * h_o * w_o;
        let mut sizes: Vec<usize> = batch_sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        let models = sizes
            .iter()
            .map(|&b| Artifact {
                name: format!("{prefix}_b{b}"),
                file: "<native-plan>".into(),
                kind: "cnn".into(),
                batch: b,
                input_shape: vec![b, shape.c_i, shape.h_i, shape.w_i],
                output_shape: vec![b, shape.c_o, h_o, w_o],
                flops: shape.flops() * b as u64,
                golden: None,
            })
            .collect();
        let scratch = Scratch {
            staged_in: vec![0.0; image_in],
            native_out: vec![0.0; image_out],
            workspace: vec![0.0; plan.workspace_len()],
        };
        Ok(PlanEngine {
            manifest: Manifest { models, layers: Vec::new() },
            shape: shape.clone(),
            plan,
            scratch: Mutex::new(scratch),
            image_in,
            image_out,
            h_o,
            w_o,
        })
    }

    /// The cached plan (backend name, memory accounting, ...).
    pub fn plan(&self) -> &dyn ConvPlan {
        self.plan.as_ref()
    }

    /// The served layer shape.
    pub fn shape(&self) -> &ConvShape {
        &self.shape
    }
}

impl ModelExecutor for PlanEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let art = self
            .manifest
            .get(model)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{model}'")))?;
        let b = art.batch;
        if input.len() != b * self.image_in {
            return Err(Error::Shape(format!(
                "artifact '{model}' wants {} elements (shape {:?}), got {}",
                b * self.image_in,
                art.input_shape,
                input.len()
            )));
        }
        let s = &self.shape;
        let mut scr = self.scratch.lock().map_err(|_| {
            Error::Runtime("plan engine scratch poisoned by a previous panic".into())
        })?;
        let Scratch { staged_in, native_out, workspace } = &mut *scr;
        // The reply buffer is the single per-batch allocation.
        let mut out = vec![0.0f32; b * self.image_out];
        for i in 0..b {
            let img = &input[i * self.image_in..][..self.image_in];
            let native_in: &[f32] = match self.plan.input_layout() {
                IoLayout::Nchw => img,
                IoLayout::Nhwc => {
                    nchw_to_nhwc_slice(img, s.c_i, s.h_i, s.w_i, staged_in)?;
                    &staged_in[..]
                }
                IoLayout::Blocked { c_b } => {
                    pack_io_slice(img, s.c_i, s.h_i, s.w_i, c_b, staged_in)?;
                    &staged_in[..]
                }
            };
            self.plan.execute_into(native_in, native_out, workspace)?;
            let dst = &mut out[i * self.image_out..][..self.image_out];
            match self.plan.output_layout() {
                IoLayout::Nchw => dst.copy_from_slice(native_out),
                IoLayout::Nhwc => nhwc_to_nchw_slice(native_out, s.c_o, self.h_o, self.w_o, dst)?,
                IoLayout::Blocked { c_b } => {
                    unpack_io_slice(native_out, s.c_o, self.h_o, self.w_o, c_b, dst)?
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;

    #[test]
    fn serves_batches_matching_the_oracle() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let kernel = Tensor::random(&[16, 8, 3, 3], 3);
        let m = haswell();
        let eng = PlanEngine::new(&s, &kernel, "direct", &m, 1, &[1, 2, 4], "conv").unwrap();
        assert_eq!(eng.plan().backend(), "direct");
        assert_eq!(eng.manifest().cnn_batches(), vec![1, 2, 4]);

        // Two images through the b2 model vs per-image oracle.
        let i0 = Tensor::random(&[8, 10, 10], 10);
        let i1 = Tensor::random(&[8, 10, 10], 11);
        let mut batch = i0.data().to_vec();
        batch.extend_from_slice(i1.data());
        let out = eng.run("conv_b2", batch).unwrap();
        for (idx, img) in [i0, i1].iter().enumerate() {
            let want = conv_naive(img, &kernel, &s).unwrap();
            let got = Tensor::from_vec(&[16, 10, 10], out[idx * want.len()..][..want.len()].to_vec())
                .unwrap();
            assert!(got.allclose(&want, 1e-3, 1e-4), "image {idx}");
        }
    }

    #[test]
    fn rejects_unknown_model_and_bad_sizes() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let kernel = Tensor::random(&[8, 4, 3, 3], 3);
        let m = haswell();
        let eng = PlanEngine::new(&s, &kernel, "auto", &m, 1, &[1], "conv").unwrap();
        assert!(eng.run("conv_b9", vec![0.0; 4 * 6 * 6]).is_err());
        assert!(eng.run("conv_b1", vec![0.0; 7]).is_err());
        assert!(PlanEngine::new(&s, &kernel, "auto", &m, 1, &[], "conv").is_err());
    }
}

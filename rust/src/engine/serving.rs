//! Native serving executors behind the coordinator's [`ModelExecutor`]
//! interface:
//!
//! * [`PlanEngine`] — one conv layer through a cached [`ConvPlan`];
//! * [`NetEngine`] — a whole network through a [`NetRunner`], with batch
//!   items fanned out across a scoped worker pool (one [`NetArena`] per
//!   worker, so the workers never contend and never allocate).
//!
//! Both are the zero-overhead hot path the ROADMAP's serving north-star
//! needs: plans (pre-transformed weights), staging buffers and
//! workspaces are all built once at construction and reused for every
//! request of every batch — per request, the conv path allocates
//! nothing. (The reply buffer handed back through the coordinator's
//! channel is the one per-batch allocation; it is the message, not conv
//! state.)

use super::{BackendRegistry, ConvPlan, NetArena, NetRunner};
use crate::arch::Machine;
use crate::conv::ConvShape;
use crate::layout::{
    nchw_to_nhwc_slice, nhwc_to_nchw_slice, pack_io_slice, unpack_io_slice, IoLayout,
};
use crate::runtime::{Artifact, Manifest, ModelExecutor};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::Mutex;

/// Build the `{prefix}_b{N}` batch-artifact manifest both native
/// engines expose: one `cnn` artifact per (deduped, ascending) batch
/// size over the given per-image input/output dims and FLOP count.
fn batch_manifest(
    prefix: &str,
    batch_sizes: &[usize],
    image_dims: (&[usize], &[usize]),
    flops_per_image: u64,
    file: &str,
) -> Result<Manifest> {
    if batch_sizes.is_empty() || batch_sizes.contains(&0) {
        return Err(Error::Runtime("batch_sizes must be non-empty and non-zero".into()));
    }
    let (in_dims, out_dims) = image_dims;
    let mut sizes: Vec<usize> = batch_sizes.to_vec();
    sizes.sort_unstable();
    sizes.dedup();
    let models = sizes
        .iter()
        .map(|&b| {
            let dims = |d: &[usize]| {
                let mut v = Vec::with_capacity(d.len() + 1);
                v.push(b);
                v.extend_from_slice(d);
                v
            };
            Artifact {
                name: format!("{prefix}_b{b}"),
                file: file.into(),
                kind: "cnn".into(),
                batch: b,
                input_shape: dims(in_dims),
                output_shape: dims(out_dims),
                flops: flops_per_image * b as u64,
                golden: None,
            }
        })
        .collect();
    Ok(Manifest { models, layers: Vec::new() })
}

/// Reused per-execution buffers (one set per engine; requests are
/// serialized by the coordinator's single worker).
struct Scratch {
    /// Native-layout input staging (unused when the plan consumes NCHW).
    staged_in: Vec<f32>,
    /// Native-layout output.
    native_out: Vec<f32>,
    /// Plan workspace ([`ConvPlan::workspace_len`] floats).
    workspace: Vec<f32>,
}

/// A single conv layer served through a cached plan, at a set of
/// batch sizes the coordinator's batcher can pad to.
pub struct PlanEngine {
    manifest: Manifest,
    shape: ConvShape,
    plan: Box<dyn ConvPlan>,
    scratch: Mutex<Scratch>,
    image_in: usize,
    image_out: usize,
    h_o: usize,
    w_o: usize,
}

impl PlanEngine {
    /// Plan `shape` x `kernel` on `backend` (a registry name or
    /// `"auto"`) and expose it as batch models `{prefix}_b{N}` for each
    /// `N` in `batch_sizes`. Inputs/outputs cross the interface in
    /// conventional flat NCHW per image; layout packing happens inside,
    /// against the cached staging buffers.
    pub fn new(
        shape: &ConvShape,
        kernel: &Tensor,
        backend: &str,
        machine: &Machine,
        threads: usize,
        batch_sizes: &[usize],
        prefix: &str,
    ) -> Result<PlanEngine> {
        let plan = BackendRegistry::shared().plan(backend, shape, kernel, machine, threads)?;
        let image_in = shape.c_i * shape.h_i * shape.w_i;
        let (h_o, w_o) = (shape.h_o(), shape.w_o());
        let image_out = shape.c_o * h_o * w_o;
        let manifest = batch_manifest(
            prefix,
            batch_sizes,
            (&[shape.c_i, shape.h_i, shape.w_i], &[shape.c_o, h_o, w_o]),
            shape.flops(),
            "<native-plan>",
        )?;
        let scratch = Scratch {
            staged_in: vec![0.0; image_in],
            native_out: vec![0.0; image_out],
            workspace: vec![0.0; plan.workspace_len()],
        };
        Ok(PlanEngine {
            manifest,
            shape: shape.clone(),
            plan,
            scratch: Mutex::new(scratch),
            image_in,
            image_out,
            h_o,
            w_o,
        })
    }

    /// The cached plan (backend name, memory accounting, ...).
    pub fn plan(&self) -> &dyn ConvPlan {
        self.plan.as_ref()
    }

    /// The served layer shape.
    pub fn shape(&self) -> &ConvShape {
        &self.shape
    }
}

impl ModelExecutor for PlanEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let art = self
            .manifest
            .get(model)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{model}'")))?;
        let b = art.batch;
        if input.len() != b * self.image_in {
            return Err(Error::Shape(format!(
                "artifact '{model}' wants {} elements (shape {:?}), got {}",
                b * self.image_in,
                art.input_shape,
                input.len()
            )));
        }
        let s = &self.shape;
        let mut scr = self.scratch.lock().map_err(|_| {
            Error::Runtime("plan engine scratch poisoned by a previous panic".into())
        })?;
        let Scratch { staged_in, native_out, workspace } = &mut *scr;
        // The reply buffer is the single per-batch allocation.
        let mut out = vec![0.0f32; b * self.image_out];
        for i in 0..b {
            let img = &input[i * self.image_in..][..self.image_in];
            let native_in: &[f32] = match self.plan.input_layout() {
                IoLayout::Nchw => img,
                IoLayout::Nhwc => {
                    nchw_to_nhwc_slice(img, s.c_i, s.h_i, s.w_i, staged_in)?;
                    &staged_in[..]
                }
                IoLayout::Blocked { c_b } => {
                    pack_io_slice(img, s.c_i, s.h_i, s.w_i, c_b, staged_in)?;
                    &staged_in[..]
                }
            };
            self.plan.execute_into(native_in, native_out, workspace)?;
            let dst = &mut out[i * self.image_out..][..self.image_out];
            match self.plan.output_layout() {
                IoLayout::Nchw => dst.copy_from_slice(native_out),
                IoLayout::Nhwc => nhwc_to_nchw_slice(native_out, s.c_o, self.h_o, self.w_o, dst)?,
                IoLayout::Blocked { c_b } => {
                    unpack_io_slice(native_out, s.c_o, self.h_o, self.w_o, c_b, dst)?
                }
            }
        }
        Ok(out)
    }
}

/// A whole network served through a [`NetRunner`], at a set of batch
/// sizes the coordinator's batcher can pad to. Batch items fan out
/// across up to `workers` scoped threads; each worker owns one
/// [`NetArena`], so the per-image forward passes are allocation-free
/// and contention-free.
pub struct NetEngine {
    manifest: Manifest,
    runner: NetRunner,
    arenas: Vec<Mutex<NetArena>>,
    image_in: usize,
    image_out: usize,
}

impl NetEngine {
    /// Expose `runner` as batch models `{prefix}_b{N}` for each `N` in
    /// `batch_sizes`, executed by a pool of `workers` threads (1 =
    /// serial). Inputs/outputs cross the interface as conventional flat
    /// NCHW per image.
    pub fn new(
        runner: NetRunner,
        workers: usize,
        batch_sizes: &[usize],
        prefix: &str,
    ) -> Result<NetEngine> {
        let flops: u64 = runner.plans().layers.iter().map(|l| l.layer.shape.flops()).sum();
        // Ask the runner for the graph's real edge shapes — the output
        // of a DAG net (GoogLeNet's final concat) is not the last conv
        // layer of the table.
        let (i, o) = (runner.input_dims(), runner.output_dims());
        let manifest = batch_manifest(
            prefix,
            batch_sizes,
            (&[i.c, i.h, i.w], &[o.c, o.h, o.w]),
            flops,
            "<net-runner>",
        )?;
        let arenas = (0..workers.max(1)).map(|_| Mutex::new(runner.arena())).collect();
        Ok(NetEngine {
            manifest,
            image_in: runner.input_len(),
            image_out: runner.output_len(),
            runner,
            arenas,
        })
    }

    /// The compiled network (aggregate accounting, layer plans).
    pub fn runner(&self) -> &NetRunner {
        &self.runner
    }

    /// Worker-pool width (number of per-worker arenas).
    pub fn workers(&self) -> usize {
        self.arenas.len()
    }

    fn run_images(&self, arena: &mut NetArena, input: &[f32], output: &mut [f32]) -> Result<()> {
        let ins = input.chunks(self.image_in);
        let outs = output.chunks_mut(self.image_out);
        for (img, dst) in ins.zip(outs) {
            self.runner.forward_with(arena, img, dst)?;
        }
        Ok(())
    }
}

impl ModelExecutor for NetEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let art = self
            .manifest
            .get(model)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{model}'")))?;
        let b = art.batch;
        if input.len() != b * self.image_in {
            return Err(Error::Shape(format!(
                "artifact '{model}' wants {} elements (shape {:?}), got {}",
                b * self.image_in,
                art.input_shape,
                input.len()
            )));
        }
        // The reply buffer is the single per-batch allocation.
        let mut out = vec![0.0f32; b * self.image_out];
        let workers = self.arenas.len().min(b).max(1);
        if workers <= 1 {
            let mut arena = self.arenas[0]
                .lock()
                .map_err(|_| Error::Runtime("net arena poisoned by a previous panic".into()))?;
            self.run_images(&mut arena, &input, &mut out)?;
            return Ok(out);
        }
        // Fan the batch out across the worker pool: contiguous image
        // ranges, one scoped thread and one arena per worker.
        let per = b.div_ceil(workers);
        let chunk_in = per * self.image_in;
        let chunk_out = per * self.image_out;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(workers);
            let chunks = input.chunks(chunk_in).zip(out.chunks_mut(chunk_out));
            for (w, (ichunk, ochunk)) in chunks.enumerate() {
                let arena_mx = &self.arenas[w];
                handles.push(scope.spawn(move || -> Result<()> {
                    let mut arena = arena_mx.lock().map_err(|_| {
                        Error::Runtime("net arena poisoned by a previous panic".into())
                    })?;
                    self.run_images(&mut arena, ichunk, ochunk)
                }));
            }
            for h in handles {
                h.join().map_err(|_| Error::Runtime("net worker panicked".into()))??;
            }
            Ok(())
        })?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;

    #[test]
    fn serves_batches_matching_the_oracle() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let kernel = Tensor::random(&[16, 8, 3, 3], 3);
        let m = haswell();
        let eng = PlanEngine::new(&s, &kernel, "direct", &m, 1, &[1, 2, 4], "conv").unwrap();
        assert_eq!(eng.plan().backend(), "direct");
        assert_eq!(eng.manifest().cnn_batches(), vec![1, 2, 4]);

        // Two images through the b2 model vs per-image oracle.
        let i0 = Tensor::random(&[8, 10, 10], 10);
        let i1 = Tensor::random(&[8, 10, 10], 11);
        let mut batch = i0.data().to_vec();
        batch.extend_from_slice(i1.data());
        let out = eng.run("conv_b2", batch).unwrap();
        for (idx, img) in [i0, i1].iter().enumerate() {
            let want = conv_naive(img, &kernel, &s).unwrap();
            let logits = out[idx * want.len()..][..want.len()].to_vec();
            let got = Tensor::from_vec(&[16, 10, 10], logits).unwrap();
            assert!(got.allclose(&want, 1e-3, 1e-4), "image {idx}");
        }
    }

    fn chain_runner(seed: u64) -> NetRunner {
        use crate::nets::NetPlans;
        let shapes = [
            ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
            ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
        ];
        let plans = NetPlans::from_shapes("chain", &shapes, "direct", &haswell(), seed).unwrap();
        NetRunner::new(plans).unwrap()
    }

    #[test]
    fn net_engine_worker_pool_matches_serial() {
        let e1 = NetEngine::new(chain_runner(11), 1, &[4], "net").unwrap();
        let e4 = NetEngine::new(chain_runner(11), 4, &[4], "net").unwrap();
        assert_eq!(e1.workers(), 1);
        assert_eq!(e4.workers(), 4);
        assert_eq!(e1.manifest().cnn_batches(), vec![4]);

        let image_in = e1.runner().input_len();
        let mut batch = Vec::new();
        for i in 0..4u64 {
            batch.extend_from_slice(Tensor::random(&[image_in], 100 + i).data());
        }
        let o1 = e1.run("net_b4", batch.clone()).unwrap();
        let o4 = e4.run("net_b4", batch.clone()).unwrap();
        assert_eq!(o1, o4, "worker pool must be bitwise identical to serial");

        // The first batch item matches the one-shot forward path.
        let img = Tensor::from_vec(&[8, 12, 12], batch[..image_in].to_vec()).unwrap();
        let want = e1.runner().forward(&img).unwrap();
        assert_eq!(&o1[..want.len()], want.data());

        assert!(e1.run("net_b9", batch.clone()).is_err());
        assert!(e1.run("net_b4", vec![0.0; 3]).is_err());
        assert!(NetEngine::new(chain_runner(11), 2, &[], "net").is_err());
    }

    #[test]
    fn rejects_unknown_model_and_bad_sizes() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let kernel = Tensor::random(&[8, 4, 3, 3], 3);
        let m = haswell();
        let eng = PlanEngine::new(&s, &kernel, "auto", &m, 1, &[1], "conv").unwrap();
        assert!(eng.run("conv_b9", vec![0.0; 4 * 6 * 6]).is_err());
        assert!(eng.run("conv_b1", vec![0.0; 7]).is_err());
        assert!(PlanEngine::new(&s, &kernel, "auto", &m, 1, &[], "conv").is_err());
    }
}

//! [`ConvAlgo`]/[`ConvPlan`] implementations for every convolution
//! algorithm in the crate. Each plan owns its pre-transformed weights
//! and executes through the allocation-free `*_into` kernel cores.

use super::{check_execute_buffers, retained_over_kernel, ConvAlgo, ConvPlan};
use crate::arch::Machine;
use crate::conv::reorder::kernel_to_hwio;
use crate::conv::{
    conv_direct_blocked_ep_into, conv_direct_blocked_into, conv_naive_into, conv_reorder_into,
    select_params, BlockParams, ConvShape, Epilogue,
};
use crate::fftconv::FftConvPlan;
use crate::layout::{to_blocked_kernel, IoLayout};
use crate::lowering::conv_im2col_into;
use crate::tensor::Tensor;
use crate::winograd::{
    conv_winograd_into, transform_kernels, winograd_applicable, winograd_workspace_len,
};
use crate::Result;

fn check_plan_inputs(shape: &ConvShape, kernel: &Tensor) -> Result<()> {
    shape.validate()?;
    let want = [shape.c_o, shape.c_i_per_group(), shape.h_f, shape.w_f];
    if kernel.shape() != want {
        return Err(crate::Error::Shape(format!(
            "plan kernel shape {:?} != expected {:?}",
            kernel.shape(),
            want
        )));
    }
    Ok(())
}

/// True for plain dense convolutions. The §2 comparator backends
/// (`reorder`, `im2col`, `fft`, `winograd`) predate grouped/dilated
/// support and only run those; `direct`, `direct_i8` and the `naive`
/// oracle handle the general case.
fn dense_only(shape: &ConvShape) -> bool {
    shape.groups == 1 && shape.dilation == 1
}

// ---------------------------------------------------------------------
// direct — Algorithm 3 (the paper's contribution)
// ---------------------------------------------------------------------

/// The paper's blocked direct convolution: §4 layouts, analytic
/// `C_o,b x W_o,b x C_i,b` blocking, zero memory overhead.
pub struct DirectBackend;

struct DirectPlan {
    shape: ConvShape,
    bp: BlockParams,
    threads: usize,
    /// §4 kernel layout `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]` —
    /// a pure permutation of the OIHW weights (same byte count).
    kernel: Tensor,
}

impl ConvAlgo for DirectBackend {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok()
    }

    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        let bp = select_params(machine, shape);
        bp.validate_for(shape)?;
        // Depthwise kernels have one input channel per filter, so the
        // blocked layout collapses to `[C/c_b][H_f][W_f][c_b]` (c_ib=1).
        let k_cib = if shape.is_depthwise() { 1 } else { bp.c_ib };
        let packed = to_blocked_kernel(kernel, bp.c_ob, k_cib)?;
        Ok(Box::new(DirectPlan {
            shape: shape.clone(),
            bp,
            threads: threads.max(1),
            kernel: packed,
        }))
    }
}

impl ConvPlan for DirectPlan {
    fn backend(&self) -> &'static str {
        "direct"
    }
    fn kernel_desc(&self) -> &'static str {
        if self.shape.is_depthwise() {
            crate::conv::dispatch::kernel_label_f32_dw(self.bp.c_ob)
        } else {
            crate::conv::dispatch::kernel_label_f32(self.bp.c_ob)
        }
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ib }
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ob }
    }
    fn retained_bytes(&self) -> u64 {
        // The blocked kernel is a permutation: exactly kernel_bytes().
        retained_over_kernel(&self.shape, 4 * self.kernel.len() as u64)
    }
    fn workspace_len(&self) -> usize {
        0
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        let ker = self.kernel.data();
        conv_direct_blocked_into(input, ker, &self.shape, self.bp, self.threads, output)
    }
    fn execute_fused_into(
        &self,
        input: &[f32],
        output: &mut [f32],
        workspace: &mut [f32],
        ep: &Epilogue,
        res: Option<&[f32]>,
    ) -> Result<()> {
        // True in-tile fusion: the epilogue runs on the register tile
        // of the last C_i,b pass, before its store — no second sweep
        // over the output. Bitwise identical to the trait default.
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        let ker = self.kernel.data();
        conv_direct_blocked_ep_into(
            input, ker, &self.shape, self.bp, self.threads, output, ep, res,
        )
    }
}

// ---------------------------------------------------------------------
// reorder — Algorithm 2
// ---------------------------------------------------------------------

/// The paper's reordered loop nest over channel-last data (Algorithm 2);
/// the unblocked midpoint between naive and direct.
pub struct ReorderBackend;

struct ReorderPlan {
    shape: ConvShape,
    /// HWIO weights `[H_f][W_f][C_i][C_o]` — a pure permutation.
    kernel: Tensor,
}

impl ConvAlgo for ReorderBackend {
    fn name(&self) -> &'static str {
        "reorder"
    }
    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok() && dense_only(shape)
    }
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        _machine: &Machine,
        _threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        if !dense_only(shape) {
            return Err(crate::Error::Shape("reorder supports only dense convs".into()));
        }
        Ok(Box::new(ReorderPlan { shape: shape.clone(), kernel: kernel_to_hwio(kernel)? }))
    }
}

impl ConvPlan for ReorderPlan {
    fn backend(&self) -> &'static str {
        "reorder"
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Nhwc
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Nhwc
    }
    fn retained_bytes(&self) -> u64 {
        retained_over_kernel(&self.shape, 4 * self.kernel.len() as u64)
    }
    fn workspace_len(&self) -> usize {
        0
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        conv_reorder_into(input, self.kernel.data(), &self.shape, output)
    }
}

// ---------------------------------------------------------------------
// naive — Algorithm 1 (correctness oracle)
// ---------------------------------------------------------------------

/// The six-loop oracle (Algorithm 1). Zero overhead, deliberately slow;
/// the conformance reference every other backend is checked against.
pub struct NaiveBackend;

struct NaivePlan {
    shape: ConvShape,
    /// OIHW weights, held as-is.
    kernel: Tensor,
}

impl ConvAlgo for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }
    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok()
    }
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        _machine: &Machine,
        _threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        Ok(Box::new(NaivePlan { shape: shape.clone(), kernel: kernel.clone() }))
    }
}

impl ConvPlan for NaivePlan {
    fn backend(&self) -> &'static str {
        "naive"
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn retained_bytes(&self) -> u64 {
        retained_over_kernel(&self.shape, 4 * self.kernel.len() as u64)
    }
    fn workspace_len(&self) -> usize {
        0
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        conv_naive_into(input, self.kernel.data(), &self.shape, output)
    }
}

// ---------------------------------------------------------------------
// im2col — Caffe lowering + Goto SGEMM (§2.2 comparator)
// ---------------------------------------------------------------------

/// Caffe's im2col lowering followed by the crate's Goto SGEMM. The
/// lowered matrix is the workspace the paper's §2.2 overhead analysis
/// charges this approach with.
pub struct Im2colBackend;

struct Im2colPlan {
    shape: ConvShape,
    /// OIHW weights; the GEMM reads them as `C_o x (C_i*H_f*W_f)`.
    kernel: Tensor,
    threads: usize,
}

impl ConvAlgo for Im2colBackend {
    fn name(&self) -> &'static str {
        "im2col"
    }
    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok() && dense_only(shape)
    }
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        _machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        if !dense_only(shape) {
            return Err(crate::Error::Shape("im2col supports only dense convs".into()));
        }
        Ok(Box::new(Im2colPlan {
            shape: shape.clone(),
            kernel: kernel.clone(),
            threads: threads.max(1),
        }))
    }
}

impl ConvPlan for Im2colPlan {
    fn backend(&self) -> &'static str {
        "im2col"
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn retained_bytes(&self) -> u64 {
        retained_over_kernel(&self.shape, 4 * self.kernel.len() as u64)
    }
    fn workspace_len(&self) -> usize {
        let s = &self.shape;
        s.c_i * s.h_f * s.w_f * s.h_o() * s.w_o()
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, self.workspace_len(), input, output, workspace)?;
        conv_im2col_into(input, self.kernel.data(), &self.shape, self.threads, output, workspace)
    }
}

// ---------------------------------------------------------------------
// fft — NNPACK-style frequency-domain convolution (§2.1 comparator)
// ---------------------------------------------------------------------

/// Frequency-domain convolution with precomputed kernel spectra (the
/// NNPACK inference mode). Retains the §2.1 memory blow-up the paper
/// describes: each `H_f x W_f` kernel becomes an `N x N` complex grid.
pub struct FftBackend;

struct FftPlan {
    inner: FftConvPlan,
}

impl ConvAlgo for FftBackend {
    fn name(&self) -> &'static str {
        "fft"
    }
    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok() && dense_only(shape)
    }
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        _machine: &Machine,
        _threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        if !dense_only(shape) {
            return Err(crate::Error::Shape("fft supports only dense convs".into()));
        }
        Ok(Box::new(FftPlan { inner: FftConvPlan::new(kernel, shape)? }))
    }
}

impl ConvPlan for FftPlan {
    fn backend(&self) -> &'static str {
        "fft"
    }
    fn shape(&self) -> &ConvShape {
        self.inner.shape()
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn retained_bytes(&self) -> u64 {
        retained_over_kernel(self.inner.shape(), self.inner.retained_bytes())
    }
    fn workspace_len(&self) -> usize {
        self.inner.workspace_len()
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(self.inner.shape(), self.workspace_len(), input, output, workspace)?;
        self.inner.run_into(input, output, workspace)
    }
}

// ---------------------------------------------------------------------
// winograd — F(2x2, 3x3) (§2 comparator for 3x3/s1 layers)
// ---------------------------------------------------------------------

/// Winograd F(2x2,3x3) over pre-transformed weights. Only applicable to
/// 3x3/stride-1 layers; retains the 16/9-sized transformed weights.
pub struct WinogradBackend;

struct WinogradPlan {
    shape: ConvShape,
    /// Transformed weights `U[C_o][C_i][16]`.
    u: Vec<f32>,
}

impl ConvAlgo for WinogradBackend {
    fn name(&self) -> &'static str {
        "winograd"
    }
    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok() && dense_only(shape) && winograd_applicable(shape)
    }
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        _machine: &Machine,
        _threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        check_plan_inputs(shape, kernel)?;
        if !dense_only(shape) {
            return Err(crate::Error::Shape("winograd supports only dense convs".into()));
        }
        Ok(Box::new(WinogradPlan { shape: shape.clone(), u: transform_kernels(kernel, shape)? }))
    }
}

impl ConvPlan for WinogradPlan {
    fn backend(&self) -> &'static str {
        "winograd"
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Nchw
    }
    fn retained_bytes(&self) -> u64 {
        retained_over_kernel(&self.shape, 4 * self.u.len() as u64)
    }
    fn workspace_len(&self) -> usize {
        winograd_workspace_len(&self.shape)
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, self.workspace_len(), input, output, workspace)?;
        conv_winograd_into(input, &self.u, &self.shape, output, workspace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;

    #[test]
    fn plans_report_paper_overheads() {
        let s = ConvShape::new(16, 13, 13, 32, 3, 3, 1, 1);
        let k = Tensor::random(&[32, 16, 3, 3], 7);
        let m = haswell();

        let direct = DirectBackend.plan(&s, &k, &m, 1).unwrap();
        assert_eq!(direct.retained_bytes(), 0, "§4 layouts are permutations");
        assert_eq!(direct.workspace_bytes(), 0, "zero-memory-overhead claim");

        let reorder = ReorderBackend.plan(&s, &k, &m, 1).unwrap();
        assert_eq!(reorder.retained_bytes() + reorder.workspace_bytes(), 0);

        let im2col = Im2colBackend.plan(&s, &k, &m, 1).unwrap();
        assert_eq!(im2col.retained_bytes(), 0);
        assert_eq!(im2col.workspace_bytes(), s.im2col_bytes());

        let fft = FftBackend.plan(&s, &k, &m, 1).unwrap();
        assert!(fft.retained_bytes() > 4 * s.kernel_bytes(), "§2.1 blow-up");

        let wino = WinogradBackend.plan(&s, &k, &m, 1).unwrap();
        // 16/9 transformed weights minus the 3x3 weights they replace.
        assert_eq!(wino.retained_bytes(), 4u64 * 16 * 32 * 16 - s.kernel_bytes());
    }

    #[test]
    fn winograd_rejects_non_3x3() {
        let s = ConvShape::new(4, 9, 9, 8, 5, 5, 1, 2);
        let k = Tensor::zeros(&[8, 4, 5, 5]);
        assert!(!WinogradBackend.applicable(&s));
        assert!(WinogradBackend.plan(&s, &k, &haswell(), 1).is_err());
    }

    #[test]
    fn plan_rejects_mismatched_kernel() {
        let s = ConvShape::new(4, 9, 9, 8, 3, 3, 1, 1);
        let bad = Tensor::zeros(&[8, 4, 3, 2]);
        assert!(DirectBackend.plan(&s, &bad, &haswell(), 1).is_err());
        assert!(Im2colBackend.plan(&s, &bad, &haswell(), 1).is_err());
    }
}

//! The crate-wide plan/execute convolution API.
//!
//! # Lifecycle
//!
//! Every convolution backend in the crate — the paper's direct
//! convolution and all of its §2 comparators — is exposed through one
//! two-phase contract:
//!
//! 1. **Plan** ([`ConvAlgo::plan`]): given the layer shape, the OIHW
//!    weights, a [`Machine`] descriptor and a thread count, the backend
//!    performs every per-layer pre-transform *once* — blocking-parameter
//!    selection and §4 kernel packing for `direct`, the HWIO permutation
//!    for `reorder`, kernel spectra for `fft`, transformed weights for
//!    `winograd` — and returns a [`ConvPlan`] that owns that state.
//! 2. **Execute** ([`ConvPlan::execute_into`]): the hot path. Operands
//!    are flat `f32` slices in the plan's native layouts
//!    ([`ConvPlan::input_layout`] / [`ConvPlan::output_layout`]) plus a
//!    caller-owned scratch buffer of exactly
//!    [`ConvPlan::workspace_len`] floats. The call allocates nothing:
//!    a serving loop plans once per layer, allocates output + workspace
//!    once, and executes per request at zero memory cost. Two bounded
//!    exceptions: `direct` planned with `threads > 1` allocates scoped
//!    thread-spawn bookkeeping, and `im2col`'s Goto SGEMM packs its
//!    panels into small internal buffers (capped by the GEMM's cache
//!    block sizes, independent of layer shape and request count);
//!    everything proportional to the tensors is caller-owned.
//!
//! [`ConvPlan::execute`] is the allocating one-shot convenience (NCHW
//! in, NCHW out, layouts converted at the edges) used by tests, CLI
//! commands and examples.
//!
//! # Memory-overhead accounting contract
//!
//! The paper's headline claim is *zero memory overhead*: direct
//! convolution touches only the input, kernel and output bytes a layer
//! intrinsically needs. Every plan reports its deviation from that
//! budget through two numbers:
//!
//! * [`ConvPlan::retained_bytes`] — bytes the plan holds *for its
//!   lifetime* beyond the layer's conventional weight storage
//!   ([`ConvShape::kernel_bytes`]). A plan's packed weights *replace*
//!   the caller's kernel (which may be dropped after planning), so pure
//!   permutations — the §4 blocked layout, HWIO — retain **0** extra
//!   bytes, while `fft` retains its `8·N²·C_o·C_i`-byte spectra minus
//!   the weights they replace and `winograd` retains the `16/9`-sized
//!   transformed weights minus the same.
//! * [`ConvPlan::workspace_bytes`] — transient scratch bytes
//!   `execute_into` needs per call (the caller owns and reuses them).
//!   `im2col` reports its lowered matrix here; `direct`, `reorder` and
//!   `naive` report **0**.
//!
//! `retained_bytes() + workspace_bytes() == 0` is therefore exactly the
//! paper's zero-overhead property, and holds for the `direct` backend
//! on every benchmark layer (asserted by the conformance suite).
//!
//! # Backends
//!
//! [`BackendRegistry`] maps names to implementations:
//!
//! | name        | algorithm                                   | overhead        |
//! |-------------|---------------------------------------------|-----------------|
//! | `direct`    | Algorithm 3, §4 layouts, analytic blocking  | 0               |
//! | `reorder`   | Algorithm 2, channel-last loop order        | 0               |
//! | `naive`     | Algorithm 1 oracle                          | 0 (but slow)    |
//! | `im2col`    | Caffe lowering + Goto SGEMM                 | workspace       |
//! | `fft`       | NNPACK-style frequency domain               | retained        |
//! | `winograd`  | F(2x2,3x3), 3x3/stride-1 only               | retained        |
//! | `direct_i8` | int8 Algorithm 3, i32 acc + fused requant   | 0 (4x smaller)  |
//!
//! `registry.auto(&shape, &machine)` (or the name `"auto"`) picks the
//! best applicable backend for a layer: `direct` whenever its analytic
//! output-channel block vectorizes on the machine, else `winograd` for
//! eligible 3x3/s1 layers, else `im2col`.
//!
//! [`PlanEngine`] closes the loop with serving: it implements the
//! coordinator's executor interface on top of a cached plan, so batched
//! requests run through `execute_into` with every buffer reused.
//!
//! # Whole networks
//!
//! [`NetRunner`] lifts the per-layer contract to entire networks:
//! every layer of a [`crate::nets::NetPlans`] table planned once, the
//! net's [`crate::nets::NetGraph`] (built by [`crate::nets::GraphBuilder`]
//! or a JSON model spec: GoogLeNet's inception modules as real fan-out
//! branches joined by channel concats, AlexNet/VGG as trivial chains,
//! residual ResNet-style `Add` joins) compiled to a flat schedule, and
//! every activation
//! placed in ONE arena by a liveness-driven region allocator sized by
//! the max live-set — plus the largest per-layer workspace, shared
//! across layers. The forward pass replays the schedule through
//! repeated `execute_into`, allocation-free — the zero-overhead claim
//! asserted network-wide over the true dataflow. [`NetEngine`] serves
//! it: batch items fan out across a scoped worker pool, each worker
//! owning its own arena.

mod backends;
mod net_runner;
mod registry;
mod serving;

pub use backends::{
    DirectBackend, FftBackend, Im2colBackend, NaiveBackend, ReorderBackend, WinogradBackend,
};
pub use net_runner::{
    adapt_nchw, add_nchw, avg_pool_nchw, pool_nchw, ArenaRegion, NetArena, NetRunner,
};
pub use registry::{BackendRegistry, BACKEND_NAMES};
pub use serving::{NetEngine, PlanEngine};

use crate::arch::Machine;
use crate::conv::{apply_post, ConvShape, Epilogue};
use crate::layout::{from_blocked_io, nchw_to_nhwc, nhwc_to_nchw, to_blocked_io, IoLayout};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A convolution algorithm: a factory for per-layer [`ConvPlan`]s.
pub trait ConvAlgo: Send + Sync {
    /// Registry name (`"direct"`, `"im2col"`, ...).
    fn name(&self) -> &'static str;

    /// Whether the backend can run this layer at all (e.g. Winograd
    /// F(2x2,3x3) requires 3x3/stride-1). [`ConvAlgo::plan`] fails on
    /// non-applicable shapes.
    fn applicable(&self, shape: &ConvShape) -> bool;

    /// Build the per-layer plan: select parameters from the machine
    /// model and pre-transform `kernel` (`[C_o][C_i][H_f][W_f]`) into
    /// the backend's execution form. `threads` is retained by backends
    /// that parallelize (`direct`, `im2col`); others execute
    /// single-threaded.
    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>>;
}

/// A planned convolution layer: pre-transformed weights plus everything
/// needed to execute allocation-free. See the module docs for the
/// lifecycle and the memory-accounting contract.
pub trait ConvPlan: Send + Sync {
    /// Name of the backend that produced this plan.
    fn backend(&self) -> &'static str;

    /// Short label of the compute kernel `execute_into` will run —
    /// the runtime-dispatched microkernel for the direct backends
    /// (`"avx2-fma"`, `"neon-fma"`, `"avx2-widen"`, ...; see
    /// [`crate::conv::dispatch`]), `"scalar"` for the comparator
    /// backends. Informational: plan tables and the CLI print it so
    /// the selected ISA is auditable per layer.
    fn kernel_desc(&self) -> &'static str {
        "scalar"
    }

    /// The layer shape the plan was built for.
    fn shape(&self) -> &ConvShape;

    /// Layout `execute_into` expects the input slice in.
    fn input_layout(&self) -> IoLayout;

    /// Layout `execute_into` produces the output slice in.
    fn output_layout(&self) -> IoLayout;

    /// Bytes retained for the plan's lifetime beyond the conventional
    /// kernel storage (see module docs).
    fn retained_bytes(&self) -> u64;

    /// Per-execution scratch bytes (`4 * workspace_len()`).
    fn workspace_bytes(&self) -> u64 {
        4 * self.workspace_len() as u64
    }

    /// Scratch floats `execute_into` requires. `0` for zero-overhead
    /// backends.
    fn workspace_len(&self) -> usize;

    /// The plan's native int8 execution surface, if it has one. The
    /// quantized backend (`direct_i8`) returns itself here so the
    /// whole-network executor can run it on an i8 byte arena
    /// ([`crate::quant::QuantExecute`]); f32 backends return `None`.
    fn as_quantized(&self) -> Option<&dyn crate::quant::QuantExecute> {
        None
    }

    /// Execute the layer on the hot path. `input` must hold
    /// `C_i*H_i*W_i` floats in [`Self::input_layout`], `output`
    /// `C_o*H_o*W_o` floats (overwritten) in [`Self::output_layout`],
    /// `workspace` exactly [`Self::workspace_len`] floats (clobbered).
    /// Allocation-free; buffers are validated by length.
    fn execute_into(
        &self,
        input: &[f32],
        output: &mut [f32],
        workspace: &mut [f32],
    ) -> Result<()>;

    /// Execute the layer with a fused epilogue (bias / folded BN /
    /// residual / ReLU — see [`Epilogue`]). `res`, when the epilogue
    /// demands one, is the residual operand in [`Self::output_layout`].
    ///
    /// The default implementation runs [`Self::execute_into`] and then
    /// applies the epilogue over the finished output buffer in place —
    /// allocation-free and **bitwise identical** to in-tile fusion
    /// (both run the same scalar tail in the same order), so every
    /// backend is fusion-correct for free. Backends with true in-tile
    /// fusion (`direct`, `direct_i8`) override this to skip the second
    /// pass over the output.
    fn execute_fused_into(
        &self,
        input: &[f32],
        output: &mut [f32],
        workspace: &mut [f32],
        ep: &Epilogue,
        res: Option<&[f32]>,
    ) -> Result<()> {
        self.execute_into(input, output, workspace)?;
        let s = self.shape();
        apply_post(output, self.output_layout(), s.c_o, s.h_o() * s.w_o(), ep, res)
    }

    /// Pack a conventional `[C_i][H_i][W_i]` input into the plan's
    /// native input layout (allocating convenience; staging at the
    /// network edge, §4.3).
    fn pack_input(&self, input: &Tensor) -> Result<Tensor> {
        let s = self.shape();
        let want = [s.c_i, s.h_i, s.w_i];
        if input.shape() != want {
            return Err(Error::Shape(format!(
                "input shape {:?} != expected {:?}",
                input.shape(),
                want
            )));
        }
        match self.input_layout() {
            IoLayout::Nchw => Ok(input.clone()),
            IoLayout::Nhwc => nchw_to_nhwc(input),
            IoLayout::Blocked { c_b } => to_blocked_io(input, c_b),
        }
    }

    /// Unpack a native-layout output tensor back to `[C_o][H_o][W_o]`
    /// (allocating convenience).
    fn unpack_output(&self, output: &Tensor) -> Result<Tensor> {
        match self.output_layout() {
            IoLayout::Nchw => Ok(output.clone()),
            IoLayout::Nhwc => nhwc_to_nchw(output),
            IoLayout::Blocked { .. } => from_blocked_io(output),
        }
    }

    /// One-shot convenience: NCHW input in, NCHW output out, buffers
    /// allocated internally. Not the hot path — serving loops hold the
    /// buffers and call [`Self::execute_into`] directly.
    fn execute(&self, input: &Tensor) -> Result<Tensor> {
        let s = self.shape();
        let want = [s.c_i, s.h_i, s.w_i];
        if input.shape() != want {
            return Err(Error::Shape(format!(
                "input shape {:?} != expected {:?}",
                input.shape(),
                want
            )));
        }
        let (h_o, w_o) = (s.h_o(), s.w_o());
        let staged: Option<Tensor> = match self.input_layout() {
            IoLayout::Nchw => None,
            IoLayout::Nhwc => Some(nchw_to_nhwc(input)?),
            IoLayout::Blocked { c_b } => Some(to_blocked_io(input, c_b)?),
        };
        let in_data = staged.as_ref().map(|t| t.data()).unwrap_or_else(|| input.data());
        let mut out = vec![0.0f32; s.c_o * h_o * w_o];
        let mut ws = vec![0.0f32; self.workspace_len()];
        self.execute_into(in_data, &mut out, &mut ws)?;
        match self.output_layout() {
            IoLayout::Nchw => Tensor::from_vec(&[s.c_o, h_o, w_o], out),
            IoLayout::Nhwc => {
                let t = Tensor::from_vec(&[h_o, w_o, s.c_o], out)?;
                nhwc_to_nchw(&t)
            }
            IoLayout::Blocked { c_b } => {
                let t = Tensor::from_vec(&[s.c_o / c_b, h_o, w_o, c_b], out)?;
                from_blocked_io(&t)
            }
        }
    }
}

/// Row-major dimensions of a `C x H x W` feature map in `layout`.
pub fn io_shape(layout: IoLayout, c: usize, h: usize, w: usize) -> Vec<usize> {
    match layout {
        IoLayout::Nchw => vec![c, h, w],
        IoLayout::Nhwc => vec![h, w, c],
        IoLayout::Blocked { c_b } => vec![c / c_b, h, w, c_b],
    }
}

/// Shared length validation for `execute_into` implementations.
pub(crate) fn check_execute_buffers(
    shape: &ConvShape,
    workspace_len: usize,
    input: &[f32],
    output: &[f32],
    workspace: &[f32],
) -> Result<()> {
    let n_in = shape.c_i * shape.h_i * shape.w_i;
    if input.len() != n_in {
        return Err(Error::Shape(format!(
            "execute_into input has {} elements, expected {n_in}",
            input.len()
        )));
    }
    let n_out = shape.c_o * shape.h_o() * shape.w_o();
    if output.len() != n_out {
        return Err(Error::Shape(format!(
            "execute_into output has {} elements, expected {n_out}",
            output.len()
        )));
    }
    if workspace.len() != workspace_len {
        return Err(Error::Shape(format!(
            "execute_into workspace has {} floats, expected {workspace_len}",
            workspace.len()
        )));
    }
    Ok(())
}

/// Plan-held weight bytes in excess of the conventional kernel storage
/// (the accounting rule from the module docs).
pub(crate) fn retained_over_kernel(shape: &ConvShape, held_bytes: u64) -> u64 {
    held_bytes.saturating_sub(shape.kernel_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;

    #[test]
    fn io_shape_covers_all_layouts() {
        assert_eq!(io_shape(IoLayout::Nchw, 8, 3, 4), vec![8, 3, 4]);
        assert_eq!(io_shape(IoLayout::Nhwc, 8, 3, 4), vec![3, 4, 8]);
        assert_eq!(io_shape(IoLayout::Blocked { c_b: 4 }, 8, 3, 4), vec![2, 3, 4, 4]);
    }

    #[test]
    fn plan_execute_round_trip_matches_naive() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let m = haswell();
        let input = Tensor::random(&[8, 10, 10], 1);
        let kernel = Tensor::random(&[16, 8, 3, 3], 2);
        let want = conv_naive(&input, &kernel, &s).unwrap();
        let registry = BackendRegistry::default();
        let plan = registry.get("direct").unwrap().plan(&s, &kernel, &m, 1).unwrap();
        let got = plan.execute(&input).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-4), "diff {}", got.max_abs_diff(&want));
        // pack/unpack helpers invert each other through the plan layouts
        let packed = plan.pack_input(&input).unwrap();
        assert_eq!(packed.len(), input.len(), "§4 layouts are permutations");
    }

    #[test]
    fn execute_rejects_wrong_input_shape() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let m = haswell();
        let kernel = Tensor::random(&[16, 8, 3, 3], 2);
        let registry = BackendRegistry::default();
        let plan = registry.get("direct").unwrap().plan(&s, &kernel, &m, 1).unwrap();
        let bad = Tensor::zeros(&[8, 9, 10]);
        assert!(plan.execute(&bad).is_err());
        // wrong buffer lengths on the raw path
        let mut out = vec![0.0f32; 5];
        let mut ws = vec![0.0f32; plan.workspace_len()];
        assert!(plan.execute_into(&[0.0; 3], &mut out, &mut ws).is_err());
    }
}

//! Name-indexed registry over every [`ConvAlgo`] backend, plus the
//! `auto` per-layer selector.

use super::backends::{
    DirectBackend, FftBackend, Im2colBackend, NaiveBackend, ReorderBackend, WinogradBackend,
};
use super::{ConvAlgo, ConvPlan};
use crate::arch::Machine;
use crate::conv::params::select_c_ob;
use crate::conv::ConvShape;
use crate::quant::DirectI8Backend;
use crate::tensor::Tensor;
use crate::winograd::winograd_applicable;
use crate::{Error, Result};

/// Every backend name the default registry serves, selection-priority
/// first. `"auto"` additionally resolves via [`BackendRegistry::auto`]
/// (which never picks `direct_i8` — quantization is an explicit
/// opt-in, not an accuracy-silent fallback).
pub const BACKEND_NAMES: [&str; 7] =
    ["direct", "reorder", "im2col", "fft", "winograd", "naive", "direct_i8"];

/// A set of convolution backends addressable by name.
pub struct BackendRegistry {
    backends: Vec<Box<dyn ConvAlgo>>,
}

impl Default for BackendRegistry {
    /// Registry with all seven built-in backends.
    fn default() -> Self {
        BackendRegistry {
            backends: vec![
                Box::new(DirectBackend),
                Box::new(ReorderBackend),
                Box::new(Im2colBackend),
                Box::new(FftBackend),
                Box::new(WinogradBackend),
                Box::new(NaiveBackend),
                Box::new(DirectI8Backend),
            ],
        }
    }
}

impl BackendRegistry {
    /// Process-wide shared default registry (the six built-in
    /// backends). Planning paths that never register custom backends —
    /// [`crate::nets::NetPlans`], the serving engines, the CLI — share
    /// this instance instead of rebuilding the backend list per call.
    pub fn shared() -> &'static BackendRegistry {
        static SHARED: std::sync::OnceLock<BackendRegistry> = std::sync::OnceLock::new();
        SHARED.get_or_init(BackendRegistry::default)
    }

    /// The dispatch-aware host machine model, detected once per
    /// process. `auto` selection and every CLI/serving planning path
    /// consult this instead of re-deriving [`crate::arch::host`] per
    /// `plan` call — the dispatch decision is process-constant, so the
    /// machine model is too.
    pub fn host_machine() -> &'static Machine {
        static HOST: std::sync::OnceLock<Machine> = std::sync::OnceLock::new();
        HOST.get_or_init(crate::arch::host)
    }

    /// Look a backend up by its registry name.
    pub fn get(&self, name: &str) -> Option<&dyn ConvAlgo> {
        self.backends.iter().find(|b| b.name() == name).map(|b| b.as_ref())
    }

    /// All registered backend names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.backends.iter().map(|b| b.name()).collect()
    }

    /// Iterate the registered backends.
    pub fn iter(&self) -> impl Iterator<Item = &dyn ConvAlgo> {
        self.backends.iter().map(|b| b.as_ref())
    }

    /// Register an additional (or replacement) backend. Later
    /// registrations win on name collisions via [`Self::get`]'s first
    /// match only if inserted in front, so push replacements first.
    pub fn register(&mut self, backend: Box<dyn ConvAlgo>) {
        self.backends.insert(0, backend);
    }

    /// Pick the best applicable backend for a layer on a machine.
    ///
    /// Heuristic (from the paper's results): `direct` wins whenever its
    /// analytically selected output-channel block is at least one full
    /// vector (`C_o,b >= N_vec`, the regime every Figure-4 layer is
    /// in). Degenerate channel counts fall back to `winograd` where
    /// eligible, else `im2col` — the robust baselines.
    pub fn auto(&self, shape: &ConvShape, machine: &Machine) -> &dyn ConvAlgo {
        if shape.groups != 1 || shape.dilation != 1 {
            // Grouped / depthwise / dilated layers: `direct` is the only
            // fast f32 backend that runs them (the comparators are
            // dense-only; `select_params` always finds a dividing block,
            // down to c_ob = 1), falling back to the oracle.
            return self
                .get("direct")
                .or_else(|| self.get("naive"))
                .or_else(|| self.backends.first().map(|b| b.as_ref()))
                .expect("registry is empty");
        }
        if select_c_ob(machine, shape.c_o) >= machine.n_vec {
            if let Some(b) = self.get("direct") {
                return b;
            }
        }
        if winograd_applicable(shape) {
            if let Some(b) = self.get("winograd") {
                return b;
            }
        }
        self.get("im2col")
            .or_else(|| self.backends.first().map(|b| b.as_ref()))
            .expect("registry is empty")
    }

    /// Resolve a CLI-style backend name (`"auto"` included) for a layer.
    pub fn resolve(
        &self,
        name: &str,
        shape: &ConvShape,
        machine: &Machine,
    ) -> Result<&dyn ConvAlgo> {
        if name == "auto" {
            return Ok(self.auto(shape, machine));
        }
        self.get(name).ok_or_else(|| {
            Error::Parse(format!(
                "unknown backend '{name}' (available: auto, {})",
                self.names().join(", ")
            ))
        })
    }

    /// One-call convenience: resolve `name` and plan the layer.
    ///
    /// An explicitly named backend propagates its plan errors — the
    /// caller asked for that backend specifically. `"auto"` instead
    /// *recovers*: if the heuristically picked backend fails to plan
    /// (a parameter-selection hole, a comparator's shape edge case),
    /// the layer falls back to `direct` with a logged reason —
    /// `select_params` always finds a dividing block, down to
    /// `c_ob = 1`, so `direct` plans everything — rather than sinking
    /// the whole net.
    pub fn plan(
        &self,
        name: &str,
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        let algo = self.resolve(name, shape, machine)?;
        match algo.plan(shape, kernel, machine, threads) {
            Ok(plan) => Ok(plan),
            Err(e) if name == "auto" && algo.name() != "direct" => match self.get("direct") {
                Some(direct) => {
                    eprintln!(
                        "auto: '{}' failed to plan {shape:?} ({e}); falling back to direct",
                        algo.name()
                    );
                    direct.plan(shape, kernel, machine, threads)
                }
                None => Err(e),
            },
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{cortex_a57, haswell};

    #[test]
    fn shared_registry_is_one_instance() {
        let a = BackendRegistry::shared() as *const BackendRegistry;
        let b = BackendRegistry::shared() as *const BackendRegistry;
        assert_eq!(a, b);
        assert!(BackendRegistry::shared().get("direct").is_some());
    }

    #[test]
    fn all_seven_backends_reachable_by_name() {
        let r = BackendRegistry::default();
        for name in BACKEND_NAMES {
            let b = r.get(name).unwrap_or_else(|| panic!("backend '{name}' missing"));
            assert_eq!(b.name(), name);
        }
        assert!(r.get("nope").is_none());
        assert_eq!(r.names().len(), BACKEND_NAMES.len());
    }

    #[test]
    fn auto_never_picks_quantization_silently() {
        let r = BackendRegistry::default();
        for m in [haswell(), cortex_a57()] {
            for l in crate::nets::all_layers().into_iter().step_by(7) {
                assert_ne!(r.auto(&l.shape, &m).name(), "direct_i8", "{}", l.name);
            }
        }
    }

    #[test]
    fn auto_prefers_direct_on_paper_layers() {
        let r = BackendRegistry::default();
        for m in [haswell(), cortex_a57()] {
            for l in crate::nets::all_layers().into_iter().step_by(9) {
                assert_eq!(r.auto(&l.shape, &m).name(), "direct", "{}", l.name);
            }
        }
    }

    #[test]
    fn auto_falls_back_on_degenerate_channels() {
        let r = BackendRegistry::default();
        let m = haswell();
        // C_o = 5: no vector-width block divides it -> not direct.
        let s3 = ConvShape::new(3, 9, 9, 5, 3, 3, 1, 1);
        assert_eq!(r.auto(&s3, &m).name(), "winograd");
        let s5 = ConvShape::new(3, 9, 9, 5, 5, 5, 1, 2);
        assert_eq!(r.auto(&s5, &m).name(), "im2col");
    }

    #[test]
    fn host_machine_is_one_instance() {
        let a = BackendRegistry::host_machine() as *const Machine;
        let b = BackendRegistry::host_machine() as *const Machine;
        assert_eq!(a, b);
        assert!(BackendRegistry::host_machine().n_vec >= 1);
    }

    /// A backend whose plan construction always errors, shadowing
    /// `winograd` (registered in front, so [`BackendRegistry::get`]
    /// finds it first).
    struct FailingWinograd;

    impl ConvAlgo for FailingWinograd {
        fn name(&self) -> &'static str {
            "winograd"
        }
        fn applicable(&self, _: &ConvShape) -> bool {
            true
        }
        fn plan(
            &self,
            _: &ConvShape,
            _: &Tensor,
            _: &Machine,
            _: usize,
        ) -> Result<Box<dyn ConvPlan>> {
            Err(Error::Runtime("injected plan failure".into()))
        }
    }

    #[test]
    fn auto_plan_falls_back_to_direct_on_plan_error() {
        let mut r = BackendRegistry::default();
        r.register(Box::new(FailingWinograd));
        let m = haswell();
        // C_o = 5, 3x3/s1: `auto` routes to winograd (see
        // auto_falls_back_on_degenerate_channels) — here the shadowed,
        // always-failing one.
        let s = ConvShape::new(3, 9, 9, 5, 3, 3, 1, 1);
        assert_eq!(r.auto(&s, &m).name(), "winograd");
        let kernel = Tensor::random(&[5, 3, 3, 3], 3);
        let plan = r.plan("auto", &s, &kernel, &m, 1).unwrap();
        assert_eq!(plan.backend(), "direct");
        // Asking for the broken backend BY NAME still propagates.
        assert!(r.plan("winograd", &s, &kernel, &m, 1).is_err());
    }

    #[test]
    fn resolve_handles_auto_and_unknown() {
        let r = BackendRegistry::default();
        let m = haswell();
        let s = ConvShape::new(64, 28, 28, 64, 3, 3, 1, 1);
        assert_eq!(r.resolve("auto", &s, &m).unwrap().name(), "direct");
        assert_eq!(r.resolve("fft", &s, &m).unwrap().name(), "fft");
        assert!(r.resolve("blas", &s, &m).is_err());
    }
}

//! [`NetRunner`] — the zero-allocation whole-network forward executor.
//!
//! The paper states its zero-memory-overhead claim per layer; the payoff
//! the ROADMAP cares about — fitting bigger networks on fixed-memory
//! devices, serving under heavy traffic — only materializes when an
//! *entire* network runs through direct convolution with no intermediate
//! allocations. `NetRunner` is that network-level contract on top of the
//! per-layer [`ConvPlan`] cache:
//!
//! 1. **Plan once.** A [`NetPlans`] table (every conv layer of a
//!    benchmark net planned through the registry) is turned into an
//!    executable schedule at construction. Weight pre-transforms,
//!    blocking parameters and layouts are all fixed here.
//! 2. **Size the arena once.** The *activation arena* is two ping-pong
//!    buffers, each of `max_activation_floats()` — the largest single
//!    inter-layer activation in the net — plus one shared scratch buffer
//!    of the largest per-layer [`ConvPlan::workspace_len`]. Nothing else
//!    is ever needed: layer `k` reads one buffer and writes the other.
//! 3. **Execute allocation-free.** [`NetRunner::forward_with`] runs
//!    every layer through [`ConvPlan::execute_into`] against the arena.
//!    After planning, a forward pass performs **zero** heap allocations
//!    (asserted by the counting-allocator test in `tests/net_forward.rs`).
//!
//! # Memory accounting
//!
//! The arena holds the network's *intrinsic* state — the layer inputs
//! and outputs every inference engine must materialize — so it is not
//! overhead in the paper's sense. The network-wide overhead is
//! [`NetRunner::retained_bytes`] (sum of per-plan retained bytes) plus
//! [`NetRunner::workspace_bytes`] (the *max* per-layer workspace, since
//! the single scratch buffer is shared across layers). For the `direct`
//! backend both are **0 on every paper net** — the zero-overhead claim,
//! asserted network-wide.
//!
//! # Inter-layer glue
//!
//! The benchmark tables list conv layers only; the pooling (and, for
//! GoogLeNet, the inception branch plumbing) between them is not part of
//! the paper's measurements. Where consecutive layers do not chain
//! directly, `NetRunner` inserts a deterministic, allocation-free
//! *adapt* step that is fused with the §4 layout conversion:
//!
//! * **spatial**: an adaptive max-pool whose kernel/stride are derived
//!   from the shapes (`stride = H_prev / H_next`,
//!   `kernel = H_prev - (H_next-1)*stride`) — this reproduces the real
//!   AlexNet (3x3/s2) and VGG (2x2/s2) pooling exactly;
//! * **channels**: channel `c` of the next input reads channel
//!   `c % C_prev` of the previous output (GoogLeNet's layer list is a
//!   branch traversal, not a sequential chain; cycling keeps the data
//!   nontrivial while staying shape-exact);
//! * **layout**: the gather reads the previous plan's native output
//!   layout and writes the next plan's native input layout directly.
//!
//! When shapes, channels and layouts all match (the §4 zero-repacking
//! chain), the adapt step disappears entirely — the output buffer is
//! handed to the next layer by pointer swap, no copy.
//!
//! [`adapt_nchw`] is an independent NCHW reference implementation of the
//! same glue, used by the conformance tests to cross-check a whole
//! forward pass against a layer-by-layer `conv_naive` chain.

use crate::conv::ConvShape;
use crate::layout::{
    blocked_io_index, nchw_to_nhwc_slice, nhwc_to_nchw_slice, pack_io_slice, unpack_io_slice,
    IoLayout,
};
use crate::nets::NetPlans;
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::ConvPlan;

/// Linear index of logical element `(c, y, x)` of a `c_t x h x w`
/// feature map stored in `layout`.
#[inline]
fn io_index(
    layout: IoLayout,
    c: usize,
    y: usize,
    x: usize,
    c_t: usize,
    h: usize,
    w: usize,
) -> usize {
    match layout {
        IoLayout::Nchw => (c * h + y) * w + x,
        IoLayout::Nhwc => (y * w + x) * c_t + c,
        IoLayout::Blocked { c_b } => blocked_io_index(c, y, x, h, w, c_b),
    }
}

/// Kernel/stride of the adaptive max-pool mapping a spatial extent of
/// `from` onto `to` (`to <= from`): `stride = from / to`,
/// `kernel = from - (to-1)*stride`, which tiles `from` exactly.
fn pool_spec(from: usize, to: usize) -> Result<(usize, usize)> {
    if to == 0 || from == 0 {
        return Err(Error::Shape("zero spatial extent in net chain".into()));
    }
    if from < to {
        return Err(Error::Shape(format!(
            "cannot chain: next layer needs spatial extent {to} > previous output {from} \
             (upsampling glue is not modeled)"
        )));
    }
    let stride = from / to;
    let kernel = from - (to - 1) * stride;
    Ok((kernel, stride))
}

/// Allocation-free glue between two consecutive layers: channel cycling
/// plus adaptive max-pool plus layout conversion, in one gather pass.
#[derive(Clone, Copy, Debug)]
struct Adapt {
    src_c: usize,
    src_h: usize,
    src_w: usize,
    src_layout: IoLayout,
    dst_c: usize,
    dst_h: usize,
    dst_w: usize,
    dst_layout: IoLayout,
    pool_kh: usize,
    pool_sh: usize,
    pool_kw: usize,
    pool_sw: usize,
    /// True when the previous output *is* the next input (same shape,
    /// same layout): the §4 zero-repacking chain, no copy at all.
    identity: bool,
}

impl Adapt {
    fn between(
        prev_shape: &ConvShape,
        prev_out: IoLayout,
        next_shape: &ConvShape,
        next_in: IoLayout,
    ) -> Result<Adapt> {
        let (src_c, src_h, src_w) = (prev_shape.c_o, prev_shape.h_o(), prev_shape.w_o());
        let (dst_c, dst_h, dst_w) = (next_shape.c_i, next_shape.h_i, next_shape.w_i);
        let (pool_kh, pool_sh) = pool_spec(src_h, dst_h)?;
        let (pool_kw, pool_sw) = pool_spec(src_w, dst_w)?;
        let identity = src_c == dst_c && src_h == dst_h && src_w == dst_w && prev_out == next_in;
        Ok(Adapt {
            src_c,
            src_h,
            src_w,
            src_layout: prev_out,
            dst_c,
            dst_h,
            dst_w,
            dst_layout: next_in,
            pool_kh,
            pool_sh,
            pool_kw,
            pool_sw,
            identity,
        })
    }

    /// Gather `src` (previous output, native layout) into `dst` (next
    /// input, native layout). Allocation-free.
    fn apply(&self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), self.src_c * self.src_h * self.src_w);
        debug_assert_eq!(dst.len(), self.dst_c * self.dst_h * self.dst_w);
        for c in 0..self.dst_c {
            let sc = c % self.src_c;
            for y in 0..self.dst_h {
                let y0 = y * self.pool_sh;
                for x in 0..self.dst_w {
                    let x0 = x * self.pool_sw;
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..self.pool_kh {
                        for dx in 0..self.pool_kw {
                            let v = src[io_index(
                                self.src_layout,
                                sc,
                                y0 + dy,
                                x0 + dx,
                                self.src_c,
                                self.src_h,
                                self.src_w,
                            )];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    dst[io_index(self.dst_layout, c, y, x, self.dst_c, self.dst_h, self.dst_w)] = m;
                }
            }
        }
    }
}

/// NCHW reference implementation of the inter-layer glue: channel `c`
/// of the result reads channel `c % C_src`, spatial extents are reduced
/// by the same adaptive max-pool [`NetRunner`] uses. Independent of the
/// arena/layout machinery so tests can cross-check a whole-network
/// forward against a layer-by-layer naive chain.
pub fn adapt_nchw(src: &Tensor, c: usize, h: usize, w: usize) -> Result<Tensor> {
    let &[sc, sh, sw] = src.shape() else {
        return Err(Error::Shape(format!("expected [C][H][W], got {:?}", src.shape())));
    };
    let (kh, strh) = pool_spec(sh, h)?;
    let (kw, strw) = pool_spec(sw, w)?;
    let s = src.data();
    let mut out = vec![0.0f32; c * h * w];
    for (cc, plane) in out.chunks_mut(h * w).enumerate() {
        let sp = &s[(cc % sc) * sh * sw..][..sh * sw];
        for y in 0..h {
            for x in 0..w {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..kh {
                    for dx in 0..kw {
                        let v = sp[(y * strh + dy) * sw + (x * strw + dx)];
                        if v > m {
                            m = v;
                        }
                    }
                }
                plane[y * w + x] = m;
            }
        }
    }
    Tensor::from_vec(&[c, h, w], out)
}

/// One layer of the executable schedule.
struct Step {
    /// Glue from the previous layer's output (`None` for the first
    /// layer, which is fed by the packed network input).
    adapt: Option<Adapt>,
    in_len: usize,
    out_len: usize,
}

/// Caller-owned execution state for one in-flight forward pass: the two
/// ping-pong activation buffers plus the shared per-layer workspace.
/// Create with [`NetRunner::arena`]; reuse across requests (that reuse
/// is exactly what makes the forward pass allocation-free). One arena
/// per concurrent request — workers in a pool each own one.
pub struct NetArena {
    bufs: [Vec<f32>; 2],
    workspace: Vec<f32>,
}

/// A whole benchmark network compiled to an allocation-free executable:
/// per-layer [`ConvPlan`]s, inter-layer glue, and the arena sizing
/// contract. See the module docs.
pub struct NetRunner {
    plans: NetPlans,
    steps: Vec<Step>,
    input_len: usize,
    output_len: usize,
    max_act: usize,
    max_ws: usize,
}

impl NetRunner {
    /// Compile a planned net into an executable schedule. Fails if the
    /// layer list cannot be chained (a later layer needs a larger
    /// spatial extent than its predecessor produces).
    pub fn new(plans: NetPlans) -> Result<NetRunner> {
        if plans.layers.is_empty() {
            return Err(Error::Shape(format!("net '{}' has no planned layers", plans.net)));
        }
        let mut steps = Vec::with_capacity(plans.layers.len());
        let mut max_act = 0usize;
        let mut max_ws = 0usize;
        for (i, pl) in plans.layers.iter().enumerate() {
            let s = &pl.layer.shape;
            let in_len = s.c_i * s.h_i * s.w_i;
            let out_len = s.c_o * s.h_o() * s.w_o();
            max_act = max_act.max(in_len).max(out_len);
            max_ws = max_ws.max(pl.plan.workspace_len());
            let adapt = if i == 0 {
                None
            } else {
                let prev = &plans.layers[i - 1];
                let a = Adapt::between(
                    &prev.layer.shape,
                    prev.plan.output_layout(),
                    s,
                    pl.plan.input_layout(),
                )
                .map_err(|e| {
                    Error::Shape(format!(
                        "{}: {} -> {}: {e}",
                        plans.net, prev.layer.name, pl.layer.name
                    ))
                })?;
                Some(a)
            };
            steps.push(Step { adapt, in_len, out_len });
        }
        let first = &plans.layers[0].layer.shape;
        let last = &plans.layers[plans.layers.len() - 1].layer.shape;
        let input_len = first.c_i * first.h_i * first.w_i;
        let output_len = last.c_o * last.h_o() * last.w_o();
        Ok(NetRunner { plans, steps, input_len, output_len, max_act, max_ws })
    }

    /// The planned layers this runner executes.
    pub fn plans(&self) -> &NetPlans {
        &self.plans
    }

    /// Number of conv layers in the schedule.
    pub fn layers(&self) -> usize {
        self.steps.len()
    }

    /// Floats of the whole-network NCHW input (first layer).
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Floats of the whole-network NCHW output (last layer).
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Largest single inter-layer activation (floats) — the size of each
    /// of the two ping-pong buffers.
    pub fn max_activation_floats(&self) -> usize {
        self.max_act
    }

    /// Bytes of the two ping-pong activation buffers. Intrinsic network
    /// state (layer inputs/outputs), not overhead.
    pub fn activation_bytes(&self) -> u64 {
        2 * 4 * self.max_act as u64
    }

    /// Sum of per-plan retained bytes beyond conventional weights.
    pub fn retained_bytes(&self) -> u64 {
        self.plans.total_retained_bytes()
    }

    /// Scratch bytes of the shared workspace: the *max* per-layer
    /// workspace, since one buffer serves every layer in turn.
    pub fn workspace_bytes(&self) -> u64 {
        4 * self.max_ws as u64
    }

    /// Network-wide memory overhead in the paper's sense:
    /// `retained + shared workspace`. **0** for the `direct` backend on
    /// every paper net.
    pub fn overhead_bytes(&self) -> u64 {
        self.retained_bytes() + self.workspace_bytes()
    }

    /// Total bytes of one execution arena (activations + workspace).
    pub fn arena_bytes(&self) -> u64 {
        self.activation_bytes() + self.workspace_bytes()
    }

    /// Allocate one execution arena (the only allocation site; do it
    /// once, reuse per request).
    pub fn arena(&self) -> NetArena {
        NetArena {
            bufs: [vec![0.0; self.max_act], vec![0.0; self.max_act]],
            workspace: vec![0.0; self.max_ws],
        }
    }

    /// Run the whole network forward, allocation-free. `input` is the
    /// first layer's flat NCHW image (`input_len()` floats), `output`
    /// receives the last layer's flat NCHW map (`output_len()` floats),
    /// `arena` is a (reused) buffer set from [`NetRunner::arena`].
    pub fn forward_with(
        &self,
        arena: &mut NetArena,
        input: &[f32],
        output: &mut [f32],
    ) -> Result<()> {
        if input.len() != self.input_len {
            return Err(Error::Shape(format!(
                "net input has {} floats, expected {}",
                input.len(),
                self.input_len
            )));
        }
        if output.len() != self.output_len {
            return Err(Error::Shape(format!(
                "net output has {} floats, expected {}",
                output.len(),
                self.output_len
            )));
        }
        if arena.bufs[0].len() != self.max_act
            || arena.bufs[1].len() != self.max_act
            || arena.workspace.len() != self.max_ws
        {
            return Err(Error::Shape("arena was not built by this runner".into()));
        }
        let NetArena { bufs, workspace } = arena;

        // Stage the NCHW input into the first layer's native layout.
        let first = &self.plans.layers[0];
        let fs = &first.layer.shape;
        let stage = &mut bufs[0][..self.input_len];
        match first.plan.input_layout() {
            IoLayout::Nchw => stage.copy_from_slice(input),
            IoLayout::Nhwc => nchw_to_nhwc_slice(input, fs.c_i, fs.h_i, fs.w_i, stage)?,
            IoLayout::Blocked { c_b } => pack_io_slice(input, fs.c_i, fs.h_i, fs.w_i, c_b, stage)?,
        }

        // Ping-pong through the layers: `cur` is the buffer holding the
        // live activation at each point.
        let mut cur = 0usize;
        for (pl, step) in self.plans.layers.iter().zip(&self.steps) {
            if let Some(ad) = &step.adapt {
                if !ad.identity {
                    let (src, dst) = two(bufs, cur);
                    let src_len = ad.src_c * ad.src_h * ad.src_w;
                    ad.apply(&src[..src_len], &mut dst[..step.in_len]);
                    cur = 1 - cur;
                }
            }
            let (inb, outb) = two(bufs, cur);
            pl.plan.execute_into(
                &inb[..step.in_len],
                &mut outb[..step.out_len],
                &mut workspace[..pl.plan.workspace_len()],
            )?;
            cur = 1 - cur;
        }

        // Unpack the last activation back to NCHW.
        let last = &self.plans.layers[self.plans.layers.len() - 1];
        let ls = &last.layer.shape;
        let (h_o, w_o) = (ls.h_o(), ls.w_o());
        let native = &bufs[cur][..self.output_len];
        match last.plan.output_layout() {
            IoLayout::Nchw => output.copy_from_slice(native),
            IoLayout::Nhwc => nhwc_to_nchw_slice(native, ls.c_o, h_o, w_o, output)?,
            IoLayout::Blocked { c_b } => unpack_io_slice(native, ls.c_o, h_o, w_o, c_b, output)?,
        }
        Ok(())
    }

    /// One-shot convenience: allocates a fresh arena and the output
    /// tensor. Not the hot path — serving holds arenas and calls
    /// [`NetRunner::forward_with`].
    pub fn forward(&self, input: &Tensor) -> Result<Tensor> {
        let fs = &self.plans.layers[0].layer.shape;
        let want = [fs.c_i, fs.h_i, fs.w_i];
        if input.shape() != want {
            return Err(Error::Shape(format!(
                "net input shape {:?} != expected {want:?}",
                input.shape()
            )));
        }
        let ls = &self.plans.layers[self.plans.layers.len() - 1].layer.shape;
        let mut arena = self.arena();
        let mut out = vec![0.0f32; self.output_len];
        self.forward_with(&mut arena, input.data(), &mut out)?;
        Tensor::from_vec(&[ls.c_o, ls.h_o(), ls.w_o()], out)
    }
}

/// Disjoint (read, write) views of the two ping-pong buffers: read from
/// `bufs[cur]`, write into the other.
fn two(bufs: &mut [Vec<f32>; 2], cur: usize) -> (&[f32], &mut [f32]) {
    let (a, b) = bufs.split_at_mut(1);
    if cur == 0 {
        (&a[0], &mut b[0])
    } else {
        (&b[0], &mut a[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;

    fn custom_plans(shapes: &[ConvShape], backend: &str, seed: u64) -> NetPlans {
        NetPlans::from_shapes("custom", shapes, backend, &haswell(), seed).unwrap()
    }

    #[test]
    fn pool_spec_reproduces_real_pools() {
        assert_eq!(pool_spec(55, 27).unwrap(), (3, 2)); // AlexNet 3x3/s2
        assert_eq!(pool_spec(27, 13).unwrap(), (3, 2));
        assert_eq!(pool_spec(224, 112).unwrap(), (2, 2)); // VGG 2x2/s2
        assert_eq!(pool_spec(14, 14).unwrap(), (1, 1)); // identity
        assert_eq!(pool_spec(7, 1).unwrap(), (7, 7)); // global pool
        assert!(pool_spec(13, 14).is_err()); // upsampling is not modeled
    }

    #[test]
    fn adapt_nchw_pools_and_cycles_channels() {
        let src = Tensor::iota(&[2, 4, 4]);
        // 2 channels, 4x4 -> 3 channels, 2x2 (2x2/s2 max pool).
        let out = adapt_nchw(&src, 3, 2, 2).unwrap();
        assert_eq!(out.shape(), &[3, 2, 2]);
        // max of each 2x2 window of channel 0: 5, 7, 13, 15
        assert_eq!(out.at(&[0, 0, 0]), 5.0);
        assert_eq!(out.at(&[0, 1, 1]), 15.0);
        // channel 2 cycles back to source channel 0
        assert_eq!(out.at(&[2, 0, 0]), out.at(&[0, 0, 0]));
        // channel 1 is source channel 1 (offset by 16)
        assert_eq!(out.at(&[1, 0, 0]), 21.0);
    }

    #[test]
    fn identity_chain_swaps_instead_of_copying() {
        // Two layers whose pencils line up would chain with zero
        // repacking only if c_ob(k) == c_ib(k+1); with the naive backend
        // both layouts are NCHW, so an equal-shape chain is an identity.
        let shapes = [
            ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1),
            ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1),
        ];
        let runner = NetRunner::new(custom_plans(&shapes, "naive", 5)).unwrap();
        assert!(runner.steps[1].adapt.unwrap().identity);
    }

    #[test]
    fn forward_matches_naive_chain_on_custom_net() {
        use crate::conv::conv_naive;
        // conv -> pool(2x2/s2 via adapt) -> conv, direct backend.
        let shapes = [
            ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
            ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
        ];
        let plans = custom_plans(&shapes, "direct", 40);
        let kernels: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 40 + i as u64))
            .collect();
        let runner = NetRunner::new(plans).unwrap();
        let input = Tensor::random(&[8, 12, 12], 99);
        let got = runner.forward(&input).unwrap();

        let mut act = input.clone();
        for (s, k) in shapes.iter().zip(&kernels) {
            let adapted = adapt_nchw(&act, s.c_i, s.h_i, s.w_i).unwrap();
            act = conv_naive(&adapted, k, s).unwrap();
        }
        assert!(got.allclose(&act, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&act));
    }

    #[test]
    fn arena_sizing_and_overhead_accounting() {
        let shapes = [
            ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
            ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
        ];
        let runner = NetRunner::new(custom_plans(&shapes, "direct", 7)).unwrap();
        // Largest activation is layer 0's output: 16 * 12 * 12.
        assert_eq!(runner.max_activation_floats(), 16 * 12 * 12);
        assert_eq!(runner.activation_bytes(), 2 * 4 * 16 * 12 * 12);
        assert_eq!(runner.overhead_bytes(), 0, "direct must be zero-overhead");
        assert_eq!(runner.arena_bytes(), runner.activation_bytes());
        assert_eq!(runner.input_len(), 8 * 12 * 12);
        assert_eq!(runner.output_len(), 16 * 6 * 6);

        // im2col charges its lowering workspace; the arena shares one
        // buffer so the network-wide workspace is the per-layer max.
        let r2 = NetRunner::new(custom_plans(&shapes, "im2col", 7)).unwrap();
        let per_layer: Vec<u64> = shapes.iter().map(ConvShape::im2col_bytes).collect();
        assert_eq!(r2.workspace_bytes(), per_layer.iter().copied().max().unwrap());
    }

    #[test]
    fn rejects_unchainable_and_empty_nets() {
        // Second layer needs a LARGER spatial input than layer 1 emits.
        let shapes = [
            ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1),
            ConvShape::new(8, 16, 16, 8, 3, 3, 1, 1),
        ];
        assert!(NetRunner::new(custom_plans(&shapes, "naive", 1)).is_err());
        let empty = NetPlans { net: "empty".into(), layers: Vec::new() };
        assert!(NetRunner::new(empty).is_err());
    }

    #[test]
    fn forward_with_validates_buffers() {
        let shapes = [ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1)];
        let runner = NetRunner::new(custom_plans(&shapes, "direct", 3)).unwrap();
        let mut arena = runner.arena();
        let input = vec![0.0f32; runner.input_len()];
        let mut out = vec![0.0f32; runner.output_len()];
        assert!(runner.forward_with(&mut arena, &input[1..], &mut out).is_err());
        assert!(runner.forward_with(&mut arena, &input, &mut out[1..]).is_err());
        assert!(runner.forward_with(&mut arena, &input, &mut out).is_ok());
        let bad = Tensor::zeros(&[4, 8, 9]);
        assert!(runner.forward(&bad).is_err());
    }
}

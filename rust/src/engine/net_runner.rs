//! [`NetRunner`] — the zero-allocation whole-network graph executor.
//!
//! The paper states its zero-memory-overhead claim per layer; the payoff
//! the ROADMAP cares about — fitting bigger networks on fixed-memory
//! devices, serving under heavy traffic — only materializes when an
//! *entire* network runs through direct convolution with no intermediate
//! allocations. Since PR 3 the network is a real dataflow graph
//! ([`crate::nets::NetGraph`]: conv/pool/concat nodes), so GoogLeNet's
//! inception modules execute as genuine fan-out branches joined by
//! channel concatenation — the earlier sequential traversal with
//! channel-cycling glue is gone, and the whole-net accounting is
//! measured against the true dataflow. `NetRunner` is the network-level
//! contract on top of the per-layer [`ConvPlan`] cache:
//!
//! 1. **Plan once.** A [`NetPlans`] table (every conv layer planned
//!    through the registry) plus its [`crate::nets::NetGraph`] is
//!    compiled into a flat op schedule at construction: one `Conv` op
//!    per layer, and `Adapt` ops — a single gather pass fusing max-pool,
//!    §4 layout conversion and concat-slice placement — wherever the
//!    graph needs glue. When a conv's input already sits in its plan's
//!    native layout, the conv reads its predecessor's region directly
//!    (the §4 zero-repacking chain: no copy at all).
//! 2. **Size the arena once.** Every activation (graph edge) gets a
//!    region in ONE shared arena, placed by a liveness-driven region
//!    allocator: lifetimes are computed over the topological schedule,
//!    regions are placed greedy-by-size so that no two *live* values
//!    ever alias, and the arena is sized by the **max live-set** — for
//!    an inception module that is the sum of the live branch outputs,
//!    not twice the largest activation. Placement lands exactly on the
//!    max live-set for every paper net (and GoogLeNet's arena shrinks
//!    ~37% vs the old ping-pong pair); see [`NetRunner::max_live_floats`]
//!    for the honest bound on arbitrary DAGs. One shared workspace of
//!    the largest per-layer [`ConvPlan::workspace_len`] completes the
//!    arena.
//! 3. **Execute allocation-free.** [`NetRunner::forward_with`] replays
//!    the schedule against the arena. After planning, a forward pass
//!    performs **zero** heap allocations (asserted by the
//!    counting-allocator tests in `tests/net_forward.rs` and
//!    `tests/net_graph.rs`).
//!
//! # Memory accounting
//!
//! The arena holds the network's *intrinsic* state — the activations any
//! inference engine must materialize — so it is not overhead in the
//! paper's sense. The network-wide overhead is
//! [`NetRunner::retained_bytes`] (sum of per-plan retained bytes) plus
//! [`NetRunner::workspace_bytes`] (the *max* per-layer workspace, since
//! one scratch buffer is shared across layers). For the `direct` backend
//! both are **0 on every paper net** — the zero-overhead claim, asserted
//! network-wide over the real GoogLeNet DAG.
//!
//! # Branch parallelism
//!
//! Independent branches of a fan-out group (the four lanes of an
//! inception module, tagged by the graph builder) may execute on scoped
//! threads: construct with [`NetRunner::with_branch_lanes`]. Lane
//! independence is enforced by graph validation, and the region
//! allocator switches to *group-time* liveness — every value touched by
//! a parallel group is live for the whole group — so concurrent lanes
//! provably never alias (each lane also gets its own workspace slice).
//! The default (`lanes == 1`) runs the schedule serially and keeps the
//! strictly allocation-free hot path; parallel stages pay bounded
//! `thread::scope` spawn bookkeeping, like any `threads > 1` plan.
//!
//! # Residual joins
//!
//! [`crate::nets::GraphOp::Add`] (the ResNet skip connection) compiles
//! to per-operand gather passes over one destination region: the first
//! operand's pass stores, later operands accumulate (`+=`), with any
//! needed layout conversion fused in. No temporary is materialized —
//! the join costs exactly its output region, and liveness keeps every
//! operand alive to the join, so the arena accounting charges residual
//! topologies honestly. This is where direct convolution's zero
//! overhead compounds: GEMM-based rivals pay their per-branch packing
//! on *both* arms of every skip connection.
//!
//! # Quantized (i8) schedules
//!
//! [`NetRunner::from_graph_quant`] compiles the same graph over an
//! **i8 byte arena**: every conv plan must expose the int8 surface
//! ([`ConvPlan::as_quantized`] — i.e. `direct_i8` plans built by
//! [`crate::quant::QuantNet`] with per-edge calibrated
//! [`QuantParams`]), activations live as single bytes (same element
//! count and placement as the f32 arena, exactly a quarter of the
//! bytes), and the producer→consumer requantize steps are fused into
//! the existing Adapt gathers — scale chaining costs no extra pass.
//! The f32 boundary survives: [`NetRunner::forward_with`] quantizes
//! the input while staging and dequantizes the output while unpacking;
//! [`NetRunner::forward_q8_with`] exposes the raw integers (what the
//! golden fixtures pin). Zero-alloc and `overhead_bytes() == 0` hold
//! exactly as in f32 mode.
//!
//! [`adapt_nchw`] / [`pool_nchw`] / [`avg_pool_nchw`] / [`add_nchw`]
//! are independent NCHW reference implementations of the glue ops,
//! used by the conformance tests to cross-check whole forward passes
//! against branch-by-branch `conv_naive` references with explicit
//! concatenation/summation.

use std::collections::BTreeMap;
use std::ops::Range;

use crate::conv::{ConvShape, EpView, Epilogue};
use crate::layout::{
    blocked_io_index, nchw_to_nhwc_slice, nhwc_to_nchw_slice, pack_io_slice, unpack_io_slice,
    IoLayout,
};
use crate::nets::{
    net_bn_params, pool_spec, BranchTag, Dims, FusedNet, GraphOp, NetGraph, NetPlans, NodeRole,
    PoolKind,
};
use crate::quant::{
    dequantize, quantize, requantize, round_half_away, DType, QuantParams, Q_MAX, Q_MIN,
};
use crate::tensor::Tensor;
use crate::trace::{self, Span, SpanKind, SpanRing};
use crate::{Error, Result};

use super::ConvPlan;

/// Linear index of logical element `(c, y, x)` of a `c_t x h x w`
/// feature map stored in `layout`.
#[inline]
fn io_index(
    layout: IoLayout,
    c: usize,
    y: usize,
    x: usize,
    c_t: usize,
    h: usize,
    w: usize,
) -> usize {
    match layout {
        IoLayout::Nchw => (c * h + y) * w + x,
        IoLayout::Nhwc => (y * w + x) * c_t + c,
        IoLayout::Blocked { c_b } => blocked_io_index(c, y, x, h, w, c_b),
    }
}

/// Short layout spelling for staging-value names (`stage:x@b8`).
fn layout_tag(l: IoLayout) -> String {
    match l {
        IoLayout::Nchw => "nchw".into(),
        IoLayout::Nhwc => "nhwc".into(),
        IoLayout::Blocked { c_b } => format!("b{c_b}"),
    }
}

/// One fused, channel-preserving gather pass: pooling (max with `-inf`
/// padding, or average over the in-bounds cells) plus layout
/// conversion, any layout to any layout. With `1x1/s1/p0` geometry it
/// degenerates to a pure layout conversion. With `accumulate` set the
/// gathered value is *added* to the destination instead of stored —
/// the second and later operands of a residual [`GraphOp::Add`] join
/// fuse into the same pass. In a quantized (i8) schedule the same pass
/// additionally requantizes from the producer's [`QuantParams`] to the
/// consumer's ([`Adapt::apply_i8`]), so scale chaining costs no extra
/// pass either.
#[derive(Clone, Copy, Debug)]
struct Adapt {
    src_c: usize,
    src_h: usize,
    src_w: usize,
    src_layout: IoLayout,
    dst_c: usize,
    dst_h: usize,
    dst_w: usize,
    dst_layout: IoLayout,
    kind: PoolKind,
    kh: usize,
    kw: usize,
    sh: usize,
    sw: usize,
    ph: usize,
    pw: usize,
    accumulate: bool,
    /// Quantization of the source / destination values (i8 schedules
    /// only; [`QuantParams::IDENT`] in f32 schedules).
    src_qp: QuantParams,
    dst_qp: QuantParams,
}

impl Adapt {
    /// Pure layout conversion (identity geometry).
    fn convert(c: usize, h: usize, w: usize, from: IoLayout, to: IoLayout) -> Adapt {
        Adapt {
            src_c: c,
            src_h: h,
            src_w: w,
            src_layout: from,
            dst_c: c,
            dst_h: h,
            dst_w: w,
            dst_layout: to,
            kind: PoolKind::Max,
            kh: 1,
            kw: 1,
            sh: 1,
            sw: 1,
            ph: 0,
            pw: 0,
            accumulate: false,
            src_qp: QuantParams::IDENT,
            dst_qp: QuantParams::IDENT,
        }
    }

    /// Gather `src` into `dst`, both in their declared layouts.
    /// Allocation-free; out-of-bounds window cells act as `-inf` under
    /// max pooling and are excluded from sum and count under average
    /// pooling.
    fn apply(&self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), self.src_c * self.src_h * self.src_w);
        debug_assert_eq!(dst.len(), self.dst_c * self.dst_h * self.dst_w);
        for c in 0..self.dst_c {
            for y in 0..self.dst_h {
                let y0 = (y * self.sh) as isize - self.ph as isize;
                for x in 0..self.dst_w {
                    let x0 = (x * self.sw) as isize - self.pw as isize;
                    let mut m = f32::NEG_INFINITY;
                    let mut sum = 0.0f32;
                    let mut count = 0u32;
                    for dy in 0..self.kh {
                        let yy = y0 + dy as isize;
                        if yy < 0 || yy >= self.src_h as isize {
                            continue;
                        }
                        for dx in 0..self.kw {
                            let xx = x0 + dx as isize;
                            if xx < 0 || xx >= self.src_w as isize {
                                continue;
                            }
                            let v = src[io_index(
                                self.src_layout,
                                c,
                                yy as usize,
                                xx as usize,
                                self.src_c,
                                self.src_h,
                                self.src_w,
                            )];
                            match self.kind {
                                PoolKind::Max => {
                                    if v > m {
                                        m = v;
                                    }
                                }
                                PoolKind::Avg => {
                                    sum += v;
                                    count += 1;
                                }
                            }
                        }
                    }
                    let v = match self.kind {
                        PoolKind::Max => m,
                        // Running sum scaled by the reciprocal count of
                        // in-bounds cells (geometry guarantees >= 1).
                        PoolKind::Avg => sum * (1.0 / count.max(1) as f32),
                    };
                    let d = io_index(self.dst_layout, c, y, x, self.dst_c, self.dst_h, self.dst_w);
                    if self.accumulate {
                        dst[d] += v;
                    } else {
                        dst[d] = v;
                    }
                }
            }
        }
    }

    /// The i8 twin of [`Adapt::apply`]: same gather, integer pooling,
    /// and the producer→consumer requantize fused in. Every arithmetic
    /// step is pinned for the NumPy reference (see [`crate::quant`]):
    /// max pools compare raw i8 (monotone under affine quantization),
    /// then `q' = clamp(round((q - zp_s) · m) + zp_d)` with
    /// `m = f64(s_src) / f64(s_dst)`; averages requantize the i32 sum
    /// of centered values through `m / count`; `accumulate` saturating-
    /// adds centered contributions into the destination.
    fn apply_i8(&self, src: &[i8], dst: &mut [i8]) {
        debug_assert_eq!(src.len(), self.src_c * self.src_h * self.src_w);
        debug_assert_eq!(dst.len(), self.dst_c * self.dst_h * self.dst_w);
        let m = self.src_qp.scale as f64 / self.dst_qp.scale as f64;
        let (szp, dzp) = (self.src_qp.zero_point, self.dst_qp.zero_point);
        for c in 0..self.dst_c {
            for y in 0..self.dst_h {
                let y0 = (y * self.sh) as isize - self.ph as isize;
                for x in 0..self.dst_w {
                    let x0 = (x * self.sw) as isize - self.pw as isize;
                    let mut mx = i32::MIN;
                    let mut sum = 0i32;
                    let mut count = 0i64;
                    for dy in 0..self.kh {
                        let yy = y0 + dy as isize;
                        if yy < 0 || yy >= self.src_h as isize {
                            continue;
                        }
                        for dx in 0..self.kw {
                            let xx = x0 + dx as isize;
                            if xx < 0 || xx >= self.src_w as isize {
                                continue;
                            }
                            let v = src[io_index(
                                self.src_layout,
                                c,
                                yy as usize,
                                xx as usize,
                                self.src_c,
                                self.src_h,
                                self.src_w,
                            )] as i32;
                            match self.kind {
                                PoolKind::Max => mx = mx.max(v),
                                PoolKind::Avg => {
                                    sum += v - szp;
                                    count += 1;
                                }
                            }
                        }
                    }
                    let q = match self.kind {
                        PoolKind::Max => requantize(mx - szp, m, dzp),
                        PoolKind::Avg => requantize(sum, m / count.max(1) as f64, dzp),
                    };
                    let d = io_index(self.dst_layout, c, y, x, self.dst_c, self.dst_h, self.dst_w);
                    if self.accumulate {
                        let t = dst[d] as i32 + q as i32 - dzp;
                        dst[d] = t.clamp(Q_MIN, Q_MAX) as i8;
                    } else {
                        dst[d] = q;
                    }
                }
            }
        }
    }
}

/// One standalone elementwise pass — a [`GraphOp::Relu`] or
/// [`GraphOp::BatchNorm`] node the fusion pass left materialized
/// (fan-out intermediates, tails of non-conv producers). Per-channel
/// scale/shift then ReLU/clamp, with any-to-any layout conversion fused
/// into the same walk. The f32 path applies [`EpView::apply`] — THE
/// scalar tail every fused path shares — so fused and unfused schedules
/// agree **bitwise**. The i8 path folds the whole tail into one
/// requantize (single rounding, like the conv cores):
/// `q' = clamp(round((q - zp_s) * m_c + off_c) + zp_d, lo, hi)` with
/// `m_c = s_src * scale[c] / s_dst` and `off_c = shift[c] / s_dst` in
/// f64, `lo = max(zp_d, Q_MIN)` under ReLU, and `hi` from the clamp
/// quantized into the destination scale.
struct Eltwise {
    c: usize,
    h: usize,
    w: usize,
    src_layout: IoLayout,
    dst_layout: IoLayout,
    /// Per-channel multiplier / addend (empty = identity) — the
    /// pre-folded BN parameters; empty for a plain ReLU node.
    scale: Vec<f32>,
    shift: Vec<f32>,
    relu: bool,
    clamp: Option<f32>,
    src_qp: QuantParams,
    dst_qp: QuantParams,
}

impl Eltwise {
    fn apply(&self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), self.c * self.h * self.w);
        debug_assert_eq!(dst.len(), src.len());
        let view =
            EpView { scale: &self.scale, shift: &self.shift, relu: self.relu, clamp: self.clamp };
        for c in 0..self.c {
            for y in 0..self.h {
                for x in 0..self.w {
                    let v = src[io_index(self.src_layout, c, y, x, self.c, self.h, self.w)];
                    dst[io_index(self.dst_layout, c, y, x, self.c, self.h, self.w)] =
                        view.apply(v, c, None);
                }
            }
        }
    }

    /// The i8 twin: scale/shift/requantize collapse into one rounded
    /// multiply-add per element (see the struct docs for the pinned
    /// formula the NumPy reference mirrors).
    fn apply_i8(&self, src: &[i8], dst: &mut [i8]) {
        debug_assert_eq!(src.len(), self.c * self.h * self.w);
        debug_assert_eq!(dst.len(), src.len());
        let (szp, dzp) = (self.src_qp.zero_point, self.dst_qp.zero_point);
        let ratio = self.src_qp.scale as f64 / self.dst_qp.scale as f64;
        let lo = if self.relu { dzp.max(Q_MIN) } else { Q_MIN };
        let hi = match self.clamp {
            Some(cl) => {
                let q = round_half_away(cl as f64 / self.dst_qp.scale as f64) as i32 + dzp;
                q.clamp(lo, Q_MAX)
            }
            None => Q_MAX,
        };
        for c in 0..self.c {
            let m = if self.scale.is_empty() { ratio } else { ratio * self.scale[c] as f64 };
            let off = if self.shift.is_empty() {
                0.0
            } else {
                self.shift[c] as f64 / self.dst_qp.scale as f64
            };
            for y in 0..self.h {
                for x in 0..self.w {
                    let q =
                        src[io_index(self.src_layout, c, y, x, self.c, self.h, self.w)] as i32;
                    let v = round_half_away((q - szp) as f64 * m + off) as i32 + dzp;
                    dst[io_index(self.dst_layout, c, y, x, self.c, self.h, self.w)] =
                        v.clamp(lo, hi) as i8;
                }
            }
        }
    }
}

/// NCHW reference max-pool with explicit geometry (`-inf` padding) —
/// independent of the arena/layout machinery so tests can build
/// branch-by-branch naive references for the inception graphs.
pub fn pool_nchw(
    src: &Tensor,
    kh: usize,
    kw: usize,
    sh: usize,
    sw: usize,
    ph: usize,
    pw: usize,
) -> Result<Tensor> {
    let &[c, h, w] = src.shape() else {
        return Err(Error::Shape(format!("expected [C][H][W], got {:?}", src.shape())));
    };
    if kh == 0 || kw == 0 || sh == 0 || sw == 0 || ph >= kh || pw >= kw {
        return Err(Error::Shape(format!("bad pool geometry {kh}x{kw}/s{sh}x{sw}/p{ph}x{pw}")));
    }
    if h + 2 * ph < kh || w + 2 * pw < kw {
        return Err(Error::Shape("pool kernel larger than padded input".into()));
    }
    let (h_o, w_o) = ((h + 2 * ph - kh) / sh + 1, (w + 2 * pw - kw) / sw + 1);
    let s = src.data();
    let mut out = vec![0.0f32; c * h_o * w_o];
    for (cc, plane) in out.chunks_mut(h_o * w_o).enumerate() {
        let sp = &s[cc * h * w..][..h * w];
        for y in 0..h_o {
            for x in 0..w_o {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..kh {
                    let yy = (y * sh + dy) as isize - ph as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = (x * sw + dx) as isize - pw as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        let v = sp[yy as usize * w + xx as usize];
                        if v > m {
                            m = v;
                        }
                    }
                }
                plane[y * w_o + x] = m;
            }
        }
    }
    Tensor::from_vec(&[c, h_o, w_o], out)
}

/// NCHW reference average-pool with explicit geometry — the mean over
/// the *in-bounds* window cells (padding excluded from sum and count,
/// classifier-head semantics), matching the fused Adapt gather's
/// [`PoolKind::Avg`] arithmetic exactly.
pub fn avg_pool_nchw(
    src: &Tensor,
    kh: usize,
    kw: usize,
    sh: usize,
    sw: usize,
    ph: usize,
    pw: usize,
) -> Result<Tensor> {
    let &[c, h, w] = src.shape() else {
        return Err(Error::Shape(format!("expected [C][H][W], got {:?}", src.shape())));
    };
    if kh == 0 || kw == 0 || sh == 0 || sw == 0 || ph >= kh || pw >= kw {
        return Err(Error::Shape(format!("bad pool geometry {kh}x{kw}/s{sh}x{sw}/p{ph}x{pw}")));
    }
    if h + 2 * ph < kh || w + 2 * pw < kw {
        return Err(Error::Shape("pool kernel larger than padded input".into()));
    }
    let (h_o, w_o) = ((h + 2 * ph - kh) / sh + 1, (w + 2 * pw - kw) / sw + 1);
    let s = src.data();
    let mut out = vec![0.0f32; c * h_o * w_o];
    for (cc, plane) in out.chunks_mut(h_o * w_o).enumerate() {
        let sp = &s[cc * h * w..][..h * w];
        for y in 0..h_o {
            for x in 0..w_o {
                let mut sum = 0.0f32;
                let mut count = 0u32;
                for dy in 0..kh {
                    let yy = (y * sh + dy) as isize - ph as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = (x * sw + dx) as isize - pw as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        sum += sp[yy as usize * w + xx as usize];
                        count += 1;
                    }
                }
                plane[y * w_o + x] = sum * (1.0 / count.max(1) as f32);
            }
        }
    }
    Tensor::from_vec(&[c, h_o, w_o], out)
}

/// NCHW reference elementwise sum (the residual [`GraphOp::Add`] join),
/// left-folded in operand order exactly like the compiled accumulate
/// gathers — independent of the arena/layout machinery so tests can
/// build naive references for residual graphs.
pub fn add_nchw(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        return Err(Error::Shape(format!(
            "add operands differ: {:?} vs {:?} (residual joins need identical shapes)",
            a.shape(),
            b.shape()
        )));
    }
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::from_vec(a.shape(), data)
}

/// NCHW reference for the derived inter-block pooling glue: reduce
/// `src`'s spatial extents onto `h x w` with the [`pool_spec`] max-pool.
/// Channel counts must match exactly (the graph IR has no channel
/// adaptation). A no-op copy when the extents already match.
pub fn adapt_nchw(src: &Tensor, c: usize, h: usize, w: usize) -> Result<Tensor> {
    let &[sc, sh, sw] = src.shape() else {
        return Err(Error::Shape(format!("expected [C][H][W], got {:?}", src.shape())));
    };
    if sc != c {
        return Err(Error::Shape(format!(
            "channel mismatch: {sc} produced vs {c} consumed (graphs have no channel glue)"
        )));
    }
    let (kh, strh) = pool_spec(sh, h)?;
    let (kw, strw) = pool_spec(sw, w)?;
    pool_nchw(src, kh, kw, strh, strw, 0, 0)
}

/// One activation (graph-edge value or conv staging buffer) with its
/// placed arena region and lifetime over the schedule.
struct Value {
    name: String,
    c: usize,
    h: usize,
    w: usize,
    layout: IoLayout,
    len: usize,
    offset: usize,
    def_t: usize,
    last_t: usize,
    /// Quantization of this value in an i8 schedule
    /// ([`QuantParams::IDENT`] in f32 schedules).
    qp: QuantParams,
}

/// One step of the compiled schedule.
enum Op {
    /// Fused gather (pool / layout / concat-slice) from value `src` into
    /// channel offset `dst_c_off` of value `dst`.
    Adapt { src: usize, dst: usize, dst_c_off: usize, adapt: Adapt },
    /// Standalone elementwise pass (an unfused `Relu` / `BatchNorm`
    /// node) from value `src` into value `dst`.
    Eltwise { src: usize, dst: usize, elt: Eltwise },
    /// Execute conv layer `layer` reading value `src` (already in the
    /// plan's input layout), writing value `dst` (the plan's output
    /// layout). `ep` is the fused epilogue (identity when nothing was
    /// fused) and `res` the fused residual operand's value, already in
    /// the plan's output layout.
    Conv { layer: usize, src: usize, dst: usize, ep: Epilogue, res: Option<usize> },
}

/// Execution-order grouping: serial op ranges, and parallel groups whose
/// lanes (op index lists, in order) are mutually independent.
enum Stage {
    Serial(Range<usize>),
    Parallel(Vec<Vec<usize>>),
}

/// A placed arena region with its schedule lifetime — introspection for
/// the allocator property tests and `plan-net` diagnostics.
#[derive(Clone, Debug)]
pub struct ArenaRegion {
    pub name: String,
    pub offset: usize,
    pub floats: usize,
    pub first_step: usize,
    pub last_step: usize,
}

/// Caller-owned execution state for one in-flight forward pass: the
/// region-allocated activation arena plus the shared per-layer
/// workspace (one slice per branch lane). Create with
/// [`NetRunner::arena`]; reuse across requests (that reuse is exactly
/// what makes the forward pass allocation-free). One arena per
/// concurrent request — workers in a pool each own one.
pub struct NetArena {
    /// f32 activation regions (empty in i8 schedules).
    buf: Vec<f32>,
    /// i8 activation regions (empty in f32 schedules) — same element
    /// count as `buf` would hold, a quarter of the bytes.
    qbuf: Vec<i8>,
    ws: Vec<f32>,
    /// Preallocated trace rings, one per branch lane (lane 0 also
    /// records the serial schedule and the forward/staging spans).
    /// Recording into them never allocates — see [`crate::trace`].
    rings: Vec<SpanRing>,
}

impl NetArena {
    /// Snapshot every recorded span (all lanes merged, start-ordered).
    /// Export path — allocates; never called from a forward.
    pub fn spans(&self) -> Vec<Span> {
        let mut v: Vec<Span> = self.rings.iter().flat_map(|r| r.iter().copied()).collect();
        v.sort_by_key(|s| s.t_start);
        v
    }

    /// Reset every lane ring (drops recorded spans and drop counters).
    pub fn clear_spans(&mut self) {
        for r in &mut self.rings {
            r.clear();
        }
    }

    /// Spans lost to ring overwrite since the last clear (0 means the
    /// snapshot is complete).
    pub fn spans_dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum()
    }

    /// Move every recorded span into `dst` with lanes offset by
    /// `lane_base` (serve workers merge per-arena rings into one
    /// service ring this way), then clear the lane rings.
    /// Allocation-free.
    pub fn drain_spans_into(&mut self, dst: &mut SpanRing, lane_base: u32) {
        for r in &mut self.rings {
            r.drain_into(dst, lane_base);
        }
    }
}

/// A whole benchmark network compiled to an allocation-free executable:
/// per-layer [`ConvPlan`]s, the [`NetGraph`] dataflow, the fused glue
/// ops and the liveness-sized arena. See the module docs.
pub struct NetRunner {
    plans: NetPlans,
    graph: NetGraph,
    values: Vec<Value>,
    ops: Vec<Op>,
    stages: Vec<Stage>,
    input_value: usize,
    output_value: usize,
    input_len: usize,
    output_len: usize,
    arena_floats: usize,
    max_live: usize,
    max_ws: usize,
    lanes: usize,
    dtype: DType,
}

impl NetRunner {
    /// Compile a planned net into an executable schedule, deriving the
    /// canonical graph from the net name ([`NetGraph::for_net`]:
    /// GoogLeNet gets the inception DAG, everything else a chain).
    /// Fails if the layer table cannot form a valid graph.
    pub fn new(plans: NetPlans) -> Result<NetRunner> {
        Self::with_branch_lanes(plans, 1)
    }

    /// Like [`NetRunner::new`], scheduling independent branches of each
    /// fan-out group across up to `lanes` scoped threads (1 = serial).
    pub fn with_branch_lanes(plans: NetPlans, lanes: usize) -> Result<NetRunner> {
        let shapes: Vec<ConvShape> = plans.layers.iter().map(|l| l.layer.shape.clone()).collect();
        let graph = NetGraph::for_net(&plans.net, &shapes)?;
        Self::from_graph(plans, graph, lanes)
    }

    /// Compile an explicit graph over `plans` (the graph's conv nodes
    /// index the plan table 1:1; validated).
    pub fn from_graph(plans: NetPlans, graph: NetGraph, lanes: usize) -> Result<NetRunner> {
        Self::compile(plans, graph, lanes, DType::F32, None, None)
    }

    /// Compile a **fused** schedule: the [`FusedNet`] annotation (from
    /// [`crate::nets::fuse`]) tells the scheduler which `batch_norm` /
    /// `add` / `relu` nodes were folded into their producing conv's
    /// epilogue. Absorbed intermediates get no arena region and no op —
    /// each fused conv applies the whole tail in-tile and writes its
    /// chain tail's value directly. f32 results are **bitwise**
    /// identical to [`NetRunner::from_graph`] on the same model.
    pub fn from_graph_fused(
        plans: NetPlans,
        graph: NetGraph,
        lanes: usize,
        fused: &FusedNet,
    ) -> Result<NetRunner> {
        Self::compile(plans, graph, lanes, DType::F32, None, Some(fused))
    }

    /// Compile a **quantized** schedule: every conv plan must expose an
    /// i8 surface ([`ConvPlan::as_quantized`], i.e. `direct_i8` plans),
    /// `node_params` holds one calibrated [`QuantParams`] per graph
    /// node (what [`crate::quant::QuantNet`] produces), and the
    /// activation arena becomes a byte arena — same element count as
    /// the f32 schedule, a quarter of the bytes. The producer→consumer
    /// requantize steps are fused into the existing Adapt gathers, so
    /// the op schedule is identical to the f32 one.
    pub fn from_graph_quant(
        plans: NetPlans,
        graph: NetGraph,
        lanes: usize,
        node_params: &[QuantParams],
    ) -> Result<NetRunner> {
        if node_params.len() != graph.len() {
            return Err(Error::Shape(format!(
                "quantized net '{}': {} node params for {} graph nodes",
                plans.net,
                node_params.len(),
                graph.len()
            )));
        }
        Self::compile(plans, graph, lanes, DType::I8, Some(node_params), None)
    }

    /// The i8 twin of [`NetRunner::from_graph_fused`]: the conv plans
    /// must have been quantized **with** the fused epilogues baked in
    /// ([`crate::quant::QuantNet`] built against the same [`FusedNet`]),
    /// so each fused conv's requantize step already folds scale, shift,
    /// residual and the quantized ReLU clamp — validated per layer at
    /// compile (output params against the chain tail, residual params
    /// against the shortcut edge).
    pub fn from_graph_quant_fused(
        plans: NetPlans,
        graph: NetGraph,
        lanes: usize,
        node_params: &[QuantParams],
        fused: &FusedNet,
    ) -> Result<NetRunner> {
        if node_params.len() != graph.len() {
            return Err(Error::Shape(format!(
                "quantized net '{}': {} node params for {} graph nodes",
                plans.net,
                node_params.len(),
                graph.len()
            )));
        }
        Self::compile(plans, graph, lanes, DType::I8, Some(node_params), Some(fused))
    }

    fn compile(
        plans: NetPlans,
        graph: NetGraph,
        lanes: usize,
        dtype: DType,
        node_params: Option<&[QuantParams]>,
        fused: Option<&FusedNet>,
    ) -> Result<NetRunner> {
        let lanes = lanes.max(1);
        if plans.layers.is_empty() {
            return Err(Error::Shape(format!("net '{}' has no planned layers", plans.net)));
        }
        if let Some(f) = fused {
            if f.roles.len() != graph.len() || f.fusions.len() != plans.layers.len() {
                return Err(Error::Shape(format!(
                    "fused net '{}': annotation covers {} nodes / {} layers, graph has {} / {}",
                    plans.net,
                    f.roles.len(),
                    f.fusions.len(),
                    graph.len(),
                    plans.layers.len()
                )));
            }
        }
        let shapes: Vec<ConvShape> = plans.layers.iter().map(|l| l.layer.shape.clone()).collect();
        let dims = graph.validate(&shapes)?;
        let mut c = Compiler::new(&plans, &graph, &dims, lanes);
        c.dtype = dtype;
        c.node_qp = node_params.map(<[QuantParams]>::to_vec);
        c.fused = fused;
        c.emit()?;
        // Copy everything out of the compiler before `plans`/`graph`
        // move into the runner (the compiler borrows both).
        let (input_value, output_value) = (c.input_value, c.output_value);
        let (mut values, ops, op_tags) = (c.values, c.ops, c.op_tags);
        let (stages, t_of_op, t_end) = build_stages(&ops, &op_tags, lanes);
        compute_lifetimes(&mut values, &ops, &t_of_op, t_end, input_value, output_value);
        let max_live = max_live_floats_of(&values, t_end);
        let arena_floats = place_regions(&mut values);
        let max_ws = plans.layers.iter().map(|l| l.plan.workspace_len()).max().unwrap_or(0);
        let input_len = dims[0].floats();
        let output_len = dims[graph.output()].floats();
        // A schedule with no parallel stage (chains; or every group
        // single-lane) needs no extra workspace lanes — clamp so the
        // arena and the overhead accounting stay honest.
        let max_width = stages
            .iter()
            .map(|s| match s {
                Stage::Serial(_) => 1,
                Stage::Parallel(l) => l.len(),
            })
            .max()
            .unwrap_or(1);
        let lanes = lanes.min(max_width).max(1);
        Ok(NetRunner {
            plans,
            graph,
            input_value,
            output_value,
            values,
            ops,
            stages,
            input_len,
            output_len,
            arena_floats,
            max_live,
            max_ws,
            lanes,
            dtype,
        })
    }

    /// The planned layers this runner executes.
    pub fn plans(&self) -> &NetPlans {
        &self.plans
    }

    /// The dataflow graph the schedule was compiled from.
    pub fn graph(&self) -> &NetGraph {
        &self.graph
    }

    /// Number of conv layers in the schedule.
    pub fn layers(&self) -> usize {
        self.plans.layers.len()
    }

    /// Branch-parallel lane count (1 = fully serial schedule).
    pub fn branch_lanes(&self) -> usize {
        self.lanes
    }

    /// `C x H x W` of the whole-network NCHW input (the graph's input
    /// node).
    pub fn input_dims(&self) -> Dims {
        let v = &self.values[self.input_value];
        Dims { c: v.c, h: v.h, w: v.w }
    }

    /// `C x H x W` of the whole-network NCHW output (the graph's last
    /// node — for GoogLeNet that is the final inception concat, not the
    /// last conv layer).
    pub fn output_dims(&self) -> Dims {
        let v = &self.values[self.output_value];
        Dims { c: v.c, h: v.h, w: v.w }
    }

    /// Floats of the whole-network NCHW input (the graph's input node).
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Floats of the whole-network NCHW output (the graph's last node).
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Element type of the activation arena ([`DType::F32`] unless the
    /// schedule was compiled with [`NetRunner::from_graph_quant`]).
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Total *elements* of the region-allocated activation arena (f32
    /// in the default schedule, i8 bytes in a quantized one — the
    /// element count is identical because the layouts are shared, so
    /// the i8 arena is exactly a quarter of the f32 bytes).
    pub fn arena_floats(&self) -> usize {
        self.arena_floats
    }

    /// Max live-set over the schedule (floats) — the hard lower bound
    /// the region allocator places against. Placement is exactly this
    /// tight on every paper net (asserted by `net_forward`/`net_graph`);
    /// on arbitrary DAGs some fragmentation above the bound is
    /// unavoidable in principle (offline offset allocation has
    /// instances whose optimum exceeds the max live-set), and the
    /// property tests bound it at 2x.
    pub fn max_live_floats(&self) -> usize {
        self.max_live
    }

    /// The placed arena regions with their schedule lifetimes.
    pub fn arena_regions(&self) -> Vec<ArenaRegion> {
        self.values
            .iter()
            .map(|v| ArenaRegion {
                name: v.name.clone(),
                offset: v.offset,
                floats: v.len,
                first_step: v.def_t,
                last_step: v.last_t,
            })
            .collect()
    }

    /// Bytes of the activation arena (element count times the dtype's
    /// element size — a quantized net's arena is 4x smaller). Intrinsic
    /// network state (the graph's live activations), not overhead.
    pub fn activation_bytes(&self) -> u64 {
        (self.dtype.elem_bytes() * self.arena_floats) as u64
    }

    /// Sum of per-plan retained bytes beyond conventional weights.
    pub fn retained_bytes(&self) -> u64 {
        self.plans.total_retained_bytes()
    }

    /// Scratch bytes of the shared workspace: the *max* per-layer
    /// workspace (times the branch-lane count — each lane owns a
    /// slice), since one buffer serves every layer in turn.
    pub fn workspace_bytes(&self) -> u64 {
        4 * (self.max_ws * self.lanes) as u64
    }

    /// Network-wide memory overhead in the paper's sense:
    /// `retained + shared workspace`. **0** for the `direct` backend on
    /// every paper net, inception DAG included.
    pub fn overhead_bytes(&self) -> u64 {
        self.retained_bytes() + self.workspace_bytes()
    }

    /// Total bytes of one execution arena (activations + workspace).
    pub fn arena_bytes(&self) -> u64 {
        self.activation_bytes() + self.workspace_bytes()
    }

    /// Allocate one execution arena (the only allocation site; do it
    /// once, reuse per request). Quantized schedules get an i8 byte
    /// arena — same element count, a quarter of the bytes.
    pub fn arena(&self) -> NetArena {
        let (buf, qbuf) = match self.dtype {
            DType::F32 => (vec![0.0; self.arena_floats], Vec::new()),
            DType::I8 => (Vec::new(), vec![0i8; self.arena_floats]),
        };
        // Trace rings sized for several forwards' worth of op spans;
        // fixed capacity — a long profiling run overwrites oldest
        // records rather than growing (see `spans_dropped`).
        let ring_cap = ((self.ops.len() + 8) * 8).clamp(256, 65_536);
        let rings = (0..self.lanes).map(|_| SpanRing::with_capacity(ring_cap)).collect();
        NetArena { buf, qbuf, ws: vec![0.0; self.max_ws * self.lanes], rings }
    }

    fn check_forward_buffers(
        &self,
        arena: &NetArena,
        input_len: usize,
        output_len: usize,
    ) -> Result<()> {
        if input_len != self.input_len {
            return Err(Error::Shape(format!(
                "net input has {input_len} floats, expected {}",
                self.input_len
            )));
        }
        if output_len != self.output_len {
            return Err(Error::Shape(format!(
                "net output has {output_len} elements, expected {}",
                self.output_len
            )));
        }
        let act_ok = match self.dtype {
            DType::F32 => arena.buf.len() == self.arena_floats && arena.qbuf.is_empty(),
            DType::I8 => arena.qbuf.len() == self.arena_floats && arena.buf.is_empty(),
        };
        if !act_ok
            || arena.ws.len() != self.max_ws * self.lanes
            || arena.rings.len() != self.lanes
        {
            return Err(Error::Shape("arena was not built by this runner".into()));
        }
        Ok(())
    }

    /// Run the whole network forward, allocation-free (serial schedule;
    /// parallel stages additionally pay scoped thread-spawn
    /// bookkeeping). `input` is the flat NCHW image (`input_len()`
    /// floats), `output` receives the flat NCHW output map
    /// (`output_len()` floats), `arena` is a (reused) buffer set from
    /// [`NetRunner::arena`]. On a quantized schedule the input is
    /// quantized while staging and the output dequantized while
    /// unpacking — both fused into the boundary layout passes, still
    /// allocation-free.
    pub fn forward_with(
        &self,
        arena: &mut NetArena,
        input: &[f32],
        output: &mut [f32],
    ) -> Result<()> {
        self.check_forward_buffers(arena, input.len(), output.len())?;
        let t0 = trace::start();
        match self.dtype {
            DType::F32 => self.forward_f32(arena, input, output)?,
            DType::I8 => {
                self.forward_i8(arena, input)?;
                let t1 = trace::start();
                let qp = self.values[self.output_value].qp;
                self.unpack_output_q8(arena, |i, q| output[i] = dequantize(q, &qp));
                if t1 != trace::OFF {
                    arena.rings[0].push(self.io_span(SpanKind::Output, self.output_value, t1));
                }
            }
        }
        if t0 != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Forward, self.output_value, t0));
        }
        Ok(())
    }

    /// Walk the i8 output value in NCHW order, handing each element's
    /// flat NCHW index and raw quantized byte to `sink` — the single
    /// unpack loop shared by the dequantizing and raw-integer output
    /// paths (so a layout/indexing fix cannot diverge between them).
    fn unpack_output_q8(&self, arena: &NetArena, mut sink: impl FnMut(usize, i8)) {
        let ov = &self.values[self.output_value];
        let native = &arena.qbuf[ov.offset..ov.offset + ov.len];
        for c in 0..ov.c {
            for y in 0..ov.h {
                for x in 0..ov.w {
                    let q = native[io_index(ov.layout, c, y, x, ov.c, ov.h, ov.w)];
                    sink((c * ov.h + y) * ov.w + x, q);
                }
            }
        }
    }

    /// Quantized forward with a **raw i8** NCHW output (no dequantize)
    /// — the exact integers the golden fixtures pin, and what an
    /// int8-consuming classifier head would read. Errors on f32
    /// schedules.
    pub fn forward_q8_with(
        &self,
        arena: &mut NetArena,
        input: &[f32],
        output: &mut [i8],
    ) -> Result<()> {
        if self.dtype != DType::I8 {
            return Err(Error::Shape(
                "forward_q8_with requires a quantized schedule (from_graph_quant)".into(),
            ));
        }
        self.check_forward_buffers(arena, input.len(), output.len())?;
        let t0 = trace::start();
        self.forward_i8(arena, input)?;
        let t1 = trace::start();
        self.unpack_output_q8(arena, |i, q| output[i] = q);
        if t1 != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Output, self.output_value, t1));
        }
        if t0 != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Forward, self.output_value, t0));
        }
        Ok(())
    }

    fn forward_f32(
        &self,
        arena: &mut NetArena,
        input: &[f32],
        output: &mut [f32],
    ) -> Result<()> {
        // Stage the NCHW input into the input value's native layout.
        let t_in = trace::start();
        {
            let iv = &self.values[self.input_value];
            let region = &mut arena.buf[iv.offset..iv.offset + iv.len];
            match iv.layout {
                IoLayout::Nchw => region.copy_from_slice(input),
                IoLayout::Nhwc => nchw_to_nhwc_slice(input, iv.c, iv.h, iv.w, region)?,
                IoLayout::Blocked { c_b } => pack_io_slice(input, iv.c, iv.h, iv.w, c_b, region)?,
            }
        }
        if t_in != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Input, self.input_value, t_in));
        }

        for stage in &self.stages {
            match stage {
                Stage::Serial(range) => {
                    let ws = &mut arena.ws[..self.max_ws];
                    for idx in range.clone() {
                        let op = &self.ops[idx];
                        let (so, sl, dofs, dl, rr) = self.op_regions(op);
                        let t0 = trace::start();
                        let (src, dst, res) = split_regions(&mut arena.buf, so, sl, dofs, dl, rr);
                        self.run_op(op, src, dst, res, ws)?;
                        if t0 != trace::OFF {
                            arena.rings[0].push(self.op_span(idx, 0, t0));
                        }
                    }
                }
                Stage::Parallel(lanes_ops) => {
                    let NetArena { buf, ws, rings, .. } = arena;
                    run_parallel_t(
                        self,
                        buf,
                        ws,
                        rings,
                        self.max_ws,
                        lanes_ops,
                        &|op, src, dst, res, ws| self.run_op(op, src, dst, res, ws),
                    )?;
                }
            }
        }

        // Unpack the output value back to NCHW.
        let t_out = trace::start();
        {
            let ov = &self.values[self.output_value];
            let native = &arena.buf[ov.offset..ov.offset + ov.len];
            match ov.layout {
                IoLayout::Nchw => output.copy_from_slice(native),
                IoLayout::Nhwc => nhwc_to_nchw_slice(native, ov.c, ov.h, ov.w, output)?,
                IoLayout::Blocked { c_b } => unpack_io_slice(native, ov.c, ov.h, ov.w, c_b, output)?,
            }
        }
        if t_out != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Output, self.output_value, t_out));
        }
        Ok(())
    }

    /// Replay the schedule over the i8 byte arena: quantize + stage the
    /// f32 input, then run every op in integer form (convs through
    /// [`crate::quant::QuantExecute`], glue through
    /// [`Adapt::apply_i8`]). The output stays in the arena in the
    /// output value's native layout; callers unpack it.
    fn forward_i8(&self, arena: &mut NetArena, input: &[f32]) -> Result<()> {
        let t_in = trace::start();
        {
            let iv = &self.values[self.input_value];
            let region = &mut arena.qbuf[iv.offset..iv.offset + iv.len];
            for c in 0..iv.c {
                for y in 0..iv.h {
                    for x in 0..iv.w {
                        let v = input[(c * iv.h + y) * iv.w + x];
                        region[io_index(iv.layout, c, y, x, iv.c, iv.h, iv.w)] =
                            quantize(v, &iv.qp);
                    }
                }
            }
        }
        if t_in != trace::OFF {
            arena.rings[0].push(self.io_span(SpanKind::Input, self.input_value, t_in));
        }
        for stage in &self.stages {
            match stage {
                Stage::Serial(range) => {
                    for idx in range.clone() {
                        let op = &self.ops[idx];
                        let (so, sl, dofs, dl, rr) = self.op_regions(op);
                        let t0 = trace::start();
                        let (src, dst, res) = split_regions(&mut arena.qbuf, so, sl, dofs, dl, rr);
                        self.run_op_i8(op, src, dst, res)?;
                        if t0 != trace::OFF {
                            arena.rings[0].push(self.op_span(idx, 0, t0));
                        }
                    }
                }
                Stage::Parallel(lanes_ops) => {
                    let NetArena { qbuf, ws, rings, .. } = arena;
                    run_parallel_t(
                        self,
                        qbuf,
                        ws,
                        rings,
                        self.max_ws,
                        lanes_ops,
                        &|op, src, dst, res, _| self.run_op_i8(op, src, dst, res),
                    )?;
                }
            }
        }
        Ok(())
    }

    /// One-shot convenience: allocates a fresh arena and the output
    /// tensor. Not the hot path — serving holds arenas and calls
    /// [`NetRunner::forward_with`].
    pub fn forward(&self, input: &Tensor) -> Result<Tensor> {
        let iv = &self.values[self.input_value];
        let want = [iv.c, iv.h, iv.w];
        if input.shape() != want {
            return Err(Error::Shape(format!(
                "net input shape {:?} != expected {want:?}",
                input.shape()
            )));
        }
        let ov = &self.values[self.output_value];
        let out_shape = [ov.c, ov.h, ov.w];
        let mut arena = self.arena();
        let mut out = vec![0.0f32; self.output_len];
        self.forward_with(&mut arena, input.data(), &mut out)?;
        Tensor::from_vec(&out_shape, out)
    }

    /// Finish a span for op `idx` on execution lane `lane`, opened at
    /// `t0` (a real timestamp — callers gate on [`trace::OFF`]).
    /// Conv spans carry the planned-layer index in `meta` and the
    /// plan's [`ConvPlan::kernel_desc`] as the static label, which is
    /// everything the roofline report needs; names resolve lazily via
    /// [`NetRunner::span_name`]. No allocation, no formatting.
    fn op_span(&self, idx: usize, lane: u32, t0: u64) -> Span {
        let (kind, label, meta) = match &self.ops[idx] {
            Op::Adapt { .. } => (SpanKind::Adapt, "", 0u64),
            Op::Eltwise { .. } => (SpanKind::Eltwise, "", 0u64),
            Op::Conv { layer, .. } => {
                let l = &self.plans.layers[*layer];
                (SpanKind::Conv, l.plan.kernel_desc(), *layer as u64)
            }
        };
        Span { id: idx as u32, kind, lane, label, t_start: t0, t_end: trace::now_ns(), meta }
    }

    /// Finish a staging / whole-forward span (`id` = the boundary
    /// value's index, always recorded on lane 0).
    fn io_span(&self, kind: SpanKind, value: usize, t0: u64) -> Span {
        Span {
            id: value as u32,
            kind,
            lane: 0,
            label: "",
            t_start: t0,
            t_end: trace::now_ns(),
            meta: 0,
        }
    }

    /// Resolve a span recorded by this runner into a display name
    /// (Chrome-trace event name). Conv spans name their planned layer
    /// and kernel; glue spans name their destination value (the graph
    /// edge they produce). Safe on foreign spans — falls back to the
    /// kind name.
    pub fn span_name(&self, s: &Span) -> String {
        match s.kind {
            SpanKind::Conv => match self.plans.layers.get(s.meta as usize) {
                Some(l) => format!("{} [{}/{}]", l.layer.name, l.backend, s.label),
                None => s.kind.name().to_string(),
            },
            SpanKind::Adapt | SpanKind::Eltwise => {
                let dst = self.ops.get(s.id as usize).map(|op| match op {
                    Op::Adapt { dst, .. } | Op::Eltwise { dst, .. } | Op::Conv { dst, .. } => *dst,
                });
                match dst.and_then(|d| self.values.get(d)) {
                    Some(v) => format!("{} -> {}", s.kind.name(), v.name),
                    None => s.kind.name().to_string(),
                }
            }
            _ => s.kind.name().to_string(),
        }
    }

    /// Arena regions of one op:
    /// `(src_off, src_len, dst_off, dst_len, residual)`.
    fn op_regions(&self, op: &Op) -> (usize, usize, usize, usize, Option<(usize, usize)>) {
        match op {
            Op::Conv { src, dst, res, .. } => {
                let (s, d) = (&self.values[*src], &self.values[*dst]);
                let r = res.map(|r| (self.values[r].offset, self.values[r].len));
                (s.offset, s.len, d.offset, d.len, r)
            }
            Op::Eltwise { src, dst, .. } => {
                let (s, d) = (&self.values[*src], &self.values[*dst]);
                (s.offset, s.len, d.offset, d.len, None)
            }
            Op::Adapt { src, dst, dst_c_off, adapt } => {
                let (s, d) = (&self.values[*src], &self.values[*dst]);
                // Concat slices land in NCHW, so a channel range is a
                // contiguous sub-region.
                let off = d.offset + dst_c_off * d.h * d.w;
                (s.offset, s.len, off, adapt.dst_c * adapt.dst_h * adapt.dst_w, None)
            }
        }
    }

    fn run_op(
        &self,
        op: &Op,
        src: &[f32],
        dst: &mut [f32],
        res: Option<&[f32]>,
        ws: &mut [f32],
    ) -> Result<()> {
        match op {
            Op::Adapt { adapt, .. } => {
                adapt.apply(src, dst);
                Ok(())
            }
            Op::Eltwise { elt, .. } => {
                elt.apply(src, dst);
                Ok(())
            }
            Op::Conv { layer, ep, .. } => {
                let plan = &self.plans.layers[*layer].plan;
                let ws = &mut ws[..plan.workspace_len()];
                if ep.is_none() {
                    plan.execute_into(src, dst, ws)
                } else {
                    plan.execute_fused_into(src, dst, ws, ep, res)
                }
            }
        }
    }

    fn run_op_i8(&self, op: &Op, src: &[i8], dst: &mut [i8], res: Option<&[i8]>) -> Result<()> {
        match op {
            Op::Adapt { adapt, .. } => {
                adapt.apply_i8(src, dst);
                Ok(())
            }
            Op::Eltwise { elt, .. } => {
                elt.apply_i8(src, dst);
                Ok(())
            }
            Op::Conv { layer, .. } => {
                let plan = &self.plans.layers[*layer].plan;
                // Presence of the i8 surface is validated at compile.
                // Scale/shift/ReLU epilogues are baked into the plan's
                // requantize step; only a fused residual changes the
                // execution entry.
                let q = plan.as_quantized().ok_or_else(|| {
                    Error::Runtime("i8 schedule holds a plan without an i8 surface".into())
                })?;
                match res {
                    Some(r) => q.execute_i8_fused_into(src, dst, Some(r)),
                    None => q.execute_i8_into(src, dst),
                }
            }
        }
    }
}

/// Execute one parallel group over an arena of element type `T`: lanes
/// are distributed round-robin over up to `runner.lanes` scoped
/// workers, each with its own workspace slice. Group-time liveness
/// (see [`build_stages`]) guarantees every region written here is
/// disjoint from every other region touched by the group, so the
/// raw-pointer slicing below never creates aliasing references.
fn run_parallel_t<T: Copy + Send + Sync>(
    runner: &NetRunner,
    buf: &mut [T],
    ws_all: &mut [f32],
    rings: &mut [SpanRing],
    max_ws: usize,
    lanes_ops: &[Vec<usize>],
    exec: &(dyn Fn(&Op, &[T], &mut [T], Option<&[T]>, &mut [f32]) -> Result<()> + Sync),
) -> Result<()> {
    let workers = runner.lanes.min(lanes_ops.len()).max(1);
    debug_assert!(rings.len() >= workers, "one trace ring per worker");
    let base = ArenaPtr { ptr: buf.as_mut_ptr(), len: buf.len() };
    let mut ws_slices: Vec<&mut [f32]> = Vec::with_capacity(workers);
    let mut rest: &mut [f32] = ws_all;
    for _ in 0..workers {
        let (a, b) = rest.split_at_mut(max_ws);
        ws_slices.push(a);
        rest = b;
    }
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(workers);
        for ((w, ws), ring) in ws_slices.into_iter().enumerate().zip(rings.iter_mut()) {
            let base = &base;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut ws = ws;
                for lane in (w..lanes_ops.len()).step_by(workers) {
                    for &idx in &lanes_ops[lane] {
                        let op = &runner.ops[idx];
                        let (so, sl, dofs, dl, rr) = runner.op_regions(op);
                        debug_assert!(so + sl <= dofs || dofs + dl <= so);
                        debug_assert!(so + sl <= base.len && dofs + dl <= base.len);
                        if let Some((ro, rl)) = rr {
                            debug_assert!(ro + rl <= dofs || dofs + dl <= ro);
                            debug_assert!(ro + rl <= base.len);
                        }
                        let t0 = trace::start();
                        // SAFETY: regions of concurrently executing
                        // ops are pairwise disjoint — values live at
                        // the same group time never share arena
                        // space (region allocator invariant), and
                        // concat slice writes use disjoint channel
                        // offsets of one value. Reads (the source and
                        // any fused residual) may overlap other reads
                        // only. Bounds checked above.
                        let (src, dst, res) = unsafe {
                            (
                                std::slice::from_raw_parts(base.ptr.add(so), sl),
                                std::slice::from_raw_parts_mut(base.ptr.add(dofs), dl),
                                rr.map(|(ro, rl)| {
                                    std::slice::from_raw_parts(base.ptr.add(ro), rl)
                                }),
                            )
                        };
                        exec(op, src, dst, res, ws)?;
                        if t0 != trace::OFF {
                            ring.push(runner.op_span(idx, w as u32, t0));
                        }
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| Error::Runtime("net branch worker panicked".into()))??;
        }
        Ok(())
    })
}

/// Shared arena base pointer for branch-parallel stages. Lanes write
/// provably disjoint regions (see [`run_parallel_t`]).
struct ArenaPtr<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the pointer is only dereferenced through the disjoint-region
// protocol documented at the single use site.
unsafe impl<T: Send> Send for ArenaPtr<T> {}
unsafe impl<T: Sync> Sync for ArenaPtr<T> {}

/// Disjoint (read, write, fused-residual read) views into the arena
/// buffer (f32 or i8). The write region never overlaps either read
/// region (region-allocator liveness invariant, debug-asserted); the
/// two read regions may alias each other freely.
fn split_regions<T>(
    buf: &mut [T],
    so: usize,
    sl: usize,
    dofs: usize,
    dl: usize,
    res: Option<(usize, usize)>,
) -> (&[T], &mut [T], Option<&[T]>) {
    fn pick<'b, T>(head: &'b [T], tail: &'b [T], dofs: usize, dl: usize, off: usize, len: usize) -> &'b [T] {
        if off + len <= dofs {
            &head[off..off + len]
        } else {
            &tail[off - (dofs + dl)..][..len]
        }
    }
    debug_assert!(so + sl <= dofs || dofs + dl <= so, "live regions must not alias");
    if let Some((ro, rl)) = res {
        debug_assert!(ro + rl <= dofs || dofs + dl <= ro, "residual must not alias the output");
    }
    let (head, rest) = buf.split_at_mut(dofs);
    let (dst, tail) = rest.split_at_mut(dl);
    let (head, tail): (&[T], &[T]) = (head, tail);
    let src = pick(head, tail, dofs, dl, so, sl);
    let r = res.map(|(ro, rl)| pick(head, tail, dofs, dl, ro, rl));
    (src, dst, r)
}

// ---------------------------------------------------------------------
// Compilation: graph -> values + ops
// ---------------------------------------------------------------------

struct Compiler<'a> {
    plans: &'a NetPlans,
    graph: &'a NetGraph,
    dims: &'a [Dims],
    values: Vec<Value>,
    ops: Vec<Op>,
    op_tags: Vec<Option<BranchTag>>,
    node_value: Vec<usize>,
    input_value: usize,
    output_value: usize,
    dtype: DType,
    /// Calibrated per-node activation params (i8 schedules only).
    node_qp: Option<Vec<QuantParams>>,
    /// Fusion annotation (fused schedules only): absorbed nodes are
    /// skipped, fused convs carry epilogues and write their tail's
    /// value.
    fused: Option<&'a FusedNet>,
    /// Staging dedup (one gather per converted value, not one per
    /// consumer): `(producer node, wanted layout) -> staging value`.
    stage_cache: Vec<(usize, IoLayout, usize)>,
    /// Pre-scanned staging demand: `(producer node, wanted layout,
    /// one consumer's branch tag, demanded from >1 distinct tag)`.
    /// Shared stages (multi-tag) run serially before the group so no
    /// lane writes a region a sibling lane reads.
    stage_tags: Vec<(usize, IoLayout, Option<BranchTag>, bool)>,
}

impl<'a> Compiler<'a> {
    fn new(plans: &'a NetPlans, graph: &'a NetGraph, dims: &'a [Dims], _lanes: usize) -> Self {
        Compiler {
            plans,
            graph,
            dims,
            values: Vec::new(),
            ops: Vec::new(),
            op_tags: Vec::new(),
            node_value: vec![usize::MAX; graph.len()],
            input_value: 0,
            output_value: 0,
            dtype: DType::F32,
            node_qp: None,
            fused: None,
            stage_cache: Vec::new(),
            stage_tags: Vec::new(),
        }
    }

    fn qp_of_node(&self, node: usize) -> QuantParams {
        self.node_qp.as_ref().map(|v| v[node]).unwrap_or(QuantParams::IDENT)
    }

    /// The graph node whose *value* node `i`'s output lives in: the
    /// chain tail for a conv that absorbed an epilogue chain, `i`
    /// itself otherwise.
    fn tail_of(&self, i: usize) -> usize {
        self.fused.map_or(i, |f| f.tail[i])
    }

    /// The fused epilogue annotation of conv layer `layer` (the
    /// all-`None` default outside fused schedules).
    fn fusion_of(&self, layer: usize) -> crate::nets::LayerFusion {
        self.fused.map(|f| f.fusions[layer].clone()).unwrap_or_default()
    }

    /// The storage layout a node's value uses: convs write their plan's
    /// native output layout; input/pool/eltwise values adopt their
    /// single conv consumer's native input layout (so the gather fuses
    /// the layout conversion and the conv reads the region directly);
    /// everything else — concat joins, multi-consumer fan-outs — lands
    /// in NCHW.
    fn value_layout(&self, node: usize, consumers: &[Vec<usize>]) -> IoLayout {
        match self.graph.nodes[node].op {
            GraphOp::Conv { layer } => self.plans.layers[layer].plan.output_layout(),
            GraphOp::Concat | GraphOp::Add => IoLayout::Nchw,
            GraphOp::Input { .. }
            | GraphOp::Pool { .. }
            | GraphOp::Relu { .. }
            | GraphOp::BatchNorm => {
                if let [single] = consumers[node][..] {
                    if let GraphOp::Conv { layer } = self.graph.nodes[single].op {
                        return self.plans.layers[layer].plan.input_layout();
                    }
                }
                IoLayout::Nchw
            }
        }
    }

    /// The value of node `p` converted to layout `want` — the node's
    /// own value when it already matches, else a staging value fed by
    /// one pure layout-permutation gather. The stage is emitted once
    /// per `(node, layout)` pair and shared by every consumer (the
    /// cross-branch staging dedup): single-tag demand stays in its
    /// consumer's lane, multi-tag demand runs serially before the
    /// parallel group.
    fn staged(&mut self, p: usize, want: IoLayout) -> usize {
        let pv = self.node_value[p];
        if self.values[pv].layout == want {
            return pv;
        }
        if let Some(&(_, _, sv)) =
            self.stage_cache.iter().find(|&&(n, l, _)| n == p && l == want)
        {
            return sv;
        }
        let tag = match self.stage_tags.iter().find(|(n, l, ..)| *n == p && *l == want) {
            Some(&(_, _, t, multi)) => {
                if multi {
                    None
                } else {
                    t
                }
            }
            None => None,
        };
        let v = &self.values[pv];
        let (d, from, qp) = (Dims { c: v.c, h: v.h, w: v.w }, v.layout, v.qp);
        let name = format!("stage:{}@{}", v.name, layout_tag(want));
        let sv = self.new_value(name, d, want, qp);
        let mut adapt = Adapt::convert(d.c, d.h, d.w, from, want);
        adapt.src_qp = qp;
        adapt.dst_qp = qp; // pure layout permutation
        self.push_op(Op::Adapt { src: pv, dst: sv, dst_c_off: 0, adapt }, tag);
        self.stage_cache.push((p, want, sv));
        sv
    }

    fn new_value(&mut self, name: String, d: Dims, layout: IoLayout, qp: QuantParams) -> usize {
        self.values.push(Value {
            name,
            c: d.c,
            h: d.h,
            w: d.w,
            layout,
            len: d.floats(),
            offset: 0,
            def_t: 0,
            last_t: 0,
            qp,
        });
        self.values.len() - 1
    }

    fn push_op(&mut self, op: Op, tag: Option<BranchTag>) {
        self.ops.push(op);
        self.op_tags.push(tag);
    }

    /// Record one consumer's staging demand (see `stage_tags`).
    fn note_demand(&mut self, p: usize, want: IoLayout, tag: Option<BranchTag>) {
        match self.stage_tags.iter_mut().find(|(n, l, ..)| *n == p && *l == want) {
            Some(e) => {
                if e.2 != tag {
                    e.3 = true;
                }
            }
            None => self.stage_tags.push((p, want, tag, false)),
        }
    }

    fn emit(&mut self) -> Result<()> {
        // Consumer lists drive the layout choice above.
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); self.graph.len()];
        for (i, n) in self.graph.nodes.iter().enumerate() {
            for &p in &n.preds {
                consumers[p].push(i);
            }
        }
        // Pre-scan staging demand: a conv's input (and fused residual)
        // staging may be shared across branch lanes, and a shared stage
        // must not run inside any single lane.
        for n in self.graph.nodes.iter() {
            let GraphOp::Conv { layer } = n.op else { continue };
            let plan = &self.plans.layers[layer].plan;
            self.note_demand(n.preds[0], plan.input_layout(), n.branch);
            if let Some(r) = self.fusion_of(layer).res_node {
                self.note_demand(r, plan.output_layout(), n.branch);
            }
        }
        let bn_ords = self.graph.bn_ordinals();
        for i in 0..self.graph.len() {
            // Fused schedules skip absorbed nodes entirely: the owning
            // conv writes the chain tail's value and intermediates never
            // materialize. Mapping an absorbed node onto the conv's
            // value keeps `node_value` total — intermediates are never
            // referenced by later nodes (single-consumer invariant), and
            // tails resolve to exactly the value the conv writes.
            if let Some(f) = self.fused {
                if let NodeRole::Absorbed { into } = f.roles[i] {
                    self.node_value[i] = self.node_value[into];
                    continue;
                }
            }
            let layout = self.value_layout(i, &consumers);
            let node = &self.graph.nodes[i];
            // A fused conv's value is its chain tail's: tail name, tail
            // dims (identical — the absorbed ops are shape-preserving)
            // and, in i8 schedules, the tail edge's calibrated params
            // (the target of the fused requantize).
            let t = self.tail_of(i);
            let node_qp = self.qp_of_node(t);
            let v =
                self.new_value(self.graph.nodes[t].name.clone(), self.dims[t], layout, node_qp);
            self.node_value[i] = v;
            match &node.op {
                GraphOp::Input { .. } => {
                    self.input_value = v;
                }
                GraphOp::Conv { layer } => {
                    let p = node.preds[0];
                    let pv = self.node_value[p];
                    let plan = &self.plans.layers[*layer].plan;
                    let fusion = self.fusion_of(*layer);
                    let ep = fusion.epilogue(self.dims[t].c);
                    ep.validate(self.dims[t].c)?;
                    if self.dtype == DType::I8 {
                        // A quantized schedule can only drive plans that
                        // expose the i8 surface, and the plan's params
                        // must agree with the calibrated edge params —
                        // scale chaining is constructed, not hoped for.
                        let q = plan.as_quantized().ok_or_else(|| {
                            Error::Shape(format!(
                                "i8 net '{}': layer '{}' was planned by backend '{}' which \
                                 has no i8 surface (plan with direct_i8 / QuantNet)",
                                self.plans.net,
                                node.name,
                                plan.backend()
                            ))
                        })?;
                        if plan.workspace_len() != 0 {
                            return Err(Error::Shape(format!(
                                "i8 net '{}': layer '{}' wants f32 workspace",
                                self.plans.net, node.name
                            )));
                        }
                        if q.input_qparams() != self.values[pv].qp
                            || q.output_qparams() != node_qp
                        {
                            return Err(Error::Shape(format!(
                                "i8 net '{}': layer '{}' was quantized with different edge \
                                 params than the graph calibration",
                                self.plans.net, node.name
                            )));
                        }
                        let want_res =
                            fusion.res_node.map(|r| self.values[self.node_value[r]].qp);
                        if q.residual_qparams() != want_res {
                            return Err(Error::Shape(format!(
                                "i8 net '{}': layer '{}' was quantized with a different fused \
                                 residual than the schedule (rebuild the QuantNet against the \
                                 same fusion annotation)",
                                self.plans.net, node.name
                            )));
                        }
                    }
                    // §4 zero-repacking chain: `staged` returns the
                    // region directly when the layout already matches.
                    let src = self.staged(p, plan.input_layout());
                    let res = fusion.res_node.map(|r| self.staged(r, plan.output_layout()));
                    self.push_op(Op::Conv { layer: *layer, src, dst: v, ep, res }, node.branch);
                }
                GraphOp::Pool { kind, kh, kw, sh, sw, ph, pw } => {
                    let p = node.preds[0];
                    let pv = self.node_value[p];
                    let (pd, d) = (self.dims[p], self.dims[i]);
                    let adapt = Adapt {
                        src_c: pd.c,
                        src_h: pd.h,
                        src_w: pd.w,
                        src_layout: self.values[pv].layout,
                        dst_c: d.c,
                        dst_h: d.h,
                        dst_w: d.w,
                        dst_layout: self.values[v].layout,
                        kind: *kind,
                        kh: *kh,
                        kw: *kw,
                        sh: *sh,
                        sw: *sw,
                        ph: *ph,
                        pw: *pw,
                        accumulate: false,
                        src_qp: self.values[pv].qp,
                        dst_qp: node_qp,
                    };
                    self.push_op(Op::Adapt { src: pv, dst: v, dst_c_off: 0, adapt }, node.branch);
                }
                GraphOp::Concat => {
                    let d = self.dims[i];
                    let mut c_off = 0usize;
                    for &p in &node.preds {
                        let pv = self.node_value[p];
                        let pd = self.dims[p];
                        let adapt = Adapt {
                            src_c: pd.c,
                            src_h: pd.h,
                            src_w: pd.w,
                            src_layout: self.values[pv].layout,
                            dst_c: pd.c,
                            dst_h: d.h,
                            dst_w: d.w,
                            dst_layout: IoLayout::Nchw,
                            kind: PoolKind::Max,
                            kh: 1,
                            kw: 1,
                            sh: 1,
                            sw: 1,
                            ph: 0,
                            pw: 0,
                            accumulate: false,
                            // Branches land on the concat's common scale
                            // — the requantize fuses into the slice copy.
                            src_qp: self.values[pv].qp,
                            dst_qp: node_qp,
                        };
                        // The gather runs in the producing branch's lane.
                        self.push_op(
                            Op::Adapt { src: pv, dst: v, dst_c_off: c_off, adapt },
                            self.graph.nodes[p].branch,
                        );
                        c_off += pd.c;
                    }
                }
                GraphOp::Add => {
                    // Residual join: the first operand's gather *sets*
                    // the destination, each later operand *accumulates*
                    // into it — the sum fuses into the same layout-
                    // converting pass (no extra temporaries, so both
                    // operands stay live to the join and the arena
                    // accounting charges them honestly). The ops share
                    // the join node's lane tag: accumulation into one
                    // region must stay sequenced, never fanned across
                    // concurrent lanes. In i8 schedules each operand is
                    // requantized to the join's scale as it lands and
                    // the accumulation saturates (see Adapt::apply_i8).
                    let d = self.dims[i];
                    for (j, &p) in node.preds.iter().enumerate() {
                        let pv = self.node_value[p];
                        let mut adapt = Adapt::convert(
                            d.c,
                            d.h,
                            d.w,
                            self.values[pv].layout,
                            self.values[v].layout,
                        );
                        adapt.accumulate = j > 0;
                        adapt.src_qp = self.values[pv].qp;
                        adapt.dst_qp = node_qp;
                        self.push_op(
                            Op::Adapt { src: pv, dst: v, dst_c_off: 0, adapt },
                            node.branch,
                        );
                    }
                }
                GraphOp::Relu { clamp } => {
                    // Standalone activation — only reached when the pass
                    // could not fold it into a conv (fan-out, misorder).
                    let p = node.preds[0];
                    let pv = self.node_value[p];
                    let d = self.dims[i];
                    let elt = Eltwise {
                        c: d.c,
                        h: d.h,
                        w: d.w,
                        src_layout: self.values[pv].layout,
                        dst_layout: self.values[v].layout,
                        scale: Vec::new(),
                        shift: Vec::new(),
                        relu: true,
                        clamp: *clamp,
                        src_qp: self.values[pv].qp,
                        dst_qp: node_qp,
                    };
                    self.push_op(Op::Eltwise { src: pv, dst: v, elt }, node.branch);
                }
                GraphOp::BatchNorm => {
                    // Inference-mode BN is a per-channel affine; the
                    // folded parameters are the net's deterministic
                    // fixtures (shared with the golden generator).
                    let p = node.preds[0];
                    let pv = self.node_value[p];
                    let d = self.dims[i];
                    let ord = bn_ords[i].expect("BatchNorm node has an ordinal");
                    let (scale, shift) = net_bn_params(ord, d.c);
                    let elt = Eltwise {
                        c: d.c,
                        h: d.h,
                        w: d.w,
                        src_layout: self.values[pv].layout,
                        dst_layout: self.values[v].layout,
                        scale,
                        shift,
                        relu: false,
                        clamp: None,
                        src_qp: self.values[pv].qp,
                        dst_qp: node_qp,
                    };
                    self.push_op(Op::Eltwise { src: pv, dst: v, elt }, node.branch);
                }
            }
        }
        self.output_value = self.node_value[self.graph.output()];
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Scheduling, liveness and placement
// ---------------------------------------------------------------------

/// Group ops into stages and assign each op a schedule time. With
/// `lanes == 1` every op is its own serial step (tightest liveness).
/// With `lanes > 1`, maximal runs of ops tagged with one branch group
/// collapse into a parallel stage whose ops all share ONE time step —
/// the conservative "group-time" liveness that makes concurrent lanes
/// mutually disjoint in the arena.
fn build_stages(
    ops: &[Op],
    tags: &[Option<crate::nets::BranchTag>],
    lanes: usize,
) -> (Vec<Stage>, Vec<usize>, usize) {
    let mut stages: Vec<Stage> = Vec::new();
    let mut t_of_op = vec![0usize; ops.len()];
    let mut t = 0usize;
    let mut i = 0usize;
    while i < ops.len() {
        if lanes <= 1 || tags[i].is_none() {
            let start = i;
            while i < ops.len() && (lanes <= 1 || tags[i].is_none()) {
                t_of_op[i] = t;
                t += 1;
                i += 1;
            }
            stages.push(Stage::Serial(start..i));
        } else {
            let group = tags[i].unwrap().group;
            let mut by_lane: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            while i < ops.len() && tags[i].map(|tg| tg.group) == Some(group) {
                by_lane.entry(tags[i].unwrap().lane).or_default().push(i);
                t_of_op[i] = t;
                i += 1;
            }
            t += 1;
            if by_lane.len() > 1 {
                stages.push(Stage::Parallel(by_lane.into_values().collect()));
            } else {
                // Single lane: run it serially (ops stay in order).
                let only: Vec<usize> = by_lane.into_values().next().unwrap_or_default();
                stages.push(Stage::Serial(only[0]..only[only.len() - 1] + 1));
            }
        }
    }
    (stages, t_of_op, t)
}

/// Fill `def_t` / `last_t` from the schedule. The input value is
/// defined at step 0 (staged before the first op); the output value
/// stays live through `t_end` (the unpack after the last op).
fn compute_lifetimes(
    values: &mut [Value],
    ops: &[Op],
    t_of_op: &[usize],
    t_end: usize,
    input_value: usize,
    output_value: usize,
) {
    for (i, v) in values.iter_mut().enumerate() {
        if i == input_value {
            v.def_t = 0;
            v.last_t = 0;
        } else {
            v.def_t = usize::MAX;
            v.last_t = 0;
        }
    }
    for (idx, op) in ops.iter().enumerate() {
        let t = t_of_op[idx];
        let (src, dst) = match op {
            Op::Adapt { src, dst, .. } => (*src, *dst),
            Op::Eltwise { src, dst, .. } => (*src, *dst),
            Op::Conv { src, dst, res, .. } => {
                // A fused residual is a third read operand — it must
                // stay live to the conv that consumes it.
                if let Some(r) = *res {
                    values[r].last_t = values[r].last_t.max(t);
                }
                (*src, *dst)
            }
        };
        values[src].last_t = values[src].last_t.max(t);
        // A value stays live from its first writer on.
        values[dst].def_t = values[dst].def_t.min(t);
        values[dst].last_t = values[dst].last_t.max(t);
    }
    values[output_value].last_t = values[output_value].last_t.max(t_end);
    debug_assert!(values.iter().all(|v| v.def_t <= v.last_t), "value never written");
}

/// Max over schedule time of the total floats live at once.
fn max_live_floats_of(values: &[Value], t_end: usize) -> usize {
    let mut delta = vec![0isize; t_end + 2];
    for v in values {
        delta[v.def_t] += v.len as isize;
        delta[v.last_t + 1] -= v.len as isize;
    }
    let (mut live, mut max) = (0isize, 0isize);
    for d in delta {
        live += d;
        max = max.max(live);
    }
    max as usize
}

/// Greedy-by-size offset assignment: process values largest-first and
/// place each at the lowest offset that does not overlap any
/// already-placed value whose lifetime intersects. Guarantees that live
/// values never alias — always. Tightness is a property of the graph:
/// on every paper net the arena lands exactly on the max live-set
/// (asserted by the conformance tests), while arbitrary DAGs can
/// force fragmentation above the lower bound no matter the allocator
/// (dynamic-storage-allocation lower bounds); the property tests keep
/// that slack under 2x on random module DAGs.
fn place_regions(values: &mut [Value]) -> usize {
    let mut order: Vec<usize> = (0..values.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(values[i].len), values[i].def_t, i));
    let mut placed: Vec<usize> = Vec::with_capacity(values.len());
    let mut arena = 0usize;
    for &i in &order {
        let mut spans: Vec<(usize, usize)> = placed
            .iter()
            .filter(|&&j| {
                values[j].def_t <= values[i].last_t && values[i].def_t <= values[j].last_t
            })
            .map(|&j| (values[j].offset, values[j].offset + values[j].len))
            .collect();
        spans.sort_unstable();
        let mut off = 0usize;
        for (s, e) in spans {
            if off + values[i].len <= s {
                break;
            }
            off = off.max(e);
        }
        values[i].offset = off;
        arena = arena.max(off + values[i].len);
        placed.push(i);
    }
    arena
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;
    use crate::nets::NetGraph;

    fn custom_plans(shapes: &[ConvShape], backend: &str, seed: u64) -> NetPlans {
        NetPlans::from_shapes("custom", shapes, backend, &haswell(), seed).unwrap()
    }

    #[test]
    fn pool_nchw_windows_and_padding() {
        let src = Tensor::iota(&[1, 4, 4]);
        // 2x2/s2, no pad: maxima 5, 7, 13, 15.
        let p = pool_nchw(&src, 2, 2, 2, 2, 0, 0).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2]);
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
        // 3x3/s1/p1 keeps the extent; corner window sees only 4 cells.
        let q = pool_nchw(&src, 3, 3, 1, 1, 1, 1).unwrap();
        assert_eq!(q.shape(), &[1, 4, 4]);
        assert_eq!(q.at(&[0, 0, 0]), 5.0, "corner max over the 2x2 in-bounds cells");
        assert_eq!(q.at(&[0, 3, 3]), 15.0);
        assert!(pool_nchw(&src, 0, 1, 1, 1, 0, 0).is_err());
        assert!(pool_nchw(&src, 2, 2, 1, 1, 2, 0).is_err(), "pad >= kernel rejected");
    }

    #[test]
    fn avg_pool_nchw_means_and_border_counts() {
        let src = Tensor::iota(&[1, 4, 4]);
        // 2x2/s2, no pad: means of {0,1,4,5} etc.
        let p = avg_pool_nchw(&src, 2, 2, 2, 2, 0, 0).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2]);
        assert_eq!(p.data(), &[2.5, 4.5, 10.5, 12.5]);
        // 3x3/s1/p1: the corner window holds 4 valid cells — padding is
        // excluded from sum AND count.
        let q = avg_pool_nchw(&src, 3, 3, 1, 1, 1, 1).unwrap();
        assert_eq!(q.at(&[0, 0, 0]), (0.0 + 1.0 + 4.0 + 5.0) / 4.0);
        assert!(avg_pool_nchw(&src, 2, 2, 1, 1, 2, 0).is_err(), "pad >= kernel rejected");
    }

    #[test]
    fn graph_avg_pool_matches_reference() {
        // input -> conv -> avg_pool head, via the builder.
        use crate::nets::{builder::GraphBuilder, NetPlans};
        let mut b = GraphBuilder::new("avg");
        let x = b.input(4, 8, 8).unwrap();
        let c = b.conv("c0", x, 8, 3, 1, 1).unwrap();
        let p = b.avg_pool("head", c, 4, 4, 0).unwrap();
        let model = b.build(p).unwrap();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let kernels: Vec<Tensor> =
            model.shapes.iter().enumerate().map(|(i, s)| crate::nets::net_kernel(i, s)).collect();
        let runner = NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap();
        let input = Tensor::random(&[4, 8, 8], 0xA76);
        let got = runner.forward(&input).unwrap();
        let convolved = conv_naive(&input, &kernels[0], &model.shapes[0]).unwrap();
        let want = avg_pool_nchw(&convolved, 4, 4, 4, 4, 0, 0).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-4, 1e-4), "avg head diverged: {}", got.max_abs_diff(&want));
    }

    #[test]
    fn adapt_nchw_pools_and_rejects_channel_mismatch() {
        let src = Tensor::iota(&[2, 4, 4]);
        let out = adapt_nchw(&src, 2, 2, 2).unwrap();
        assert_eq!(out.shape(), &[2, 2, 2]);
        assert_eq!(out.at(&[0, 0, 0]), 5.0);
        assert_eq!(out.at(&[1, 1, 1]), 31.0);
        // The graph IR has no channel glue: mismatches are errors now.
        assert!(adapt_nchw(&src, 3, 2, 2).is_err());
    }

    #[test]
    fn identity_chain_reads_regions_directly() {
        // Equal-shape NCHW chain (naive backend): no Adapt ops at all —
        // each conv reads its predecessor's region in place.
        let shapes = [
            ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1),
            ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1),
        ];
        let runner = NetRunner::new(custom_plans(&shapes, "naive", 5)).unwrap();
        assert_eq!(runner.ops.len(), 2);
        assert!(runner.ops.iter().all(|o| matches!(o, Op::Conv { .. })));
    }

    #[test]
    fn forward_matches_naive_chain_on_custom_net() {
        // conv -> pool(2x2/s2 via graph glue) -> conv, direct backend.
        let shapes = [
            ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
            ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
        ];
        let plans = custom_plans(&shapes, "direct", 40);
        let kernels: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 40 + i as u64))
            .collect();
        let runner = NetRunner::new(plans).unwrap();
        let input = Tensor::random(&[8, 12, 12], 99);
        let got = runner.forward(&input).unwrap();

        let mut act = input.clone();
        for (s, k) in shapes.iter().zip(&kernels) {
            let adapted = adapt_nchw(&act, s.c_i, s.h_i, s.w_i).unwrap();
            act = conv_naive(&adapted, k, s).unwrap();
        }
        assert!(got.allclose(&act, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&act));
    }

    /// Small inception-style table: stem (3 convs) + 2 modules.
    fn mini_inception_shapes() -> Vec<ConvShape> {
        let mut v = vec![
            ConvShape::new(3, 32, 32, 16, 7, 7, 2, 3),  // stem1 -> 16x16x16
            ConvShape::new(16, 8, 8, 16, 1, 1, 1, 0),   // stem2 (pool 16->8)
            ConvShape::new(16, 8, 8, 32, 3, 3, 1, 1),   // stem3 -> 32x8x8
        ];
        // module A @8, c_in 32 -> 16+16+8+8 = 48
        let ma = [
            (32, 16, 1, 0),
            (32, 8, 1, 0),
            (8, 16, 3, 1),
            (32, 4, 1, 0),
            (4, 8, 5, 2),
            (32, 8, 1, 0),
        ];
        for (ci, co, f, p) in ma {
            v.push(ConvShape::new(ci, 8, 8, co, f, f, 1, p));
        }
        // module B @4 (pool 8->4), c_in 48 -> 32+32+16+16 = 96
        let mb = [
            (48, 32, 1, 0),
            (48, 16, 1, 0),
            (16, 32, 3, 1),
            (48, 8, 1, 0),
            (8, 16, 5, 2),
            (48, 16, 1, 0),
        ];
        for (ci, co, f, p) in mb {
            v.push(ConvShape::new(ci, 4, 4, co, f, f, 1, p));
        }
        v
    }

    /// Branch-by-branch NCHW reference for an inception-style table.
    fn mini_inception_reference(
        shapes: &[ConvShape],
        kernels: &[Tensor],
        input: &Tensor,
    ) -> Tensor {
        let conv = |x: &Tensor, i: usize| conv_naive(x, &kernels[i], &shapes[i]).unwrap();
        let to = |x: &Tensor, s: &ConvShape| adapt_nchw(x, s.c_i, s.h_i, s.w_i).unwrap();
        let mut x = to(input, &shapes[0]);
        x = conv(&x, 0);
        x = to(&x, &shapes[1]);
        x = conv(&x, 1);
        x = conv(&to(&x, &shapes[2]), 2);
        let modules = (shapes.len() - 3) / 6;
        for m in 0..modules {
            let base = 3 + 6 * m;
            x = to(&x, &shapes[base]);
            let b0 = conv(&x, base);
            let b1 = conv(&conv(&x, base + 1), base + 2);
            let b2 = conv(&conv(&x, base + 3), base + 4);
            let b3 = conv(&pool_nchw(&x, 3, 3, 1, 1, 1, 1).unwrap(), base + 5);
            let mut data = Vec::new();
            for b in [&b0, &b1, &b2, &b3] {
                data.extend_from_slice(b.data());
            }
            let c: usize = [&b0, &b1, &b2, &b3].iter().map(|t| t.shape()[0]).sum();
            x = Tensor::from_vec(&[c, b0.shape()[1], b0.shape()[2]], data).unwrap();
        }
        x
    }

    #[test]
    fn inception_graph_forward_matches_branchwise_reference() {
        let shapes = mini_inception_shapes();
        let plans = custom_plans(&shapes, "direct", 70);
        let kernels: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], 70 + i as u64))
            .collect();
        let graph = NetGraph::inception("mini", &shapes).unwrap();
        let runner = NetRunner::from_graph(plans, graph, 1).unwrap();
        assert_eq!(runner.output_len(), 96 * 4 * 4);

        let input = Tensor::random(&[3, 32, 32], 71);
        let got = runner.forward(&input).unwrap();
        let want = mini_inception_reference(&shapes, &kernels, &input);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&want));
    }

    #[test]
    fn branch_parallel_lanes_match_serial_bitwise() {
        let shapes = mini_inception_shapes();
        let input = Tensor::random(&[3, 32, 32], 72);
        let serial = NetRunner::from_graph(
            custom_plans(&shapes, "direct", 70),
            NetGraph::inception("mini", &shapes).unwrap(),
            1,
        )
        .unwrap();
        let parallel = NetRunner::from_graph(
            custom_plans(&shapes, "direct", 70),
            NetGraph::inception("mini", &shapes).unwrap(),
            4,
        )
        .unwrap();
        assert_eq!(parallel.branch_lanes(), 4);
        let a = serial.forward(&input).unwrap();
        let b = parallel.forward(&input).unwrap();
        assert_eq!(a.data(), b.data(), "lane scheduling must not change a single bit");
        // Group-time liveness may grow the arena (branch transients
        // coexist), never shrink it.
        assert!(parallel.arena_floats() >= serial.arena_floats());
    }

    #[test]
    fn live_regions_never_alias_and_arena_is_max_live() {
        for lanes in [1usize, 4] {
            let shapes = mini_inception_shapes();
            let runner = NetRunner::from_graph(
                custom_plans(&shapes, "direct", 70),
                NetGraph::inception("mini", &shapes).unwrap(),
                lanes,
            )
            .unwrap();
            let regions = runner.arena_regions();
            for (i, a) in regions.iter().enumerate() {
                for b in &regions[i + 1..] {
                    let overlap_time = a.first_step <= b.last_step && b.first_step <= a.last_step;
                    let overlap_space =
                        a.offset < b.offset + b.floats && b.offset < a.offset + a.floats;
                    assert!(
                        !(overlap_time && overlap_space),
                        "live values alias: {} and {} (lanes {lanes})",
                        a.name,
                        b.name
                    );
                }
            }
            assert_eq!(
                runner.arena_floats(),
                runner.max_live_floats(),
                "placement fragmented beyond the max live-set (lanes {lanes})"
            );
        }
    }

    #[test]
    fn add_nchw_sums_and_rejects_mismatch() {
        let a = Tensor::iota(&[2, 2, 2]);
        let b = Tensor::iota(&[2, 2, 2]);
        let s = add_nchw(&a, &b).unwrap();
        assert_eq!(s.at(&[1, 1, 1]), 14.0);
        assert!(add_nchw(&a, &Tensor::zeros(&[2, 2, 3])).is_err());
    }

    /// NCHW reference of a standalone BN node: the shared deterministic
    /// per-channel affine, applied as two separately-rounded f32 ops —
    /// exactly the [`EpView::apply`] order.
    fn bn_nchw(x: &Tensor, ord: usize) -> Tensor {
        let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (scale, shift) = crate::nets::net_bn_params(ord, c);
        let mut d = x.data().to_vec();
        for ci in 0..c {
            for i in 0..h * w {
                let v = &mut d[ci * h * w + i];
                *v *= scale[ci];
                *v += shift[ci];
            }
        }
        Tensor::from_vec(&[c, h, w], d).unwrap()
    }

    /// NCHW reference of a standalone ReLU node (optional upper clamp).
    fn relu_nchw(x: &Tensor, clamp: Option<f32>) -> Tensor {
        let mut d = x.data().to_vec();
        for v in &mut d {
            *v = v.max(0.0);
            if let Some(cl) = clamp {
                *v = v.min(cl);
            }
        }
        Tensor::from_vec(x.shape(), d).unwrap()
    }

    /// Two-block residual micro-net (the `resnet_micro` topology, with
    /// its BN + ReLU interludes) via the builder; direct backend,
    /// unfused schedule.
    #[test]
    fn residual_add_forward_matches_naive_reference() {
        use crate::nets::builder::resnet_micro;
        let model = resnet_micro();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let kernels: Vec<Tensor> =
            model.shapes.iter().enumerate().map(|(i, s)| crate::nets::net_kernel(i, s)).collect();
        let runner = NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap();
        assert_eq!(runner.overhead_bytes(), 0, "direct residual net must stay zero-overhead");

        let input = Tensor::random(&[3, 32, 32], 0xADD);
        let got = runner.forward(&input).unwrap();

        let conv = |x: &Tensor, i: usize| conv_naive(x, &kernels[i], &model.shapes[i]).unwrap();
        let stem = relu_nchw(&bn_nchw(&conv(&input, 0), 0), None);
        let b2 = bn_nchw(&conv(&relu_nchw(&bn_nchw(&conv(&stem, 1), 1), None), 2), 2);
        let j1 = relu_nchw(&add_nchw(&stem, &b2).unwrap(), None);
        let b4 = bn_nchw(&conv(&relu_nchw(&bn_nchw(&conv(&j1, 3), 3), None), 4), 4);
        let j2 = relu_nchw(&add_nchw(&j1, &b4).unwrap(), None);
        let want = conv(&pool_nchw(&j2, 2, 2, 2, 2, 0, 0).unwrap(), 5);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&want));
    }

    /// The tentpole parity claim: the fused schedule (epilogues folded
    /// into the conv cores, intermediates never materialized) is
    /// **bitwise** identical to the unfused schedule on the residual
    /// net — same accumulator bits, same scalar epilogue order, and
    /// IEEE addition commutes across the two residual operand orders.
    #[test]
    fn fused_schedule_matches_unfused_bitwise_with_zero_overhead() {
        use crate::nets::{builder::resnet_micro, fuse};
        let model = resnet_micro();
        let fused = fuse(&model).unwrap();
        assert!(
            fused.report.merges.iter().any(|m| m.kind == "conv+bn+relu"),
            "resnet_micro must fuse a conv+bn+relu chain"
        );
        assert!(
            fused.report.merges.iter().any(|m| m.kind == "conv+bn+add+relu"),
            "resnet_micro must fuse a conv+bn+add+relu chain"
        );
        let mk = || NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let unfused = NetRunner::from_graph(mk(), model.graph.clone(), 1).unwrap();
        let fr = NetRunner::from_graph_fused(mk(), model.graph.clone(), 1, &fused).unwrap();
        assert_eq!(fr.overhead_bytes(), 0, "fused residual net must stay zero-overhead");
        assert!(
            fr.ops.len() < unfused.ops.len(),
            "fusion must shrink the schedule ({} !< {})",
            fr.ops.len(),
            unfused.ops.len()
        );
        let input = Tensor::random(&[3, 32, 32], 0xF05E);
        let a = unfused.forward(&input).unwrap();
        let b = fr.forward(&input).unwrap();
        assert_eq!(a.data(), b.data(), "fusion must not change a single bit");
    }

    /// Depthwise + dilated micro-net through the fused pipeline against
    /// the NCHW naive reference (grouped/dilated `conv_naive`).
    #[test]
    fn mobilenet_micro_fused_forward_matches_reference() {
        use crate::nets::{builder::mobilenet_micro, fuse};
        let model = mobilenet_micro();
        let fused = fuse(&model).unwrap();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let kernels: Vec<Tensor> =
            model.shapes.iter().enumerate().map(|(i, s)| crate::nets::net_kernel(i, s)).collect();
        let runner = NetRunner::from_graph_fused(plans, model.graph.clone(), 1, &fused).unwrap();
        assert_eq!(runner.overhead_bytes(), 0, "fused depthwise net must stay zero-overhead");

        let input = Tensor::random(&[3, 16, 16], 0x30B);
        let got = runner.forward(&input).unwrap();

        let conv = |x: &Tensor, i: usize| conv_naive(x, &kernels[i], &model.shapes[i]).unwrap();
        let r6 = |x: &Tensor| relu_nchw(x, Some(6.0));
        let mut x = input.clone();
        for i in 0..5 {
            x = r6(&bn_nchw(&conv(&x, i), i));
        }
        let want = relu_nchw(&conv(&x, 5), None);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&want));
    }

    /// Cross-branch staging dedup: one value demanded in the same
    /// converted layout by two convs is gathered ONCE, and the shared
    /// stage never runs inside a single branch lane.
    #[test]
    fn shared_layout_staging_is_gathered_once() {
        use crate::nets::builder::GraphBuilder;
        let mut b = GraphBuilder::new("fanout");
        let x = b.input(8, 8, 8).unwrap();
        let a = b.conv("a", x, 8, 3, 1, 1).unwrap();
        let c = b.conv("b", x, 8, 3, 1, 1).unwrap();
        let j = b.add("j", &[a, c]).unwrap();
        let model = b.build(j).unwrap();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let kernels: Vec<Tensor> =
            model.shapes.iter().enumerate().map(|(i, s)| crate::nets::net_kernel(i, s)).collect();
        let runner = NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap();
        let stages: Vec<_> = runner
            .arena_regions()
            .into_iter()
            .filter(|r| r.name.starts_with("stage:"))
            .collect();
        assert_eq!(stages.len(), 1, "both convs must share one staged gather: {stages:?}");

        let input = Tensor::random(&[8, 8, 8], 0xFA0);
        let got = runner.forward(&input).unwrap();
        let conv = |x: &Tensor, i: usize| conv_naive(x, &kernels[i], &model.shapes[i]).unwrap();
        let want = add_nchw(&conv(&input, 0), &conv(&input, 1)).unwrap();
        assert!(got.allclose(&want, 1e-3, 1e-3), "diverged: {}", got.max_abs_diff(&want));
    }

    #[test]
    fn add_operands_stay_live_to_the_join() {
        // stem feeds both the residual arm and the join: its region must
        // not be reused while the arm computes.
        use crate::nets::builder::resnet_micro;
        let model = resnet_micro();
        let plans = NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let runner = NetRunner::from_graph(plans, model.graph.clone(), 1).unwrap();
        let regions = runner.arena_regions();
        for (i, a) in regions.iter().enumerate() {
            for b in &regions[i + 1..] {
                let overlap_t = a.first_step <= b.last_step && b.first_step <= a.last_step;
                let overlap_s =
                    a.offset < b.offset + b.floats && b.offset < a.offset + a.floats;
                assert!(!(overlap_t && overlap_s), "live alias: {} vs {}", a.name, b.name);
            }
        }
    }

    #[test]
    fn arena_sizing_and_overhead_accounting() {
        let shapes = [
            ConvShape::new(8, 12, 12, 16, 3, 3, 1, 1),
            ConvShape::new(16, 6, 6, 16, 3, 3, 1, 1),
        ];
        let runner = NetRunner::new(custom_plans(&shapes, "direct", 7)).unwrap();
        assert_eq!(runner.overhead_bytes(), 0, "direct must be zero-overhead");
        assert_eq!(runner.arena_bytes(), runner.activation_bytes());
        assert_eq!(runner.input_len(), 8 * 12 * 12);
        assert_eq!(runner.output_len(), 16 * 6 * 6);
        assert_eq!(runner.arena_floats(), runner.max_live_floats());
        // The liveness arena beats the old ping-pong bound (2 x largest
        // activation) on this chain and never exceeds it.
        let largest = 16 * 12 * 12;
        assert!(runner.arena_floats() <= 2 * largest);

        // im2col charges its lowering workspace; the arena shares one
        // buffer so the network-wide workspace is the per-layer max.
        let r2 = NetRunner::new(custom_plans(&shapes, "im2col", 7)).unwrap();
        let per_layer: Vec<u64> = shapes.iter().map(ConvShape::im2col_bytes).collect();
        assert_eq!(r2.workspace_bytes(), per_layer.iter().copied().max().unwrap());
    }

    #[test]
    fn rejects_unchainable_and_empty_nets() {
        // Second layer needs a LARGER spatial input than layer 1 emits.
        let shapes = [
            ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1),
            ConvShape::new(8, 16, 16, 8, 3, 3, 1, 1),
        ];
        assert!(NetRunner::new(custom_plans(&shapes, "naive", 1)).is_err());
        // Channel mismatch is no longer silently cycled.
        let shapes = [
            ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1),
            ConvShape::new(12, 8, 8, 8, 3, 3, 1, 1),
        ];
        assert!(NetRunner::new(custom_plans(&shapes, "naive", 1)).is_err());
        let empty = NetPlans { net: "empty".into(), layers: Vec::new() };
        assert!(NetRunner::new(empty).is_err());
    }

    #[test]
    fn forward_with_validates_buffers() {
        let shapes = [ConvShape::new(4, 8, 8, 8, 3, 3, 1, 1)];
        let runner = NetRunner::new(custom_plans(&shapes, "direct", 3)).unwrap();
        let mut arena = runner.arena();
        let input = vec![0.0f32; runner.input_len()];
        let mut out = vec![0.0f32; runner.output_len()];
        assert!(runner.forward_with(&mut arena, &input[1..], &mut out).is_err());
        assert!(runner.forward_with(&mut arena, &input, &mut out[1..]).is_err());
        assert!(runner.forward_with(&mut arena, &input, &mut out).is_ok());
        let bad = Tensor::zeros(&[4, 8, 9]);
        assert!(runner.forward(&bad).is_err());
    }

    #[test]
    fn googlenet_compiles_as_dag_with_tight_arena() {
        let plans = NetPlans::build("googlenet", "direct", &haswell(), 1).unwrap();
        let runner = NetRunner::new(plans).unwrap();
        assert_eq!(runner.layers(), 57);
        assert_eq!(runner.output_len(), 1024 * 7 * 7);
        assert_eq!(runner.overhead_bytes(), 0);
        assert_eq!(
            runner.arena_floats(),
            runner.max_live_floats(),
            "inception liveness must place without fragmentation"
        );
    }
}

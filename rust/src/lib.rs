//! # dconv — High Performance Zero-Memory Overhead Direct Convolutions
//!
//! Full-system reproduction of Zhang, Franchetti & Low (ICML 2018).
//!
//! The crate is organized in three tiers:
//!
//! 1. **Kernel substrates** — native-Rust implementations of every
//!    convolution algorithm the paper evaluates:
//!    [`conv`] (the paper's direct convolution, Algorithms 1–3),
//!    [`gemm`] (Goto-algorithm SGEMM), [`lowering`] (im2col / MEC),
//!    [`fftconv`] and [`winograd`] (the NNPACK stand-ins), together
//!    with the [`tensor`] and [`layout`] foundations (the paper's §4
//!    convolution-friendly layouts).
//! 2. **Evaluation substrates** — [`arch`] machine descriptors for the
//!    paper's Intel Haswell / AMD Piledriver / ARM Cortex-A57 testbed
//!    (Table 1), the [`sim`] analytical + cache-trace performance
//!    simulator that regenerates Figures 1/4/5, and [`nets`] (all conv
//!    layers of AlexNet, GoogLeNet and VGG-16).
//! 3. **Serving stack** — [`runtime`] (PJRT artifact loading/execution
//!    for the JAX/Pallas AOT compile path) and [`coordinator`]
//!    (request router, dynamic batcher, worker pool) with [`metrics`].
//!
//! Support modules: [`bench_harness`] (criterion-lite), [`json`]
//! (manifest/results I/O), [`cli`] (argument parsing).

pub mod arch;
pub mod bench_harness;
pub mod cli;
pub mod conv;
pub mod coordinator;
pub mod fftconv;
pub mod gemm;
pub mod json;
pub mod layout;
pub mod lowering;
pub mod metrics;
pub mod nets;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod winograd;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("layout error: {0}")]
    Layout(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

//! # dconv — High Performance Zero-Memory Overhead Direct Convolutions
//!
//! Full-system reproduction of Zhang, Franchetti & Low (ICML 2018).
//!
//! ## The plan/execute API (start here)
//!
//! The paper's thesis is that direct convolution wins because it is
//! *planned for the layer shape* — blocked layouts and analytically
//! selected `C_o,b x W_o,b` register tiles — and then runs with *zero
//! memory overhead*. The [`engine`] module is that thesis as an API:
//!
//! ```no_run
//! use dconv::arch::host;
//! use dconv::conv::ConvShape;
//! use dconv::engine::{BackendRegistry, ConvAlgo, ConvPlan};
//! use dconv::tensor::Tensor;
//!
//! let shape = ConvShape::new(64, 56, 56, 64, 3, 3, 1, 1);
//! let kernel = Tensor::random(&[64, 64, 3, 3], 2);
//! let machine = host();
//!
//! let registry = BackendRegistry::default();
//! let algo = registry.auto(&shape, &machine);        // or .get("direct")
//! let plan = algo.plan(&shape, &kernel, &machine, 1).unwrap();
//! assert_eq!(plan.retained_bytes() + plan.workspace_bytes(), 0);
//!
//! let input = Tensor::random(&[64, 56, 56], 1);
//! let out = plan.execute(&input).unwrap();           // one-shot convenience
//! // hot path: plan.execute_into(...) with caller-owned buffers — see
//! // the engine module docs for the allocation-free serving loop.
//! # let _ = out;
//! ```
//!
//! Every backend the paper evaluates — `direct`, `reorder`, `im2col`,
//! `fft`, `winograd`, `naive` — sits behind [`engine::BackendRegistry`],
//! each reporting its memory overhead through the same
//! `retained_bytes()`/`workspace_bytes()` contract so the paper's
//! overhead table falls out of the API uniformly. A seventh backend,
//! [`quant`]'s `direct_i8`, carries the zero-overhead property into
//! int8: weights quantized per output channel, i32 accumulation over
//! the same blocked layouts, requantize fused into the epilogue —
//! quartering weight and activation bytes for the embedded-memory
//! regime the paper motivates (see the [`quant`] module docs).
//!
//! ## Whole networks: the graph IR and the arena-sizing contract
//!
//! [`engine::NetRunner`] lifts the per-layer claim to entire networks,
//! executed as real dataflow graphs. A [`nets::NetGraph`]
//! (conv/pool/concat/add nodes) is built through the public
//! [`nets::GraphBuilder`] API — the paper nets are builder programs
//! (GoogLeNet's nine inception modules as genuine fan-out branches
//! re-joined by channel concats, AlexNet/VGG as trivial chains,
//! ResNet-style residual joins as first-class `Add` nodes) — or loaded
//! from a JSON model spec ([`nets::Model`], CLI `--model path.json`).
//! The graph is compiled together with its [`nets::NetPlans`] table
//! into a flat schedule, and **one** execution arena is sized once —
//! then the forward pass never allocates again:
//!
//! * every activation (graph edge) gets a region from a
//!   liveness-driven allocator: lifetimes over the topological
//!   schedule, placement greedy-by-size, arena sized by the **max
//!   live-set** (inside an inception module that is the sum of the
//!   live branch outputs — not twice the largest activation);
//! * one shared workspace of the *largest per-layer*
//!   `workspace_len()` — a single scratch buffer serves every layer in
//!   turn, so the network-wide workspace charge is a `max`, not a sum.
//!
//! Activations are intrinsic network state, not overhead; the
//! network-wide overhead is `retained + shared workspace`, and for the
//! `direct` backend it is **0 on every paper net** over the true DAG
//! (asserted by `tests/net_forward.rs` and `tests/net_graph.rs`: a
//! branch-by-branch naive reference with explicit concatenation,
//! a counting-allocator proof that a whole forward pass allocates
//! nothing after planning, and golden-value fixtures in
//! `tests/net_golden.rs`). [`nets::NetPlans::build_autotuned`] measures
//! per-layer thread counts at plan time, and independent inception
//! branches can run on scoped lanes
//! ([`engine::NetRunner::with_branch_lanes`]).
//! [`engine::NetEngine`] serves the runner through the coordinator,
//! fanning batch items across a scoped worker pool with one arena per
//! worker.
//!
//! ## Crate layout
//!
//! 1. **Kernel substrates** — native-Rust implementations of every
//!    convolution algorithm the paper evaluates:
//!    [`conv`] (the paper's direct convolution, Algorithms 1–3),
//!    [`gemm`] (Goto-algorithm SGEMM), [`lowering`] (im2col / MEC),
//!    [`fftconv`] and [`winograd`] (the NNPACK stand-ins), together
//!    with the [`tensor`] and [`layout`] foundations (the paper's §4
//!    convolution-friendly layouts).
//! 2. **Evaluation substrates** — [`arch`] machine descriptors for the
//!    paper's Intel Haswell / AMD Piledriver / ARM Cortex-A57 testbed
//!    (Table 1), the [`sim`] analytical + cache-trace performance
//!    simulator that regenerates Figures 1/4/5, and [`nets`] (all conv
//!    layers of AlexNet, GoogLeNet and VGG-16, plus per-layer plan
//!    tables built on the engine).
//! 3. **Serving stack** — [`engine`] (the `ConvAlgo`/`ConvPlan`
//!    plan/execute API, the [`engine::NetRunner`] whole-network
//!    executor, and the native [`engine::PlanEngine`] /
//!    [`engine::NetEngine`] executors), [`coordinator`] (request
//!    router, dynamic batcher with multi-execution split, worker pool)
//!    and [`serve`] — the production path: multi-model server with
//!    bounded admission queues and typed shedding
//!    ([`serve::Rejected`]), continuous cross-request batching,
//!    spec-hash plan cache, per-model [`metrics::ServeMetrics`]
//!    telemetry, and the seeded heavy-tail load generator
//!    ([`serve::loadgen`], CLI `loadgen`) — with [`metrics`].
//!    [`runtime`] holds the artifact manifest plus,
//!    behind the `pjrt` feature, the XLA/PJRT executor for the
//!    JAX/Pallas AOT compile path.
//!
//! Support modules: [`bench_harness`] (criterion-lite), [`json`]
//! (manifest/results I/O), [`cli`] (argument parsing), and [`trace`]
//! (zero-overhead span recording with Chrome-trace export, roofline
//! reports against the [`arch`] machine model, and Prometheus text
//! exposition — CLI `profile`).
//!
//! The pre-engine one-shot free functions (`conv_direct`,
//! `conv_im2col`, ...) are gone: every backend is reached through the
//! registry's plan/execute contract (the allocation-free `*_into` cores
//! remain public for callers that manage their own buffers).

pub mod arch;
pub mod bench_harness;
pub mod cli;
pub mod conv;
pub mod coordinator;
pub mod engine;
pub mod fftconv;
pub mod gemm;
pub mod json;
pub mod layout;
pub mod lowering;
pub mod metrics;
pub mod nets;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod trace;
pub mod tune;
pub mod winograd;

/// Crate-wide error type.
///
/// `Display`/`Error` are implemented by hand (not via `thiserror`) so
/// the crate builds with zero dependencies in offline environments.
#[derive(Debug)]
pub enum Error {
    Shape(String),
    Layout(String),
    Runtime(String),
    Parse(String),
    Io(std::io::Error),
    /// A serving request was not admitted or was dropped before
    /// execution, with the typed [`serve::Rejected`] reason. Raised by
    /// [`serve::Server`] and the [`coordinator`]'s admission edge.
    Rejected(serve::Rejected),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Layout(m) => write!(f, "layout error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Rejected(r) => write!(f, "rejected: {r}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_matches_seed_format() {
        assert_eq!(format!("{}", Error::Shape("x".into())), "shape mismatch: x");
        assert_eq!(format!("{}", Error::Layout("x".into())), "layout error: x");
        assert_eq!(format!("{}", Error::Runtime("x".into())), "runtime error: x");
        assert_eq!(format!("{}", Error::Parse("x".into())), "parse error: x");
    }

    #[test]
    fn io_error_converts_and_sources() {
        use std::error::Error as _;
        let e: Error = std::io::Error::other("boom").into();
        assert!(format!("{e}").contains("boom"));
        assert!(e.source().is_some());
    }
}

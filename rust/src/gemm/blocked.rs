//! The Goto three-loop blocked GEMM driver and its threaded variant.

use super::kernel::{kernel_edge, kernel_full, MR, NR};
use super::pack::{pack_a, pack_b};

/// Cache block sizes (`MC x KC` A block in L2, `KC x NC` B panel in L3,
/// `MR x KC` micro-panel streamed through L1).
#[derive(Clone, Copy, Debug)]
pub struct BlockSizes {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Default for BlockSizes {
    fn default() -> Self {
        // Tuned for ~32 KiB L1 / 256 KiB-1 MiB L2 f32 operation.
        BlockSizes { mc: 96, kc: 256, nc: 2048 }
    }
}

/// `C[m x n] += A[m x k] * B[k x n]` (row-major, leading dimensions).
#[allow(clippy::too_many_arguments)] // the BLAS sgemm signature
pub fn sgemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    sgemm_with(BlockSizes::default(), m, n, k, a, lda, b, ldb, c, ldc)
}

/// [`sgemm`] with explicit block sizes (used by the blocking ablation).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with(
    bs: BlockSizes,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut a_buf = Vec::new();
    let mut b_buf = Vec::new();
    // Loop 5 (jc): NC columns of B/C.
    let mut jc = 0;
    while jc < n {
        let nc = bs.nc.min(n - jc);
        // Loop 4 (pc): KC slice of the reduction.
        let mut pc = 0;
        while pc < k {
            let kc = bs.kc.min(k - pc);
            pack_b(kc, nc, &b[pc * ldb + jc..], ldb, &mut b_buf);
            // Loop 3 (ic): MC rows of A/C.
            let mut ic = 0;
            while ic < m {
                let mc = bs.mc.min(m - ic);
                pack_a(mc, kc, &a[ic * lda + pc..], lda, &mut a_buf);
                macro_kernel(mc, nc, kc, &a_buf, &b_buf, &mut c[ic * ldc + jc..], ldc);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Loops 2 (jr) and 1 (ir) plus the microkernel over packed panels.
fn macro_kernel(
    mc: usize,
    nc: usize,
    kc: usize,
    a_pack: &[f32],
    b_pack: &[f32],
    c: &mut [f32],
    ldc: usize,
) {
    let mut jr = 0;
    while jr < nc {
        let nr = NR.min(nc - jr);
        let bp = &b_pack[(jr / NR) * kc * NR..][..kc * NR];
        let mut ir = 0;
        while ir < mc {
            let mr = MR.min(mc - ir);
            let ap = &a_pack[(ir / MR) * kc * MR..][..kc * MR];
            let ctile = &mut c[ir * ldc + jr..];
            if mr == MR && nr == NR {
                kernel_full(kc, ap, bp, ctile, ldc);
            } else {
                kernel_edge(kc, ap, bp, ctile, ldc, mr, nr);
            }
            ir += MR;
        }
        jr += NR;
    }
}

/// Threaded GEMM. Parallelism follows the BLAS convention the paper
/// critiques (§2.2): the output is partitioned across threads by rows
/// and columns, which skews the per-thread matrix shapes. Each thread
/// runs an independent [`sgemm`] on its slice (private packing buffers,
/// like OpenBLAS's per-thread buffers).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_threaded(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    let threads = threads.max(1);
    if threads == 1 || m * n < 64 * 64 {
        return sgemm(m, n, k, a, lda, b, ldb, c, ldc);
    }
    // Partition rows of C into `threads` contiguous bands. (Row-only
    // partitioning is what OpenBLAS does at these thread counts; the
    // resulting skinny per-thread shapes are exactly the inefficiency
    // §2.2 describes.)
    let band = m.div_ceil(threads);
    // Split c into disjoint row bands. `ldc` may exceed `n`, bands are
    // still disjoint as long as band rows don't interleave — they don't.
    let mut bands: Vec<(usize, &mut [f32])> = Vec::new();
    let mut rest = c;
    let mut row = 0;
    while row < m {
        let rows = band.min(m - row);
        let take = if row + rows < m { rows * ldc } else { rest.len() };
        let (head, tail) = rest.split_at_mut(take);
        bands.push((row, head));
        rest = tail;
        row += rows;
    }
    std::thread::scope(|scope| {
        for (row0, cband) in bands {
            let rows = band.min(m - row0);
            scope.spawn(move || {
                sgemm(rows, n, k, &a[row0 * lda..], lda, b, ldb, cband, ldc);
            });
        }
    });
}

//! Reference triple-loop GEMM (row-major, `C += A * B`).

/// `C[m x n] += A[m x k] * B[k x n]`, row-major with leading dimensions.
pub fn sgemm_naive(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * lda + p];
            let brow = &b[p * ldb..][..n];
            let crow = &mut c[i * ldc..][..n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        // A = I(3), B arbitrary -> C = B
        let a = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut c = [0.0; 6];
        sgemm_naive(3, 2, 3, &a, 3, &b, 2, &mut c, 2);
        assert_eq!(c, b);
    }

    #[test]
    fn hand_2x2() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        sgemm_naive(2, 2, 2, &a, 2, &b, 2, &mut c, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }
}

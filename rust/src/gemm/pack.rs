//! Operand packing — the memory overhead the paper indicts (§1, §2.2).
//!
//! `pack_a` copies an `mc x kc` block of A into column-major micro-panels
//! of height [`super::MR`]; `pack_b` copies a `kc x nc` block of B into
//! row-major micro-panels of width [`super::NR`]. Partial panels are
//! zero-padded — this is precisely the "additional memory + bandwidth
//! cost" that direct convolution avoids.

use super::kernel::{MR, NR};

/// Pack `a[mc x kc]` (leading dimension `lda`) into `buf` as
/// `ceil(mc/MR)` panels of `kc * MR`. Returns the packed length.
pub fn pack_a(mc: usize, kc: usize, a: &[f32], lda: usize, buf: &mut Vec<f32>) -> usize {
    let panels = mc.div_ceil(MR);
    buf.clear();
    buf.resize(panels * kc * MR, 0.0);
    for ip in 0..panels {
        let i0 = ip * MR;
        let rows = MR.min(mc - i0);
        let dst = &mut buf[ip * kc * MR..][..kc * MR];
        for p in 0..kc {
            for r in 0..rows {
                dst[p * MR + r] = a[(i0 + r) * lda + p];
            }
            // rows..MR already zero
        }
    }
    buf.len()
}

/// Pack `b[kc x nc]` (leading dimension `ldb`) into `buf` as
/// `ceil(nc/NR)` panels of `kc * NR`. Returns the packed length.
pub fn pack_b(kc: usize, nc: usize, b: &[f32], ldb: usize, buf: &mut Vec<f32>) -> usize {
    let panels = nc.div_ceil(NR);
    buf.clear();
    buf.resize(panels * kc * NR, 0.0);
    for jp in 0..panels {
        let j0 = jp * NR;
        let cols = NR.min(nc - j0);
        let dst = &mut buf[jp * kc * NR..][..kc * NR];
        for p in 0..kc {
            let src = &b[p * ldb + j0..][..cols];
            dst[p * NR..][..cols].copy_from_slice(src);
        }
    }
    buf.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_layout() {
        // 3x2 block of a 3x5 matrix -> one panel, zero padded to MR rows.
        let a: Vec<f32> = (0..15).map(|v| v as f32).collect();
        let mut buf = Vec::new();
        pack_a(3, 2, &a, 5, &mut buf);
        assert_eq!(buf.len(), 2 * MR);
        // panel column p holds A[0..3, p] then zeros
        assert_eq!(&buf[0..4], &[0.0, 5.0, 10.0, 0.0]);
        assert_eq!(&buf[MR..MR + 4], &[1.0, 6.0, 11.0, 0.0]);
    }

    #[test]
    fn pack_b_layout() {
        // 2 x (NR+3) block -> two panels, second padded.
        let nc = NR + 3;
        let b: Vec<f32> = (0..2 * nc).map(|v| v as f32).collect();
        let mut buf = Vec::new();
        pack_b(2, nc, &b, nc, &mut buf);
        assert_eq!(buf.len(), 2 * 2 * NR);
        // first panel row p = b[p, 0..NR]
        assert_eq!(buf[0], 0.0);
        assert_eq!(buf[NR], nc as f32); // p=1 row starts at b[1,0]
        // second panel has 3 real columns then zeros
        let p2 = &buf[2 * NR * 2 - NR..];
        assert_eq!(p2[0], (nc + NR) as f32);
        assert_eq!(p2[3], 0.0);
    }

    #[test]
    fn pack_sizes_account_padding() {
        let a = vec![1.0f32; 100 * 64];
        let mut buf = Vec::new();
        let len = pack_a(100, 64, &a, 64, &mut buf);
        assert_eq!(len, 100usize.div_ceil(MR) * 64 * MR);
    }
}

//! Goto-algorithm single-precision GEMM — the "expert-implemented
//! matrix-matrix multiplication" baseline (§2.2).
//!
//! This is the same algorithm OpenBLAS implements (Goto & van de Geijn
//! 2008): three cache-blocking loops (`NC`, `KC`, `MC`), explicit packing
//! of both operands into contiguous panels, and an `MR x NR` register
//! microkernel. It exists so the paper's comparison — direct convolution
//! vs im2col + SGEMM — can be reproduced end-to-end on one machine with
//! no external BLAS (none is available offline, and using our own keeps
//! the comparison apples-to-apples: both sides get the same compiler).
//!
//! All matrices are row-major. The public entry points are
//! [`sgemm`] (`C += A * B` with leading dimensions) and the convolution
//! drivers in [`crate::lowering`].

mod blocked;
mod kernel;
mod naive;
mod pack;

pub use blocked::{sgemm, sgemm_threaded, BlockSizes};
pub use kernel::{MR, NR};
pub use naive::sgemm_naive;
pub use pack::{pack_a, pack_b};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn check(m: usize, n: usize, k: usize, lda_extra: usize) {
        let lda = k + lda_extra;
        let a = Tensor::random(&[m, lda], 100 + m as u64);
        let b = Tensor::random(&[k, n], 200 + n as u64);
        let mut c_ref = vec![0.0f32; m * n];
        sgemm_naive(m, n, k, a.data(), lda, b.data(), n, &mut c_ref, n);
        let mut c = vec![0.0f32; m * n];
        sgemm(m, n, k, a.data(), lda, b.data(), n, &mut c, n);
        let md = c
            .iter()
            .zip(c_ref.iter())
            .fold(0.0f32, |mx, (&x, &y)| mx.max((x - y).abs()));
        assert!(md < 1e-3 * (k as f32).sqrt().max(1.0), "m={m} n={n} k={k}: max diff {md}");
    }

    #[test]
    fn square_sizes() {
        for &s in &[1, 2, 7, 16, 33, 64, 100] {
            check(s, s, s, 0);
        }
    }

    #[test]
    fn rectangular_and_conv_like() {
        check(96, 3025, 363, 0); // AlexNet conv1 as im2col GEMM
        check(17, 5, 129, 0);
        check(5, 129, 17, 0);
        check(1, 64, 64, 0);
        check(64, 1, 64, 0);
        check(64, 64, 1, 0);
    }

    #[test]
    fn respects_lda() {
        check(13, 9, 21, 7);
    }

    #[test]
    fn accumulates_into_c() {
        let (m, n, k) = (9, 11, 5);
        let a = Tensor::random(&[m, k], 1);
        let b = Tensor::random(&[k, n], 2);
        let mut c = vec![1.0f32; m * n];
        let mut c2 = vec![1.0f32; m * n];
        sgemm(m, n, k, a.data(), k, b.data(), n, &mut c, n);
        sgemm_naive(m, n, k, a.data(), k, b.data(), n, &mut c2, n);
        let md = c
            .iter()
            .zip(c2.iter())
            .fold(0.0f32, |mx, (&x, &y)| mx.max((x - y).abs()));
        assert!(md < 1e-4);
        // and C really was accumulated, not overwritten
        let mut c3 = vec![0.0f32; m * n];
        sgemm_naive(m, n, k, a.data(), k, b.data(), n, &mut c3, n);
        assert!((c[0] - (c3[0] + 1.0)).abs() < 1e-5);
    }

    #[test]
    fn threaded_matches_single() {
        let (m, n, k) = (120, 240, 96);
        let a = Tensor::random(&[m, k], 5);
        let b = Tensor::random(&[k, n], 6);
        let mut c1 = vec![0.0f32; m * n];
        let mut c4 = vec![0.0f32; m * n];
        sgemm(m, n, k, a.data(), k, b.data(), n, &mut c1, n);
        sgemm_threaded(m, n, k, a.data(), k, b.data(), n, &mut c4, n, 4);
        let md = c1
            .iter()
            .zip(c4.iter())
            .fold(0.0f32, |mx, (&x, &y)| mx.max((x - y).abs()));
        assert!(md < 1e-4, "threaded mismatch {md}");
    }
}

//! The `MR x NR` register microkernel.
//!
//! `MR = 6`, `NR = 16` — six broadcast rows against two 8-lane vector
//! columns, the classic AVX2 f32 tile (12 accumulator registers + 2
//! operand registers + broadcasts, mirroring OpenBLAS/BLIS kernels).

/// Microkernel rows (panel height of packed A).
pub const MR: usize = 6;
/// Microkernel columns (panel width of packed B).
pub const NR: usize = 16;

/// Full-tile kernel: `C[MR x NR] += Ap * Bp` over `kc` rank-1 updates.
///
/// * `ap` — packed A panel: `kc` slices of `MR` (column-major micro-panel).
/// * `bp` — packed B panel: `kc` slices of `NR` (row-major micro-panel).
/// * `c`  — output tile origin, leading dimension `ldc`.
#[inline(always)]
pub fn kernel_full(kc: usize, ap: &[f32], bp: &[f32], c: &mut [f32], ldc: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let a = &ap[p * MR..][..MR];
        let b = &bp[p * NR..][..NR];
        for i in 0..MR {
            let ai = a[i];
            let row = &mut acc[i];
            for j in 0..NR {
                row[j] = ai.mul_add(b[j], row[j]);
            }
        }
    }
    for i in 0..MR {
        let crow = &mut c[i * ldc..][..NR];
        for j in 0..NR {
            crow[j] += acc[i][j];
        }
    }
}

/// Edge kernel for partial tiles (`mr <= MR`, `nr <= NR`). Same packed
/// panel format (panels are always padded to full MR/NR with zeros).
#[inline(always)]
pub fn kernel_edge(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let a = &ap[p * MR..][..MR];
        let b = &bp[p * NR..][..NR];
        for i in 0..MR {
            let ai = a[i];
            let row = &mut acc[i];
            for j in 0..NR {
                row[j] = ai.mul_add(b[j], row[j]);
            }
        }
    }
    for i in 0..mr {
        let crow = &mut c[i * ldc..][..nr];
        for j in 0..nr {
            crow[j] += acc[i][j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_one_update() {
        // kc=1: C = a (MR) outer b (NR)
        let ap: Vec<f32> = (0..MR).map(|i| i as f32).collect();
        let bp: Vec<f32> = (0..NR).map(|j| (j + 1) as f32).collect();
        let mut c = vec![0.0f32; MR * NR];
        kernel_full(1, &ap, &bp, &mut c, NR);
        for i in 0..MR {
            for j in 0..NR {
                assert_eq!(c[i * NR + j], (i * (j + 1)) as f32);
            }
        }
    }

    #[test]
    fn edge_writes_only_its_tile() {
        let ap = vec![1.0f32; 2 * MR];
        let bp = vec![1.0f32; 2 * NR];
        let mut c = vec![0.0f32; MR * NR];
        kernel_edge(2, &ap, &bp, &mut c, NR, 2, 3);
        for i in 0..MR {
            for j in 0..NR {
                let want = if i < 2 && j < 3 { 2.0 } else { 0.0 };
                assert_eq!(c[i * NR + j], want, "({i},{j})");
            }
        }
    }
}

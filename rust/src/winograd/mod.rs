//! Winograd F(2x2, 3x3) convolution — NNPACK's fast path for the
//! ubiquitous 3x3/stride-1 layers (the paper's FFT/Winograd comparator
//! reports whichever of NNPACK's transform implementations is fastest;
//! for small kernels that is usually Winograd).
//!
//! Each 2x2 output tile costs 16 multiplies instead of 36 (2.25x fewer),
//! paid for with input/output transforms and a transformed-weight tensor
//! of `C_o*C_i*16` floats (16/9 ≈ 1.8x the weights) — again trading
//! memory for FLOPs, which is the paper's §2 theme.

use crate::conv::ConvShape;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Transformed-weight memory retained by Winograd (bytes).
pub fn winograd_extra_bytes(shape: &ConvShape) -> u64 {
    4 * 16 * (shape.c_o * shape.c_i) as u64
}

/// Whether the layer is eligible (3x3, stride 1).
pub fn winograd_applicable(shape: &ConvShape) -> bool {
    shape.h_f == 3 && shape.w_f == 3 && shape.stride == 1
}

/// `U = G g G^T` for one 3x3 kernel `g`, where
/// `G = [[1,0,0],[1/2,1/2,1/2],[1/2,-1/2,1/2],[0,0,1]]`.
fn transform_kernel(g: &[f32]) -> [f32; 16] {
    // t = G g  (4x3)
    let mut t = [0.0f32; 12];
    for c in 0..3 {
        let (g0, g1, g2) = (g[c], g[3 + c], g[6 + c]);
        t[c] = g0;
        t[3 + c] = 0.5 * (g0 + g1 + g2);
        t[6 + c] = 0.5 * (g0 - g1 + g2);
        t[9 + c] = g2;
    }
    // U = t G^T (4x4)
    let mut u = [0.0f32; 16];
    for r in 0..4 {
        let (t0, t1, t2) = (t[r * 3], t[r * 3 + 1], t[r * 3 + 2]);
        u[r * 4] = t0;
        u[r * 4 + 1] = 0.5 * (t0 + t1 + t2);
        u[r * 4 + 2] = 0.5 * (t0 - t1 + t2);
        u[r * 4 + 3] = t2;
    }
    u
}

/// `V = B^T d B` for one 4x4 input tile `d`, where
/// `B^T = [[1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]]`.
#[inline]
fn transform_input(d: &[f32; 16]) -> [f32; 16] {
    let mut t = [0.0f32; 16]; // B^T d
    for c in 0..4 {
        let (d0, d1, d2, d3) = (d[c], d[4 + c], d[8 + c], d[12 + c]);
        t[c] = d0 - d2;
        t[4 + c] = d1 + d2;
        t[8 + c] = d2 - d1;
        t[12 + c] = d1 - d3;
    }
    let mut v = [0.0f32; 16]; // t B
    for r in 0..4 {
        let (t0, t1, t2, t3) = (t[r * 4], t[r * 4 + 1], t[r * 4 + 2], t[r * 4 + 3]);
        v[r * 4] = t0 - t2;
        v[r * 4 + 1] = t1 + t2;
        v[r * 4 + 2] = t2 - t1;
        v[r * 4 + 3] = t1 - t3;
    }
    v
}

/// `Y = A^T M A` for one 4x4 element-product sum `m`, where
/// `A^T = [[1,1,1,0],[0,1,-1,-1]]`.
#[inline]
fn transform_output(m: &[f32; 16]) -> [f32; 4] {
    let mut t = [0.0f32; 8]; // A^T m (2x4)
    for c in 0..4 {
        let (m0, m1, m2, m3) = (m[c], m[4 + c], m[8 + c], m[12 + c]);
        t[c] = m0 + m1 + m2;
        t[4 + c] = m1 - m2 - m3;
    }
    let mut y = [0.0f32; 4]; // t A (2x2)
    for r in 0..2 {
        let (t0, t1, t2, t3) = (t[r * 4], t[r * 4 + 1], t[r * 4 + 2], t[r * 4 + 3]);
        y[r * 2] = t0 + t1 + t2;
        y[r * 2 + 1] = t1 - t2 - t3;
    }
    y
}

/// Pre-transform all kernels of a layer: `U[c_o][c_i][16]`, the state a
/// Winograd plan retains across executions. Weights are
/// `[C_o][C_i][3][3]`; the layer must be [`winograd_applicable`].
pub fn transform_kernels(kernel: &Tensor, shape: &ConvShape) -> Result<Vec<f32>> {
    shape.validate()?;
    if !winograd_applicable(shape) {
        return Err(Error::Shape(format!(
            "winograd F(2x2,3x3) needs 3x3/s1, got {}x{}/s{}",
            shape.h_f, shape.w_f, shape.stride
        )));
    }
    let want_k = [shape.c_o, shape.c_i, 3, 3];
    if kernel.shape() != want_k {
        return Err(Error::Shape(format!(
            "kernel shape {:?} != expected {:?}",
            kernel.shape(),
            want_k
        )));
    }
    let (c_o, c_i) = (shape.c_o, shape.c_i);
    let ks = kernel.data();
    let mut u = vec![0.0f32; c_o * c_i * 16];
    for o in 0..c_o {
        for i in 0..c_i {
            let g = &ks[(o * c_i + i) * 9..][..9];
            u[(o * c_i + i) * 16..][..16].copy_from_slice(&transform_kernel(g));
        }
    }
    Ok(u)
}

/// Scratch floats [`conv_winograd_into`] needs (`C_i` transformed input
/// tiles of 16 floats).
pub fn winograd_workspace_len(shape: &ConvShape) -> usize {
    shape.c_i * 16
}

/// Allocation-free Winograd core over pre-transformed weights `u`
/// (from [`transform_kernels`]): writes the flat `[C_o][H_o][W_o]`
/// result into `od` (fully overwritten) using the caller-owned `v_all`
/// scratch of [`winograd_workspace_len`] floats. This is the
/// `execute_into` path of the `winograd` engine backend.
pub fn conv_winograd_into(
    src: &[f32],
    u: &[f32],
    shape: &ConvShape,
    od: &mut [f32],
    v_all: &mut [f32],
) -> Result<()> {
    shape.validate()?;
    if !winograd_applicable(shape) {
        // The tile math below hardcodes stride 1 / 3x3; anything else
        // would pass the length checks yet compute garbage.
        return Err(Error::Shape(format!(
            "winograd F(2x2,3x3) needs 3x3/s1, got {}x{}/s{}",
            shape.h_f, shape.w_f, shape.stride
        )));
    }
    let (h_o, w_o) = (shape.h_o(), shape.w_o());
    let (c_i, h_i, w_i) = (shape.c_i, shape.h_i, shape.w_i);
    let c_o = shape.c_o;
    let p = shape.pad;
    if src.len() != c_i * h_i * w_i {
        return Err(Error::Shape(format!(
            "input has {} elements, expected {}",
            src.len(),
            c_i * h_i * w_i
        )));
    }
    if u.len() != c_o * c_i * 16 {
        return Err(Error::Shape(format!(
            "transformed weights have {} elements, expected {}",
            u.len(),
            c_o * c_i * 16
        )));
    }
    if od.len() != c_o * h_o * w_o {
        return Err(Error::Shape(format!(
            "output has {} elements, expected {}",
            od.len(),
            c_o * h_o * w_o
        )));
    }
    if v_all.len() != winograd_workspace_len(shape) {
        return Err(Error::Shape(format!(
            "workspace has {} floats, expected {}",
            v_all.len(),
            winograd_workspace_len(shape)
        )));
    }

    let tiles_y = h_o.div_ceil(2);
    let tiles_x = w_o.div_ceil(2);

    // Per tile: gather d per input channel, V = B^T d B, accumulate
    // M[o] += U[o][i] ⊙ V, then Y = A^T M A. Every output element is
    // written by exactly one tile, so `od` needs no pre-zeroing.
    for ty in 0..tiles_y {
        for tx in 0..tiles_x {
            let y0 = (ty * 2) as isize - p as isize;
            let x0 = (tx * 2) as isize - p as isize;
            // input tiles for all channels
            for i in 0..c_i {
                let mut d = [0.0f32; 16];
                for r in 0..4 {
                    let yy = y0 + r as isize;
                    if yy < 0 || yy >= h_i as isize {
                        continue;
                    }
                    for c in 0..4 {
                        let xx = x0 + c as isize;
                        if xx < 0 || xx >= w_i as isize {
                            continue;
                        }
                        d[r * 4 + c] = src[(i * h_i + yy as usize) * w_i + xx as usize];
                    }
                }
                v_all[i * 16..][..16].copy_from_slice(&transform_input(&d));
            }
            for o in 0..c_o {
                let mut m = [0.0f32; 16];
                for i in 0..c_i {
                    let uu = &u[(o * c_i + i) * 16..][..16];
                    let vv = &v_all[i * 16..][..16];
                    for t in 0..16 {
                        m[t] += uu[t] * vv[t];
                    }
                }
                let y = transform_output(&m);
                for r in 0..2 {
                    let oy = ty * 2 + r;
                    if oy >= h_o {
                        continue;
                    }
                    for c in 0..2 {
                        let ox = tx * 2 + c;
                        if ox >= w_o {
                            continue;
                        }
                        od[(o * h_o + oy) * w_o + ox] = y[r * 2 + c];
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_naive;

    /// Transform weights then run the `_into` core (what the removed
    /// `conv_winograd` wrapper did; production plans through the
    /// engine's `winograd` backend, which retains the transform).
    fn winograd_oneshot(input: &Tensor, kernel: &Tensor, s: &ConvShape) -> Result<Tensor> {
        let u = transform_kernels(kernel, s)?;
        let mut out = Tensor::zeros(&[s.c_o, s.h_o(), s.w_o()]);
        let mut v_all = vec![0.0f32; winograd_workspace_len(s)];
        conv_winograd_into(input.data(), &u, s, out.data_mut(), &mut v_all)?;
        Ok(out)
    }

    fn check(s: &ConvShape, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, 3, 3], seed + 1);
        let want = conv_naive(&input, &kernel, s).unwrap();
        let got = winograd_oneshot(&input, &kernel, s).unwrap();
        assert!(
            got.allclose(&want, 1e-3, 1e-3),
            "mismatch {:?}: {}",
            s,
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_naive() {
        check(&ConvShape::new(2, 8, 8, 3, 3, 3, 1, 0), 90);
        check(&ConvShape::new(3, 9, 9, 4, 3, 3, 1, 1), 91);
        check(&ConvShape::new(4, 7, 11, 2, 3, 3, 1, 1), 92);
    }

    #[test]
    fn odd_output_sizes() {
        // H_o odd -> last tile row is partial.
        check(&ConvShape::new(2, 7, 7, 2, 3, 3, 1, 0), 93); // 5x5 out
        check(&ConvShape::new(1, 6, 6, 1, 3, 3, 1, 0), 94); // 4x4 out
    }

    #[test]
    fn kernel_transform_identity() {
        // delta kernel (center tap) convolved with anything = input crop;
        // its Winograd transform must reproduce that.
        let s = ConvShape::new(1, 6, 6, 1, 3, 3, 1, 1);
        let input = Tensor::random(&[1, 6, 6], 95);
        let mut k = vec![0.0f32; 9];
        k[4] = 1.0; // center
        let kernel = Tensor::from_vec(&[1, 1, 3, 3], k).unwrap();
        let got = winograd_oneshot(&input, &kernel, &s).unwrap();
        assert!(got.allclose(&input, 1e-4, 1e-4));
    }

    #[test]
    fn rejects_non_3x3() {
        let s = ConvShape::new(1, 8, 8, 1, 5, 5, 1, 0);
        let input = Tensor::zeros(&[1, 8, 8]);
        let kernel = Tensor::zeros(&[1, 1, 5, 5]);
        assert!(winograd_oneshot(&input, &kernel, &s).is_err());
        assert!(!winograd_applicable(&s));
    }

    #[test]
    fn memory_overhead_ratio() {
        let s = ConvShape::new(256, 13, 13, 384, 3, 3, 1, 1);
        let ratio = winograd_extra_bytes(&s) as f64 / s.kernel_bytes() as f64;
        assert!((ratio - 16.0 / 9.0).abs() < 0.01);
    }
}

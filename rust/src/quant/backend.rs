//! [`DirectI8Backend`] — the engine's seventh backend (`"direct_i8"`).
//!
//! A [`DirectI8Plan`] owns the per-output-channel-quantized §4 blocked
//! i8 kernel plus the requantize multipliers, and executes through the
//! shared integer core in [`super::direct`]:
//!
//! * through the ordinary f32 [`ConvPlan`] contract (inputs quantized
//!   on the fly per load, outputs dequantized per store — **no**
//!   staging buffer, so `workspace_bytes() == 0` is honest);
//! * through [`QuantExecute`] on real i8 slices — the byte-arena hot
//!   path the quantized [`crate::engine::NetRunner`] drives.
//!
//! Both paths share every integer operation, so their quantized values
//! are bit-identical.
//!
//! # Memory accounting
//!
//! The plan's weights are the caller's OIHW f32 kernel *re-expressed*
//! in i8 — a quarter of [`ConvShape::kernel_bytes`] — plus `8·C_o`
//! bytes of multipliers, so under the engine's accounting rule (held
//! bytes minus the conventional weight storage the plan replaces) the
//! retained overhead is 0 on every benchmark layer, and
//! [`QuantExecute::weight_bytes`] reports the ~4x shrink explicitly.
//!
//! # Default calibration
//!
//! Planned standalone (through the registry, without a network-level
//! calibration pass), the plan self-calibrates: activations are assumed
//! in `[-1, 1)` (the crate's synthetic serving inputs) and the output
//! range is measured by running the layer once in f32 on a seeded
//! sample image, inflated 1.5x as clipping headroom. Whole-network
//! planning ([`super::QuantNet`]) replaces both with per-edge min/max
//! calibration via [`DirectI8Plan::with_params`].

use super::direct::{conv_quant_core, QuantGeom};
use super::params::{
    per_channel_weight_scales, quantize, requant_multiplier, QuantParams,
};
use super::QuantExecute;
use crate::arch::Machine;
use crate::conv::{conv_direct_blocked_into, select_params, BlockParams, ConvShape};
use crate::engine::{check_execute_buffers, retained_over_kernel, ConvAlgo, ConvPlan};
use crate::layout::{blocked_kernel_index, to_blocked_io, to_blocked_kernel, IoLayout};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Seed of the synthetic sample image the standalone (registry) plan
/// path calibrates its output range with.
const SAMPLE_SEED: u64 = 0xCA11B;

/// Int8 direct convolution behind the engine API. See the module docs.
pub struct DirectI8Backend;

/// A planned int8 direct-convolution layer.
pub struct DirectI8Plan {
    shape: ConvShape,
    bp: BlockParams,
    threads: usize,
    /// §4 blocked kernel `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]`,
    /// symmetric per-output-channel int8.
    kernel_q: Vec<i8>,
    /// Per-output-channel requantize multipliers (`s_in·s_w_j/s_out`).
    mult: Vec<f64>,
    in_qp: QuantParams,
    out_qp: QuantParams,
}

impl DirectI8Plan {
    /// Quantize and plan one layer with explicit activation params:
    /// per-channel symmetric weight quantization, §4 blocked i8
    /// packing, analytic blocking from the machine model (same
    /// [`select_params`] as the f32 direct backend, so the i8 layouts
    /// block exactly like their f32 counterparts and a quantized net
    /// reuses the f32 net's layout chain).
    pub fn with_params(
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
        in_qp: QuantParams,
        out_qp: QuantParams,
    ) -> Result<DirectI8Plan> {
        shape.validate()?;
        let want = [shape.c_o, shape.c_i, shape.h_f, shape.w_f];
        if kernel.shape() != want {
            return Err(Error::Shape(format!(
                "plan kernel shape {:?} != expected {:?}",
                kernel.shape(),
                want
            )));
        }
        let bp = select_params(machine, shape);
        bp.validate_for(shape)?;
        let w_scales = per_channel_weight_scales(kernel);
        let mult: Vec<f64> = w_scales
            .iter()
            .map(|&sw| requant_multiplier(in_qp.scale, sw, out_qp.scale))
            .collect();
        // Quantize straight into the blocked layout (one pass, no OIHW
        // i8 intermediate).
        let src = kernel.data();
        let mut kernel_q = vec![0i8; src.len()];
        let per = shape.c_i * shape.h_f * shape.w_f;
        for o in 0..shape.c_o {
            let wq = QuantParams { scale: w_scales[o], zero_point: 0 };
            for i in 0..shape.c_i {
                for n in 0..shape.h_f {
                    for m in 0..shape.w_f {
                        let d = blocked_kernel_index(
                            o, i, n, m, shape.c_i, shape.h_f, shape.w_f, bp.c_ib, bp.c_ob,
                        );
                        kernel_q[d] =
                            quantize(src[o * per + (i * shape.h_f + n) * shape.w_f + m], &wq);
                    }
                }
            }
        }
        Ok(DirectI8Plan {
            shape: shape.clone(),
            bp,
            threads: threads.max(1),
            kernel_q,
            mult,
            in_qp,
            out_qp,
        })
    }

    /// The analytic blocking the plan executes with.
    pub fn block_params(&self) -> BlockParams {
        self.bp
    }

    fn geom(&self) -> QuantGeom<'_> {
        QuantGeom {
            shape: &self.shape,
            bp: self.bp,
            in_qp: self.in_qp,
            out_qp: self.out_qp,
            mult: &self.mult,
        }
    }
}

impl ConvAlgo for DirectI8Backend {
    fn name(&self) -> &'static str {
        "direct_i8"
    }

    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok()
    }

    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        // Standalone self-calibration: assume [-1, 1) activations and
        // measure the output range on one seeded f32 sample (1.5x
        // headroom against inputs drawn from the same distribution but
        // other seeds). See the module docs.
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let bp = select_params(machine, shape);
        bp.validate_for(shape)?;
        let sample = Tensor::random(&[shape.c_i, shape.h_i, shape.w_i], SAMPLE_SEED);
        let bi = to_blocked_io(&sample, bp.c_ib)?;
        let bk = to_blocked_kernel(kernel, bp.c_ob, bp.c_ib)?;
        let mut out = vec![0.0f32; shape.c_o * shape.h_o() * shape.w_o()];
        conv_direct_blocked_into(bi.data(), bk.data(), shape, bp, threads.max(1), &mut out)?;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &out {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mid = 0.5 * (lo + hi);
        let half = 0.75 * (hi - lo).max(1e-6); // 1.5x headroom
        let out_qp = QuantParams::from_range(mid - half, mid + half);
        Ok(Box::new(DirectI8Plan::with_params(shape, kernel, machine, threads, in_qp, out_qp)?))
    }
}

impl ConvPlan for DirectI8Plan {
    fn backend(&self) -> &'static str {
        "direct_i8"
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ib }
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ob }
    }
    fn retained_bytes(&self) -> u64 {
        // i8 weights + f64 multipliers replace the caller's f32 kernel;
        // the sum sits far below kernel_bytes() on every real layer.
        let held = self.kernel_q.len() as u64 + 8 * self.mult.len() as u64;
        retained_over_kernel(&self.shape, held)
    }
    fn workspace_len(&self) -> usize {
        0 // on-the-fly quantization: nothing is staged, see module docs
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output)
    }
    fn as_quantized(&self) -> Option<&dyn QuantExecute> {
        Some(self)
    }
}

impl QuantExecute for DirectI8Plan {
    fn input_qparams(&self) -> QuantParams {
        self.in_qp
    }
    fn output_qparams(&self) -> QuantParams {
        self.out_qp
    }
    fn weight_bytes(&self) -> u64 {
        self.kernel_q.len() as u64
    }
    fn execute_i8_into(&self, input: &[i8], output: &mut [i8]) -> Result<()> {
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;
    use crate::layout::pack_io_slice_t;

    #[test]
    fn plan_reports_zero_overhead_and_quarter_weights() {
        let s = ConvShape::new(16, 13, 13, 32, 3, 3, 1, 1);
        let k = Tensor::random(&[32, 16, 3, 3], 7);
        let plan = DirectI8Backend.plan(&s, &k, &haswell(), 1).unwrap();
        assert_eq!(plan.backend(), "direct_i8");
        assert_eq!(plan.retained_bytes(), 0, "i8 weights replace (and undercut) f32 storage");
        assert_eq!(plan.workspace_bytes(), 0, "on-the-fly quantization needs no staging");
        let q = plan.as_quantized().expect("direct_i8 exposes the i8 surface");
        assert_eq!(4 * q.weight_bytes(), s.kernel_bytes(), "exactly a quarter of the bytes");
    }

    #[test]
    fn f32_boundary_tracks_the_oracle_within_quant_error() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let k = Tensor::random(&[16, 8, 3, 3], 11);
        let input = Tensor::random(&[8, 10, 10], 12);
        let plan = DirectI8Backend.plan(&s, &k, &haswell(), 1).unwrap();
        let got = plan.execute(&input).unwrap();
        let want = conv_naive(&input, &k, &s).unwrap();
        assert!(
            got.allclose(&want, 0.08, 0.08),
            "quantized conv drifted beyond 8-bit error: {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn i8_path_is_bit_identical_to_the_f32_boundary() {
        let s = ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1);
        let k = Tensor::random(&[16, 8, 3, 3], 21);
        let input = Tensor::random(&[8, 9, 9], 22);
        let m = haswell();
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let out_qp = QuantParams::from_range(-15.0, 15.0);
        let plan = DirectI8Plan::with_params(&s, &k, &m, 1, in_qp, out_qp).unwrap();
        let bp = plan.block_params();

        // f32 boundary: pack f32, execute, re-quantize the output.
        let packed = plan.pack_input(&input).unwrap();
        let mut out_f = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
        plan.execute_into(packed.data(), &mut out_f, &mut []).unwrap();

        // i8 native: quantize + pack the input, execute on bytes.
        let x_q: Vec<i8> = input.data().iter().map(|&v| quantize(v, &in_qp)).collect();
        let mut bi = vec![0i8; x_q.len()];
        pack_io_slice_t(&x_q, s.c_i, s.h_i, s.w_i, bp.c_ib, &mut bi).unwrap();
        let mut out_q = vec![0i8; out_f.len()];
        plan.execute_i8_into(&bi, &mut out_q).unwrap();

        for (f, q) in out_f.iter().zip(&out_q) {
            assert_eq!(*f, super::super::dequantize(*q, &out_qp), "paths diverged");
        }
    }

    #[test]
    fn with_params_rejects_mismatched_kernel() {
        let s = ConvShape::new(4, 9, 9, 8, 3, 3, 1, 1);
        let bad = Tensor::zeros(&[8, 4, 3, 2]);
        let qp = QuantParams::IDENT;
        assert!(DirectI8Plan::with_params(&s, &bad, &haswell(), 1, qp, qp).is_err());
    }
}

//! [`DirectI8Backend`] — the engine's seventh backend (`"direct_i8"`).
//!
//! A [`DirectI8Plan`] owns the per-output-channel-quantized §4 blocked
//! i8 kernel plus the requantize multipliers, and executes through the
//! shared integer core in [`super::direct`]:
//!
//! * through the ordinary f32 [`ConvPlan`] contract (inputs quantized
//!   on the fly per load, outputs dequantized per store — **no**
//!   staging buffer, so `workspace_bytes() == 0` is honest);
//! * through [`QuantExecute`] on real i8 slices — the byte-arena hot
//!   path the quantized [`crate::engine::NetRunner`] drives.
//!
//! Both paths share every integer operation, so their quantized values
//! are bit-identical.
//!
//! # Memory accounting
//!
//! The plan's weights are the caller's OIHW f32 kernel *re-expressed*
//! in i8 — a quarter of [`ConvShape::kernel_bytes`] — plus `8·C_o`
//! bytes of multipliers, so under the engine's accounting rule (held
//! bytes minus the conventional weight storage the plan replaces) the
//! retained overhead is 0 on every benchmark layer, and
//! [`QuantExecute::weight_bytes`] reports the ~4x shrink explicitly.
//!
//! # Default calibration
//!
//! Planned standalone (through the registry, without a network-level
//! calibration pass), the plan self-calibrates: activations are assumed
//! in `[-1, 1)` (the crate's synthetic serving inputs) and the output
//! range is measured by running the layer once in f32 on a seeded
//! sample image, inflated 1.5x as clipping headroom. Whole-network
//! planning ([`super::QuantNet`]) replaces both with per-edge min/max
//! calibration via [`DirectI8Plan::with_params`].

use super::direct::{conv_quant_core, QuantGeom};
use super::params::{
    per_channel_weight_scales, quantize, requant_multiplier, round_half_away, QuantParams,
};
use super::QuantExecute;
use crate::arch::Machine;
use crate::conv::{
    conv_direct_blocked_into, select_params, BlockParams, ConvShape, Epilogue,
};
use crate::engine::{check_execute_buffers, retained_over_kernel, ConvAlgo, ConvPlan};
use crate::layout::{blocked_kernel_index, to_blocked_io, to_blocked_kernel, IoLayout};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Seed of the synthetic sample image the standalone (registry) plan
/// path calibrates its output range with.
const SAMPLE_SEED: u64 = 0xCA11B;

/// Int8 direct convolution behind the engine API. See the module docs.
pub struct DirectI8Backend;

/// A planned int8 direct-convolution layer, optionally with a fused
/// epilogue folded into its requantize step (see
/// [`DirectI8Plan::with_params_fused`]).
pub struct DirectI8Plan {
    shape: ConvShape,
    bp: BlockParams,
    threads: usize,
    /// §4 blocked kernel `[C_o/C_ob][C_i/C_ib][H_f][W_f][C_ib][C_ob]`
    /// per group (or `[C/c_b][H_f][W_f][c_b]` for depthwise), symmetric
    /// per-output-channel int8.
    kernel_q: Vec<i8>,
    /// Per-output-channel requantize multipliers (`s_in·s_w_j/s_out`),
    /// with any fused batch-norm scale folded in.
    mult: Vec<f64>,
    /// Per-channel pre-rounding offsets `shift_j/s_out` (empty = none).
    off: Vec<f64>,
    /// Fused residual: its quant params + `s_res/s_out` ratio.
    res: Option<(QuantParams, f64)>,
    relu: bool,
    clamp_q: Option<i32>,
    in_qp: QuantParams,
    out_qp: QuantParams,
}

/// Quantize an OIHW f32 kernel straight into the blocked i8 layout
/// (one pass, no OIHW i8 intermediate): per-group §4 slabs, or
/// depthwise `[C/c_b][H_f][W_f][c_b]` lanes.
fn quantize_kernel_blocked(
    src: &[f32],
    shape: &ConvShape,
    bp: BlockParams,
    w_scales: &[f32],
) -> Vec<i8> {
    let (c_ipg, c_opg) = (shape.c_i_per_group(), shape.c_o_per_group());
    let per = c_ipg * shape.h_f * shape.w_f;
    let mut kernel_q = vec![0i8; src.len()];
    if shape.is_depthwise() {
        for o in 0..shape.c_o {
            let wq = QuantParams { scale: w_scales[o], zero_point: 0 };
            for n in 0..shape.h_f {
                for m in 0..shape.w_f {
                    let d = ((o / bp.c_ob) * shape.h_f * shape.w_f + n * shape.w_f + m)
                        * bp.c_ob
                        + o % bp.c_ob;
                    kernel_q[d] = quantize(src[o * per + n * shape.w_f + m], &wq);
                }
            }
        }
        return kernel_q;
    }
    let per_g = c_opg * per;
    for o in 0..shape.c_o {
        let wq = QuantParams { scale: w_scales[o], zero_point: 0 };
        let (grp, o_l) = (o / c_opg, o % c_opg);
        for i in 0..c_ipg {
            for n in 0..shape.h_f {
                for m in 0..shape.w_f {
                    let d = blocked_kernel_index(
                        o_l, i, n, m, c_ipg, shape.h_f, shape.w_f, bp.c_ib, bp.c_ob,
                    );
                    kernel_q[grp * per_g + d] =
                        quantize(src[o * per + (i * shape.h_f + n) * shape.w_f + m], &wq);
                }
            }
        }
    }
    kernel_q
}

impl DirectI8Plan {
    /// Quantize and plan one layer with explicit activation params:
    /// per-channel symmetric weight quantization, §4 blocked i8
    /// packing, analytic blocking from the machine model (same
    /// [`select_params`] as the f32 direct backend, so the i8 layouts
    /// block exactly like their f32 counterparts and a quantized net
    /// reuses the f32 net's layout chain).
    pub fn with_params(
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
        in_qp: QuantParams,
        out_qp: QuantParams,
    ) -> Result<DirectI8Plan> {
        Self::with_params_fused(
            shape,
            kernel,
            machine,
            threads,
            in_qp,
            out_qp,
            &Epilogue::none(),
            None,
        )
    }

    /// [`Self::with_params`] plus a fused epilogue, folded **into the
    /// requantize step at plan time** so execution still performs one
    /// rounding per output element (see [`QuantGeom`]'s formula):
    ///
    /// * `ep.scale` (folded batch-norm) multiplies the per-channel
    ///   requantize multipliers;
    /// * `ep.shift` (bias / BN shift) becomes the pre-rounding offset
    ///   `shift_j / s_out`;
    /// * `ep.relu`/`ep.clamp` become quantized-domain clamp bounds;
    /// * a residual (`ep.residual`) requires `res_qp` — the quant params
    ///   of the shortcut operand the caller will pass at execution.
    #[allow(clippy::too_many_arguments)]
    pub fn with_params_fused(
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
        in_qp: QuantParams,
        out_qp: QuantParams,
        ep: &Epilogue,
        res_qp: Option<QuantParams>,
    ) -> Result<DirectI8Plan> {
        shape.validate()?;
        let want = [shape.c_o, shape.c_i_per_group(), shape.h_f, shape.w_f];
        if kernel.shape() != want {
            return Err(Error::Shape(format!(
                "plan kernel shape {:?} != expected {:?}",
                kernel.shape(),
                want
            )));
        }
        ep.validate(shape.c_o)?;
        if ep.residual != res_qp.is_some() {
            return Err(Error::Shape(
                "fused residual requires its quant params (and vice versa)".into(),
            ));
        }
        let bp = select_params(machine, shape);
        bp.validate_for(shape)?;
        let w_scales = per_channel_weight_scales(kernel);
        let mult: Vec<f64> = w_scales
            .iter()
            .enumerate()
            .map(|(j, &sw)| {
                let m = requant_multiplier(in_qp.scale, sw, out_qp.scale);
                if ep.scale.is_empty() { m } else { m * ep.scale[j] as f64 }
            })
            .collect();
        let off: Vec<f64> =
            ep.shift.iter().map(|&s| s as f64 / out_qp.scale as f64).collect();
        let res = res_qp.map(|r| (r, r.scale as f64 / out_qp.scale as f64));
        let clamp_q = ep
            .clamp
            .map(|c| round_half_away(c as f64 / out_qp.scale as f64) as i32 + out_qp.zero_point);
        let kernel_q = quantize_kernel_blocked(kernel.data(), shape, bp, &w_scales);
        Ok(DirectI8Plan {
            shape: shape.clone(),
            bp,
            threads: threads.max(1),
            kernel_q,
            mult,
            off,
            res,
            relu: ep.relu,
            clamp_q,
            in_qp,
            out_qp,
        })
    }

    /// The analytic blocking the plan executes with.
    pub fn block_params(&self) -> BlockParams {
        self.bp
    }

    /// Quant params the fused residual operand must carry (set iff the
    /// plan was built with one).
    pub fn residual_qparams(&self) -> Option<QuantParams> {
        self.res.map(|(qp, _)| qp)
    }

    fn geom(&self) -> QuantGeom<'_> {
        QuantGeom {
            shape: &self.shape,
            bp: self.bp,
            in_qp: self.in_qp,
            out_qp: self.out_qp,
            mult: &self.mult,
            off: &self.off,
            res: self.res,
            relu: self.relu,
            clamp_q: self.clamp_q,
        }
    }
}

impl ConvAlgo for DirectI8Backend {
    fn name(&self) -> &'static str {
        "direct_i8"
    }

    fn applicable(&self, shape: &ConvShape) -> bool {
        shape.validate().is_ok()
    }

    fn plan(
        &self,
        shape: &ConvShape,
        kernel: &Tensor,
        machine: &Machine,
        threads: usize,
    ) -> Result<Box<dyn ConvPlan>> {
        // Standalone self-calibration: assume [-1, 1) activations and
        // measure the output range on one seeded f32 sample (1.5x
        // headroom against inputs drawn from the same distribution but
        // other seeds). See the module docs.
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let bp = select_params(machine, shape);
        bp.validate_for(shape)?;
        let sample = Tensor::random(&[shape.c_i, shape.h_i, shape.w_i], SAMPLE_SEED);
        let bi = to_blocked_io(&sample, bp.c_ib)?;
        let k_cib = if shape.is_depthwise() { 1 } else { bp.c_ib };
        let bk = to_blocked_kernel(kernel, bp.c_ob, k_cib)?;
        let mut out = vec![0.0f32; shape.c_o * shape.h_o() * shape.w_o()];
        conv_direct_blocked_into(bi.data(), bk.data(), shape, bp, threads.max(1), &mut out)?;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &out {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mid = 0.5 * (lo + hi);
        let half = 0.75 * (hi - lo).max(1e-6); // 1.5x headroom
        let out_qp = QuantParams::from_range(mid - half, mid + half);
        Ok(Box::new(DirectI8Plan::with_params(shape, kernel, machine, threads, in_qp, out_qp)?))
    }
}

impl ConvPlan for DirectI8Plan {
    fn backend(&self) -> &'static str {
        "direct_i8"
    }
    fn kernel_desc(&self) -> &'static str {
        if self.shape.is_depthwise() {
            // The i8 depthwise taps are lane-wise and memory-bound;
            // no SIMD variant ships (see quant::direct).
            "scalar"
        } else {
            crate::conv::dispatch::kernel_label_i8(self.bp.c_ob)
        }
    }
    fn shape(&self) -> &ConvShape {
        &self.shape
    }
    fn input_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ib }
    }
    fn output_layout(&self) -> IoLayout {
        IoLayout::Blocked { c_b: self.bp.c_ob }
    }
    fn retained_bytes(&self) -> u64 {
        // i8 weights + f64 multipliers replace the caller's f32 kernel;
        // the sum sits far below kernel_bytes() on every real layer.
        let held = self.kernel_q.len() as u64 + 8 * self.mult.len() as u64;
        retained_over_kernel(&self.shape, held)
    }
    fn workspace_len(&self) -> usize {
        0 // on-the-fly quantization: nothing is staged, see module docs
    }
    fn execute_into(&self, input: &[f32], output: &mut [f32], workspace: &mut [f32]) -> Result<()> {
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        if self.res.is_some() {
            return Err(Error::Shape(
                "plan fused a residual: use execute_fused_into with the operand".into(),
            ));
        }
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output, None)
    }
    fn execute_fused_into(
        &self,
        input: &[f32],
        output: &mut [f32],
        workspace: &mut [f32],
        ep: &Epilogue,
        res: Option<&[f32]>,
    ) -> Result<()> {
        // The i8 epilogue was folded into the requantize multipliers /
        // offsets / clamp bounds at plan time (`with_params_fused`);
        // applying an f32 epilogue after the fact would double-apply
        // it. This entry verifies the caller's epilogue matches what
        // was baked in and routes the residual operand.
        check_execute_buffers(&self.shape, 0, input, output, workspace)?;
        if ep.relu != self.relu
            || ep.residual != self.res.is_some()
            || ep.clamp.is_some() != self.clamp_q.is_some()
            || ep.shift.is_empty() != self.off.is_empty()
        {
            return Err(Error::Shape(
                "direct_i8 epilogue must be folded at plan time (with_params_fused)".into(),
            ));
        }
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output, res)
    }
    fn as_quantized(&self) -> Option<&dyn QuantExecute> {
        Some(self)
    }
}

impl QuantExecute for DirectI8Plan {
    fn input_qparams(&self) -> QuantParams {
        self.in_qp
    }
    fn output_qparams(&self) -> QuantParams {
        self.out_qp
    }
    fn weight_bytes(&self) -> u64 {
        self.kernel_q.len() as u64
    }
    fn execute_i8_into(&self, input: &[i8], output: &mut [i8]) -> Result<()> {
        if self.res.is_some() {
            return Err(Error::Shape(
                "plan fused a residual: use execute_i8_fused_into with the operand".into(),
            ));
        }
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output, None)
    }
    fn execute_i8_fused_into(
        &self,
        input: &[i8],
        output: &mut [i8],
        res: Option<&[i8]>,
    ) -> Result<()> {
        if self.res.is_some() != res.is_some() {
            return Err(Error::Shape("fused residual operand mismatch".into()));
        }
        conv_quant_core(input, &self.kernel_q, &self.geom(), self.threads, output, res)
    }
    fn residual_qparams(&self) -> Option<QuantParams> {
        DirectI8Plan::residual_qparams(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::conv::conv_naive;
    use crate::layout::pack_io_slice_t;

    #[test]
    fn plan_reports_zero_overhead_and_quarter_weights() {
        let s = ConvShape::new(16, 13, 13, 32, 3, 3, 1, 1);
        let k = Tensor::random(&[32, 16, 3, 3], 7);
        let plan = DirectI8Backend.plan(&s, &k, &haswell(), 1).unwrap();
        assert_eq!(plan.backend(), "direct_i8");
        assert_eq!(plan.retained_bytes(), 0, "i8 weights replace (and undercut) f32 storage");
        assert_eq!(plan.workspace_bytes(), 0, "on-the-fly quantization needs no staging");
        let q = plan.as_quantized().expect("direct_i8 exposes the i8 surface");
        assert_eq!(4 * q.weight_bytes(), s.kernel_bytes(), "exactly a quarter of the bytes");
    }

    #[test]
    fn f32_boundary_tracks_the_oracle_within_quant_error() {
        let s = ConvShape::new(8, 10, 10, 16, 3, 3, 1, 1);
        let k = Tensor::random(&[16, 8, 3, 3], 11);
        let input = Tensor::random(&[8, 10, 10], 12);
        let plan = DirectI8Backend.plan(&s, &k, &haswell(), 1).unwrap();
        let got = plan.execute(&input).unwrap();
        let want = conv_naive(&input, &k, &s).unwrap();
        assert!(
            got.allclose(&want, 0.08, 0.08),
            "quantized conv drifted beyond 8-bit error: {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn i8_path_is_bit_identical_to_the_f32_boundary() {
        let s = ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1);
        let k = Tensor::random(&[16, 8, 3, 3], 21);
        let input = Tensor::random(&[8, 9, 9], 22);
        let m = haswell();
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let out_qp = QuantParams::from_range(-15.0, 15.0);
        let plan = DirectI8Plan::with_params(&s, &k, &m, 1, in_qp, out_qp).unwrap();
        let bp = plan.block_params();

        // f32 boundary: pack f32, execute, re-quantize the output.
        let packed = plan.pack_input(&input).unwrap();
        let mut out_f = vec![0.0f32; s.c_o * s.h_o() * s.w_o()];
        plan.execute_into(packed.data(), &mut out_f, &mut []).unwrap();

        // i8 native: quantize + pack the input, execute on bytes.
        let x_q: Vec<i8> = input.data().iter().map(|&v| quantize(v, &in_qp)).collect();
        let mut bi = vec![0i8; x_q.len()];
        pack_io_slice_t(&x_q, s.c_i, s.h_i, s.w_i, bp.c_ib, &mut bi).unwrap();
        let mut out_q = vec![0i8; out_f.len()];
        plan.execute_i8_into(&bi, &mut out_q).unwrap();

        for (f, q) in out_f.iter().zip(&out_q) {
            assert_eq!(*f, super::super::dequantize(*q, &out_qp), "paths diverged");
        }
    }

    #[test]
    fn depthwise_plan_runs_and_tracks_oracle() {
        let s = ConvShape::new(8, 10, 10, 8, 3, 3, 1, 1).with_groups(8);
        let k = Tensor::random(&[8, 1, 3, 3], 31);
        let input = Tensor::random(&[8, 10, 10], 32);
        let plan = DirectI8Backend.plan(&s, &k, &haswell(), 1).unwrap();
        assert_eq!(plan.workspace_bytes(), 0);
        let got = plan.execute(&input).unwrap();
        let want = conv_naive(&input, &k, &s).unwrap();
        assert!(got.allclose(&want, 0.1, 0.1), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn fused_plan_applies_bias_relu_and_guards_entries() {
        let s = ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1);
        let k = Tensor::random(&[16, 8, 3, 3], 41);
        let input = Tensor::random(&[8, 8, 8], 42);
        let m = haswell();
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let out_qp = QuantParams::from_range(-8.0, 8.0);
        let shift: Vec<f32> = (0..16).map(|j| (j as f32 - 8.0) * 0.1).collect();
        let ep = crate::conv::Epilogue::bias(shift).with_relu(None);
        let plan =
            DirectI8Plan::with_params_fused(&s, &k, &m, 1, in_qp, out_qp, &ep, None).unwrap();

        let packed = plan.pack_input(&input).unwrap();
        let n_out = s.c_o * s.h_o() * s.w_o();
        let mut out = vec![0.0f32; n_out];
        plan.execute_fused_into(packed.data(), &mut out, &mut [], &ep, None).unwrap();
        let cb = plan.block_params().c_ob;
        let t = Tensor::from_vec(&[s.c_o / cb, s.h_o(), s.w_o(), cb], out).unwrap();
        let got = crate::layout::from_blocked_io(&t).unwrap();

        let mut want = conv_naive(&input, &k, &s).unwrap();
        crate::conv::apply_post(
            want.data_mut(),
            IoLayout::Nchw,
            s.c_o,
            s.h_o() * s.w_o(),
            &ep,
            None,
        )
        .unwrap();
        assert!(got.allclose(&want, 0.12, 0.12), "diff {}", got.max_abs_diff(&want));
        assert!(got.data().iter().all(|&v| v >= 0.0), "fused relu floor");

        // An epilogue that disagrees with the folded one is rejected —
        // silently re-applying it would corrupt the integer contract.
        let mut buf = vec![0.0f32; n_out];
        assert!(plan
            .execute_fused_into(packed.data(), &mut buf, &mut [], &crate::conv::Epilogue::none(), None)
            .is_err());
        // Residual mismatch on the i8 surface is rejected too.
        let q = plan.as_quantized().unwrap();
        let bi = vec![0i8; s.c_i * s.h_i * s.w_i];
        let mut bo = vec![0i8; n_out];
        let bad_res = vec![0i8; n_out];
        assert!(q.execute_i8_fused_into(&bi, &mut bo, Some(&bad_res)).is_err());
    }

    #[test]
    fn with_params_rejects_mismatched_kernel() {
        let s = ConvShape::new(4, 9, 9, 8, 3, 3, 1, 1);
        let bad = Tensor::zeros(&[8, 4, 3, 2]);
        let qp = QuantParams::IDENT;
        assert!(DirectI8Plan::with_params(&s, &bad, &haswell(), 1, qp, qp).is_err());
    }
}

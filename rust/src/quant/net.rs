//! Whole-network quantization: calibrate every graph edge, plan every
//! conv with edge-chained requantize params, compile to the i8 byte
//! arena.
//!
//! Post-training quantization needs one piece of information the
//! weights cannot provide: the dynamic range of every activation. A
//! [`QuantNet`] gets it the classic way — a **sample batch forward
//! pass** in f32 (one deterministic synthetic image, seed
//! [`CALIBRATION_SEED`]), recording per-node min/max and turning each
//! into affine [`QuantParams`]. Each conv layer is then quantized with
//! *its producer edge's* input params and *its own* output params
//! ([`DirectI8Plan::with_params`]), so requantize scales chain
//! layer-to-layer by construction; pooling / concat / residual glue
//! between differently scaled edges is requantized inside the
//! executor's fused Adapt gathers at no extra pass.
//!
//! Calibration is a plan-time cost (one f32 forward through the
//! per-layer engine plus min/max scans, with intermediate activations
//! freed as their last consumer finishes); the resulting runner's hot
//! path is pure int8.

use crate::arch::Machine;
use crate::engine::{add_nchw, avg_pool_nchw, pool_nchw, BackendRegistry, NetRunner};
use crate::nets::{
    net_kernel, GraphOp, Layer, Model, NetGraph, NetPlans, PlannedLayer, PoolKind,
};
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::backend::DirectI8Plan;
use super::params::QuantParams;

/// Seed of the deterministic synthetic calibration image — the same
/// seed the golden fixtures feed forward, so the calibrated ranges are
/// exact for the pinned input.
pub const CALIBRATION_SEED: u64 = 0x601D;

/// Min/max-calibrate every node of a graph from one sample input:
/// run the f32 reference forward (direct plans per layer, NCHW glue)
/// and return one [`QuantParams`] per graph node, in node order.
pub fn calibrate_graph(
    graph: &NetGraph,
    shapes: &[crate::conv::ConvShape],
    machine: &Machine,
    threads: usize,
    input: &Tensor,
) -> Result<Vec<QuantParams>> {
    graph.validate(shapes)?;
    let registry = BackendRegistry::shared();
    let mut outs: Vec<Option<Tensor>> = (0..graph.len()).map(|_| None).collect();
    let mut remaining = graph.consumer_counts();
    let mut params = Vec::with_capacity(graph.len());
    for (i, node) in graph.nodes.iter().enumerate() {
        let t = match &node.op {
            GraphOp::Input { .. } => input.clone(),
            GraphOp::Conv { layer } => {
                let s = &shapes[*layer];
                let kernel = net_kernel(*layer, s);
                // Thread count only affects calibration speed: the
                // direct kernel is bitwise deterministic across thread
                // partitions, so the measured ranges are too.
                let plan = registry.plan("direct", s, &kernel, machine, threads)?;
                plan.execute(outs[node.preds[0]].as_ref().expect("topological order"))?
            }
            GraphOp::Pool { kind, kh, kw, sh, sw, ph, pw } => {
                let src = outs[node.preds[0]].as_ref().expect("topological order");
                match kind {
                    PoolKind::Max => pool_nchw(src, *kh, *kw, *sh, *sw, *ph, *pw)?,
                    PoolKind::Avg => avg_pool_nchw(src, *kh, *kw, *sh, *sw, *ph, *pw)?,
                }
            }
            GraphOp::Concat => {
                let parts: Vec<&Tensor> =
                    node.preds.iter().map(|&p| outs[p].as_ref().expect("topo")).collect();
                let (h, w) = (parts[0].shape()[1], parts[0].shape()[2]);
                let c: usize = parts.iter().map(|t| t.shape()[0]).sum();
                let mut data = Vec::with_capacity(c * h * w);
                for p in &parts {
                    data.extend_from_slice(p.data());
                }
                Tensor::from_vec(&[c, h, w], data)?
            }
            GraphOp::Add => {
                let mut acc = outs[node.preds[0]].as_ref().expect("topo").clone();
                for &p in &node.preds[1..] {
                    acc = add_nchw(&acc, outs[p].as_ref().expect("topo"))?;
                }
                acc
            }
        };
        if !t.data().iter().all(|v| v.is_finite()) {
            return Err(Error::Runtime(format!(
                "calibration forward produced non-finite activations at node '{}' — \
                 ranges cannot be quantized",
                node.name
            )));
        }
        params.push(QuantParams::calibrate(t.data()));
        outs[i] = Some(t);
        // Free activations whose last consumer just ran (bounds peak
        // calibration memory at the live set, like the executor).
        for &p in &node.preds {
            remaining[p] -= 1;
            if remaining[p] == 0 {
                outs[p] = None;
            }
        }
    }
    Ok(params)
}

/// A fully quantized network: `direct_i8` plans with edge-chained
/// requantize params, the per-node calibration table, and the graph —
/// everything [`NetRunner::from_graph_quant`] needs.
pub struct QuantNet {
    pub plans: NetPlans,
    pub node_params: Vec<QuantParams>,
    pub graph: NetGraph,
}

impl QuantNet {
    /// Calibrate and quantize a [`Model`] (same deterministic
    /// [`net_kernel`] weights as the f32 planning paths, so f32 and i8
    /// nets are directly comparable).
    pub fn build_model(model: &Model, machine: &Machine, threads: usize) -> Result<QuantNet> {
        let dims = model.validate()?;
        let d = dims[0];
        let input = Tensor::random(&[d.c, d.h, d.w], CALIBRATION_SEED);
        let params = calibrate_graph(&model.graph, &model.shapes, machine, threads, &input)?;
        Self::with_node_params(&model.name, &model.graph, &model.shapes, machine, threads, params)
    }

    /// Calibrate and quantize a built-in net by name (every net with a
    /// builder program: `alexnet`, `googlenet`, `vgg16`,
    /// `resnet_micro`).
    pub fn build(net: &str, machine: &Machine, threads: usize) -> Result<QuantNet> {
        let model = crate::nets::model_by_name(net).ok_or_else(|| {
            Error::Parse(format!(
                "unknown net '{net}' (alexnet|googlenet|vgg16|resnet_micro)"
            ))
        })?;
        Self::build_model(&model, machine, threads)
    }

    /// Quantize a graph with **prescribed** per-node activation params
    /// (one per graph node, node order). This is how the golden tests
    /// pin exact integer outputs: the independent NumPy reference picks
    /// the params, commits them to the fixture, and both sides run the
    /// identical integer program.
    pub fn with_node_params(
        name: &str,
        graph: &NetGraph,
        shapes: &[crate::conv::ConvShape],
        machine: &Machine,
        threads: usize,
        node_params: Vec<QuantParams>,
    ) -> Result<QuantNet> {
        graph.validate(shapes)?;
        if node_params.len() != graph.len() {
            return Err(Error::Shape(format!(
                "quantizing '{name}': {} node params for {} graph nodes",
                node_params.len(),
                graph.len()
            )));
        }
        let mut planned: Vec<Option<PlannedLayer>> = (0..shapes.len()).map(|_| None).collect();
        for (i, node) in graph.nodes.iter().enumerate() {
            let GraphOp::Conv { layer } = &node.op else {
                continue;
            };
            let layer = *layer;
            let s = &shapes[layer];
            let kernel = net_kernel(layer, s);
            let in_qp = node_params[node.preds[0]];
            let out_qp = node_params[i];
            let plan =
                DirectI8Plan::with_params(s, &kernel, machine, threads, in_qp, out_qp)?;
            planned[layer] = Some(PlannedLayer {
                layer: Layer { net: name.to_string(), name: node.name.clone(), shape: s.clone() },
                backend: "direct_i8",
                threads: threads.max(1),
                plan: Box::new(plan),
            });
        }
        let layers = planned
            .into_iter()
            .map(|p| p.expect("graph validation guarantees every layer is used"))
            .collect();
        Ok(QuantNet {
            plans: NetPlans { net: name.to_string(), layers },
            node_params,
            graph: graph.clone(),
        })
    }

    /// Compile to the i8 byte-arena executor.
    pub fn runner(self, lanes: usize) -> Result<NetRunner> {
        NetRunner::from_graph_quant(self.plans, self.graph, lanes, &self.node_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::engine::ConvPlan;

    #[test]
    fn calibration_covers_every_node_and_frees_as_it_goes() {
        let model = crate::nets::builder::resnet_micro();
        let input = Tensor::random(&[3, 32, 32], CALIBRATION_SEED);
        let params =
            calibrate_graph(&model.graph, &model.shapes, &haswell(), 1, &input).unwrap();
        assert_eq!(params.len(), model.graph.len());
        for (p, n) in params.iter().zip(&model.graph.nodes) {
            assert!(p.scale > 0.0, "{}: degenerate scale", n.name);
            assert!((-127..=127).contains(&p.zero_point), "{}: zp out of budget", n.name);
        }
    }

    #[test]
    fn quant_net_builds_with_chained_edges() {
        let q = QuantNet::build("resnet_micro", &haswell(), 1).unwrap();
        assert_eq!(q.plans.layers.len(), 6);
        assert!(q.plans.layers.iter().all(|l| l.backend == "direct_i8"));
        // Edge chaining: conv1's input params are conv0's output params
        // (conv0 is conv1's producer in resnet_micro).
        let p0 = q.plans.layers[0].plan.as_quantized().unwrap().output_qparams();
        let p1 = q.plans.layers[1].plan.as_quantized().unwrap().input_qparams();
        assert_eq!(p0, p1, "requantize params must chain producer -> consumer");
        let runner = q.runner(1).unwrap();
        assert_eq!(runner.dtype(), crate::quant::DType::I8);
        assert_eq!(runner.overhead_bytes(), 0);
    }

    #[test]
    fn unknown_net_and_bad_param_counts_are_rejected() {
        assert!(QuantNet::build("resnet", &haswell(), 1).is_err());
        let model = crate::nets::builder::resnet_micro();
        assert!(QuantNet::with_node_params(
            "t",
            &model.graph,
            &model.shapes,
            &haswell(),
            1,
            vec![QuantParams::IDENT; 3],
        )
        .is_err());
    }
}

//! Whole-network quantization: calibrate every graph edge, plan every
//! conv with edge-chained requantize params, compile to the i8 byte
//! arena.
//!
//! Post-training quantization needs one piece of information the
//! weights cannot provide: the dynamic range of every activation. A
//! [`QuantNet`] gets it the classic way — a **sample batch forward
//! pass** in f32 (one deterministic synthetic image, seed
//! [`CALIBRATION_SEED`]), recording per-node min/max and turning each
//! into affine [`QuantParams`]. Each conv layer is then quantized with
//! *its producer edge's* input params and *its own* output params
//! ([`DirectI8Plan::with_params`]), so requantize scales chain
//! layer-to-layer by construction; pooling / concat / residual glue
//! between differently scaled edges is requantized inside the
//! executor's fused Adapt gathers at no extra pass.
//!
//! Calibration is a plan-time cost (one f32 forward through the
//! per-layer engine plus min/max scans, with intermediate activations
//! freed as their last consumer finishes); the resulting runner's hot
//! path is pure int8.

use crate::arch::Machine;
use crate::engine::{add_nchw, avg_pool_nchw, pool_nchw, BackendRegistry, NetRunner};
use crate::nets::{
    net_bn_params, net_kernel, FusedNet, GraphOp, Layer, LayerFusion, Model, NetGraph, NetPlans,
    PlannedLayer, PoolKind,
};
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::backend::DirectI8Plan;
use super::params::QuantParams;

/// Seed of the deterministic synthetic calibration image — the same
/// seed the golden fixtures feed forward, so the calibrated ranges are
/// exact for the pinned input.
pub const CALIBRATION_SEED: u64 = 0x601D;

/// Min/max-calibrate every node of a graph from one sample input:
/// run the f32 reference forward (direct plans per layer, NCHW glue)
/// and return one [`QuantParams`] per graph node, in node order.
pub fn calibrate_graph(
    graph: &NetGraph,
    shapes: &[crate::conv::ConvShape],
    machine: &Machine,
    threads: usize,
    input: &Tensor,
) -> Result<Vec<QuantParams>> {
    graph.validate(shapes)?;
    let registry = BackendRegistry::shared();
    let bn_ords = graph.bn_ordinals();
    let mut outs: Vec<Option<Tensor>> = (0..graph.len()).map(|_| None).collect();
    let mut remaining = graph.consumer_counts();
    let mut params = Vec::with_capacity(graph.len());
    for (i, node) in graph.nodes.iter().enumerate() {
        let t = match &node.op {
            GraphOp::Input { .. } => input.clone(),
            GraphOp::Conv { layer } => {
                let s = &shapes[*layer];
                let kernel = net_kernel(*layer, s);
                // Thread count only affects calibration speed: the
                // direct kernel is bitwise deterministic across thread
                // partitions, so the measured ranges are too.
                let plan = registry.plan("direct", s, &kernel, machine, threads)?;
                plan.execute(outs[node.preds[0]].as_ref().expect("topological order"))?
            }
            GraphOp::Pool { kind, kh, kw, sh, sw, ph, pw } => {
                let src = outs[node.preds[0]].as_ref().expect("topological order");
                match kind {
                    PoolKind::Max => pool_nchw(src, *kh, *kw, *sh, *sw, *ph, *pw)?,
                    PoolKind::Avg => avg_pool_nchw(src, *kh, *kw, *sh, *sw, *ph, *pw)?,
                }
            }
            GraphOp::Concat => {
                let parts: Vec<&Tensor> =
                    node.preds.iter().map(|&p| outs[p].as_ref().expect("topo")).collect();
                let (h, w) = (parts[0].shape()[1], parts[0].shape()[2]);
                let c: usize = parts.iter().map(|t| t.shape()[0]).sum();
                let mut data = Vec::with_capacity(c * h * w);
                for p in &parts {
                    data.extend_from_slice(p.data());
                }
                Tensor::from_vec(&[c, h, w], data)?
            }
            GraphOp::Add => {
                let mut acc = outs[node.preds[0]].as_ref().expect("topo").clone();
                for &p in &node.preds[1..] {
                    acc = add_nchw(&acc, outs[p].as_ref().expect("topo"))?;
                }
                acc
            }
            GraphOp::Relu { clamp } => {
                let src = outs[node.preds[0]].as_ref().expect("topological order");
                let mut d = src.data().to_vec();
                for v in &mut d {
                    *v = v.max(0.0);
                    if let Some(cl) = clamp {
                        *v = v.min(*cl);
                    }
                }
                Tensor::from_vec(src.shape(), d)?
            }
            GraphOp::BatchNorm => {
                let src = outs[node.preds[0]].as_ref().expect("topological order");
                let (c, h, w) = (src.shape()[0], src.shape()[1], src.shape()[2]);
                let (scale, shift) =
                    net_bn_params(bn_ords[i].expect("BatchNorm node has an ordinal"), c);
                let mut d = src.data().to_vec();
                for ci in 0..c {
                    for j in 0..h * w {
                        let v = &mut d[ci * h * w + j];
                        *v *= scale[ci];
                        *v += shift[ci];
                    }
                }
                Tensor::from_vec(&[c, h, w], d)?
            }
        };
        if !t.data().iter().all(|v| v.is_finite()) {
            return Err(Error::Runtime(format!(
                "calibration forward produced non-finite activations at node '{}' — \
                 ranges cannot be quantized",
                node.name
            )));
        }
        params.push(QuantParams::calibrate(t.data()));
        outs[i] = Some(t);
        // Free activations whose last consumer just ran (bounds peak
        // calibration memory at the live set, like the executor).
        for &p in &node.preds {
            remaining[p] -= 1;
            if remaining[p] == 0 {
                outs[p] = None;
            }
        }
    }
    Ok(params)
}

/// A fully quantized network: `direct_i8` plans with edge-chained
/// requantize params, the per-node calibration table, and the graph —
/// everything [`NetRunner::from_graph_quant`] needs.
pub struct QuantNet {
    pub plans: NetPlans,
    pub node_params: Vec<QuantParams>,
    pub graph: NetGraph,
}

impl QuantNet {
    /// Calibrate and quantize a [`Model`] (same deterministic
    /// [`net_kernel`] weights as the f32 planning paths, so f32 and i8
    /// nets are directly comparable).
    pub fn build_model(model: &Model, machine: &Machine, threads: usize) -> Result<QuantNet> {
        let dims = model.validate()?;
        let d = dims[0];
        let input = Tensor::random(&[d.c, d.h, d.w], CALIBRATION_SEED);
        let params = calibrate_graph(&model.graph, &model.shapes, machine, threads, &input)?;
        Self::with_node_params(&model.name, &model.graph, &model.shapes, machine, threads, params)
    }

    /// Calibrate and quantize a [`Model`] against a fusion annotation:
    /// every fused conv gets its epilogue baked into the requantize step
    /// ([`DirectI8Plan::with_params_fused`]) with the **chain tail
    /// edge's** calibrated output params — the single-rounding integer
    /// pipeline the paper's zero-overhead accounting wants. Calibration
    /// itself always runs the unfused f32 reference (fusion is a
    /// scheduling choice, not a semantics change, so the tail ranges
    /// are identical).
    pub fn build_model_fused(
        model: &Model,
        fused: &FusedNet,
        machine: &Machine,
        threads: usize,
    ) -> Result<QuantNet> {
        let dims = model.validate()?;
        let d = dims[0];
        let input = Tensor::random(&[d.c, d.h, d.w], CALIBRATION_SEED);
        let params = calibrate_graph(&model.graph, &model.shapes, machine, threads, &input)?;
        Self::quantize(
            &model.name,
            &model.graph,
            &model.shapes,
            machine,
            threads,
            params,
            Some(fused),
        )
    }

    /// Calibrate and quantize a built-in net by name (every net with a
    /// builder program: `alexnet`, `googlenet`, `vgg16`, `resnet_micro`,
    /// `mobilenet_micro`).
    pub fn build(net: &str, machine: &Machine, threads: usize) -> Result<QuantNet> {
        let model = crate::nets::model_by_name(net).ok_or_else(|| {
            Error::Parse(format!(
                "unknown net '{net}' (alexnet|googlenet|vgg16|resnet_micro|mobilenet_micro)"
            ))
        })?;
        Self::build_model(&model, machine, threads)
    }

    /// Quantize a graph with **prescribed** per-node activation params
    /// (one per graph node, node order). This is how the golden tests
    /// pin exact integer outputs: the independent NumPy reference picks
    /// the params, commits them to the fixture, and both sides run the
    /// identical integer program.
    pub fn with_node_params(
        name: &str,
        graph: &NetGraph,
        shapes: &[crate::conv::ConvShape],
        machine: &Machine,
        threads: usize,
        node_params: Vec<QuantParams>,
    ) -> Result<QuantNet> {
        Self::quantize(name, graph, shapes, machine, threads, node_params, None)
    }

    /// Prescribed-params quantization against a fusion annotation — the
    /// fused twin of [`QuantNet::with_node_params`] (the fused golden
    /// fixtures pin exact integers through this entry).
    pub fn with_node_params_fused(
        name: &str,
        graph: &NetGraph,
        shapes: &[crate::conv::ConvShape],
        machine: &Machine,
        threads: usize,
        node_params: Vec<QuantParams>,
        fused: &FusedNet,
    ) -> Result<QuantNet> {
        Self::quantize(name, graph, shapes, machine, threads, node_params, Some(fused))
    }

    fn quantize(
        name: &str,
        graph: &NetGraph,
        shapes: &[crate::conv::ConvShape],
        machine: &Machine,
        threads: usize,
        node_params: Vec<QuantParams>,
        fused: Option<&FusedNet>,
    ) -> Result<QuantNet> {
        graph.validate(shapes)?;
        if node_params.len() != graph.len() {
            return Err(Error::Shape(format!(
                "quantizing '{name}': {} node params for {} graph nodes",
                node_params.len(),
                graph.len()
            )));
        }
        if let Some(f) = fused {
            if f.roles.len() != graph.len() || f.fusions.len() != shapes.len() {
                return Err(Error::Shape(format!(
                    "quantizing '{name}': fusion annotation covers {} nodes / {} layers, \
                     graph has {} / {}",
                    f.roles.len(),
                    f.fusions.len(),
                    graph.len(),
                    shapes.len()
                )));
            }
        }
        let mut planned: Vec<Option<PlannedLayer>> = (0..shapes.len()).map(|_| None).collect();
        for (i, node) in graph.nodes.iter().enumerate() {
            let GraphOp::Conv { layer } = &node.op else {
                continue;
            };
            let layer = *layer;
            let s = &shapes[layer];
            let kernel = net_kernel(layer, s);
            // A fused conv requantizes straight to its chain tail's
            // calibrated edge; its epilogue (BN scale/shift, residual
            // ratio, ReLU floor / clamp ceiling) folds into that one
            // rounding. Unfused convs keep their own edge.
            let (out_node, fusion) = match fused {
                Some(f) => (f.tail[i], f.fusions[layer].clone()),
                None => (i, LayerFusion::default()),
            };
            let in_qp = node_params[node.preds[0]];
            let out_qp = node_params[out_node];
            let plan = if fusion.is_none() {
                DirectI8Plan::with_params(s, &kernel, machine, threads, in_qp, out_qp)?
            } else {
                let ep = fusion.epilogue(s.c_o);
                let res_qp = fusion.res_node.map(|r| node_params[r]);
                DirectI8Plan::with_params_fused(
                    s, &kernel, machine, threads, in_qp, out_qp, &ep, res_qp,
                )?
            };
            planned[layer] = Some(PlannedLayer {
                layer: Layer { net: name.to_string(), name: node.name.clone(), shape: s.clone() },
                backend: "direct_i8",
                threads: threads.max(1),
                plan: Box::new(plan),
            });
        }
        let layers = planned
            .into_iter()
            .map(|p| p.expect("graph validation guarantees every layer is used"))
            .collect();
        Ok(QuantNet {
            plans: NetPlans { net: name.to_string(), layers },
            node_params,
            graph: graph.clone(),
        })
    }

    /// Compile to the i8 byte-arena executor.
    pub fn runner(self, lanes: usize) -> Result<NetRunner> {
        NetRunner::from_graph_quant(self.plans, self.graph, lanes, &self.node_params)
    }

    /// Compile to the i8 byte-arena executor under the same fusion
    /// annotation the net was quantized with.
    pub fn runner_fused(self, lanes: usize, fused: &FusedNet) -> Result<NetRunner> {
        NetRunner::from_graph_quant_fused(self.plans, self.graph, lanes, &self.node_params, fused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::engine::ConvPlan;

    #[test]
    fn calibration_covers_every_node_and_frees_as_it_goes() {
        let model = crate::nets::builder::resnet_micro();
        let input = Tensor::random(&[3, 32, 32], CALIBRATION_SEED);
        let params =
            calibrate_graph(&model.graph, &model.shapes, &haswell(), 1, &input).unwrap();
        assert_eq!(params.len(), model.graph.len());
        for (p, n) in params.iter().zip(&model.graph.nodes) {
            assert!(p.scale > 0.0, "{}: degenerate scale", n.name);
            assert!((-127..=127).contains(&p.zero_point), "{}: zp out of budget", n.name);
        }
    }

    #[test]
    fn quant_net_builds_with_chained_edges() {
        let model = crate::nets::builder::resnet_micro();
        let q = QuantNet::build("resnet_micro", &haswell(), 1).unwrap();
        assert_eq!(q.plans.layers.len(), 6);
        assert!(q.plans.layers.iter().all(|l| l.backend == "direct_i8"));
        // Edge chaining: conv1 reads the `relu0` edge, so its input
        // params are that node's calibration (conv0's producer chain is
        // conv0 -> bn0 -> relu0 -> conv1 in resnet_micro v2).
        let relu0 = model.graph.nodes.iter().position(|n| n.name == "relu0").unwrap();
        let p1 = q.plans.layers[1].plan.as_quantized().unwrap().input_qparams();
        assert_eq!(
            p1, q.node_params[relu0],
            "requantize params must chain producer edge -> consumer"
        );
        let runner = q.runner(1).unwrap();
        assert_eq!(runner.dtype(), crate::quant::DType::I8);
        assert_eq!(runner.overhead_bytes(), 0);
    }

    /// Fused i8 pipeline end-to-end: quantize against the fusion
    /// annotation, compile the fused schedule, and keep the output
    /// within a few output quanta of the f32 fused runner. (Fused i8 is
    /// deliberately NOT bitwise-comparable to unfused i8 — folding the
    /// epilogue into the conv's requantize replaces a chain of
    /// roundings with one; the exact integers are pinned by the golden
    /// fixtures against an independent NumPy reference instead.)
    #[test]
    fn fused_quant_net_tracks_f32_within_quanta() {
        let model = crate::nets::builder::resnet_micro();
        let fused = crate::nets::fuse(&model).unwrap();
        let q = QuantNet::build_model_fused(&model, &fused, &haswell(), 1).unwrap();
        let runner = q.runner_fused(1, &fused).unwrap();
        assert_eq!(runner.dtype(), crate::quant::DType::I8);
        assert_eq!(runner.overhead_bytes(), 0, "fused i8 net must stay zero-overhead");

        let f32_plans =
            crate::nets::NetPlans::build_model(&model, "direct", &haswell(), 1).unwrap();
        let f32_runner =
            NetRunner::from_graph_fused(f32_plans, model.graph.clone(), 1, &fused).unwrap();

        let input = Tensor::random(&[3, 32, 32], CALIBRATION_SEED);
        let got = runner.forward(&input).unwrap();
        let want = f32_runner.forward(&input).unwrap();
        assert_eq!(got.shape(), want.shape());
        let sum = |t: &Tensor| t.data().iter().map(|v| v.abs() as f64).sum::<f64>();
        let (a, b) = (sum(&got), sum(&want));
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(
            rel <= 5e-2,
            "fused i8 abs_sum {a:.4e} vs f32 {b:.4e} (rel {rel:.3e} > 5e-2)"
        );
    }

    #[test]
    fn unknown_net_and_bad_param_counts_are_rejected() {
        assert!(QuantNet::build("resnet", &haswell(), 1).is_err());
        let model = crate::nets::builder::resnet_micro();
        assert!(QuantNet::with_node_params(
            "t",
            &model.graph,
            &model.shapes,
            &haswell(),
            1,
            vec![QuantParams::IDENT; 3],
        )
        .is_err());
    }
}

//! The int8 blocked direct convolution core.
//!
//! Same §4 layouts and `jb / l / k0` traversal as the f32 Algorithm 3
//! ([`crate::conv::direct`]): input `[C_i/c_ib][H_i][W_i][c_ib]`,
//! kernel `[C_o/c_ob][C_i/c_ib][H_f][W_f][C_ib][C_ob]`, output
//! `[C_o/c_ob][H_o][W_o][c_ob]` — all i8, all pure permutations, zero
//! workspace. One deliberate deviation from the f32 loop nest: the
//! `C_i,b` cache-block loop sits *inside* the register tile instead of
//! outside it, because i32 partial sums cannot round-trip through the
//! i8 output the way f32 partials round-trip through the f32 output —
//! the full input-channel reduction must finish in the i32 accumulator
//! before the (lossy) requantize epilogue runs.
//!
//! The core is generic over [`QuantIo`], so the same integer arithmetic
//! serves two element types:
//!
//! * `i8`/`i8` — the byte-arena hot path ([`super::QuantExecute`]);
//! * `f32`/`f32` — the engine-API boundary ([`super::DirectI8Plan`]'s
//!   `execute_into`), which quantizes each input element on the fly and
//!   dequantizes outputs on store. No staging buffer exists in either
//!   direction, which is what lets the `direct_i8` backend report
//!   `workspace_bytes() == 0` honestly; both paths produce bit-identical
//!   quantized values because they share every integer op.
//!
//! Border taps are skipped exactly like the f32 kernel (a skipped tap
//! contributes `(zp - zp) * w == 0`, the quantized image of zero
//! padding). Accumulator bound: `|x_q - zp| <= 254`, `|w_q| <= 127`, so
//! a tap term is at most `32258` and i32 holds `> 66k` input-channel
//! taps — an order of magnitude beyond the largest benchmark layer
//! (VGG 512·3·3 = 4608).

use super::params::{dequantize, quantize, requantize, QuantParams};
use crate::conv::microkernel::MAX_WOB;
use crate::conv::{BlockParams, ConvShape};
use crate::{Error, Result};

/// Element type the quantized core reads and writes: either real i8
/// values or f32 values converted at the load/store (see module docs).
pub(crate) trait QuantIo: Copy + Send + Sync {
    /// Load as a zero-centered quantized value (`q - zero_point`).
    fn to_centered(self, qp: &QuantParams) -> i32;
    /// Store a freshly requantized i8 value.
    fn from_q(q: i8, qp: &QuantParams) -> Self;
}

impl QuantIo for i8 {
    #[inline(always)]
    fn to_centered(self, qp: &QuantParams) -> i32 {
        self as i32 - qp.zero_point
    }
    #[inline(always)]
    fn from_q(q: i8, _qp: &QuantParams) -> i8 {
        q
    }
}

impl QuantIo for f32 {
    #[inline(always)]
    fn to_centered(self, qp: &QuantParams) -> i32 {
        quantize(self, qp) as i32 - qp.zero_point
    }
    #[inline(always)]
    fn from_q(q: i8, qp: &QuantParams) -> f32 {
        dequantize(q, qp)
    }
}

/// Geometry + params of one quantized layer execution.
pub(crate) struct QuantGeom<'a> {
    pub shape: &'a ConvShape,
    pub bp: BlockParams,
    pub in_qp: QuantParams,
    pub out_qp: QuantParams,
    /// Per-output-channel requantize multipliers (`len == c_o`).
    pub mult: &'a [f64],
}

/// Allocation-free i8 direct convolution over blocked i8 operands (the
/// public slice core; [`super::DirectI8Plan`] is the planned entry).
#[allow(clippy::too_many_arguments)] // mirrors the f32 core's signature plus quant params
pub fn conv_direct_blocked_i8_into(
    inp: &[i8],
    ker: &[i8],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    in_qp: QuantParams,
    out_qp: QuantParams,
    mult: &[f64],
    out: &mut [i8],
) -> Result<()> {
    let g = QuantGeom { shape, bp, in_qp, out_qp, mult };
    conv_quant_core(inp, ker, &g, threads, out)
}

/// The generic core shared by the i8 and f32-boundary paths.
pub(crate) fn conv_quant_core<T: QuantIo>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
) -> Result<()> {
    let (shape, bp) = (g.shape, g.bp);
    shape.validate()?;
    bp.validate_for(shape)?;
    if bp.w_ob == 0 || bp.w_ob > MAX_WOB {
        return Err(Error::Shape(format!("w_ob={} out of range 1..={}", bp.w_ob, MAX_WOB)));
    }
    let n_img = shape.c_i * shape.h_i * shape.w_i;
    if inp.len() != n_img {
        return Err(Error::Shape(format!(
            "quant blocked input has {} elements, expected {n_img}",
            inp.len()
        )));
    }
    let n_ker = shape.c_o * shape.c_i * shape.h_f * shape.w_f;
    if ker.len() != n_ker {
        return Err(Error::Shape(format!(
            "quant blocked kernel has {} elements, expected {n_ker}",
            ker.len()
        )));
    }
    let n_out = shape.c_o * shape.h_o() * shape.w_o();
    if out.len() != n_out {
        return Err(Error::Shape(format!(
            "quant blocked output has {} elements, expected {n_out}",
            out.len()
        )));
    }
    if g.mult.len() != shape.c_o {
        return Err(Error::Shape(format!(
            "requant multipliers: {} entries for C_o={}",
            g.mult.len(),
            shape.c_o
        )));
    }
    let threads = threads.max(1);
    match bp.c_ob {
        1 => run_q::<T, 1>(inp, ker, g, threads, out),
        2 => run_q::<T, 2>(inp, ker, g, threads, out),
        4 => run_q::<T, 4>(inp, ker, g, threads, out),
        8 => run_q::<T, 8>(inp, ker, g, threads, out),
        16 => run_q::<T, 16>(inp, ker, g, threads, out),
        32 => run_q::<T, 32>(inp, ker, g, threads, out),
        other => Err(Error::Shape(format!(
            "unsupported c_ob={other} (supported: 1,2,4,8,16,32)"
        ))),
    }
}

fn run_q<T: QuantIo, const COB: usize>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
) -> Result<()> {
    let (h_o, w_o) = (g.shape.h_o(), g.shape.w_o());
    let n_ob = g.shape.c_o / COB;
    let blk_len = h_o * w_o * COB;
    if threads <= 1 || n_ob <= 1 {
        for (jb, out_blk) in out.chunks_mut(blk_len).enumerate() {
            conv_block_q::<T, COB>(inp, ker, g, jb, out_blk);
        }
    } else {
        // §3.2 thread partition over C_o blocks, as in the f32 kernel.
        let mut per_thread: Vec<Vec<(usize, &mut [T])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (idx, b) in out.chunks_mut(blk_len).enumerate() {
            per_thread[idx % threads].push((idx, b));
        }
        std::thread::scope(|scope| {
            for chunk in per_thread {
                scope.spawn(move || {
                    for (jb, out_blk) in chunk {
                        conv_block_q::<T, COB>(inp, ker, g, jb, out_blk);
                    }
                });
            }
        });
    }
    Ok(())
}

/// One output-channel block: full `C_i` reduction in i32 per register
/// tile, then the fused requantize epilogue.
fn conv_block_q<T: QuantIo, const COB: usize>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    jb: usize,
    out_blk: &mut [T],
) {
    let s = g.shape;
    let (h_o, w_o) = (s.h_o(), s.w_o());
    let (h_i, w_i) = (s.h_i, s.w_i);
    let (h_f, w_f) = (s.h_f, s.w_f);
    let (stride, pad) = (s.stride, s.pad);
    let c_ib = g.bp.c_ib;
    let n_ib = s.c_i / c_ib;
    let ker_ib = h_f * w_f * c_ib * COB;
    let ker_jb = n_ib * ker_ib;
    let islab_len = h_i * w_i * c_ib;
    let row_stride = w_i * c_ib;
    let tw_max = g.bp.w_ob.min(MAX_WOB);

    for l in 0..h_o {
        let mut k0 = 0usize;
        while k0 < w_o {
            let tw = tw_max.min(w_o - k0);
            let mut acc = [[0i32; COB]; MAX_WOB];
            for ib in 0..n_ib {
                let kslab = &ker[jb * ker_jb + ib * ker_ib..][..ker_ib];
                let islab = &inp[ib * islab_len..][..islab_len];
                for n in 0..h_f {
                    let iy = (l * stride + n) as isize - pad as isize;
                    if iy < 0 || iy >= h_i as isize {
                        continue; // whole kernel row outside the image
                    }
                    let row = &islab[iy as usize * row_stride..][..row_stride];
                    for m in 0..w_f {
                        let kptr = &kslab[(n * w_f + m) * c_ib * COB..][..c_ib * COB];
                        let x0 = (k0 * stride + m) as isize - pad as isize;
                        let x_last = x0 + ((tw - 1) * stride) as isize;
                        if x0 >= 0 && x_last < w_i as isize {
                            // Interior fast path: every tile column valid.
                            let base = x0 as usize * c_ib;
                            for ii in 0..c_ib {
                                let w = &kptr[ii * COB..][..COB];
                                for (kk, a) in acc.iter_mut().enumerate().take(tw) {
                                    let xv = row[base + kk * stride * c_ib + ii]
                                        .to_centered(&g.in_qp);
                                    for j in 0..COB {
                                        a[j] += xv * w[j] as i32;
                                    }
                                }
                            }
                        } else {
                            // Border tap: guard each column (skip == 0
                            // contribution, the quantized zero padding).
                            for (kk, a) in acc.iter_mut().enumerate().take(tw) {
                                let x = x0 + (kk * stride) as isize;
                                if x < 0 || x >= w_i as isize {
                                    continue;
                                }
                                let base = x as usize * c_ib;
                                for ii in 0..c_ib {
                                    let w = &kptr[ii * COB..][..COB];
                                    let xv = row[base + ii].to_centered(&g.in_qp);
                                    for j in 0..COB {
                                        a[j] += xv * w[j] as i32;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Fused requantize epilogue: i32 -> i8 (or dequantized f32).
            let tile = &mut out_blk[(l * w_o + k0) * COB..][..tw * COB];
            let mults = &g.mult[jb * COB..][..COB];
            for kk in 0..tw {
                for j in 0..COB {
                    let q = requantize(acc[kk][j], mults[j], g.out_qp.zero_point);
                    tile[kk * COB + j] = T::from_q(q, &g.out_qp);
                }
            }
            k0 += tw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::params::{per_channel_weight_scales, requant_multiplier};
    use crate::tensor::Tensor;

    /// Scalar NCHW oracle performing the documented integer arithmetic
    /// directly (no blocking) — the in-crate cross-check; the NumPy
    /// reference in `python/golden_gen.py` pins the same contract
    /// externally.
    #[allow(clippy::too_many_arguments)]
    fn naive_q8(
        x_q: &[i8],
        w_q: &[i8],
        s: &ConvShape,
        in_qp: QuantParams,
        out_qp: QuantParams,
        mult: &[f64],
    ) -> Vec<i8> {
        let (h_o, w_o) = (s.h_o(), s.w_o());
        let mut out = vec![0i8; s.c_o * h_o * w_o];
        for o in 0..s.c_o {
            for y in 0..h_o {
                for x in 0..w_o {
                    let mut acc = 0i32;
                    for c in 0..s.c_i {
                        for n in 0..s.h_f {
                            let iy = (y * s.stride + n) as isize - s.pad as isize;
                            if iy < 0 || iy >= s.h_i as isize {
                                continue;
                            }
                            for m in 0..s.w_f {
                                let ix = (x * s.stride + m) as isize - s.pad as isize;
                                if ix < 0 || ix >= s.w_i as isize {
                                    continue;
                                }
                                let xv = x_q[(c * s.h_i + iy as usize) * s.w_i + ix as usize]
                                    as i32
                                    - in_qp.zero_point;
                                let wv = w_q[((o * s.c_i + c) * s.h_f + n) * s.w_f + m] as i32;
                                acc += xv * wv;
                            }
                        }
                    }
                    out[(o * h_o + y) * w_o + x] =
                        requantize(acc, mult[o], out_qp.zero_point);
                }
            }
        }
        out
    }

    fn quantize_nchw(t: &Tensor, qp: &QuantParams) -> Vec<i8> {
        t.data().iter().map(|&v| quantize(v, qp)).collect()
    }

    fn pack_i8_io(src: &[i8], c: usize, h: usize, w: usize, c_b: usize) -> Vec<i8> {
        let mut dst = vec![0i8; src.len()];
        crate::layout::pack_io_slice_t(src, c, h, w, c_b, &mut dst).unwrap();
        dst
    }

    fn unpack_i8_io(src: &[i8], c: usize, h: usize, w: usize, c_b: usize) -> Vec<i8> {
        let mut dst = vec![0i8; src.len()];
        crate::layout::unpack_io_slice_t(src, c, h, w, c_b, &mut dst).unwrap();
        dst
    }

    fn pack_i8_kernel(w_q: &[i8], s: &ConvShape, c_ob: usize, c_ib: usize) -> Vec<i8> {
        let mut out = vec![0i8; w_q.len()];
        for o in 0..s.c_o {
            for i in 0..s.c_i {
                for n in 0..s.h_f {
                    for m in 0..s.w_f {
                        let d = crate::layout::blocked_kernel_index(
                            o, i, n, m, s.c_i, s.h_f, s.w_f, c_ib, c_ob,
                        );
                        out[d] = w_q[((o * s.c_i + i) * s.h_f + n) * s.w_f + m];
                    }
                }
            }
        }
        out
    }

    fn check(s: &ConvShape, bp: BlockParams, threads: usize, seed: u64) {
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, s.c_i, s.h_f, s.w_f], seed + 1);
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let out_qp = QuantParams::from_range(-20.0, 20.0);
        let w_scales = per_channel_weight_scales(&kernel);
        let w_q: Vec<i8> = kernel
            .data()
            .chunks(s.c_i * s.h_f * s.w_f)
            .zip(&w_scales)
            .flat_map(|(ch, &sc)| {
                ch.iter()
                    .map(|&v| quantize(v, &QuantParams { scale: sc, zero_point: 0 }))
                    .collect::<Vec<i8>>()
            })
            .collect();
        let mult: Vec<f64> = w_scales
            .iter()
            .map(|&sw| requant_multiplier(in_qp.scale, sw, out_qp.scale))
            .collect();

        let x_q = quantize_nchw(&input, &in_qp);
        let want = naive_q8(&x_q, &w_q, s, in_qp, out_qp, &mult);

        let bi = pack_i8_io(&x_q, s.c_i, s.h_i, s.w_i, bp.c_ib);
        let bk = pack_i8_kernel(&w_q, s, bp.c_ob, bp.c_ib);
        let mut bo = vec![0i8; s.c_o * s.h_o() * s.w_o()];
        conv_direct_blocked_i8_into(&bi, &bk, s, bp, threads, in_qp, out_qp, &mult, &mut bo)
            .unwrap();
        let got = unpack_i8_io(&bo, s.c_o, s.h_o(), s.w_o(), bp.c_ob);
        assert_eq!(got, want, "integer mismatch on {s:?} bp={bp:?} threads={threads}");
    }

    #[test]
    fn blocked_i8_matches_scalar_oracle_exactly() {
        check(&ConvShape::new(8, 10, 10, 16, 3, 3, 1, 0), BlockParams::new(8, 4, 4), 1, 21);
        check(&ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1), BlockParams::new(16, 3, 8), 1, 22);
        check(&ConvShape::new(4, 7, 7, 8, 5, 5, 1, 2), BlockParams::new(8, 4, 4), 1, 23);
        check(&ConvShape::new(8, 14, 14, 8, 3, 3, 2, 1), BlockParams::new(8, 2, 8), 1, 25);
        check(&ConvShape::new(16, 7, 7, 32, 1, 1, 1, 0), BlockParams::new(16, 4, 8), 1, 40);
    }

    #[test]
    fn threaded_i8_is_bitwise_identical() {
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 4, 26);
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 7, 27);
    }

    #[test]
    fn all_cob_variants_exact() {
        for &cob in &[1usize, 2, 4, 8, 16, 32] {
            let s = ConvShape::new(4, 8, 8, 32, 3, 3, 1, 1);
            check(&s, BlockParams::new(cob, 4, 2), 1, 31 + cob as u64);
        }
    }

    #[test]
    fn rejects_bad_buffers_and_params() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let bp = BlockParams::new(8, 4, 4);
        let qp = QuantParams::IDENT;
        let mut out = vec![0i8; s.c_o * s.h_o() * s.w_o()];
        let inp = vec![0i8; s.c_i * s.h_i * s.w_i];
        let ker = vec![0i8; s.c_o * s.c_i * 9];
        // wrong multiplier count
        assert!(conv_direct_blocked_i8_into(&inp, &ker, &s, bp, 1, qp, qp, &[1.0], &mut out)
            .is_err());
        let mult = vec![1.0f64; s.c_o];
        // wrong input length
        assert!(conv_direct_blocked_i8_into(&inp[1..], &ker, &s, bp, 1, qp, qp, &mult, &mut out)
            .is_err());
        // non-dividing c_ib
        assert!(conv_direct_blocked_i8_into(
            &inp,
            &ker,
            &s,
            BlockParams::new(8, 4, 3),
            1,
            qp,
            qp,
            &mult,
            &mut out
        )
        .is_err());
    }
}

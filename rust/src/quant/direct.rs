//! The int8 blocked direct convolution core.
//!
//! Same §4 layouts and `jb / l / k0` traversal as the f32 Algorithm 3
//! ([`crate::conv::direct`]): input `[C_i/c_ib][H_i][W_i][c_ib]`,
//! kernel `[C_o/c_ob][C_i/c_ib][H_f][W_f][C_ib][C_ob]`, output
//! `[C_o/c_ob][H_o][W_o][c_ob]` — all i8, all pure permutations, zero
//! workspace. One deliberate deviation from the f32 loop nest: the
//! `C_i,b` cache-block loop sits *inside* the register tile instead of
//! outside it, because i32 partial sums cannot round-trip through the
//! i8 output the way f32 partials round-trip through the f32 output —
//! the full input-channel reduction must finish in the i32 accumulator
//! before the (lossy) requantize epilogue runs.
//!
//! The core is generic over [`QuantIo`], so the same integer arithmetic
//! serves two element types:
//!
//! * `i8`/`i8` — the byte-arena hot path ([`super::QuantExecute`]);
//! * `f32`/`f32` — the engine-API boundary ([`super::DirectI8Plan`]'s
//!   `execute_into`), which quantizes each input element on the fly and
//!   dequantizes outputs on store. No staging buffer exists in either
//!   direction, which is what lets the `direct_i8` backend report
//!   `workspace_bytes() == 0` honestly; both paths produce bit-identical
//!   quantized values because they share every integer op.
//!
//! Border taps are skipped exactly like the f32 kernel (a skipped tap
//! contributes `(zp - zp) * w == 0`, the quantized image of zero
//! padding). Accumulator bound: `|x_q - zp| <= 254`, `|w_q| <= 127`, so
//! a tap term is at most `32258` and i32 holds `> 66k` input-channel
//! taps — an order of magnitude beyond the largest benchmark layer
//! (VGG 512·3·3 = 4608).

use super::params::{
    dequantize, quantize, round_half_away, QuantParams, Q_MAX, Q_MIN,
};
use crate::conv::microkernel::MAX_WOB;
use crate::conv::{BlockParams, ConvShape};
use crate::{Error, Result};

/// Element type the quantized core reads and writes: either real i8
/// values or f32 values converted at the load/store (see module docs).
pub(crate) trait QuantIo: Copy + Send + Sync {
    /// Load as a zero-centered quantized value (`q - zero_point`).
    fn to_centered(self, qp: &QuantParams) -> i32;
    /// Store a freshly requantized i8 value.
    fn from_q(q: i8, qp: &QuantParams) -> Self;
}

impl QuantIo for i8 {
    #[inline(always)]
    fn to_centered(self, qp: &QuantParams) -> i32 {
        self as i32 - qp.zero_point
    }
    #[inline(always)]
    fn from_q(q: i8, _qp: &QuantParams) -> i8 {
        q
    }
}

impl QuantIo for f32 {
    #[inline(always)]
    fn to_centered(self, qp: &QuantParams) -> i32 {
        quantize(self, qp) as i32 - qp.zero_point
    }
    #[inline(always)]
    fn from_q(q: i8, qp: &QuantParams) -> f32 {
        dequantize(q, qp)
    }
}

/// Geometry + params of one quantized layer execution.
///
/// The fused epilogue lives **inside** the requantize step: the conv's
/// real-valued tail `y = (acc·s_in·s_w_j)·scale_j + shift_j [+ res]`,
/// followed by ReLU/clamp, collapses in the output quant domain to
///
/// ```text
/// q = clamp(round(acc·mult_j + off_j [+ centered(res)·res_ratio]) + zp_out,
///           lo, hi)
/// ```
///
/// with `mult_j` pre-folded to `s_in·s_w_j·scale_j/s_out`,
/// `off_j = shift_j/s_out`, `lo = zp_out` when ReLU (real 0 maps to the
/// zero point), `hi = round(c/s_out)+zp_out` for a clamp — a **single**
/// rounding, bit-exactly mirrored by the NumPy reference. With no
/// epilogue every field is inert and the arithmetic reduces exactly to
/// the classic `requantize(acc, m, zp)`.
pub(crate) struct QuantGeom<'a> {
    pub shape: &'a ConvShape,
    pub bp: BlockParams,
    pub in_qp: QuantParams,
    pub out_qp: QuantParams,
    /// Per-output-channel requantize multipliers (`len == c_o`), with
    /// any batch-norm scale already folded in.
    pub mult: &'a [f64],
    /// Per-channel pre-rounding offsets `shift_j/s_out` (empty = none).
    pub off: &'a [f64],
    /// Fused residual operand: its quant params + `s_res/s_out` ratio.
    pub res: Option<(QuantParams, f64)>,
    /// Clamp below at `zp_out` after requantize (quantized ReLU).
    pub relu: bool,
    /// Quantized-domain upper bound (`round(clamp/s_out) + zp_out`).
    pub clamp_q: Option<i32>,
}

impl<'a> QuantGeom<'a> {
    /// Geometry with no fused epilogue (the classic requantize tail).
    pub fn plain(
        shape: &'a ConvShape,
        bp: BlockParams,
        in_qp: QuantParams,
        out_qp: QuantParams,
        mult: &'a [f64],
    ) -> QuantGeom<'a> {
        QuantGeom { shape, bp, in_qp, out_qp, mult, off: &[], res: None, relu: false, clamp_q: None }
    }

    /// Quantized-domain clamp bounds of the fused activation.
    fn bounds(&self) -> (i32, i32) {
        let lo = if self.relu { self.out_qp.zero_point.max(Q_MIN) } else { Q_MIN };
        let hi = self.clamp_q.map_or(Q_MAX, |c| c.clamp(lo, Q_MAX));
        (lo, hi)
    }
}

/// Allocation-free i8 direct convolution over blocked i8 operands (the
/// public slice core; [`super::DirectI8Plan`] is the planned entry).
#[allow(clippy::too_many_arguments)] // mirrors the f32 core's signature plus quant params
pub fn conv_direct_blocked_i8_into(
    inp: &[i8],
    ker: &[i8],
    shape: &ConvShape,
    bp: BlockParams,
    threads: usize,
    in_qp: QuantParams,
    out_qp: QuantParams,
    mult: &[f64],
    out: &mut [i8],
) -> Result<()> {
    let g = QuantGeom::plain(shape, bp, in_qp, out_qp, mult);
    conv_quant_core(inp, ker, &g, threads, out, None)
}

/// The generic core shared by the i8 and f32-boundary paths. `res` is
/// the fused residual operand (required iff `g.res` is set), in the
/// output's blocked layout and `g`'s element type.
pub(crate) fn conv_quant_core<T: QuantIo>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
    res: Option<&[T]>,
) -> Result<()> {
    let (shape, bp) = (g.shape, g.bp);
    shape.validate()?;
    bp.validate_for(shape)?;
    if bp.w_ob == 0 || bp.w_ob > MAX_WOB {
        return Err(Error::Shape(format!("w_ob={} out of range 1..={}", bp.w_ob, MAX_WOB)));
    }
    let n_img = shape.c_i * shape.h_i * shape.w_i;
    if inp.len() != n_img {
        return Err(Error::Shape(format!(
            "quant blocked input has {} elements, expected {n_img}",
            inp.len()
        )));
    }
    let n_ker = shape.c_o * shape.c_i_per_group() * shape.h_f * shape.w_f;
    if ker.len() != n_ker {
        return Err(Error::Shape(format!(
            "quant blocked kernel has {} elements, expected {n_ker}",
            ker.len()
        )));
    }
    let n_out = shape.c_o * shape.h_o() * shape.w_o();
    if out.len() != n_out {
        return Err(Error::Shape(format!(
            "quant blocked output has {} elements, expected {n_out}",
            out.len()
        )));
    }
    if g.mult.len() != shape.c_o {
        return Err(Error::Shape(format!(
            "requant multipliers: {} entries for C_o={}",
            g.mult.len(),
            shape.c_o
        )));
    }
    if !g.off.is_empty() && g.off.len() != shape.c_o {
        return Err(Error::Shape(format!(
            "requant offsets: {} entries for C_o={}",
            g.off.len(),
            shape.c_o
        )));
    }
    if g.res.is_some() != res.is_some() {
        return Err(Error::Shape("fused residual operand mismatch".into()));
    }
    if let Some(r) = res {
        if r.len() != n_out {
            return Err(Error::Shape(format!(
                "fused residual has {} elements, expected {n_out}",
                r.len()
            )));
        }
    }
    let threads = threads.max(1);
    if shape.is_depthwise() {
        return dispatch_dw_q(inp, ker, g, threads, out, res);
    }
    if shape.groups == 1 {
        return dispatch_q(inp, ker, g, threads, out, res);
    }
    // Grouped: block-aligned contiguous slices per group, exactly like
    // the f32 core.
    let (c_ipg, c_opg) = (shape.c_i_per_group(), shape.c_o_per_group());
    let gs = ConvShape { c_i: c_ipg, c_o: c_opg, groups: 1, ..shape.clone() };
    let (in_len, k_len) = (c_ipg * shape.h_i * shape.w_i, c_opg * c_ipg * shape.h_f * shape.w_f);
    let out_len = c_opg * shape.h_o() * shape.w_o();
    for grp in 0..shape.groups {
        let g2 = QuantGeom {
            shape: &gs,
            bp: g.bp,
            in_qp: g.in_qp,
            out_qp: g.out_qp,
            mult: &g.mult[grp * c_opg..][..c_opg],
            off: if g.off.is_empty() { &[] } else { &g.off[grp * c_opg..][..c_opg] },
            res: g.res,
            relu: g.relu,
            clamp_q: g.clamp_q,
        };
        dispatch_q(
            &inp[grp * in_len..][..in_len],
            &ker[grp * k_len..][..k_len],
            &g2,
            threads,
            &mut out[grp * out_len..][..out_len],
            res.map(|r| &r[grp * out_len..][..out_len]),
        )?;
    }
    Ok(())
}

fn dispatch_q<T: QuantIo>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
    res: Option<&[T]>,
) -> Result<()> {
    match g.bp.c_ob {
        1 => run_q::<T, 1>(inp, ker, g, threads, out, res),
        2 => run_q::<T, 2>(inp, ker, g, threads, out, res),
        4 => run_q::<T, 4>(inp, ker, g, threads, out, res),
        8 => run_q::<T, 8>(inp, ker, g, threads, out, res),
        16 => run_q::<T, 16>(inp, ker, g, threads, out, res),
        32 => run_q::<T, 32>(inp, ker, g, threads, out, res),
        other => Err(Error::Shape(format!(
            "unsupported c_ob={other} (supported: 1,2,4,8,16,32)"
        ))),
    }
}

fn run_q<T: QuantIo, const COB: usize>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
    res: Option<&[T]>,
) -> Result<()> {
    let (h_o, w_o) = (g.shape.h_o(), g.shape.w_o());
    let n_ob = g.shape.c_o / COB;
    let blk_len = h_o * w_o * COB;
    if threads <= 1 || n_ob <= 1 {
        for (jb, out_blk) in out.chunks_mut(blk_len).enumerate() {
            let res_blk = res.map(|r| &r[jb * blk_len..][..blk_len]);
            conv_block_q::<T, COB>(inp, ker, g, jb, out_blk, res_blk);
        }
    } else {
        // §3.2 thread partition over C_o blocks, as in the f32 kernel.
        let mut per_thread: Vec<Vec<(usize, &mut [T])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (idx, b) in out.chunks_mut(blk_len).enumerate() {
            per_thread[idx % threads].push((idx, b));
        }
        std::thread::scope(|scope| {
            for chunk in per_thread {
                scope.spawn(move || {
                    for (jb, out_blk) in chunk {
                        let res_blk = res.map(|r| &r[jb * blk_len..][..blk_len]);
                        conv_block_q::<T, COB>(inp, ker, g, jb, out_blk, res_blk);
                    }
                });
            }
        });
    }
    Ok(())
}

/// The fused requantize epilogue for one accumulator: real-tail folded
/// into a single f64 rounding (see [`QuantGeom`] docs). With no fused
/// epilogue (`off == 0`, no residual, full bounds) this is bit-for-bit
/// the classic `requantize(acc, m, zp)`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn requant_ep(acc: i32, m: f64, off: f64, res_term: f64, zp: i32, lo: i32, hi: i32) -> i8 {
    let q = round_half_away(acc as f64 * m + off + res_term) + zp as f64;
    (q.clamp(lo as f64, hi as f64)) as i8
}

/// Loop bounds of one i8 tile reduction, hoisted once per
/// output-channel block (the SIMD kernels take it whole rather than a
/// ten-argument list).
#[derive(Clone, Copy)]
struct QTile {
    h_f: usize,
    w_f: usize,
    c_ib: usize,
    n_ib: usize,
    h_i: usize,
    w_i: usize,
    stride: usize,
    pad: usize,
    dil: usize,
    ker_jb: usize,
    ker_ib: usize,
    islab_len: usize,
    row_stride: usize,
    /// Output-channel block, output row, first output column of the tile.
    jb: usize,
    l: usize,
    k0: usize,
}

/// Scalar i8 tile reduction — the conformance oracle: the full
/// `(ib, n, m, ii)` i32 accumulation of one register tile. Exact
/// integer arithmetic, so every dispatch variant must (and does) match
/// it bit-for-bit.
fn reduce_tile_q<T: QuantIo, const COB: usize>(
    acc: &mut [[i32; COB]; MAX_WOB],
    inp: &[T],
    ker: &[i8],
    in_qp: &QuantParams,
    t: &QTile,
    tw: usize,
) {
    for ib in 0..t.n_ib {
        let kslab = &ker[t.jb * t.ker_jb + ib * t.ker_ib..][..t.ker_ib];
        let islab = &inp[ib * t.islab_len..][..t.islab_len];
        for n in 0..t.h_f {
            let iy = (t.l * t.stride + n * t.dil) as isize - t.pad as isize;
            if iy < 0 || iy >= t.h_i as isize {
                continue; // whole kernel row outside the image
            }
            let row = &islab[iy as usize * t.row_stride..][..t.row_stride];
            for m in 0..t.w_f {
                let kptr = &kslab[(n * t.w_f + m) * t.c_ib * COB..][..t.c_ib * COB];
                let x0 = (t.k0 * t.stride + m * t.dil) as isize - t.pad as isize;
                let x_last = x0 + ((tw - 1) * t.stride) as isize;
                if x0 >= 0 && x_last < t.w_i as isize {
                    // Interior fast path: every tile column valid.
                    let base = x0 as usize * t.c_ib;
                    for ii in 0..t.c_ib {
                        let w = &kptr[ii * COB..][..COB];
                        for (kk, a) in acc.iter_mut().enumerate().take(tw) {
                            let xv = row[base + kk * t.stride * t.c_ib + ii].to_centered(in_qp);
                            for j in 0..COB {
                                a[j] += xv * w[j] as i32;
                            }
                        }
                    }
                } else {
                    // Border tap: guard each column (skip == 0
                    // contribution, the quantized zero padding).
                    for (kk, a) in acc.iter_mut().enumerate().take(tw) {
                        let x = x0 + (kk * t.stride) as isize;
                        if x < 0 || x >= t.w_i as isize {
                            continue;
                        }
                        let base = x as usize * t.c_ib;
                        for ii in 0..t.c_ib {
                            let w = &kptr[ii * COB..][..COB];
                            let xv = row[base + ii].to_centered(in_qp);
                            for j in 0..COB {
                                a[j] += xv * w[j] as i32;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Runtime-dispatched [`reduce_tile_q`]: the VNNI-shaped AVX2 core
/// (widening i8→i32 weight loads, broadcast `mullo+add` — see
/// `conv::dispatch`) when the host supports it and `COB` fills whole
/// ymm registers, else the scalar oracle. i32 arithmetic is exact, so
/// the variants are bit-identical by construction.
#[inline(always)]
fn reduce_tile_q_auto<T: QuantIo, const COB: usize>(
    acc: &mut [[i32; COB]; MAX_WOB],
    inp: &[T],
    ker: &[i8],
    in_qp: &QuantParams,
    t: &QTile,
    tw: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        use crate::conv::dispatch::{active, SimdLevel};
        if matches!(active(), SimdLevel::Avx2 | SimdLevel::Avx512) && COB % 8 == 0 {
            // SAFETY: avx2 runtime-detected; the flat view is the
            // tile's contiguous MAX_WOB*COB storage.
            unsafe {
                let flat = core::slice::from_raw_parts_mut(
                    acc.as_mut_ptr().cast::<i32>(),
                    MAX_WOB * COB,
                );
                macro_rules! go {
                    ($nv:literal, $tw:literal) => {
                        reduce_tile_q_avx2::<T, $nv, $tw>(flat, inp, ker, in_qp, t)
                    };
                    ($nv:literal) => {
                        match tw {
                            1 => go!($nv, 1),
                            2 => go!($nv, 2),
                            3 => go!($nv, 3),
                            4 => go!($nv, 4),
                            5 => go!($nv, 5),
                            6 => go!($nv, 6),
                            7 => go!($nv, 7),
                            _ => go!($nv, 8),
                        }
                    };
                }
                match COB / 8 {
                    1 => go!(1),
                    2 => go!(2),
                    _ => go!(4),
                }
            }
            return;
        }
    }
    reduce_tile_q::<T, COB>(acc, inp, ker, in_qp, t, tw);
}

/// AVX2 i8 tile reduction over `NV` ymm accumulators per tile row
/// (`COB = 8 * NV`, `TW` live rows): weights sign-extend i8→i32
/// lane-wise (`_mm256_cvtepi8_epi32`), the centered input broadcasts,
/// and `_mm256_mullo_epi32 + _mm256_add_epi32` emulate the dot-product
/// FMA that VNNI would fuse. All-integer, so bit-identical to
/// [`reduce_tile_q`] regardless of order.
///
/// # Safety
/// Caller must have runtime-detected `avx2`; `acc` must hold
/// `MAX_WOB * NV * 8` i32 (row pitch `NV * 8`, first `TW` rows used).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn reduce_tile_q_avx2<T: QuantIo, const NV: usize, const TW: usize>(
    acc: &mut [i32],
    inp: &[T],
    ker: &[i8],
    in_qp: &QuantParams,
    t: &QTile,
) {
    use core::arch::x86_64::*;
    let cob = NV * 8;
    debug_assert!(acc.len() >= TW * cob);
    let mut va = [[_mm256_setzero_si256(); NV]; TW];
    for kk in 0..TW {
        for v in 0..NV {
            va[kk][v] =
                _mm256_loadu_si256(acc.as_ptr().add(kk * cob + v * 8) as *const __m256i);
        }
    }
    for ib in 0..t.n_ib {
        let kslab = &ker[t.jb * t.ker_jb + ib * t.ker_ib..][..t.ker_ib];
        let islab = &inp[ib * t.islab_len..][..t.islab_len];
        for n in 0..t.h_f {
            let iy = (t.l * t.stride + n * t.dil) as isize - t.pad as isize;
            if iy < 0 || iy >= t.h_i as isize {
                continue;
            }
            let row = &islab[iy as usize * t.row_stride..][..t.row_stride];
            for m in 0..t.w_f {
                let kptr = &kslab[(n * t.w_f + m) * t.c_ib * cob..][..t.c_ib * cob];
                let x0 = (t.k0 * t.stride + m * t.dil) as isize - t.pad as isize;
                let x_last = x0 + ((TW - 1) * t.stride) as isize;
                if x0 >= 0 && x_last < t.w_i as isize {
                    let base = x0 as usize * t.c_ib;
                    for ii in 0..t.c_ib {
                        let mut w = [_mm256_setzero_si256(); NV];
                        for v in 0..NV {
                            w[v] = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                                kptr.as_ptr().add(ii * cob + v * 8) as *const __m128i,
                            ));
                        }
                        for kk in 0..TW {
                            let xv = _mm256_set1_epi32(
                                row[base + kk * t.stride * t.c_ib + ii].to_centered(in_qp),
                            );
                            for v in 0..NV {
                                va[kk][v] =
                                    _mm256_add_epi32(va[kk][v], _mm256_mullo_epi32(xv, w[v]));
                            }
                        }
                    }
                } else {
                    for kk in 0..TW {
                        let x = x0 + (kk * t.stride) as isize;
                        if x < 0 || x >= t.w_i as isize {
                            continue;
                        }
                        let base = x as usize * t.c_ib;
                        for ii in 0..t.c_ib {
                            let xv = _mm256_set1_epi32(row[base + ii].to_centered(in_qp));
                            for v in 0..NV {
                                let w = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                                    kptr.as_ptr().add(ii * cob + v * 8) as *const __m128i,
                                ));
                                va[kk][v] = _mm256_add_epi32(va[kk][v], _mm256_mullo_epi32(xv, w));
                            }
                        }
                    }
                }
            }
        }
    }
    for kk in 0..TW {
        for v in 0..NV {
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(kk * cob + v * 8) as *mut __m256i,
                va[kk][v],
            );
        }
    }
}

/// One output-channel block: full `C_i` reduction in i32 per register
/// tile, then the fused requantize epilogue.
fn conv_block_q<T: QuantIo, const COB: usize>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    jb: usize,
    out_blk: &mut [T],
    res_blk: Option<&[T]>,
) {
    let s = g.shape;
    let (h_o, w_o) = (s.h_o(), s.w_o());
    let c_ib = g.bp.c_ib;
    let n_ib = s.c_i / c_ib;
    let ker_ib = s.h_f * s.w_f * c_ib * COB;
    let tw_max = g.bp.w_ob.min(MAX_WOB);
    let (lo, hi) = g.bounds();
    let mut t = QTile {
        h_f: s.h_f,
        w_f: s.w_f,
        c_ib,
        n_ib,
        h_i: s.h_i,
        w_i: s.w_i,
        stride: s.stride,
        pad: s.pad,
        dil: s.dilation,
        ker_jb: n_ib * ker_ib,
        ker_ib,
        islab_len: s.h_i * s.w_i * c_ib,
        row_stride: s.w_i * c_ib,
        jb,
        l: 0,
        k0: 0,
    };

    for l in 0..h_o {
        t.l = l;
        let mut k0 = 0usize;
        while k0 < w_o {
            let tw = tw_max.min(w_o - k0);
            t.k0 = k0;
            let mut acc = [[0i32; COB]; MAX_WOB];
            reduce_tile_q_auto::<T, COB>(&mut acc, inp, ker, &g.in_qp, &t, tw);
            // Fused requantize epilogue: i32 -> i8 (or dequantized f32).
            let tile = &mut out_blk[(l * w_o + k0) * COB..][..tw * COB];
            let res_tile = res_blk.map(|r| &r[(l * w_o + k0) * COB..][..tw * COB]);
            let mults = &g.mult[jb * COB..][..COB];
            let offs = (!g.off.is_empty()).then(|| &g.off[jb * COB..][..COB]);
            for kk in 0..tw {
                for j in 0..COB {
                    let off = offs.map_or(0.0, |o| o[j]);
                    let res_term = match (g.res, res_tile) {
                        (Some((rqp, ratio)), Some(rt)) => {
                            rt[kk * COB + j].to_centered(&rqp) as f64 * ratio
                        }
                        _ => 0.0,
                    };
                    let q = requant_ep(
                        acc[kk][j], mults[j], off, res_term, g.out_qp.zero_point, lo, hi,
                    );
                    tile[kk * COB + j] = T::from_q(q, &g.out_qp);
                }
            }
            k0 += tw;
        }
    }
}

// ---------------------------------------------------------------------
// Depthwise (groups == C_i == C_o): lane-wise taps over `c_b` blocked
// channels — the i8 twin of `conv::depthwise`. Per-group slicing does
// not apply (a block interleaves `c_b` groups), so the channel lanes
// ARE the groups.
// ---------------------------------------------------------------------

fn dispatch_dw_q<T: QuantIo>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
    res: Option<&[T]>,
) -> Result<()> {
    match g.bp.c_ob {
        1 => run_dw_q::<T, 1>(inp, ker, g, threads, out, res),
        2 => run_dw_q::<T, 2>(inp, ker, g, threads, out, res),
        4 => run_dw_q::<T, 4>(inp, ker, g, threads, out, res),
        8 => run_dw_q::<T, 8>(inp, ker, g, threads, out, res),
        16 => run_dw_q::<T, 16>(inp, ker, g, threads, out, res),
        32 => run_dw_q::<T, 32>(inp, ker, g, threads, out, res),
        other => Err(Error::Shape(format!(
            "unsupported depthwise c_b={other} (supported: 1,2,4,8,16,32)"
        ))),
    }
}

fn run_dw_q<T: QuantIo, const CB: usize>(
    inp: &[T],
    ker: &[i8],
    g: &QuantGeom<'_>,
    threads: usize,
    out: &mut [T],
    res: Option<&[T]>,
) -> Result<()> {
    let s = g.shape;
    let (h_o, w_o) = (s.h_o(), s.w_o());
    let n_cb = s.c_o / CB;
    let blk_out = h_o * w_o * CB;
    let blk_in = s.h_i * s.w_i * CB;
    let blk_ker = s.h_f * s.w_f * CB;
    if threads <= 1 || n_cb <= 1 {
        for (cb, out_blk) in out.chunks_mut(blk_out).enumerate() {
            let res_blk = res.map(|r| &r[cb * blk_out..][..blk_out]);
            dw_block_q::<T, CB>(
                &inp[cb * blk_in..][..blk_in],
                &ker[cb * blk_ker..][..blk_ker],
                g,
                cb,
                out_blk,
                res_blk,
            );
        }
    } else {
        let mut per_thread: Vec<Vec<(usize, &mut [T])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (idx, b) in out.chunks_mut(blk_out).enumerate() {
            per_thread[idx % threads].push((idx, b));
        }
        std::thread::scope(|scope| {
            for chunk in per_thread {
                scope.spawn(move || {
                    for (cb, out_blk) in chunk {
                        let res_blk = res.map(|r| &r[cb * blk_out..][..blk_out]);
                        dw_block_q::<T, CB>(
                            &inp[cb * blk_in..][..blk_in],
                            &ker[cb * blk_ker..][..blk_ker],
                            g,
                            cb,
                            out_blk,
                            res_blk,
                        );
                    }
                });
            }
        });
    }
    Ok(())
}

/// One depthwise channel block: `inp_blk [H_i][W_i][CB]`, `ker_blk
/// [H_f][W_f][CB]` (i8), `out_blk [H_o][W_o][CB]`; each lane reduces
/// independently, then takes the fused requantize epilogue.
fn dw_block_q<T: QuantIo, const CB: usize>(
    inp_blk: &[T],
    ker_blk: &[i8],
    g: &QuantGeom<'_>,
    cb: usize,
    out_blk: &mut [T],
    res_blk: Option<&[T]>,
) {
    let s = g.shape;
    let (h_o, w_o) = (s.h_o(), s.w_o());
    let (h_i, w_i) = (s.h_i, s.w_i);
    let (stride, pad, dil) = (s.stride, s.pad, s.dilation);
    let row_stride = w_i * CB;
    let mults = &g.mult[cb * CB..][..CB];
    let offs = (!g.off.is_empty()).then(|| &g.off[cb * CB..][..CB]);
    let (lo, hi) = g.bounds();
    for l in 0..h_o {
        for k in 0..w_o {
            let mut acc = [0i32; CB];
            for n in 0..s.h_f {
                let iy = (l * stride + n * dil) as isize - pad as isize;
                if iy < 0 || iy >= h_i as isize {
                    continue;
                }
                let row = &inp_blk[iy as usize * row_stride..][..row_stride];
                for m in 0..s.w_f {
                    let ix = (k * stride + m * dil) as isize - pad as isize;
                    if ix < 0 || ix >= w_i as isize {
                        continue;
                    }
                    let x = &row[ix as usize * CB..][..CB];
                    let w = &ker_blk[(n * s.w_f + m) * CB..][..CB];
                    for j in 0..CB {
                        acc[j] += x[j].to_centered(&g.in_qp) * w[j] as i32;
                    }
                }
            }
            let at = (l * w_o + k) * CB;
            let tile = &mut out_blk[at..][..CB];
            let res_tile = res_blk.map(|r| &r[at..][..CB]);
            for j in 0..CB {
                let off = offs.map_or(0.0, |o| o[j]);
                let res_term = match (g.res, res_tile) {
                    (Some((rqp, ratio)), Some(rt)) => rt[j].to_centered(&rqp) as f64 * ratio,
                    _ => 0.0,
                };
                let q = requant_ep(acc[j], mults[j], off, res_term, g.out_qp.zero_point, lo, hi);
                tile[j] = T::from_q(q, &g.out_qp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::params::{per_channel_weight_scales, requant_multiplier, requantize};
    use crate::tensor::Tensor;

    /// Scalar i32 accumulator of one output element over NCHW quantized
    /// operands — group- and dilation-aware.
    fn acc_q8(
        x_q: &[i8],
        w_q: &[i8],
        s: &ConvShape,
        in_qp: QuantParams,
        o: usize,
        y: usize,
        x: usize,
    ) -> i32 {
        let (c_ipg, c_opg) = (s.c_i_per_group(), s.c_o_per_group());
        let mut acc = 0i32;
        for ci in 0..c_ipg {
            let c = (o / c_opg) * c_ipg + ci;
            for n in 0..s.h_f {
                let iy = (y * s.stride + n * s.dilation) as isize - s.pad as isize;
                if iy < 0 || iy >= s.h_i as isize {
                    continue;
                }
                for m in 0..s.w_f {
                    let ix = (x * s.stride + m * s.dilation) as isize - s.pad as isize;
                    if ix < 0 || ix >= s.w_i as isize {
                        continue;
                    }
                    let xv = x_q[(c * s.h_i + iy as usize) * s.w_i + ix as usize] as i32
                        - in_qp.zero_point;
                    let wv = w_q[((o * c_ipg + ci) * s.h_f + n) * s.w_f + m] as i32;
                    acc += xv * wv;
                }
            }
        }
        acc
    }

    /// Scalar NCHW oracle performing the documented integer arithmetic
    /// directly (no blocking) — the in-crate cross-check; the NumPy
    /// reference in `python/golden_gen.py` pins the same contract
    /// externally. Deliberately ends in the *classic* `requantize` so a
    /// match also proves the inert fused path is backward compatible.
    #[allow(clippy::too_many_arguments)]
    fn naive_q8(
        x_q: &[i8],
        w_q: &[i8],
        s: &ConvShape,
        in_qp: QuantParams,
        out_qp: QuantParams,
        mult: &[f64],
    ) -> Vec<i8> {
        let (h_o, w_o) = (s.h_o(), s.w_o());
        let mut out = vec![0i8; s.c_o * h_o * w_o];
        for o in 0..s.c_o {
            for y in 0..h_o {
                for x in 0..w_o {
                    let acc = acc_q8(x_q, w_q, s, in_qp, o, y, x);
                    out[(o * h_o + y) * w_o + x] =
                        requantize(acc, mult[o], out_qp.zero_point);
                }
            }
        }
        out
    }

    /// Fused-epilogue oracle: the single-rounding formula from the
    /// [`QuantGeom`] docs, written out longhand over NCHW data.
    #[allow(clippy::too_many_arguments)]
    fn naive_q8_ep(
        x_q: &[i8],
        w_q: &[i8],
        s: &ConvShape,
        in_qp: QuantParams,
        out_qp: QuantParams,
        mult: &[f64],
        off: &[f64],
        res: Option<(&[i8], QuantParams, f64)>,
        relu: bool,
        clamp_q: Option<i32>,
    ) -> Vec<i8> {
        let (h_o, w_o) = (s.h_o(), s.w_o());
        let lo = if relu { out_qp.zero_point.max(Q_MIN) } else { Q_MIN };
        let hi = clamp_q.map_or(Q_MAX, |c| c.clamp(lo, Q_MAX));
        let mut out = vec![0i8; s.c_o * h_o * w_o];
        for o in 0..s.c_o {
            for y in 0..h_o {
                for x in 0..w_o {
                    let acc = acc_q8(x_q, w_q, s, in_qp, o, y, x);
                    let mut v = acc as f64 * mult[o];
                    if !off.is_empty() {
                        v += off[o];
                    }
                    if let Some((r, rqp, ratio)) = res {
                        let rc = r[(o * h_o + y) * w_o + x] as i32 - rqp.zero_point;
                        v += rc as f64 * ratio;
                    }
                    let q = round_half_away(v) + out_qp.zero_point as f64;
                    out[(o * h_o + y) * w_o + x] = q.clamp(lo as f64, hi as f64) as i8;
                }
            }
        }
        out
    }

    fn quantize_nchw(t: &Tensor, qp: &QuantParams) -> Vec<i8> {
        t.data().iter().map(|&v| quantize(v, qp)).collect()
    }

    fn pack_i8_io(src: &[i8], c: usize, h: usize, w: usize, c_b: usize) -> Vec<i8> {
        let mut dst = vec![0i8; src.len()];
        crate::layout::pack_io_slice_t(src, c, h, w, c_b, &mut dst).unwrap();
        dst
    }

    fn unpack_i8_io(src: &[i8], c: usize, h: usize, w: usize, c_b: usize) -> Vec<i8> {
        let mut dst = vec![0i8; src.len()];
        crate::layout::unpack_io_slice_t(src, c, h, w, c_b, &mut dst).unwrap();
        dst
    }

    /// Pack an NCHW-ordered quantized kernel into the blocked layout the
    /// core consumes: per-group `[c_opg/c_ob][c_ipg/c_ib][H_f][W_f][c_ib]
    /// [c_ob]` slabs concatenated, or `[C/c_b][H_f][W_f][c_b]` lanes for
    /// depthwise.
    fn pack_i8_kernel(w_q: &[i8], s: &ConvShape, c_ob: usize, c_ib: usize) -> Vec<i8> {
        let (c_ipg, c_opg) = (s.c_i_per_group(), s.c_o_per_group());
        let mut out = vec![0i8; w_q.len()];
        if s.is_depthwise() {
            for c in 0..s.c_o {
                for n in 0..s.h_f {
                    for m in 0..s.w_f {
                        let d = ((c / c_ob) * s.h_f * s.w_f + n * s.w_f + m) * c_ob + c % c_ob;
                        out[d] = w_q[(c * s.h_f + n) * s.w_f + m];
                    }
                }
            }
            return out;
        }
        let per_g = c_opg * c_ipg * s.h_f * s.w_f;
        for grp in 0..s.groups {
            for o in 0..c_opg {
                for i in 0..c_ipg {
                    for n in 0..s.h_f {
                        for m in 0..s.w_f {
                            let d = crate::layout::blocked_kernel_index(
                                o, i, n, m, c_ipg, s.h_f, s.w_f, c_ib, c_ob,
                            );
                            out[grp * per_g + d] = w_q
                                [(((grp * c_opg + o) * c_ipg + i) * s.h_f + n) * s.w_f + m];
                        }
                    }
                }
            }
        }
        out
    }

    fn check(s: &ConvShape, bp: BlockParams, threads: usize, seed: u64) {
        let c_ipg = s.c_i_per_group();
        let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], seed);
        let kernel = Tensor::random(&[s.c_o, c_ipg, s.h_f, s.w_f], seed + 1);
        let in_qp = QuantParams::from_range(-1.0, 1.0);
        let out_qp = QuantParams::from_range(-20.0, 20.0);
        let w_scales = per_channel_weight_scales(&kernel);
        let w_q: Vec<i8> = kernel
            .data()
            .chunks(c_ipg * s.h_f * s.w_f)
            .zip(&w_scales)
            .flat_map(|(ch, &sc)| {
                ch.iter()
                    .map(|&v| quantize(v, &QuantParams { scale: sc, zero_point: 0 }))
                    .collect::<Vec<i8>>()
            })
            .collect();
        let mult: Vec<f64> = w_scales
            .iter()
            .map(|&sw| requant_multiplier(in_qp.scale, sw, out_qp.scale))
            .collect();

        let x_q = quantize_nchw(&input, &in_qp);
        let want = naive_q8(&x_q, &w_q, s, in_qp, out_qp, &mult);

        let bi = pack_i8_io(&x_q, s.c_i, s.h_i, s.w_i, bp.c_ib);
        let bk = pack_i8_kernel(&w_q, s, bp.c_ob, bp.c_ib);
        let mut bo = vec![0i8; s.c_o * s.h_o() * s.w_o()];
        conv_direct_blocked_i8_into(&bi, &bk, s, bp, threads, in_qp, out_qp, &mult, &mut bo)
            .unwrap();
        let got = unpack_i8_io(&bo, s.c_o, s.h_o(), s.w_o(), bp.c_ob);
        assert_eq!(got, want, "integer mismatch on {s:?} bp={bp:?} threads={threads}");
    }

    #[test]
    fn blocked_i8_matches_scalar_oracle_exactly() {
        check(&ConvShape::new(8, 10, 10, 16, 3, 3, 1, 0), BlockParams::new(8, 4, 4), 1, 21);
        check(&ConvShape::new(8, 9, 9, 16, 3, 3, 1, 1), BlockParams::new(16, 3, 8), 1, 22);
        check(&ConvShape::new(4, 7, 7, 8, 5, 5, 1, 2), BlockParams::new(8, 4, 4), 1, 23);
        check(&ConvShape::new(8, 14, 14, 8, 3, 3, 2, 1), BlockParams::new(8, 2, 8), 1, 25);
        check(&ConvShape::new(16, 7, 7, 32, 1, 1, 1, 0), BlockParams::new(16, 4, 8), 1, 40);
    }

    #[test]
    fn threaded_i8_is_bitwise_identical() {
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 4, 26);
        check(&ConvShape::new(8, 12, 12, 32, 3, 3, 1, 1), BlockParams::new(8, 4, 4), 7, 27);
    }

    #[test]
    fn all_cob_variants_exact() {
        for &cob in &[1usize, 2, 4, 8, 16, 32] {
            let s = ConvShape::new(4, 8, 8, 32, 3, 3, 1, 1);
            check(&s, BlockParams::new(cob, 4, 2), 1, 31 + cob as u64);
        }
    }

    #[test]
    fn grouped_and_dilated_i8_match_oracle() {
        check(
            &ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1).with_groups(2),
            BlockParams::new(4, 4, 4),
            1,
            51,
        );
        check(
            &ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1).with_groups(4),
            BlockParams::new(2, 4, 2),
            3,
            52,
        );
        check(
            &ConvShape::new(4, 9, 9, 8, 3, 3, 1, 2).with_dilation(2),
            BlockParams::new(8, 4, 4),
            1,
            53,
        );
    }

    #[test]
    fn depthwise_i8_matches_oracle() {
        let s = ConvShape::new(8, 9, 9, 8, 3, 3, 1, 1).with_groups(8);
        check(&s, BlockParams::new(4, 4, 4), 1, 61);
        check(&s, BlockParams::new(8, 4, 8), 3, 62);
        // strided + dilated depthwise
        let s2 = ConvShape::new(4, 11, 11, 4, 3, 3, 2, 2).with_groups(4).with_dilation(2);
        check(&s2, BlockParams::new(4, 2, 4), 2, 63);
    }

    /// The fused requantize epilogue (per-channel offset + residual +
    /// ReLU + clamp) is exact against the longhand single-rounding
    /// oracle, for both the standard and depthwise cores.
    #[test]
    fn fused_requant_epilogue_is_exact() {
        for (s, bp) in [
            (ConvShape::new(8, 8, 8, 16, 3, 3, 1, 1), BlockParams::new(8, 4, 4)),
            (
                ConvShape::new(8, 9, 9, 8, 3, 3, 1, 1).with_groups(8),
                BlockParams::new(4, 4, 4),
            ),
        ] {
            let c_ipg = s.c_i_per_group();
            let input = Tensor::random(&[s.c_i, s.h_i, s.w_i], 71);
            let kernel = Tensor::random(&[s.c_o, c_ipg, s.h_f, s.w_f], 72);
            let in_qp = QuantParams::from_range(-1.0, 1.0);
            let out_qp = QuantParams::from_range(-20.0, 20.0);
            let res_qp = QuantParams::from_range(-10.0, 10.0);
            let w_scales = per_channel_weight_scales(&kernel);
            let w_q: Vec<i8> = kernel
                .data()
                .chunks(c_ipg * s.h_f * s.w_f)
                .zip(&w_scales)
                .flat_map(|(ch, &sc)| {
                    ch.iter()
                        .map(|&v| quantize(v, &QuantParams { scale: sc, zero_point: 0 }))
                        .collect::<Vec<i8>>()
                })
                .collect();
            let mult: Vec<f64> = w_scales
                .iter()
                .map(|&sw| requant_multiplier(in_qp.scale, sw, out_qp.scale))
                .collect();
            let off: Vec<f64> = (0..s.c_o).map(|j| (j as f64 - 3.0) * 0.37).collect();
            let res_f = Tensor::random(&[s.c_o, s.h_o(), s.w_o()], 73);
            let res_q = quantize_nchw(&res_f, &res_qp);
            let ratio = res_qp.scale as f64 / out_qp.scale as f64;
            let clamp_q =
                Some(round_half_away(2.0 / out_qp.scale as f64) as i32 + out_qp.zero_point);

            let x_q = quantize_nchw(&input, &in_qp);
            let want = naive_q8_ep(
                &x_q,
                &w_q,
                &s,
                in_qp,
                out_qp,
                &mult,
                &off,
                Some((&res_q, res_qp, ratio)),
                true,
                clamp_q,
            );

            let g = QuantGeom {
                shape: &s,
                bp,
                in_qp,
                out_qp,
                mult: &mult,
                off: &off,
                res: Some((res_qp, ratio)),
                relu: true,
                clamp_q,
            };
            let bi = pack_i8_io(&x_q, s.c_i, s.h_i, s.w_i, bp.c_ib);
            let bk = pack_i8_kernel(&w_q, &s, bp.c_ob, bp.c_ib);
            let br = pack_i8_io(&res_q, s.c_o, s.h_o(), s.w_o(), bp.c_ob);
            let mut bo = vec![0i8; s.c_o * s.h_o() * s.w_o()];
            conv_quant_core(&bi, &bk, &g, 3, &mut bo, Some(&br)).unwrap();
            let got = unpack_i8_io(&bo, s.c_o, s.h_o(), s.w_o(), bp.c_ob);
            assert_eq!(got, want, "fused i8 mismatch on {s:?}");
            // The fused ReLU floor and clamp ceiling must actually bite
            // for this to be a meaningful test.
            assert!(want.iter().any(|&q| q == out_qp.zero_point as i8));
            assert!(want.iter().any(|&q| q == clamp_q.unwrap() as i8));
        }
    }

    #[test]
    fn fused_rejects_mismatched_epilogue_operands() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let bp = BlockParams::new(8, 4, 4);
        let qp = QuantParams::IDENT;
        let mult = vec![1.0f64; s.c_o];
        let inp = vec![0i8; s.c_i * s.h_i * s.w_i];
        let ker = vec![0i8; s.c_o * s.c_i * 9];
        let n_out = s.c_o * s.h_o() * s.w_o();
        let mut out = vec![0i8; n_out];
        // residual geometry set but operand missing
        let g = QuantGeom {
            res: Some((qp, 1.0)),
            ..QuantGeom::plain(&s, bp, qp, qp, &mult)
        };
        assert!(conv_quant_core(&inp, &ker, &g, 1, &mut out, None).is_err());
        // operand passed but geometry plain
        let g2 = QuantGeom::plain(&s, bp, qp, qp, &mult);
        let res = vec![0i8; n_out];
        assert!(conv_quant_core(&inp, &ker, &g2, 1, &mut out, Some(&res)).is_err());
        // wrong offset count
        let bad_off = vec![0.0f64; 3];
        let g3 = QuantGeom { off: &bad_off, ..QuantGeom::plain(&s, bp, qp, qp, &mult) };
        assert!(conv_quant_core(&inp, &ker, &g3, 1, &mut out, None).is_err());
    }

    #[test]
    fn rejects_bad_buffers_and_params() {
        let s = ConvShape::new(4, 6, 6, 8, 3, 3, 1, 1);
        let bp = BlockParams::new(8, 4, 4);
        let qp = QuantParams::IDENT;
        let mut out = vec![0i8; s.c_o * s.h_o() * s.w_o()];
        let inp = vec![0i8; s.c_i * s.h_i * s.w_i];
        let ker = vec![0i8; s.c_o * s.c_i * 9];
        // wrong multiplier count
        assert!(conv_direct_blocked_i8_into(&inp, &ker, &s, bp, 1, qp, qp, &[1.0], &mut out)
            .is_err());
        let mult = vec![1.0f64; s.c_o];
        // wrong input length
        assert!(conv_direct_blocked_i8_into(&inp[1..], &ker, &s, bp, 1, qp, qp, &mult, &mut out)
            .is_err());
        // non-dividing c_ib
        assert!(conv_direct_blocked_i8_into(
            &inp,
            &ker,
            &s,
            BlockParams::new(8, 4, 3),
            1,
            qp,
            qp,
            &mult,
            &mut out
        )
        .is_err());
    }
}

//! Quantization parameters and the scalar quantize/requantize contract.
//!
//! Every arithmetic step here is deliberately pinned to a bit-exact
//! definition (f64 intermediates, round-half-away-from-zero, the
//! `[-127, 127]` clamp) so the independent NumPy reference in
//! `python/golden_gen.py` reproduces the integers exactly — see the
//! [`super`] module docs.

use crate::tensor::Tensor;

/// Smallest representable quantized value. `-128` is deliberately
/// excluded: the symmetric budget keeps `-q` and `q - zp` in range, so
/// i32 accumulation bounds stay trivial.
pub const Q_MIN: i32 = -127;
/// Largest representable quantized value.
pub const Q_MAX: i32 = 127;

/// Element type of a planned network. The default everywhere is
/// [`DType::F32`]; [`DType::I8`] selects the quantized engine (i8 byte
/// arena, `direct_i8` plans, requantize fused into the glue passes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DType {
    #[default]
    F32,
    I8,
}

impl DType {
    /// Bytes per activation element.
    pub fn elem_bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I8 => 1,
        }
    }

    /// The JSON spec / CLI spelling (`"f32"` / `"i8"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
        }
    }

    /// Parse the JSON spec / CLI spelling.
    pub fn from_str_opt(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i8" | "int8" => Some(DType::I8),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-tensor affine quantization: `x ≈ (q - zero_point) * scale`.
///
/// The f32 value `0.0` always quantizes to exactly `zero_point`, so
/// zero padding and skipped border taps are exact under quantization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    /// Identity-ish params (scale 1, zero point 0) — the placeholder
    /// carried by f32 values, never used arithmetically in f32 mode.
    pub const IDENT: QuantParams = QuantParams { scale: 1.0, zero_point: 0 };

    /// Affine params covering `[min, max]`: `scale = (max - min) / 253`
    /// with the zero point anchored at the range midpoint. The one-step
    /// slack (253 of the 254 available steps) plus midpoint anchoring
    /// guarantee that **no value inside the calibrated range ever
    /// clamps** — `|round(x/s) - round(c/s)| <= 127` for all
    /// `x ∈ [min, max]` regardless of rounding alignment — which is
    /// what makes the `<= scale/2` round-trip bound unconditional.
    /// Degenerate ranges get a tiny scale so `quantize` stays
    /// well-defined.
    pub fn from_range(min: f32, max: f32) -> QuantParams {
        // The representable range must include 0 so that zero padding
        // is exact and the midpoint-anchored zero point stays inside
        // the budget: widen to cover 0.
        let min = min.min(0.0);
        let max = max.max(0.0);
        let range = (max - min).max(1e-30);
        let scale = range / (Q_MAX - Q_MIN - 1) as f32;
        let center = 0.5 * (min as f64 + max as f64);
        let zp = (-center / scale as f64).round();
        QuantParams { scale, zero_point: (zp as i32).clamp(Q_MIN, Q_MAX) }
    }

    /// Symmetric params covering `[-a, a]` (zero point 0).
    pub fn symmetric(abs_max: f32) -> QuantParams {
        QuantParams { scale: abs_max.max(1e-30) / Q_MAX as f32, zero_point: 0 }
    }

    /// Min/max calibration over a sample of f32 values (the "sample
    /// batch" of the classic post-training quantization recipe).
    pub fn calibrate(sample: &[f32]) -> QuantParams {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        for &v in sample {
            min = min.min(v);
            max = max.max(v);
        }
        if !min.is_finite() || !max.is_finite() {
            return QuantParams::IDENT;
        }
        QuantParams::from_range(min, max)
    }
}

/// `f64::round` — rounds half away from zero. Named so call sites and
/// the NumPy mirror (`np.floor(x+0.5)` / `np.ceil(x-0.5)` by sign)
/// agree on the convention.
#[inline]
pub fn round_half_away(x: f64) -> f64 {
    x.round()
}

/// Quantize one f32 value: `clamp(round(x / s) + zp)` in f64.
#[inline]
pub fn quantize(x: f32, qp: &QuantParams) -> i8 {
    let q = round_half_away(x as f64 / qp.scale as f64) + qp.zero_point as f64;
    (q.clamp(Q_MIN as f64, Q_MAX as f64)) as i8
}

/// Dequantize one i8 value: `(q - zp) * s`.
#[inline]
pub fn dequantize(q: i8, qp: &QuantParams) -> f32 {
    (q as i32 - qp.zero_point) as f32 * qp.scale
}

/// Requantize an i32 accumulator (or centered value) through the f64
/// multiplier `m`: `clamp(round(acc * m) + zp_out)`.
#[inline]
pub fn requantize(acc: i32, m: f64, zp_out: i32) -> i8 {
    let q = round_half_away(acc as f64 * m) + zp_out as f64;
    (q.clamp(Q_MIN as f64, Q_MAX as f64)) as i8
}

/// The per-output-channel requantize multiplier
/// `m_j = f64(s_in) * f64(s_w_j) / f64(s_out)`.
#[inline]
pub fn requant_multiplier(s_in: f32, s_w: f32, s_out: f32) -> f64 {
    s_in as f64 * s_w as f64 / s_out as f64
}

/// Symmetric per-output-channel weight scales: `s_j = max|W_j| / 127`
/// over the OIHW kernel (one scale per output channel, zero point 0 —
/// the standard int8 weight scheme).
pub fn per_channel_weight_scales(kernel: &Tensor) -> Vec<f32> {
    let &[c_o, c_i, h_f, w_f] = kernel.shape() else {
        return Vec::new();
    };
    let per = c_i * h_f * w_f;
    kernel
        .data()
        .chunks(per)
        .map(|ch| {
            let abs_max = ch.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            abs_max.max(1e-30) / Q_MAX as f32
        })
        .take(c_o)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_is_exact_under_any_range() {
        for &(lo, hi) in &[(-1.0f32, 1.0f32), (0.0, 5.0), (-3.0, 0.5), (-2.0, -0.5)] {
            let qp = QuantParams::from_range(lo, hi);
            assert_eq!(quantize(0.0, &qp) as i32, qp.zero_point);
            assert_eq!(dequantize(quantize(0.0, &qp), &qp), 0.0);
        }
    }

    #[test]
    fn round_trip_error_is_bounded_by_half_scale() {
        let qp = QuantParams::from_range(-2.0, 3.0);
        for i in 0..=1000 {
            let x = -2.0 + 5.0 * i as f32 / 1000.0;
            let back = dequantize(quantize(x, &qp), &qp);
            assert!(
                (back - x).abs() <= 0.5 * qp.scale * (1.0 + 1e-5),
                "x={x}: err {} > scale/2 {}",
                (back - x).abs(),
                0.5 * qp.scale
            );
        }
    }

    #[test]
    fn endpoints_stay_in_budget() {
        let qp = QuantParams::from_range(-7.5, 11.25);
        assert!((Q_MIN..=Q_MAX).contains(&(quantize(-7.5, &qp) as i32)));
        assert!((Q_MIN..=Q_MAX).contains(&(quantize(11.25, &qp) as i32)));
        // Out-of-range values clamp instead of wrapping.
        assert_eq!(quantize(1e9, &qp) as i32, Q_MAX);
        assert_eq!(quantize(-1e9, &qp) as i32, Q_MIN);
    }

    #[test]
    fn calibrate_matches_from_range() {
        let sample = [0.5f32, -1.25, 3.0, 0.0, 2.9];
        assert_eq!(QuantParams::calibrate(&sample), QuantParams::from_range(-1.25, 3.0));
        assert_eq!(QuantParams::calibrate(&[]), QuantParams::IDENT);
    }

    #[test]
    fn weight_scales_are_per_channel_symmetric() {
        let mut k = Tensor::zeros(&[2, 1, 2, 2]);
        k.set(&[0, 0, 1, 1], -4.0);
        k.set(&[1, 0, 0, 0], 0.5);
        let s = per_channel_weight_scales(&k);
        assert_eq!(s.len(), 2);
        assert!((s[0] - 4.0 / 127.0).abs() < 1e-9);
        assert!((s[1] - 0.5 / 127.0).abs() < 1e-9);
        // The channel max itself quantizes to exactly ±127.
        assert_eq!(quantize(-4.0, &QuantParams { scale: s[0], zero_point: 0 }), -127);
    }

    #[test]
    fn requantize_rounds_half_away_and_clamps() {
        assert_eq!(requantize(5, 0.5, 0), 3, "2.5 rounds away from zero");
        assert_eq!(requantize(-5, 0.5, 0), -3);
        assert_eq!(requantize(1_000_000, 1.0, 0), 127);
        assert_eq!(requantize(-1_000_000, 1.0, 10), -127);
    }

    #[test]
    fn dtype_strings_round_trip() {
        for d in [DType::F32, DType::I8] {
            assert_eq!(DType::from_str_opt(d.as_str()), Some(d));
            assert_eq!(d.elem_bytes(), if d == DType::I8 { 1 } else { 4 });
        }
        assert_eq!(DType::from_str_opt("int8"), Some(DType::I8));
        assert!(DType::from_str_opt("f16").is_none());
    }
}

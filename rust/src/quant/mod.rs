//! Int8 quantized direct convolution — the zero-overhead engine for the
//! memory regime the paper motivates.
//!
//! The paper's headline argument is that direct convolution "eliminates
//! all memory overhead", which matters most on embedded devices with
//! limited memory capacity — yet everything else in this crate moves
//! f32. This module quarters the bytes *again*: kernels are quantized
//! to int8 with **symmetric per-output-channel** scales, activations
//! with a **per-tensor affine** scheme (`QuantParams {scale,
//! zero_point}`, calibrated from a sample batch via min/max), the
//! convolution accumulates in i32 over the same §4 blocked layouts and
//! loop order as [`crate::conv::direct`], and the requantize-to-i8 step
//! is fused into the microkernel epilogue — no f32 intermediate, no
//! workspace, no retained state beyond the (4x smaller) weights.
//!
//! # The arithmetic contract (exactly reproducible)
//!
//! Quantized inference is only trustworthy if its integer arithmetic is
//! pinned, so every step here is defined to be bit-exactly reproducible
//! by the independent NumPy reference in `python/golden_gen.py`:
//!
//! * quantize:   `q = clamp(round_half_away(x_f64 / scale_f64) + zp)`,
//!   clamped to `[-127, 127]` (the symmetric i8 budget; -128 is never
//!   produced, so negation and accumulation never overflow);
//! * convolution: `acc_i32 = sum over taps of (x_q - zp_in) * w_q` —
//!   skipped border taps contribute exactly 0, matching f32
//!   zero-padding (the f32 zero quantizes to `zp_in`);
//! * requantize: `q_out = clamp(round_half_away(acc * m_j) + zp_out)`
//!   with the per-output-channel multiplier
//!   `m_j = f64(s_in) * f64(s_w[j]) / f64(s_out)`;
//! * `round_half_away` is `f64::round` (half away from zero), mirrored
//!   in NumPy as `floor(x + 0.5)` / `ceil(x - 0.5)` by sign.
//!
//! # Entry points
//!
//! * [`QuantParams`] / [`quantize`] / [`dequantize`] — the scalar
//!   contract plus min/max calibration.
//! * [`DirectI8Backend`] — the engine's seventh backend
//!   (`"direct_i8"`): plans through the ordinary
//!   [`crate::engine::ConvAlgo`] API with an f32 boundary (inputs are
//!   quantized on the fly — **zero** workspace, nothing staged) and
//!   additionally exposes the native i8 hot path through
//!   [`QuantExecute`].
//! * [`QuantNet`] — whole-network quantization: calibrate every graph
//!   edge from a sample forward pass, plan each conv with its
//!   edge-chained requantize params, and compile to an i8 byte arena
//!   via [`crate::engine::NetRunner`] (activation memory shrinks 4x,
//!   `overhead_bytes()` stays 0).

mod backend;
mod direct;
mod net;
mod params;

pub use backend::{DirectI8Backend, DirectI8Plan};
pub use direct::conv_direct_blocked_i8_into;
pub use net::{calibrate_graph, QuantNet, CALIBRATION_SEED};
pub use params::{
    dequantize, per_channel_weight_scales, quantize, requant_multiplier, requantize,
    round_half_away, DType, QuantParams, Q_MAX, Q_MIN,
};

use crate::Result;

/// Native int8 execution surface of a quantized [`crate::engine::ConvPlan`]
/// (reached through [`crate::engine::ConvPlan::as_quantized`]). This is
/// the byte-arena hot path: operands are i8 slices in the plan's §4
/// blocked layouts, quantized with the plan's own params, and the call
/// allocates nothing and needs no workspace.
pub trait QuantExecute: Send + Sync {
    /// Quantization of the i8 input slice the plan expects.
    fn input_qparams(&self) -> QuantParams;

    /// Quantization of the i8 output slice the plan produces.
    fn output_qparams(&self) -> QuantParams;

    /// Bytes of the plan's quantized weights (the 4x shrink vs
    /// [`crate::conv::ConvShape::kernel_bytes`]).
    fn weight_bytes(&self) -> u64;

    /// Execute the layer on i8 operands (blocked layouts, validated by
    /// length). Allocation-free with `threads <= 1`.
    fn execute_i8_into(&self, input: &[i8], output: &mut [i8]) -> Result<()>;

    /// Execute with a fused residual operand (i8, output layout,
    /// quantized with the params baked into the plan at build time).
    /// Plans built without a fused residual reject `Some`; the default
    /// rejects any residual (scale/shift/ReLU epilogues don't need this
    /// entry — they are folded into the plan's requantize step and flow
    /// through [`Self::execute_i8_into`] transparently).
    fn execute_i8_fused_into(
        &self,
        input: &[i8],
        output: &mut [i8],
        res: Option<&[i8]>,
    ) -> Result<()> {
        match res {
            None => self.execute_i8_into(input, output),
            Some(_) => Err(crate::Error::Shape(
                "this quantized plan has no fused residual input".into(),
            )),
        }
    }

    /// Quantization of the fused residual operand baked into the plan,
    /// `None` when the plan has no fused residual. Schedulers validate
    /// this against the shortcut edge's calibration before wiring a
    /// residual region into [`Self::execute_i8_fused_into`].
    fn residual_qparams(&self) -> Option<QuantParams> {
        None
    }
}

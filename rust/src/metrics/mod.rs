//! Measurement utilities: wall-clock timers, latency histograms and
//! GFLOPS accounting, plus markdown/CSV table rendering shared by the
//! benches and the coordinator's stats endpoint.

mod histogram;
mod table;

pub use histogram::Histogram;
pub use table::Table;

use std::time::Instant;

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// GFLOPS given a FLOP count and seconds.
pub fn gflops(flops: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops as f64 / secs / 1e9
}

/// Simple throughput/latency summary used by the serving example.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub total_batch_occupancy: u64,
    pub latency: Histogram,
}

impl ServeStats {
    pub fn record_batch(&mut self, batch_size: usize) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.total_batch_occupancy += batch_size as u64;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_batch_occupancy as f64 / self.batches as f64
        }
    }
}

/// Per-model serving telemetry for the [`crate::serve`] subsystem:
/// admission counters plus the latency split into queue wait and
/// execution. Kept behind one mutex per model; workers lock it once per
/// sub-batch, so contention stays off the conv hot path.
///
/// The three histograms decompose end-to-end latency:
///
/// * `queue_wait` — submit to dispatch (admission + batching delay);
/// * `execute` — per-batch wall time inside the worker's forward loop;
/// * `e2e` — submit to reply, per request (what the client feels).
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Requests offered to admission (accepted + shed).
    pub submitted: u64,
    /// Requests that completed with a successful reply.
    pub completed: u64,
    /// Requests rejected at admission because the bounded queue was
    /// full (explicit shedding — the producer was never blocked).
    pub shed_queue_full: u64,
    /// Requests dropped *before execution* because their deadline had
    /// already passed when a worker picked them up.
    pub deadline_missed: u64,
    /// Requests that reached execution but failed.
    pub failed: u64,
    /// Sub-batches executed.
    pub batches: u64,
    /// Sum of live requests over all executed sub-batches.
    pub total_occupancy: u64,
    pub queue_wait: Histogram,
    pub execute: Histogram,
    pub e2e: Histogram,
}

impl ServeMetrics {
    /// One executed sub-batch of `occupancy` live requests taking
    /// `exec_secs` of worker wall time.
    pub fn record_batch(&mut self, occupancy: usize, exec_secs: f64) {
        self.batches += 1;
        self.total_occupancy += occupancy as u64;
        self.execute.record(exec_secs);
    }

    /// One successfully completed request with its latency split.
    pub fn record_done(&mut self, queue_wait_secs: f64, e2e_secs: f64) {
        self.completed += 1;
        self.queue_wait.record(queue_wait_secs);
        self.e2e.record(e2e_secs);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_occupancy as f64 / self.batches as f64
        }
    }

    /// Completed-request throughput over a measurement window.
    pub fn throughput(&self, secs: f64) -> f64 {
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    /// Accounting identity: every offered request is exactly one of
    /// completed / shed / deadline-missed / failed / still in flight.
    pub fn in_flight(&self) -> u64 {
        self.submitted
            .saturating_sub(self.completed)
            .saturating_sub(self.shed_queue_full)
            .saturating_sub(self.deadline_missed)
            .saturating_sub(self.failed)
    }

    /// Zero every counter and histogram, starting a fresh measurement
    /// window. Callers that want seamless windows snapshot and reset
    /// under one lock (`ModelHandle::snapshot_and_reset` in
    /// [`crate::serve`]) so no request lands between the two.
    ///
    /// After a reset [`ServeMetrics::in_flight`] reads 0 until the
    /// next submit — in-flight requests from the previous window
    /// complete against the new window's counters (the saturating
    /// accounting absorbs the underflow).
    pub fn reset(&mut self) {
        *self = ServeMetrics::default();
    }

    /// Multi-line human report (the `serve --stats` block body).
    pub fn report(&self) -> String {
        format!(
            "offered={} completed={} shed={} deadline_missed={} failed={} in_flight={}\n\
             batches={} (mean occupancy {:.2})\n\
             queue wait : {}\n\
             execute    : {}\n\
             end-to-end : {}",
            self.submitted,
            self.completed,
            self.shed_queue_full,
            self.deadline_missed,
            self.failed,
            self.in_flight(),
            self.batches,
            self.mean_batch_size(),
            self.queue_wait.summary(),
            self.execute.summary(),
            self.e2e.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_math() {
        assert!((gflops(2_000_000_000, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(100, 0.0), 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn serve_stats_batches() {
        let mut s = ServeStats::default();
        s.record_batch(4);
        s.record_batch(2);
        assert_eq!(s.requests, 6);
        assert!((s.mean_batch_size() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn serve_metrics_accounting_identity() {
        let mut m = ServeMetrics::default();
        m.submitted = 10;
        m.shed_queue_full = 2;
        m.deadline_missed = 1;
        m.record_batch(3, 0.010);
        m.record_batch(3, 0.012);
        for _ in 0..6 {
            m.record_done(0.001, 0.015);
        }
        assert_eq!(m.completed, 6);
        assert_eq!(m.in_flight(), 1);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert!((m.throughput(2.0) - 3.0).abs() < 1e-12);
        assert_eq!(m.throughput(0.0), 0.0);
        let r = m.report();
        assert!(r.contains("offered=10") && r.contains("shed=2"));
    }

    #[test]
    fn reset_opens_a_fresh_window() {
        let mut m = ServeMetrics::default();
        m.submitted = 5;
        m.record_batch(2, 0.010);
        m.record_done(0.001, 0.012);
        m.reset();
        assert_eq!(m.submitted, 0);
        assert_eq!(m.completed, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.execute.count(), 0);
        assert_eq!(m.in_flight(), 0);
        // A completion straggling in from the previous window must not
        // underflow the accounting.
        m.record_done(0.001, 0.012);
        assert_eq!(m.in_flight(), 0);
    }
}

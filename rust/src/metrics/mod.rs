//! Measurement utilities: wall-clock timers, latency histograms and
//! GFLOPS accounting, plus markdown/CSV table rendering shared by the
//! benches and the coordinator's stats endpoint.

mod histogram;
mod table;

pub use histogram::Histogram;
pub use table::Table;

use std::time::Instant;

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// GFLOPS given a FLOP count and seconds.
pub fn gflops(flops: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops as f64 / secs / 1e9
}

/// Simple throughput/latency summary used by the serving example.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub total_batch_occupancy: u64,
    pub latency: Histogram,
}

impl ServeStats {
    pub fn record_batch(&mut self, batch_size: usize) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.total_batch_occupancy += batch_size as u64;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_batch_occupancy as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_math() {
        assert!((gflops(2_000_000_000, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(100, 0.0), 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn serve_stats_batches() {
        let mut s = ServeStats::default();
        s.record_batch(4);
        s.record_batch(2);
        assert_eq!(s.requests, 6);
        assert!((s.mean_batch_size() - 3.0).abs() < 1e-12);
    }
}

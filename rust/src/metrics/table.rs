//! Markdown / CSV table rendering for bench output — every figure bench
//! prints the same rows the paper plots.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    /// JSON array-of-objects keyed by the header (cells stay strings —
    /// the benches pre-format their numbers). This is the `BENCH_*.json`
    /// baseline format CI archives per dispatch arm (scalar vs SIMD) so
    /// perf trajectories can be diffed mechanically across PRs.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut out = String::from("[");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n  {");
            for (j, (h, c)) in self.header.iter().zip(r).enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("\"{}\": \"{}\"", esc(h), esc(c)));
            }
            out.push('}');
        }
        out.push_str("\n]\n");
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_aligns() {
        let mut t = Table::new(&["layer", "gflops"]);
        t.row(vec!["conv1".into(), "1.23".into()]);
        t.row(vec!["conv2_long_name".into(), "45.6".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| layer"));
        assert!(md.lines().count() == 4);
        let widths: Vec<usize> = md.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "all lines same width");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn json_is_array_of_objects_with_escapes() {
        let mut t = Table::new(&["layer", "note"]);
        t.row(vec!["conv1".into(), "a\"b\\c\nd".into()]);
        let j = t.to_json();
        assert!(j.starts_with('[') && j.trim_end().ends_with(']'));
        assert!(j.contains("\"layer\": \"conv1\""));
        assert!(j.contains("\"note\": \"a\\\"b\\\\c\\nd\""));
        assert!(Table::new(&["x"]).to_json().contains("[\n]"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

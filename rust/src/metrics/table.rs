//! Markdown / CSV table rendering for bench output — every figure bench
//! prints the same rows the paper plots.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_aligns() {
        let mut t = Table::new(&["layer", "gflops"]);
        t.row(vec!["conv1".into(), "1.23".into()]);
        t.row(vec!["conv2_long_name".into(), "45.6".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| layer"));
        assert!(md.lines().count() == 4);
        let widths: Vec<usize> = md.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "all lines same width");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

//! Log-bucketed latency histogram (microsecond resolution, p50/p95/p99).

/// Histogram over positive durations in seconds. Buckets are
/// logarithmic: ~4% relative width from 1 µs to ~1000 s.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BUCKETS: usize = 512;
const LOG_MIN: f64 = -6.0; // 1 µs
const LOG_MAX: f64 = 3.0; // 1000 s

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; BUCKETS], count: 0, sum: 0.0, min: f64::MAX, max: 0.0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(secs: f64) -> usize {
        let l = secs.max(1e-9).log10();
        let frac = (l - LOG_MIN) / (LOG_MAX - LOG_MIN);
        ((frac * BUCKETS as f64) as isize).clamp(0, BUCKETS as isize - 1) as usize
    }

    fn bucket_value(idx: usize) -> f64 {
        let frac = (idx as f64 + 0.5) / BUCKETS as f64;
        10f64.powf(LOG_MIN + frac * (LOG_MAX - LOG_MIN))
    }

    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_of(secs)] += 1;
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile (0..=1) estimated from bucket midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// One-line human summary (durations in ms).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count,
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
            self.max() * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn quantiles_roughly_correct() {
        let mut h = Histogram::new();
        // 1..=100 ms uniformly
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.p50();
        assert!(p50 > 0.035 && p50 < 0.065, "p50={p50}");
        let p99 = h.p99();
        assert!(p99 > 0.080 && p99 < 0.130, "p99={p99}");
        assert!((h.mean() - 0.0505).abs() < 1e-6);
    }

    #[test]
    fn min_max() {
        let mut h = Histogram::new();
        h.record(0.002);
        h.record(0.2);
        assert_eq!(h.min(), 0.002);
        assert_eq!(h.max(), 0.2);
    }

    #[test]
    fn extreme_values_clamp() {
        let mut h = Histogram::new();
        h.record(1e-9);
        h.record(1e6);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= 1e2);
    }
}

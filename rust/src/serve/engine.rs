//! The multi-model serving engine: compiled-once plans behind bounded
//! admission, continuously-batched workers, and per-model telemetry.
//!
//! # Architecture
//!
//! ```text
//!   submit(model, image) ──► AdmissionQueue (bounded; sheds QueueFull)
//!                                 │
//!              worker pull loop (per model, N workers)
//!                                 │
//!        collect: first request opens a batch window; accumulate
//!        arrivals until batch_wait or the largest batch size
//!                                 │
//!        expire: deadline-passed requests dropped BEFORE execution
//!                                 │
//!        Batcher::split(backlog) ─► sub-batches
//!                                 │
//!        gather → NetRunner::forward_with per image (per-worker
//!        arena + staging buffers, allocation-free) → scatter replies
//! ```
//!
//! Each worker owns its [`WorkerState`] (one [`NetArena`] plus input/
//! output staging sized for the largest batch) for its whole life, so
//! the steady-state execute path — [`ModelHandle::execute_staged`], the
//! exact function the workers run — performs **zero** heap allocations
//! (proven by the counting-allocator test in `tests/serve.rs`).
//! Allocations are confined to the admission edge: the request's input
//! `Vec` (the message in), the reply logits `Vec` (the message out),
//! and the backlog bookkeeping around `Batcher::split`.
//!
//! # Plan cache
//!
//! Models are compiled once per distinct spec: [`spec_hash`] (FNV-1a
//! over the canonical JSON plus the dtype) keys a cache of
//! `Arc<NetRunner>`, so serving the same spec under two names — or
//! re-adding a model — shares one set of packed weights and plans.

use super::admission::AdmissionQueue;
use super::Rejected;
use crate::arch::Machine;
use crate::coordinator::{Batcher, BatcherConfig};
use crate::engine::{NetArena, NetRunner};
use crate::metrics::{ServeMetrics, Table};
use crate::nets::{fuse, Model, NetPlans};
use crate::quant::{DType, QuantNet};
use crate::trace::{
    self, chrome::ChromeEvent, prom::ModelExposition, Span, SpanKind, SpanRing, TraceAgg,
};
use crate::tune::Tuner;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving knobs. One config per server; workers can be overridden per
/// model ([`ServerBuilder::add_model_with_workers`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bounded admission-queue depth per model (requests beyond it are
    /// shed with [`Rejected::QueueFull`]).
    pub queue_depth: usize,
    /// How long the first request in a batch window waits for
    /// stragglers before the batch dispatches.
    pub batch_wait: Duration,
    /// Default per-request deadline (None = no deadline). Measured from
    /// submit; expired requests are dropped before execution.
    pub deadline: Option<Duration>,
    /// Worker threads per model (each owns an arena + staging buffers).
    pub workers: usize,
    /// Batch sizes the [`Batcher`] may dispatch (the DP split covers
    /// any backlog with these).
    pub batch_sizes: Vec<usize>,
    /// Branch lanes inside each forward pass (1 = serial; workers are
    /// the primary parallelism axis here).
    pub branch_lanes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_depth: 128,
            batch_wait: Duration::from_millis(2),
            deadline: None,
            workers: 2,
            batch_sizes: vec![1, 2, 4, 8],
            branch_lanes: 1,
        }
    }
}

/// FNV-1a 64 over a canonical serialization of the model spec plus its
/// element type — the plan-cache key. Two specs hash equal iff their
/// JSON form and dtype are identical.
pub fn spec_hash(model: &Model, dtype: DType) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(model.to_json().as_bytes());
    eat(dtype.as_str().as_bytes());
    h
}

/// One queued inference request.
struct Req {
    input: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// A pending reply from [`Server::submit`].
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>>>,
}

impl Ticket {
    /// Block until the logits arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| Error::Runtime("server dropped the request".into()))?
    }

    /// Block for at most `timeout`. Lets load generators and watchdog
    /// tests bound their exposure to a wedged worker.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>> {
        self.rx
            .recv_timeout(timeout)
            .map_err(|e| Error::Runtime(format!("server reply: {e}")))?
    }
}

/// Per-worker execution state: one arena plus input/output staging
/// sized for the largest dispatchable batch. Built once per worker
/// (or per test) via [`ModelHandle::worker_state`]; reusing it is what
/// makes the execute path allocation-free.
pub struct WorkerState {
    arena: NetArena,
    inbuf: Vec<f32>,
    outbuf: Vec<f32>,
}

/// One resident model: compiled runner, admission queue, batcher,
/// telemetry.
struct ServiceInner {
    name: String,
    spec_hash: u64,
    dtype: DType,
    runner: Arc<NetRunner>,
    queue: AdmissionQueue<Req>,
    batcher: Batcher,
    workers: usize,
    /// Deepest backlog one worker drains per wakeup.
    max_backlog: usize,
    deadline: Option<Duration>,
    stats: Mutex<ServeMetrics>,
    /// Per-model span ring: worker pipeline spans (batch-assemble /
    /// execute / reply) plus the per-op spans drained from each
    /// worker's arena after a batch. Fixed capacity; see
    /// [`crate::trace`].
    trace: Mutex<SpanRing>,
    image_in: usize,
    image_out: usize,
}

impl ServiceInner {
    fn stats_lock(&self) -> std::sync::MutexGuard<'_, ServeMetrics> {
        self.stats.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn trace_lock(&self) -> std::sync::MutexGuard<'_, SpanRing> {
        self.trace.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn worker_state(&self) -> WorkerState {
        let max_batch = self.batcher.max_size();
        WorkerState {
            arena: self.runner.arena(),
            inbuf: vec![0.0; max_batch * self.image_in],
            outbuf: vec![0.0; max_batch * self.image_out],
        }
    }

    /// Pull one backlog: block for the first request (or exit on
    /// close+drained), then accumulate arrivals until the batch window
    /// closes, the largest batch size fills, or the backlog cap hits.
    /// `lane` is the worker's trace track (see [`worker_loop`]).
    fn collect_backlog(&self, lane: u32) -> Option<Vec<Req>> {
        let first = self.queue.pop_blocking()?;
        // The span opens once the first request arrived: it measures
        // assembly (waiting for stragglers), not idle queue time.
        let t0 = trace::start();
        let mut reqs = Vec::with_capacity(self.max_backlog);
        reqs.push(first);
        let window = Instant::now() + self.batcher.cfg().max_wait;
        while reqs.len() < self.max_backlog {
            if let Some(r) = self.queue.try_pop() {
                reqs.push(r);
                continue;
            }
            // Below a full batch it pays to wait for stragglers; at or
            // beyond one, dispatch.
            if reqs.len() >= self.batcher.max_size() || Instant::now() >= window {
                break;
            }
            match self.queue.pop_deadline(window) {
                Some(r) => reqs.push(r),
                None => break,
            }
        }
        if t0 != trace::OFF {
            self.trace_lock().push(Span {
                id: 0,
                kind: SpanKind::BatchAssemble,
                lane,
                label: "",
                t_start: t0,
                t_end: trace::now_ns(),
                meta: reqs.len() as u64,
            });
        }
        Some(reqs)
    }

    /// Serve one collected backlog: expire stale requests, cover the
    /// rest with the DP batch split, execute each sub-batch.
    fn serve_backlog(&self, state: &mut WorkerState, reqs: Vec<Req>, lane: u32) {
        let now = Instant::now();
        let mut live = Vec::with_capacity(reqs.len());
        let mut missed = 0u64;
        for r in reqs {
            if r.deadline.is_some_and(|d| now >= d) {
                missed += 1;
                let _ = r.reply.send(Err(Rejected::DeadlineExceeded.into()));
            } else {
                live.push(r);
            }
        }
        if missed > 0 {
            self.stats_lock().deadline_missed += missed;
        }
        let mut it = live.into_iter();
        for plan in self.batcher.split(it.len()) {
            let group: Vec<Req> = it.by_ref().take(plan.occupancy).collect();
            self.execute_group(state, group, lane);
        }
    }

    /// Gather → forward → scatter for one sub-batch. The forward loop
    /// ([`ModelHandle::execute_staged`] drives the same function) is
    /// allocation-free; the reply `Vec`s are the messages out.
    fn execute_group(&self, state: &mut WorkerState, group: Vec<Req>, lane: u32) {
        let occupancy = group.len() as u64;
        let t0 = Instant::now();
        let ts = trace::start();
        for (i, r) in group.iter().enumerate() {
            state.inbuf[i * self.image_in..][..self.image_in].copy_from_slice(&r.input);
        }
        let res = self.forward_staged(state, group.len());
        let exec = t0.elapsed().as_secs_f64();
        if ts != trace::OFF {
            // One lock: the execute span plus the per-op spans the
            // forwards left in this worker's arena rings (offset onto
            // the tracks right above the worker's pipeline track).
            let mut tr = self.trace_lock();
            state.arena.drain_spans_into(&mut tr, lane + 1);
            tr.push(Span {
                id: 0,
                kind: SpanKind::Execute,
                lane,
                label: "",
                t_start: ts,
                t_end: trace::now_ns(),
                meta: occupancy,
            });
        }

        let tr0 = trace::start();
        {
            let mut st = self.stats_lock();
            st.record_batch(group.len(), exec);
            match res {
                Ok(()) => {
                    for (i, r) in group.into_iter().enumerate() {
                        let out = state.outbuf[i * self.image_out..][..self.image_out].to_vec();
                        let wait = t0.saturating_duration_since(r.enqueued).as_secs_f64();
                        st.record_done(wait, r.enqueued.elapsed().as_secs_f64());
                        let _ = r.reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    st.failed += group.len() as u64;
                    let msg = format!("batch failed: {e}");
                    for r in group {
                        let _ = r.reply.send(Err(Error::Runtime(msg.clone())));
                    }
                }
            }
        }
        if tr0 != trace::OFF {
            self.trace_lock().push(Span {
                id: 0,
                kind: SpanKind::Reply,
                lane,
                label: "",
                t_start: tr0,
                t_end: trace::now_ns(),
                meta: occupancy,
            });
        }
    }

    /// The zero-alloc hot path: forward `n` staged images over the
    /// worker's arena.
    fn forward_staged(&self, state: &mut WorkerState, n: usize) -> Result<()> {
        for i in 0..n {
            let img = &state.inbuf[i * self.image_in..][..self.image_in];
            let dst = &mut state.outbuf[i * self.image_out..][..self.image_out];
            self.runner.forward_with(&mut state.arena, img, dst)?;
        }
        Ok(())
    }
}

/// Trace tracks per worker: the pipeline spans sit on the worker's base
/// lane and the drained arena op spans on the lanes right above it, so
/// a worker plus its branch lanes render as one group of Chrome-trace
/// tids. 16 comfortably exceeds any branch-lane count.
const TRACE_LANES_PER_WORKER: u32 = 16;

fn worker_loop(svc: Arc<ServiceInner>, w: usize) {
    let lane = w as u32 * TRACE_LANES_PER_WORKER;
    let mut state = svc.worker_state();
    while let Some(reqs) = svc.collect_backlog(lane) {
        svc.serve_backlog(&mut state, reqs, lane);
    }
}

/// Introspection + test handle for one resident model.
#[derive(Clone)]
pub struct ModelHandle {
    inner: Arc<ServiceInner>,
}

impl ModelHandle {
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    pub fn dtype(&self) -> DType {
        self.inner.dtype
    }

    /// The plan-cache key this model was compiled under.
    pub fn spec_hash(&self) -> u64 {
        self.inner.spec_hash
    }

    /// Whether two served names share one compiled plan (the cache hit).
    pub fn shares_plans_with(&self, other: &ModelHandle) -> bool {
        Arc::ptr_eq(&self.inner.runner, &other.inner.runner)
    }

    /// The compiled network (accounting, arena sizing, graph).
    pub fn runner(&self) -> &NetRunner {
        &self.inner.runner
    }

    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Current queued requests (telemetry gauge).
    pub fn queue_len(&self) -> usize {
        self.inner.queue.len()
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue.depth()
    }

    pub fn image_in(&self) -> usize {
        self.inner.image_in
    }

    pub fn image_out(&self) -> usize {
        self.inner.image_out
    }

    /// Snapshot of the model's telemetry.
    pub fn stats(&self) -> ServeMetrics {
        self.inner.stats_lock().clone()
    }

    /// Consistent snapshot of the model's telemetry: one lock
    /// acquisition, so counters, histograms and the derived `in_flight`
    /// gauge describe the same instant. (`stats` is an alias.)
    pub fn snapshot(&self) -> ServeMetrics {
        self.inner.stats_lock().clone()
    }

    /// Snapshot the telemetry and reset it under the same lock — the
    /// windowed `--stats` reporter: each report covers exactly the
    /// interval since the previous one, with no seam where a request
    /// could be counted twice or not at all.
    pub fn snapshot_and_reset(&self) -> ServeMetrics {
        let mut st = self.inner.stats_lock();
        let snap = st.clone();
        st.reset();
        snap
    }

    /// Snapshot and clear the model's span ring (worker pipeline spans
    /// plus drained per-op arena spans).
    pub fn take_trace(&self) -> Vec<Span> {
        let mut tr = self.inner.trace_lock();
        let v = tr.to_vec();
        tr.clear();
        v
    }

    /// Non-destructive per-kind aggregates of the model's span ring.
    pub fn trace_agg(&self) -> TraceAgg {
        TraceAgg::from_spans(self.inner.trace_lock().iter())
    }

    /// Build one worker's execution state (arena + staging). The only
    /// allocation site of the execute path; workers do this once.
    pub fn worker_state(&self) -> WorkerState {
        self.inner.worker_state()
    }

    /// Stage one image into batch slot `slot` of `state`.
    pub fn stage(&self, state: &mut WorkerState, slot: usize, image: &[f32]) -> Result<()> {
        if image.len() != self.inner.image_in {
            return Err(Error::Shape(format!(
                "model '{}' wants {} floats per image, got {}",
                self.inner.name,
                self.inner.image_in,
                image.len()
            )));
        }
        if (slot + 1) * self.inner.image_in > state.inbuf.len() {
            return Err(Error::Shape(format!(
                "slot {slot} exceeds the staged batch capacity {}",
                state.inbuf.len() / self.inner.image_in
            )));
        }
        state.inbuf[slot * self.inner.image_in..][..self.inner.image_in].copy_from_slice(image);
        Ok(())
    }

    /// Execute `n` staged images — the exact allocation-free function
    /// the serving workers run in steady state (the counting-allocator
    /// test drives this directly).
    pub fn execute_staged(&self, state: &mut WorkerState, n: usize) -> Result<()> {
        if n * self.inner.image_in > state.inbuf.len() {
            return Err(Error::Shape(format!(
                "{n} images exceed the staged batch capacity {}",
                state.inbuf.len() / self.inner.image_in
            )));
        }
        self.inner.forward_staged(state, n)
    }

    /// Read batch slot `slot` of the staged output.
    pub fn staged_output<'a>(&self, state: &'a WorkerState, slot: usize) -> &'a [f32] {
        &state.outbuf[slot * self.inner.image_out..][..self.inner.image_out]
    }
}

/// Builds a [`Server`]: compile models (through the spec-hash plan
/// cache), then [`ServerBuilder::start`] spawns the worker pools.
pub struct ServerBuilder {
    cfg: ServeConfig,
    machine: Machine,
    backend: String,
    plan_threads: usize,
    tuner: Option<Tuner>,
    cache: BTreeMap<u64, Arc<NetRunner>>,
    services: Vec<Arc<ServiceInner>>,
}

impl ServerBuilder {
    pub fn new(machine: &Machine, cfg: ServeConfig) -> ServerBuilder {
        ServerBuilder {
            cfg,
            machine: machine.clone(),
            backend: "auto".into(),
            plan_threads: 1,
            tuner: None,
            cache: BTreeMap::new(),
            services: Vec::new(),
        }
    }

    /// Backend for f32 plans (registry name or `"auto"`; i8 models
    /// always plan `direct_i8`).
    pub fn backend(mut self, backend: &str) -> ServerBuilder {
        self.backend = backend.to_string();
        self
    }

    /// Plan f32 models through a [`Tuner`] (mixed-backend per-layer
    /// winners) instead of the fixed `backend` name. The spec-hash
    /// plan cache still applies — identical specs tune once and share
    /// the compiled runner. Call [`ServerBuilder::tuner`] after the
    /// models are added to read hit counters or persist the cache.
    pub fn with_tuner(mut self, tuner: Tuner) -> ServerBuilder {
        self.tuner = Some(tuner);
        self
    }

    /// The tuner installed by [`ServerBuilder::with_tuner`], if any.
    pub fn tuner(&self) -> Option<&Tuner> {
        self.tuner.as_ref()
    }

    /// Intra-layer threads handed to planning.
    pub fn plan_threads(mut self, threads: usize) -> ServerBuilder {
        self.plan_threads = threads.max(1);
        self
    }

    /// Compiled runners currently cached (distinct spec hashes).
    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }

    /// Make `model` resident under `served_name` with the default
    /// worker allocation. The model's own `dtype` picks the f32 or i8
    /// compile path; identical specs share one compiled plan.
    pub fn add_model(&mut self, served_name: &str, model: &Model) -> Result<()> {
        self.add_model_with_workers(served_name, model, self.cfg.workers)
    }

    /// [`ServerBuilder::add_model`] with a per-model worker count.
    pub fn add_model_with_workers(
        &mut self,
        served_name: &str,
        model: &Model,
        workers: usize,
    ) -> Result<()> {
        if self.services.iter().any(|s| s.name == served_name) {
            return Err(Error::Runtime(format!(
                "model name '{served_name}' is already served"
            )));
        }
        let dtype = model.dtype;
        let hash = spec_hash(model, dtype);
        let runner = match self.cache.get(&hash) {
            Some(r) => Arc::clone(r),
            None => {
                // Serving always compiles the fused schedule: bitwise
                // identical to the unfused one in f32, single-rounding
                // epilogues in i8, and strictly fewer scheduled nodes.
                let fused = fuse(model)?;
                let compiled = match dtype {
                    DType::F32 => {
                        let plans = match self.tuner.as_mut() {
                            Some(tuner) => {
                                NetPlans::build_model_tuned(
                                    model,
                                    &self.machine,
                                    tuner,
                                    self.plan_threads,
                                )?
                                .0
                            }
                            None => NetPlans::build_model(
                                model,
                                &self.backend,
                                &self.machine,
                                self.plan_threads,
                            )?,
                        };
                        NetRunner::from_graph_fused(
                            plans,
                            model.graph.clone(),
                            self.cfg.branch_lanes,
                            &fused,
                        )?
                    }
                    DType::I8 => {
                        QuantNet::build_model_fused(model, &fused, &self.machine, self.plan_threads)?
                            .runner_fused(self.cfg.branch_lanes, &fused)?
                    }
                };
                let arc = Arc::new(compiled);
                self.cache.insert(hash, Arc::clone(&arc));
                arc
            }
        };
        let batcher = Batcher::new(BatcherConfig {
            sizes: self.cfg.batch_sizes.clone(),
            max_wait: self.cfg.batch_wait,
        });
        let max_backlog = self.cfg.queue_depth.max(batcher.max_size());
        self.services.push(Arc::new(ServiceInner {
            name: served_name.to_string(),
            spec_hash: hash,
            dtype,
            image_in: runner.input_len(),
            image_out: runner.output_len(),
            runner,
            queue: AdmissionQueue::bounded(self.cfg.queue_depth),
            batcher,
            workers: workers.max(1),
            max_backlog,
            deadline: self.cfg.deadline,
            stats: Mutex::new(ServeMetrics::default()),
            trace: Mutex::new(SpanRing::with_capacity(16_384)),
        }));
        Ok(())
    }

    /// Spawn every model's worker pool and hand back the live server.
    pub fn start(self) -> Result<Server> {
        if self.services.is_empty() {
            return Err(Error::Runtime("server has no resident models".into()));
        }
        let mut handles = Vec::new();
        for svc in &self.services {
            for w in 0..svc.workers {
                let svc = Arc::clone(svc);
                let h = std::thread::Builder::new()
                    .name(format!("serve-{}-{w}", svc.name))
                    .spawn(move || worker_loop(svc, w))
                    .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?;
                handles.push(h);
            }
        }
        Ok(Server { services: self.services, handles, started: Instant::now() })
    }
}

/// A live multi-model inference server. Submit with [`Server::submit`];
/// stop with [`Server::shutdown`] (graceful: closes admission, drains
/// accepted work, joins every worker). Dropping without `shutdown`
/// closes admission too, so workers always terminate.
pub struct Server {
    services: Vec<Arc<ServiceInner>>,
    handles: Vec<JoinHandle<()>>,
    started: Instant,
}

impl Server {
    fn service(&self, model: &str) -> Result<&Arc<ServiceInner>> {
        self.services
            .iter()
            .find(|s| s.name == model)
            .ok_or_else(|| Rejected::UnknownModel(model.to_string()).into())
    }

    /// Resident model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.services.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn model(&self, name: &str) -> Option<ModelHandle> {
        self.services
            .iter()
            .find(|s| s.name == name)
            .map(|s| ModelHandle { inner: Arc::clone(s) })
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Submit one image under the model's default deadline. Never
    /// blocks: overload sheds with `Error::Rejected(QueueFull)`.
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Result<Ticket> {
        let svc = self.service(model)?;
        self.submit_to(svc, input, svc.deadline)
    }

    /// Submit with an explicit per-request deadline (None = none),
    /// overriding the config default.
    pub fn submit_with_deadline(
        &self,
        model: &str,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Ticket> {
        let svc = self.service(model)?;
        self.submit_to(svc, input, deadline)
    }

    /// Closed-loop convenience for drivers that want every request
    /// admitted: yield-retry while the queue sheds. Still fails fast on
    /// shutdown / unknown model / bad input.
    pub fn submit_blocking(&self, model: &str, input: Vec<f32>) -> Result<Ticket> {
        loop {
            match self.submit(model, input.clone()) {
                Err(Error::Rejected(Rejected::QueueFull { .. })) => std::thread::yield_now(),
                other => return other,
            }
        }
    }

    fn submit_to(
        &self,
        svc: &Arc<ServiceInner>,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Ticket> {
        if input.len() != svc.image_in {
            return Err(Error::Shape(format!(
                "model '{}' wants {} floats per image, got {}",
                svc.name,
                svc.image_in,
                input.len()
            )));
        }
        let (reply, rx) = sync_channel(1);
        let now = Instant::now();
        let req = Req {
            input,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            reply,
        };
        svc.stats_lock().submitted += 1;
        match svc.queue.try_push(req) {
            Ok(()) => Ok(Ticket { rx }),
            Err((_req, why)) => {
                if matches!(why, Rejected::QueueFull { .. }) {
                    svc.stats_lock().shed_queue_full += 1;
                }
                Err(why.into())
            }
        }
    }

    /// Snapshot one model's telemetry.
    pub fn stats(&self, model: &str) -> Option<ServeMetrics> {
        self.model(model).map(|h| h.stats())
    }

    /// Prometheus text exposition (format 0.0.4) over every resident
    /// model: request counters, latency summaries, the in-flight gauge
    /// and — when tracing is enabled — per-kind span aggregates. Each
    /// model's sample set comes from one lock acquisition. Written to a
    /// file by `serve --metrics-out`; no network involved.
    pub fn prometheus(&self) -> String {
        let models: Vec<ModelExposition> = self
            .services
            .iter()
            .map(|svc| {
                let metrics = svc.stats_lock().clone();
                let tr = svc.trace_lock();
                let trace =
                    if tr.is_empty() { None } else { Some(TraceAgg::from_spans(tr.iter())) };
                ModelExposition { model: svc.name.clone(), metrics, trace }
            })
            .collect();
        trace::prom::exposition(&models)
    }

    /// Export every model's recorded spans as Chrome-trace events:
    /// one process row per model (`pid` = registration index), span
    /// names resolved through the model's runner. Non-destructive.
    pub fn trace_events(&self) -> Vec<ChromeEvent> {
        let mut events = Vec::new();
        for (pid, svc) in self.services.iter().enumerate() {
            let spans = svc.trace_lock().to_vec();
            for s in &spans {
                events.push(trace::chrome::event(s, svc.runner.span_name(s), pid as u64));
            }
        }
        events
    }

    /// Render the per-model telemetry table (the `--stats` report and
    /// the final summary).
    pub fn report(&self) -> String {
        let secs = self.uptime().as_secs_f64();
        let ms = |s: f64| format!("{:.2}", s * 1e3);
        let mut t = Table::new(&[
            "model", "dtype", "queue", "offered", "done", "shed", "miss", "req/s",
            "wait p50 ms", "exec p50 ms", "e2e p50 ms", "e2e p99 ms",
        ]);
        for svc in &self.services {
            let st = svc.stats_lock().clone();
            t.row(vec![
                svc.name.clone(),
                svc.dtype.to_string(),
                format!("{}/{}", svc.queue.len(), svc.queue.depth()),
                st.submitted.to_string(),
                st.completed.to_string(),
                st.shed_queue_full.to_string(),
                st.deadline_missed.to_string(),
                format!("{:.1}", st.throughput(secs)),
                ms(st.queue_wait.p50()),
                ms(st.execute.p50()),
                ms(st.e2e.p50()),
                ms(st.e2e.p99()),
            ]);
        }
        t.to_markdown()
    }

    /// Graceful shutdown: close every admission queue (new submits get
    /// [`Rejected::ShuttingDown`]), let the workers drain everything
    /// already accepted, and join them.
    pub fn shutdown(mut self) -> Result<()> {
        for svc in &self.services {
            svc.queue.close();
        }
        let mut panicked = 0;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            return Err(Error::Runtime(format!("{panicked} serving worker(s) panicked")));
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Wake blocked workers so their threads terminate even when the
        // caller skipped shutdown(); handles detach, work drains.
        for svc in &self.services {
            svc.queue.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::haswell;
    use crate::nets::builder::resnet_micro;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            queue_depth: 32,
            batch_wait: Duration::from_millis(1),
            workers: 1,
            batch_sizes: vec![1, 2, 4],
            ..Default::default()
        }
    }

    #[test]
    fn spec_hash_distinguishes_dtype_and_spec() {
        let m = resnet_micro();
        let a = spec_hash(&m, DType::F32);
        let b = spec_hash(&m, DType::I8);
        assert_ne!(a, b, "dtype must be part of the cache key");
        assert_eq!(a, spec_hash(&m, DType::F32), "hash is deterministic");
        let other = crate::nets::builder::alexnet();
        assert_ne!(a, spec_hash(&other, DType::F32));
    }

    #[test]
    fn builder_rejects_duplicates_and_empty_servers() {
        let m = resnet_micro();
        let mut b = ServerBuilder::new(&haswell(), tiny_cfg()).backend("direct");
        b.add_model("rm", &m).unwrap();
        assert!(b.add_model("rm", &m).is_err(), "duplicate served name");
        let empty = ServerBuilder::new(&haswell(), tiny_cfg());
        assert!(empty.start().is_err());
    }

    #[test]
    fn plan_cache_shares_identical_specs() {
        let m = resnet_micro();
        let mut b = ServerBuilder::new(&haswell(), tiny_cfg()).backend("direct");
        b.add_model("a", &m).unwrap();
        b.add_model("b", &m).unwrap();
        assert_eq!(b.cached_plans(), 1, "identical specs compile once");
        let srv = b.start().unwrap();
        let (ha, hb) = (srv.model("a").unwrap(), srv.model("b").unwrap());
        assert!(ha.shares_plans_with(&hb));
        assert_eq!(ha.spec_hash(), hb.spec_hash());
        srv.shutdown().unwrap();
    }

    #[test]
    fn serves_and_reports() {
        let m = resnet_micro();
        let mut b = ServerBuilder::new(&haswell(), tiny_cfg()).backend("direct");
        b.add_model("rm", &m).unwrap();
        let srv = b.start().unwrap();
        let h = srv.model("rm").unwrap();
        let img = crate::tensor::Tensor::random(&[h.image_in()], 5).into_vec();
        let out = srv.submit("rm", img).unwrap().wait().unwrap();
        assert_eq!(out.len(), h.image_out());
        assert!(srv.submit("nope", vec![0.0; 4]).is_err());
        assert!(srv.submit("rm", vec![0.0; 4]).is_err(), "bad input length");
        let report = srv.report();
        assert!(report.contains("rm"), "report lists the model: {report}");
        let st = srv.stats("rm").unwrap();
        assert_eq!(st.completed, 1);
        srv.shutdown().unwrap();
    }
}

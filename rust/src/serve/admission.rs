//! Bounded admission queue with explicit shedding and graceful close.
//!
//! The contract the serving path needs and `std::sync::mpsc` doesn't
//! quite give:
//!
//! * **producers never block** — [`AdmissionQueue::try_push`] either
//!   admits or returns the item back with a typed [`Rejected`] reason
//!   (`QueueFull` under overload, `ShuttingDown` after close), so
//!   overload is shed at the edge instead of propagating backpressure
//!   into the caller's thread;
//! * **consumers drain on close** — [`AdmissionQueue::close`] stops
//!   admission but [`AdmissionQueue::pop_blocking`] keeps returning
//!   already-accepted items until the queue is empty, which is exactly
//!   the graceful-drain semantic shutdown wants (`recv` on a dropped
//!   mpsc channel loses nothing either, but mpsc cannot shed without
//!   consuming the slot bound, nor share one queue across N workers);
//! * **many consumers** — workers pull batches concurrently from one
//!   queue (mpsc receivers cannot be shared).
//!
//! Plain `Mutex<VecDeque> + Condvar`; the lock is held only for O(1)
//! push/pop, never across execution.

use super::Rejected;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
}

/// Bounded multi-producer multi-consumer queue. Clone freely: clones
/// share the queue.
pub struct AdmissionQueue<T> {
    shared: Arc<Shared<T>>,
    depth: usize,
}

impl<T> Clone for AdmissionQueue<T> {
    fn clone(&self) -> Self {
        AdmissionQueue { shared: Arc::clone(&self.shared), depth: self.depth }
    }
}

impl<T> AdmissionQueue<T> {
    /// A queue admitting at most `depth` queued items (min 1).
    pub fn bounded(depth: usize) -> AdmissionQueue<T> {
        let depth = depth.max(1);
        AdmissionQueue {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    items: VecDeque::with_capacity(depth),
                    closed: false,
                }),
                not_empty: Condvar::new(),
            }),
            depth,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State<T>> {
        // A worker panicking while holding this O(1) lock leaves the
        // queue structurally intact; serving degraded beats deadlock.
        self.shared.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Admit `item`, or hand it back with the shedding reason. Never
    /// blocks.
    pub fn try_push(&self, item: T) -> Result<(), (T, Rejected)> {
        let mut st = self.lock();
        if st.closed {
            return Err((item, Rejected::ShuttingDown));
        }
        if st.items.len() >= self.depth {
            return Err((item, Rejected::QueueFull { depth: self.depth }));
        }
        st.items.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Take the oldest item without waiting.
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Block until an item is available or the queue is closed *and*
    /// drained (`None` — the consumer should exit).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Block until an item is available, the queue closes empty, or
    /// `deadline` passes — the batch-window accumulate step.
    pub fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) = self
                .shared
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
            if timeout.timed_out() {
                return st.items.pop_front();
            }
        }
    }

    /// Stop admission (producers get [`Rejected::ShuttingDown`]) and
    /// wake every blocked consumer so it can drain and exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.shared.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Currently queued items (the `--stats` queue-depth gauge).
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn sheds_when_full_and_hands_the_item_back() {
        let q = AdmissionQueue::bounded(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        let (item, why) = q.try_push(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, Rejected::QueueFull { depth: 2 });
        assert_eq!(q.len(), 2);
        // Freeing a slot re-admits.
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = AdmissionQueue::bounded(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        let (item, why) = q.try_push(9).unwrap_err();
        assert_eq!((item, why), (9, Rejected::ShuttingDown));
        // Already-admitted items still drain...
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        // ...then consumers are told to exit instead of blocking forever.
        assert_eq!(q.pop_blocking(), None);
        assert_eq!(q.pop_deadline(Instant::now() + Duration::from_millis(5)), None);
    }

    #[test]
    fn pop_deadline_times_out_without_items() {
        let q: AdmissionQueue<u32> = AdmissionQueue::bounded(4);
        let t0 = Instant::now();
        assert_eq!(q.pop_deadline(t0 + Duration::from_millis(10)), None);
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn cross_thread_handoff_and_close_wakeup() {
        let q: AdmissionQueue<u32> = AdmissionQueue::bounded(8);
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop_blocking() {
                    got.push(v);
                }
                got
            })
        };
        for v in 0..5 {
            while q.try_push(v).is_err() {
                std::thread::yield_now();
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn depth_floor_is_one() {
        let q: AdmissionQueue<u8> = AdmissionQueue::bounded(0);
        assert_eq!(q.depth(), 1);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_err());
        assert!(!q.is_empty());
    }
}

//! Seeded heavy-tail traffic generator for the serving engine.
//!
//! Replays [`crate::sim::arrivals`] schedules against a live
//! [`Server`] in open loop: requests are submitted at their scheduled
//! offsets whether or not earlier ones finished, which is what exposes
//! queue growth, shedding and deadline misses under burst. Everything is
//! derived from the per-load seed, so a run is bit-reproducible down to
//! the arrival schedule ([`ModelLoadResult::fingerprint`] proves two
//! runs replayed the same schedule) and the whole report serializes to a
//! JSON artifact for the benches and CI.
//!
//! Client-side the generator only counts outcomes and wall time; the
//! latency story (queue wait vs execute, p50/p95/p99) comes from the
//! server's own [`ServeMetrics`], snapshotted into each result.

use super::engine::Server;
use super::Rejected;
use crate::json::Json;
use crate::metrics::{ServeMetrics, Table};
use crate::sim::{arrival_offsets, schedule_fingerprint, ArrivalPattern};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One stream of traffic aimed at one served model.
#[derive(Clone, Debug)]
pub struct ModelLoad {
    /// Served model name ([`Server::models`]).
    pub model: String,
    pub pattern: ArrivalPattern,
    /// Mean offered rate, requests/second.
    pub rate: f64,
    /// Requests in the schedule.
    pub requests: usize,
    /// Seeds both the arrival schedule and the input image.
    pub seed: u64,
    /// Per-request deadline override (None = server default).
    pub deadline: Option<Duration>,
}

impl ModelLoad {
    pub fn new(model: &str, pattern: ArrivalPattern, rate: f64, requests: usize) -> ModelLoad {
        ModelLoad {
            model: model.to_string(),
            pattern,
            rate,
            requests,
            seed: 0xC0FFEE,
            deadline: None,
        }
    }

    pub fn seed(mut self, seed: u64) -> ModelLoad {
        self.seed = seed;
        self
    }

    pub fn deadline(mut self, d: Duration) -> ModelLoad {
        self.deadline = Some(d);
        self
    }
}

/// A full load-generation run: several streams replayed concurrently
/// (one driver thread each), e.g. an f32 and an i8 model under the same
/// offered load.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub loads: Vec<ModelLoad>,
    /// Watchdog bound on any single reply wait; a wedged server turns
    /// into a counted failure instead of a hung generator.
    pub reply_timeout: Duration,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec { loads: Vec::new(), reply_timeout: Duration::from_secs(30) }
    }
}

impl LoadSpec {
    pub fn one(load: ModelLoad) -> LoadSpec {
        LoadSpec { loads: vec![load], ..Default::default() }
    }

    pub fn push(mut self, load: ModelLoad) -> LoadSpec {
        self.loads.push(load);
        self
    }
}

/// Outcome of one [`ModelLoad`] stream.
#[derive(Clone, Debug)]
pub struct ModelLoadResult {
    pub model: String,
    pub pattern: ArrivalPattern,
    pub rate: f64,
    pub requests: usize,
    pub seed: u64,
    /// FNV-1a over the replayed arrival schedule — equal across runs
    /// with the same (pattern, rate, requests, seed).
    pub fingerprint: u64,
    /// Admitted into the model's queue.
    pub accepted: u64,
    /// Shed at admission with [`Rejected::QueueFull`].
    pub shed: u64,
    /// Rejected for any other reason (shutdown, unknown model, shape).
    pub rejected_other: u64,
    /// Replies that arrived with logits.
    pub completed: u64,
    /// Replies that arrived as [`Rejected::DeadlineExceeded`].
    pub deadline_missed: u64,
    /// Execution failures plus reply-timeout watchdog hits.
    pub failed: u64,
    /// Submit of the first request to last reply, seconds.
    pub wall_secs: f64,
    /// The served model's telemetry, snapshotted when this stream's
    /// replies finished (streams sharing a model share these numbers).
    pub server: ServeMetrics,
}

impl ModelLoadResult {
    /// Completed requests per second of stream wall time.
    pub fn throughput(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_secs
        }
    }

    fn to_json(&self) -> Json {
        let ms = |s: f64| s * 1e3;
        let mut o = BTreeMap::new();
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("pattern".into(), Json::Str(self.pattern.name().into()));
        o.insert("rate_rps".into(), Json::Num(self.rate));
        o.insert("requests".into(), Json::Num(self.requests as f64));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("fingerprint".into(), Json::Str(format!("{:016x}", self.fingerprint)));
        o.insert("accepted".into(), Json::Num(self.accepted as f64));
        o.insert("shed".into(), Json::Num(self.shed as f64));
        o.insert("rejected_other".into(), Json::Num(self.rejected_other as f64));
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert("deadline_missed".into(), Json::Num(self.deadline_missed as f64));
        o.insert("failed".into(), Json::Num(self.failed as f64));
        o.insert("wall_secs".into(), Json::Num(self.wall_secs));
        o.insert("throughput_rps".into(), Json::Num(self.throughput()));
        let mut srv = BTreeMap::new();
        srv.insert("queue_wait_p50_ms".into(), Json::Num(ms(self.server.queue_wait.p50())));
        srv.insert("queue_wait_p99_ms".into(), Json::Num(ms(self.server.queue_wait.p99())));
        srv.insert("execute_p50_ms".into(), Json::Num(ms(self.server.execute.p50())));
        srv.insert("e2e_p50_ms".into(), Json::Num(ms(self.server.e2e.p50())));
        srv.insert("e2e_p95_ms".into(), Json::Num(ms(self.server.e2e.p95())));
        srv.insert("e2e_p99_ms".into(), Json::Num(ms(self.server.e2e.p99())));
        srv.insert("mean_batch".into(), Json::Num(self.server.mean_batch_size()));
        srv.insert("batches".into(), Json::Num(self.server.batches as f64));
        o.insert("server".into(), Json::Obj(srv));
        Json::Obj(o)
    }
}

/// Results of a [`run`]: one entry per load stream plus the run's wall
/// time. Serializes to the JSON artifact the benches and CI consume.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub results: Vec<ModelLoadResult>,
    pub wall_secs: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kind".into(), Json::Str("loadgen".into()));
        o.insert("wall_secs".into(), Json::Num(self.wall_secs));
        o.insert(
            "results".into(),
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        Json::Obj(o)
    }

    /// Write the JSON artifact (pretty-printed, trailing newline).
    pub fn write_artifact(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| Error::Runtime(format!("create {}: {e}", dir.display())))?;
            }
        }
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text).map_err(|e| Error::Runtime(format!("write {path}: {e}")))
    }

    /// Markdown summary table (one row per stream).
    pub fn summary(&self) -> String {
        let mut t = Table::new(&[
            "model", "pattern", "rate", "offered", "done", "shed", "miss", "fail", "req/s",
            "e2e p50 ms", "e2e p99 ms",
        ]);
        for r in &self.results {
            t.row(vec![
                r.model.clone(),
                r.pattern.name().into(),
                format!("{:.0}", r.rate),
                r.requests.to_string(),
                r.completed.to_string(),
                r.shed.to_string(),
                r.deadline_missed.to_string(),
                (r.rejected_other + r.failed).to_string(),
                format!("{:.1}", r.throughput()),
                format!("{:.2}", r.server.e2e.p50() * 1e3),
                format!("{:.2}", r.server.e2e.p99() * 1e3),
            ]);
        }
        t.to_markdown()
    }

    /// Total requests completed across every stream.
    pub fn total_completed(&self) -> u64 {
        self.results.iter().map(|r| r.completed).sum()
    }
}

/// Replay one stream against the server (open loop, real-time pacing).
fn drive(server: &Server, load: &ModelLoad, reply_timeout: Duration) -> Result<ModelLoadResult> {
    let handle = server
        .model(&load.model)
        .ok_or_else(|| Error::from(Rejected::UnknownModel(load.model.clone())))?;
    let offsets = arrival_offsets(load.pattern, load.rate, load.requests, load.seed);
    let fingerprint = schedule_fingerprint(&offsets);
    let input = Tensor::random(&[handle.image_in()], load.seed ^ 0x1A6E).into_vec();

    let mut res = ModelLoadResult {
        model: load.model.clone(),
        pattern: load.pattern,
        rate: load.rate,
        requests: load.requests,
        seed: load.seed,
        fingerprint,
        accepted: 0,
        shed: 0,
        rejected_other: 0,
        completed: 0,
        deadline_missed: 0,
        failed: 0,
        wall_secs: 0.0,
        server: ServeMetrics::default(),
    };

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(load.requests);
    for &off in &offsets {
        let target = Duration::from_secs_f64(off);
        let elapsed = t0.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        match server.submit_with_deadline(&load.model, input.clone(), load.deadline) {
            Ok(t) => {
                res.accepted += 1;
                tickets.push(t);
            }
            Err(Error::Rejected(Rejected::QueueFull { .. })) => res.shed += 1,
            Err(_) => res.rejected_other += 1,
        }
    }
    for t in tickets {
        match t.wait_timeout(reply_timeout) {
            Ok(_) => res.completed += 1,
            Err(Error::Rejected(Rejected::DeadlineExceeded)) => res.deadline_missed += 1,
            Err(_) => res.failed += 1,
        }
    }
    res.wall_secs = t0.elapsed().as_secs_f64();
    res.server = handle.stats();
    Ok(res)
}

/// Run every stream in `spec` concurrently (one driver thread each)
/// against `server`. Returns per-stream results in spec order.
pub fn run(server: &Server, spec: &LoadSpec) -> Result<LoadReport> {
    if spec.loads.is_empty() {
        return Err(Error::Runtime("load spec has no streams".into()));
    }
    let t0 = Instant::now();
    let results: Vec<Result<ModelLoadResult>> = std::thread::scope(|s| {
        let handles: Vec<_> = spec
            .loads
            .iter()
            .map(|load| s.spawn(move || drive(server, load, spec.reply_timeout)))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Runtime("load driver panicked".into())))
            })
            .collect()
    });
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r?);
    }
    Ok(LoadReport { results: out, wall_secs: t0.elapsed().as_secs_f64() })
}

/// The CI smoke run: a small f32 and i8 model behind one server, two
/// seeded bursty streams, bounded by `reply_timeout` watchdogs so a
/// deadlock turns into an error instead of a hang. Errors if either
/// stream completes zero requests.
pub fn smoke() -> Result<LoadReport> {
    use super::engine::{ServeConfig, ServerBuilder};
    use crate::nets::builder::resnet_micro;
    use crate::quant::DType;

    let machine = crate::arch::host();
    let cfg = ServeConfig {
        queue_depth: 64,
        batch_wait: Duration::from_millis(1),
        workers: 1,
        batch_sizes: vec![1, 2, 4],
        ..Default::default()
    };
    let f32_model = resnet_micro();
    let mut i8_model = resnet_micro();
    i8_model.dtype = DType::I8;

    let mut b = ServerBuilder::new(&machine, cfg).backend("direct");
    b.add_model("rm_f32", &f32_model)?;
    b.add_model("rm_i8", &i8_model)?;
    let server = b.start()?;

    let spec = LoadSpec::default()
        .push(ModelLoad::new("rm_f32", ArrivalPattern::Burst, 400.0, 40).seed(11))
        .push(ModelLoad::new("rm_i8", ArrivalPattern::Poisson, 400.0, 40).seed(12));
    let report = run(&server, &spec)?;
    server.shutdown()?;
    for r in &report.results {
        if r.completed == 0 {
            return Err(Error::Runtime(format!(
                "smoke: stream '{}' completed zero requests",
                r.model
            )));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{ServeConfig, ServerBuilder};

    fn tiny_server(queue_depth: usize) -> Server {
        let cfg = ServeConfig {
            queue_depth,
            batch_wait: Duration::from_millis(1),
            workers: 1,
            batch_sizes: vec![1, 2, 4],
            ..Default::default()
        };
        let model = crate::nets::builder::resnet_micro();
        let mut b = ServerBuilder::new(&crate::arch::haswell(), cfg).backend("direct");
        b.add_model("rm", &model).unwrap();
        b.start().unwrap()
    }

    #[test]
    fn loadgen_counts_balance_and_fingerprint_is_reproducible() {
        let server = tiny_server(32);
        let load = ModelLoad::new("rm", ArrivalPattern::Poisson, 500.0, 20).seed(7);
        let spec = LoadSpec::one(load.clone());
        let report = run(&server, &spec).unwrap();
        let r = &report.results[0];
        assert_eq!(r.accepted + r.shed + r.rejected_other, 20);
        assert_eq!(r.completed + r.deadline_missed + r.failed, r.accepted);
        assert!(r.completed > 0, "some requests must complete");
        let again = run(&server, &LoadSpec::one(load)).unwrap();
        assert_eq!(r.fingerprint, again.results[0].fingerprint);
        server.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_is_an_error_not_a_hang() {
        let server = tiny_server(8);
        let spec = LoadSpec::one(ModelLoad::new("nope", ArrivalPattern::Poisson, 100.0, 4));
        assert!(run(&server, &spec).is_err());
        server.shutdown().unwrap();
    }

    #[test]
    fn report_serializes_to_json_artifact_shape() {
        let server = tiny_server(16);
        let spec = LoadSpec::one(ModelLoad::new("rm", ArrivalPattern::Pareto, 800.0, 8).seed(3));
        let report = run(&server, &spec).unwrap();
        server.shutdown().unwrap();
        let text = report.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("model").and_then(|m| m.as_str()), Some("rm"));
        assert_eq!(
            arr[0].get("fingerprint").and_then(|f| f.as_str()).map(str::len),
            Some(16),
            "fingerprint is a 16-hex-digit string"
        );
        assert!(report.summary().contains("rm"));
    }
}

//! Production serving subsystem — the network-serving path that
//! replaces the bare [`crate::coordinator`] loop for whole-model
//! traffic.
//!
//! The paper's system-level claim (§5) is that direct convolution
//! *scales*: zero memory overhead per request means adding threads adds
//! throughput without allocator contention or working-set growth. This
//! module makes that a measured, load-tested property instead of a
//! slogan. Four pieces:
//!
//! * **Admission control** ([`admission`]) — every model sits behind a
//!   bounded queue. A full queue rejects immediately with a typed
//!   reason ([`Rejected::QueueFull`]) instead of blocking the producer;
//!   per-request deadlines drop stale work *before* execution
//!   ([`Rejected::DeadlineExceeded`]); shutdown closes admission
//!   ([`Rejected::ShuttingDown`]) and then drains everything already
//!   accepted.
//! * **Continuous batching** ([`engine`]) — workers pull from the
//!   queue continuously: the first request opens a batch window, the
//!   worker accumulates compatible arrivals until the window's deadline
//!   or the largest batch size, then covers the backlog with the DP
//!   [`crate::coordinator::Batcher::split`]. While one worker executes,
//!   the next is already accumulating — batching happens *across*
//!   arrivals, not within whatever one drain happened to find.
//! * **Multi-model engine** ([`engine::Server`]) — several JSON model
//!   specs (f32 and i8) resident behind one server. Each spec is
//!   compiled once — [`crate::engine::NetRunner`] plan + per-worker
//!   arena pool — and cached by spec hash, so two served names with the
//!   same spec share one compiled plan. Workers are allocated per
//!   model; the forward hot path stays allocation-free
//!   (`overhead_bytes() == 0` for direct plans), with allocations
//!   confined to the admission/queueing edges (request and reply
//!   buffers are messages, not conv state).
//! * **Telemetry** ([`crate::metrics::ServeMetrics`]) — per-model
//!   p50/p95/p99 latency split into queue wait vs execute, throughput,
//!   queue depth, shed and deadline-miss counters; periodic
//!   `serve --stats` reports and a final summary.
//!
//! [`loadgen`] closes the loop: it replays seeded heavy-tail arrival
//! schedules ([`crate::sim::arrivals`]) against the server and emits a
//! JSON results artifact, so throughput-vs-offered-load and
//! latency-under-burst curves are reproducible benchmarks.

pub mod admission;
pub mod engine;
pub mod loadgen;

pub use admission::AdmissionQueue;
pub use engine::{
    spec_hash, ModelHandle, Server, ServerBuilder, ServeConfig, Ticket, WorkerState,
};
pub use loadgen::{LoadReport, LoadSpec, ModelLoad, ModelLoadResult};

/// Why a request was not served. The typed vocabulary shared by the new
/// serving path and the legacy [`crate::coordinator`] (whose `submit`
/// sheds with [`Rejected::QueueFull`] too), so overload looks the same
/// to every caller. Carried by [`crate::Error::Rejected`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The model's bounded admission queue was full; the request was
    /// shed immediately rather than blocking the producer.
    QueueFull {
        /// The configured queue depth that was exhausted.
        depth: usize,
    },
    /// The request's deadline expired while it waited in the queue; it
    /// was dropped before execution.
    DeadlineExceeded,
    /// The server (or coordinator) is shutting down and no longer
    /// admits new work. Already-accepted requests still drain.
    ShuttingDown,
    /// No model with this name is resident behind the server.
    UnknownModel(String),
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { depth } => {
                write!(f, "queue full (depth {depth}, request shed)")
            }
            Rejected::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            Rejected::ShuttingDown => write!(f, "server shutting down"),
            Rejected::UnknownModel(m) => write!(f, "unknown model '{m}'"),
        }
    }
}

impl From<Rejected> for crate::Error {
    fn from(r: Rejected) -> crate::Error {
        crate::Error::Rejected(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejection_reasons_render() {
        assert!(format!("{}", Rejected::QueueFull { depth: 8 }).contains("depth 8"));
        assert!(format!("{}", Rejected::DeadlineExceeded).contains("deadline"));
        assert!(format!("{}", Rejected::ShuttingDown).contains("shutting down"));
        assert!(format!("{}", Rejected::UnknownModel("x".into())).contains("'x'"));
        let e: crate::Error = Rejected::ShuttingDown.into();
        assert!(matches!(e, crate::Error::Rejected(Rejected::ShuttingDown)));
    }
}

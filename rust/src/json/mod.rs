//! Minimal JSON parser/serializer (`serde`/`serde_json` are not in the
//! offline registry). Supports the full JSON data model; used for the
//! artifact manifest written by `python/compile/aot.py` and for bench
//! result files.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Parse(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Parse(format!("unexpected {:?} at byte {}", other, self.i))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Parse("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::Parse("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Parse(format!("bad escape {:?}", other)));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| Error::Parse("invalid utf-8".into()))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let numeric = |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if numeric(c)) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(Error::Parse(format!("bad array sep {:?}", other))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(Error::Parse(format!("bad object sep {:?}", other))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"layers": [{"name": "conv1", "flops": 210830400}], "ok": true}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_serialize_without_point() {
        let j = Json::Num(42.0);
        assert_eq!(j.to_string_pretty(), "42");
    }
}

//! Criterion-lite benchmark harness (the `criterion` crate is not in the
//! offline registry). Provides warmup, adaptive iteration counts, robust
//! statistics (median / MAD) and result persistence to `bench_results/`.
//!
//! Every `[[bench]]` target with `harness = false` builds its figures on
//! this module so `cargo bench` regenerates the paper's tables uniformly.

use crate::json::Json;
use crate::metrics::Table;
use std::time::Instant;

/// A single benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median_secs: f64,
    /// Median absolute deviation (robust spread).
    pub mad_secs: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn gflops(&self, flops: u64) -> f64 {
        crate::metrics::gflops(flops, self.median_secs)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Target wall time spent measuring each benchmark (seconds).
    pub target_secs: f64,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations.
    pub max_iters: usize,
    /// Warmup iterations before timing.
    pub warmup_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { target_secs: 1.0, min_iters: 5, max_iters: 200, warmup_iters: 2 }
    }
}

/// Fast options for CI-style smoke runs (`DCONV_BENCH_FAST=1`).
pub fn opts_from_env() -> BenchOpts {
    if std::env::var("DCONV_BENCH_FAST").is_ok() {
        BenchOpts { target_secs: 0.1, min_iters: 2, max_iters: 10, warmup_iters: 1 }
    } else {
        BenchOpts::default()
    }
}

/// Time `f` adaptively and return robust statistics.
pub fn bench(name: &str, opts: BenchOpts, mut f: impl FnMut()) -> Measurement {
    for _ in 0..opts.warmup_iters {
        f();
    }
    // Estimate a single-iteration cost.
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((opts.target_secs / est) as usize).clamp(opts.min_iters, opts.max_iters);
    let mut samples = Vec::with_capacity(iters + 1);
    samples.push(est);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Measurement { name: name.to_string(), median_secs: median, mad_secs: mad, iters: samples.len() }
}

/// Persist a finished table under `bench_results/<bench>.{md,csv}`
/// plus a machine-diffable `BENCH_<bench>.json` baseline (tagged with
/// the kernel-dispatch decision: the human-readable `dispatch` line
/// plus structured `simd_level`/`lanes` fields, so a scalar-pinned run
/// and a SIMD run of the same bench are distinguishable — and
/// mechanically attributable — artifacts), and echo the markdown to
/// stdout (what EXPERIMENTS.md records).
pub fn emit(bench_name: &str, title: &str, table: &Table) {
    emit_with_roofline(bench_name, title, table, None)
}

/// [`emit`] plus an optional per-layer roofline breakdown (the
/// [`crate::trace::roofline::RooflineReport::to_json`] document) stored
/// under a `"roofline"` key in the `BENCH_*.json` artifact, next to
/// `simd_level`/`lanes` — so a baseline diff sees *why* a layer
/// regressed (achieved vs ceiling, compute- vs memory-bound), not just
/// that it did.
pub fn emit_with_roofline(
    bench_name: &str,
    title: &str,
    table: &Table,
    roofline: Option<&Json>,
) {
    println!("\n## {title}\n");
    print!("{}", table.to_markdown());
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{bench_name}.md")), table.to_markdown());
        let _ = std::fs::write(dir.join(format!("{bench_name}.csv")), table.to_csv());
        let level = crate::conv::dispatch::active();
        let roofline_line = match roofline {
            Some(r) => format!("\"roofline\": {},\n", r.to_string_pretty()),
            None => String::new(),
        };
        let json = format!(
            "{{\n\"bench\": \"{bench_name}\",\n\"dispatch\": \"{}\",\n\
             \"simd_level\": \"{}\",\n\"lanes\": {},\n{roofline_line}\"rows\": {}}}\n",
            crate::conv::dispatch::describe(),
            level.name(),
            level.lanes(),
            table.to_json(),
        );
        let _ = std::fs::write(dir.join(format!("BENCH_{bench_name}.json")), json);
    }
}

/// A black-box sink preventing the optimizer from deleting benchmarked
/// work (stable-friendly `std::hint::black_box` wrapper).
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_stats() {
        let opts = BenchOpts { target_secs: 0.01, min_iters: 3, max_iters: 10, warmup_iters: 1 };
        let mut acc = 0u64;
        let m = bench("spin", opts, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(sink(i));
            }
        });
        assert!(m.median_secs > 0.0);
        assert!(m.iters >= 3);
        assert!(m.mad_secs >= 0.0);
    }

    #[test]
    fn gflops_from_measurement() {
        let m = Measurement { name: "x".into(), median_secs: 0.5, mad_secs: 0.0, iters: 1 };
        assert!((m.gflops(1_000_000_000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fast_opts_env() {
        // Just exercise both branches (env may or may not be set).
        let o = opts_from_env();
        assert!(o.min_iters >= 1);
    }
}
